# GRACE-MoE build entry points.
#
#   make build      — release build of the whole workspace
#   make test       — tier-1 verify (build + full test suite)
#   make artifacts  — AOT-lower the tiny JAX/Pallas models to HLO text
#                     (writes rust/artifacts/; needed only for execute
#                     mode — simulate mode and tier-1 tests run without it)
#   make bench-smoke— compile every paper-figure bench without running it
#   make bench-record — run the serving + cluster_sim + fleet_sharding
#                     + prefetch benches with the JSON emitter on,
#                     archiving BENCH_serving.json,
#                     BENCH_cluster_sim.json, BENCH_fleet_sharding.json,
#                     and BENCH_prefetch.json in the repo root
#   make lint       — rustfmt + clippy, as CI runs them
#   make docs       — rustdoc with warnings-as-errors (missing_docs,
#                     broken intra-doc links) + check that every public
#                     module is covered by docs/ARCHITECTURE.md
#   make pytest     — python test suite (loudly skips without jax)
#   make clean      — remove build products and artifacts

PYTHON       ?= python3
ARTIFACTS    ?= rust/artifacts

.PHONY: all build test artifacts bench-smoke bench-record lint docs \
        pytest clean

all: build

build:
	cargo build --release

test:
	cargo build --release
	cargo test -q

# The AOT → PJRT handshake: python/compile/aot.py lowers every L2
# computation to HLO text + a weight blob + manifest.json, which the rust
# engine (rust/src/runtime/) consumes. Incremental: a fingerprint of the
# python sources makes this a no-op when nothing changed.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../$(ARTIFACTS)

bench-smoke:
	cargo bench --no-run

# Machine-readable bench archive: the serving-path benches run with the
# JSON emitter enabled (see grace_moe::bench::JsonRecorder), writing
# BENCH_<name>.json next to this Makefile. Each bench self-checks its
# acceptance claim before recording, so a stale archive cannot pass.
bench-record:
	BENCH_JSON=$(CURDIR) cargo bench --bench serving
	BENCH_JSON=$(CURDIR) cargo bench --bench cluster_sim
	BENCH_JSON=$(CURDIR) cargo bench --bench fleet_sharding
	BENCH_JSON=$(CURDIR) cargo bench --bench prefetch

lint:
	cargo fmt --all --check
	cargo clippy --workspace --all-targets -- -D warnings

# Documentation gate: rustdoc must be warning-clean (lib.rs carries
# #![warn(missing_docs)] and denies broken intra-doc links), and the
# paper-to-code guide must mention every public module so it cannot rot.
docs:
	RUSTDOCFLAGS="-D warnings" cargo doc --workspace --no-deps
	@missing=0; \
	for m in $$(sed -n 's/^pub mod \([a-z_]*\);.*/\1/p' rust/src/lib.rs); do \
	  grep -q "\`$$m\`" docs/ARCHITECTURE.md || { \
	    echo "docs/ARCHITECTURE.md: missing module $$m"; missing=1; }; \
	done; \
	test $$missing -eq 0 && echo "ARCHITECTURE.md covers every pub mod"

pytest:
	$(PYTHON) -m pytest python/tests -q

clean:
	cargo clean
	rm -rf $(ARTIFACTS)
	find python -name __pycache__ -type d -exec rm -rf {} +
