"""Pytest wiring for the python/ tree.

* Puts ``python/`` on ``sys.path`` so tests import the ``compile``
  package the same way ``python -m compile.aot`` resolves it.
* Implements the loud-skip policy of the CI contract (mirroring
  ``rust/tests/end_to_end.rs``): test modules that need the JAX/Pallas
  toolchain (or hypothesis) are skipped — not failed — when those
  packages are unavailable, with an unmissable message on stderr.
"""

import importlib.util
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)


def _available(module: str) -> bool:
    try:
        return importlib.util.find_spec(module) is not None
    except (ImportError, ValueError):
        return False


#: test module -> packages it cannot run without
_REQUIREMENTS = {
    "tests/test_aot.py": ("jax", "numpy"),
    "tests/test_kernels.py": ("jax", "numpy", "hypothesis"),
    "tests/test_kv_cache.py": ("jax", "numpy"),
    "tests/test_model.py": ("jax", "numpy", "hypothesis"),
}

collect_ignore = []
_SKIP_NOTES = []
for _mod, _needs in _REQUIREMENTS.items():
    _missing = [m for m in _needs if not _available(m)]
    if _missing:
        collect_ignore.append(_mod)
        _SKIP_NOTES.append(
            f"SKIP: python/{_mod} needs {', '.join(_missing)} "
            f"(toolchain unavailable — not a failure; install jax[cpu] "
            f"and hypothesis to run it)"
        )
        # Visible when running without pytest's fd capture (e.g. -s).
        sys.stderr.write(_SKIP_NOTES[-1] + "\n")


def pytest_terminal_summary(terminalreporter):
    """Make the toolchain skips unmissable in the summary (stderr writes
    at collection time are swallowed by pytest's fd-level capture)."""
    if _SKIP_NOTES:
        terminalreporter.section("toolchain skips")
        for note in _SKIP_NOTES:
            terminalreporter.write_line(note)
