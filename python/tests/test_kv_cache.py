"""Parity tests for the incremental-attention (KV cache) ref kernels.

The headline invariant of the KV-cached decode path is that it produces
*token-for-token* identical output to the full-recompute path: layernorm
and the QKV projection are row-wise, so the K/V of position ``p`` depend
only on row ``p``'s layer input, and the causal softmax over ``0..=p``
sees exactly the same keys either way. Intermediate float rows agree up
to XLA reduction reassociation (the two paths lower differently-shaped
einsums); the greedy argmax chain — the actual output — is exact, and
these tests pin both levels (the rust side pins them again at the
serving level).

Needs only jax + numpy (no hypothesis), so it runs wherever the AOT
toolchain itself runs.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

CFG = model.ModelConfig(
    name="test_tiny", experts=8, top_k=2, layers=2, paper_layers=2,
    hidden=16, ffn=24, heads=2, vocab=64, tile_t=16, tile_m=4,
    cap_tiles=24, ctx=24)


def rand(key, shape, scale=0.3):
    return jax.random.normal(key, shape) * scale


def padded(x_valid, ctx):
    """Zero-pad a [T, H] block to [ctx, H] (the rust engine's layout)."""
    pad = jnp.zeros((ctx - x_valid.shape[0], x_valid.shape[1]))
    return jnp.concatenate([x_valid, pad], axis=0)


def test_prefill_matches_full_attention_and_caches_kv():
    c = CFG
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    valid = 7
    x = padded(rand(ks[0], (valid, c.hidden)), c.ctx)
    wqkv = rand(ks[1], (c.hidden, 3 * c.hidden))
    wo = rand(ks[2], (c.hidden, c.hidden))

    out, k_cache, v_cache = ref.attention_prefill_ref(
        x, wqkv, wo, c.heads, valid)
    want = ref.attention_ref(x, wqkv, wo, c.heads, valid)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))

    # Cached K/V rows are the row-wise projection of the *valid* inputs…
    qkv = ref.layernorm_ref(x) @ wqkv
    _, k_want, v_want = jnp.split(qkv, 3, axis=-1)
    np.testing.assert_array_equal(
        np.asarray(k_cache[:valid]), np.asarray(k_want[:valid]))
    np.testing.assert_array_equal(
        np.asarray(v_cache[:valid]), np.asarray(v_want[:valid]))
    # …and padding rows are exactly zero (nothing leaks into the cache).
    assert not np.asarray(k_cache[valid:]).any()
    assert not np.asarray(v_cache[valid:]).any()


def test_step_rows_match_full_prefix_rows():
    # Feed a sequence one token at a time through attention_step_ref; every
    # produced row must match the corresponding row of the one-shot
    # full-prefix attention_ref on the same inputs. Same dot products, but
    # XLA tiles the [1, C] and [T, C] einsum reductions differently, so
    # the comparison is up-to-reassociation (ulp-level) — the same
    # tolerance class the losslessness oracle uses. Token-level parity
    # (below) is exact.
    c = CFG
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    T = 9
    x_valid = rand(ks[0], (T, c.hidden))
    x = padded(x_valid, c.ctx)
    wqkv = rand(ks[1], (c.hidden, 3 * c.hidden))
    wo = rand(ks[2], (c.hidden, c.hidden))
    want = ref.attention_ref(x, wqkv, wo, c.heads, T)

    k_cache = jnp.zeros((c.ctx, c.hidden))
    v_cache = jnp.zeros((c.ctx, c.hidden))
    for p in range(T):
        row, k_cache, v_cache = ref.attention_step_ref(
            x[p:p + 1], k_cache, v_cache, wqkv, wo, c.heads, p)
        np.testing.assert_allclose(
            np.asarray(row[0]), np.asarray(want[p]),
            rtol=1e-5, atol=1e-6,
            err_msg=f"row {p} diverged from full-prefix attention")


def test_step_appends_exactly_one_cache_row():
    c = CFG
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    x = padded(rand(ks[0], (4, c.hidden)), c.ctx)
    wqkv = rand(ks[1], (c.hidden, 3 * c.hidden))
    wo = rand(ks[2], (c.hidden, c.hidden))
    _, k0, v0 = ref.attention_prefill_ref(x, wqkv, wo, c.heads, 3)
    _, k1, v1 = ref.attention_step_ref(
        x[3:4], k0, v0, wqkv, wo, c.heads, 3)
    # Rows < pos and rows > pos are untouched; row pos is newly written.
    np.testing.assert_array_equal(np.asarray(k1[:3]), np.asarray(k0[:3]))
    np.testing.assert_array_equal(np.asarray(v1[:3]), np.asarray(v0[:3]))
    np.testing.assert_array_equal(np.asarray(k1[4:]), np.asarray(k0[4:]))
    assert np.asarray(k1[3]).any(), "step must write cache row `pos`"


def greedy_recompute(cfg, params, prompt, n_new):
    """Oracle: greedy decode by full forward recompute every step."""
    ids = list(prompt)
    out = []
    for _ in range(n_new):
        padded_ids = jnp.array(
            ids + [0] * (cfg.ctx - len(ids)), dtype=jnp.int32)
        logits = model.forward_ref(cfg, params, padded_ids, len(ids))
        t = int(jnp.argmax(logits[len(ids) - 1]))
        out.append(t)
        ids.append(t)
    return out


def greedy_cached(cfg, params, prompt, n_new):
    """KV-cached greedy decode: prefill once, then one row per step.

    Mirrors the rust `decode_step_cached` structure: per layer, attention
    runs incrementally against the cache while the MoE layer (which has no
    cross-token state) runs on just the new rows.
    """
    c = cfg
    caches = [(jnp.zeros((c.ctx, c.hidden)), jnp.zeros((c.ctx, c.hidden)))
              for _ in range(c.layers)]
    ids = list(prompt)
    out = []

    def moe(x, l):
        (y,) = model.moe_layer_full_fn(
            c, x, params["wg"][l], params["w1"][l], params["w3"][l],
            params["w2"][l])
        return y

    # Prefill: full-prefix pass that populates every layer's cache.
    padded_ids = jnp.array(
        ids + [0] * (c.ctx - len(ids)), dtype=jnp.int32)
    (x,) = model.embed_fn(c, padded_ids, params["emb"])
    for l in range(c.layers):
        a, k, v = ref.attention_prefill_ref(
            x, params["wqkv"][l], params["wo"][l], c.heads, len(ids))
        caches[l] = (k, v)
        x = moe(a, l)
    (logits,) = model.lmhead_fn(c, x[len(ids) - 1:len(ids)], params["emb"])
    t = int(jnp.argmax(logits[0]))
    out.append(t)
    ids.append(t)

    # Decode: one token per step through attention_step + MoE on one row.
    while len(out) < n_new:
        pos = len(ids) - 1
        (row,) = model.embed_fn(
            c,
            jnp.array(ids[pos:] + [0] * (c.ctx - 1), dtype=jnp.int32),
            params["emb"])
        row = row[:1]
        for l in range(c.layers):
            k, v = caches[l]
            row, k, v = ref.attention_step_ref(
                row, k, v, params["wqkv"][l], params["wo"][l], c.heads,
                pos)
            caches[l] = (k, v)
            row = moe(row, l)
        (logits,) = model.lmhead_fn(c, row, params["emb"])
        t = int(jnp.argmax(logits[0]))
        out.append(t)
        ids.append(t)
    return out


@pytest.mark.parametrize("prompt_len,n_new", [(5, 6), (1, 4), (10, 8)])
def test_cached_greedy_decode_matches_recompute(prompt_len, n_new):
    # The end-to-end tentpole invariant, at the python level: KV-cached
    # incremental decode produces token-for-token the same greedy output
    # as full recompute. Attention rows agree bit-for-bit; the MoE layer
    # sees identical inputs either way (it has no cross-token state), so
    # the argmax chain cannot diverge.
    params = model.init_params(CFG, seed=3)
    prompt = [(i * 37 + 11) % CFG.vocab for i in range(prompt_len)]
    want = greedy_recompute(CFG, params, prompt, n_new)
    got = greedy_cached(CFG, params, prompt, n_new)
    assert got == want, f"cached decode diverged: {got} vs {want}"


def test_artifact_specs_include_incremental_entries():
    # The manifest contract: the new artifacts exist with the shapes the
    # rust engine binds to (new-token row + [ctx, hidden] caches).
    specs = {name: shapes for name, _, shapes in model.artifact_specs(CFG)}
    assert "attention_prefill" in specs
    assert "attention_step" in specs
    assert "lmhead_row" in specs
    step = specs["attention_step"]
    assert tuple(step[0].shape) == (1, CFG.hidden)
    assert tuple(step[1].shape) == (CFG.ctx, CFG.hidden)
    assert tuple(step[2].shape) == (CFG.ctx, CFG.hidden)
    assert tuple(step[5].shape) == ()
    assert tuple(specs["lmhead_row"][0].shape) == (1, CFG.hidden)
