"""Cross-language contract tests for the AOT handshake — pure text-level
checks over the python and rust sources, so they run with no JAX/Pallas
toolchain at all (the loud-skip CI lane still exercises *something* real).

The contract: ``python/compile/aot.py`` writes ``manifest.json`` +
weight blobs; ``rust/src/runtime/manifest.rs`` and ``engine/real.rs``
consume them. Drift between the two sides (a renamed config key, a weight
tensor the rust engine expects but python stopped writing) must fail CI
even on runners that cannot import jax.
"""

import os
import re

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))


def _read(*parts: str) -> str:
    with open(os.path.join(REPO, *parts), encoding="utf-8") as f:
        return f.read()


def test_tiny_config_keys_match_rust_parser():
    """Every config key the rust manifest parser requires is written by
    aot.py's build_variant, and vice versa."""
    aot = _read("python", "compile", "aot.py")
    manifest_rs = _read("rust", "src", "runtime", "manifest.rs")

    # rust: `experts: c.req_usize("experts")?` inside parse_variant's
    # TinyConfig construction (receiver `c` distinguishes it from the
    # weight-tensor offsets, which parse through `tv`).
    rust_keys = set(re.findall(r'c\.req_usize\("(\w+)"\)', manifest_rs))
    assert rust_keys, "rust parser should require config keys"

    # python: the "config" dict literal in build_variant: `"experts": cfg.experts`
    config_block = re.search(r'"config":\s*\{(.*?)\}', aot, re.S)
    assert config_block, "aot.py must write a config block"
    py_keys = set(re.findall(r'"(\w+)":\s*cfg\.\w+', config_block.group(1)))

    assert rust_keys == py_keys, (
        f"manifest config keys drifted: rust-only={rust_keys - py_keys}, "
        f"python-only={py_keys - rust_keys}"
    )


def test_weight_tensor_order_matches_rust_engine():
    """The tensors aot.py serialises cover everything the rust engine
    loads per layer / per model."""
    aot = _read("python", "compile", "aot.py")
    real_rs = _read("rust", "src", "engine", "real.rs")

    order = re.search(r'order\s*=\s*\[([^\]]*)\]', aot)
    assert order, "aot.py must declare the weight blob order"
    py_tensors = set(re.findall(r'"(\w+)"', order.group(1)))

    # rust loads: ws.tensor("emb") plus lit("wqkv") … per layer.
    rust_tensors = set(re.findall(r'ws\.tensor\("(\w+)"\)', real_rs))
    rust_tensors |= set(re.findall(r'lit\("(\w+)"\)', real_rs))
    rust_tensors |= set(
        re.findall(r'expert_tensor\("(\w+)"', real_rs))

    missing = rust_tensors - py_tensors
    assert not missing, f"rust engine loads tensors python never writes: {missing}"


def test_artifact_names_cover_rust_run_calls():
    """Every artifact name the rust engine executes is registered in
    model.artifact_specs."""
    model_py = _read("python", "compile", "model.py")
    real_rs = _read("rust", "src", "engine", "real.rs")

    py_artifacts = set(re.findall(r'^\s+\("(\w+)",', model_py, re.M))
    assert py_artifacts, "artifact_specs should register artifacts"

    rust_calls = set(re.findall(r'self\.run\(\s*"(\w+)"', real_rs))
    rust_calls |= set(re.findall(r'\.run\(\s*\n?\s*"(\w+)"', real_rs))

    missing = rust_calls - py_artifacts
    assert not missing, f"rust engine runs artifacts python never lowers: {missing}"


def test_makefile_drives_aot():
    """`make artifacts` must lower via python -m compile.aot into the
    directory the rust tests expect (rust/artifacts)."""
    makefile = _read("Makefile")
    assert "compile.aot" in makefile
    assert "rust/artifacts" in makefile
