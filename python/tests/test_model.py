"""L2 model tests: shapes, gate invariants, and the losslessness identity
(the distributed gate→dispatch→grouped-FFN→combine pipeline must equal the
single-device ``moe_layer_full`` oracle — the property the paper's
"lossless co-optimization" claim rests on)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import align_dispatch, grouped_ffn_tiled, ref

jax.config.update("jax_platform_name", "cpu")

CFG = model.ModelConfig(
    name="test_tiny", experts=8, top_k=2, layers=2, paper_layers=2,
    hidden=16, ffn=24, heads=2, vocab=64, tile_t=16, tile_m=4,
    cap_tiles=24, ctx=24)


def _x(rng, T, H):
    return jnp.asarray(rng.standard_normal((T, H)).astype(np.float32))


# ---------------------------------------------------------------------------
# gate
# ---------------------------------------------------------------------------


def test_gate_weights_normalised_and_indices_unique():
    rng = np.random.default_rng(0)
    x = _x(rng, CFG.tile_t, CFG.hidden)
    wg = _x(rng, CFG.hidden, CFG.experts)
    xn, topw, topi = model.gate_fn(CFG, x, wg)
    assert topw.shape == (CFG.tile_t, CFG.top_k)
    assert topi.dtype == jnp.int32
    np.testing.assert_allclose(np.asarray(topw).sum(-1), 1.0, rtol=1e-5)
    for row in np.asarray(topi):
        assert len(set(row.tolist())) == CFG.top_k
    np.testing.assert_allclose(np.asarray(xn),
                               np.asarray(ref.layernorm_ref(x)), rtol=1e-5)


def test_gate_topk_picks_highest_probability_experts():
    rng = np.random.default_rng(1)
    x = _x(rng, 8, CFG.hidden)
    wg = _x(rng, CFG.hidden, CFG.experts)
    xn, topw, topi = model.gate_fn(CFG, x, wg)
    probs = np.asarray(jax.nn.softmax(np.asarray(xn) @ np.asarray(wg), -1))
    for t in range(8):
        want = set(np.argsort(probs[t])[-CFG.top_k:].tolist())
        assert set(np.asarray(topi)[t].tolist()) == want


# ---------------------------------------------------------------------------
# losslessness: manual dispatch/combine == moe_layer_full oracle
# ---------------------------------------------------------------------------


def _manual_moe_layer(cfg, x, wg, w1, w3, w2, perm_shuffle_seed=None):
    """Reimplements exactly what the rust engine does per MoE layer:
    gate → build dispatch buffer (optionally shuffled, to emulate an
    arbitrary placement/routing order) → tiled grouped FFN → weighted
    combine → residual."""
    xn, topw, topi = model.gate_fn(cfg, x, wg)
    T = x.shape[0]
    copies = np.arange(T * cfg.top_k)
    src = copies // cfg.top_k
    eid = np.asarray(topi).reshape(-1)
    gw = np.asarray(topw).reshape(-1)
    if perm_shuffle_seed is not None:
        # any permutation of the copies must give identical results
        rs = np.random.default_rng(perm_shuffle_seed)
        p = rs.permutation(len(copies))
        src, eid, gw = src[p], eid[p], gw[p]
    order = np.argsort(eid, kind="stable")
    src, eid, gw = src[order], eid[order], gw[order]
    perm, tile_expert, _ = align_dispatch(eid, cfg.tile_m, cfg.cap_tiles)
    live = perm >= 0
    xa = np.zeros((cfg.cap_rows, cfg.hidden), np.float32)
    xa[live] = np.asarray(xn)[src[perm[live]]]
    ya = np.asarray(grouped_ffn_tiled(
        jnp.asarray(xa), jnp.asarray(tile_expert),
        w1, w3, w2, tile_m=cfg.tile_m))
    y = np.zeros((T, cfg.hidden), np.float32)
    for slot in np.nonzero(live)[0]:
        c = perm[slot]
        y[src[c]] += gw[c] * ya[slot]
    return np.asarray(x) + y


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       shuffle=st.integers(0, 2**31 - 1))
def test_distributed_pipeline_is_lossless(seed, shuffle):
    rng = np.random.default_rng(seed)
    c = CFG
    x = _x(rng, c.tile_t, c.hidden)
    wg = _x(rng, c.hidden, c.experts)
    w1 = _x(rng, c.experts * c.hidden * c.ffn, 1).reshape(
        c.experts, c.hidden, c.ffn) * 0.1
    w3 = _x(rng, c.experts * c.hidden * c.ffn, 1).reshape(
        c.experts, c.hidden, c.ffn) * 0.1
    w2 = _x(rng, c.experts * c.ffn * c.hidden, 1).reshape(
        c.experts, c.ffn, c.hidden) * 0.1
    (want,) = model.moe_layer_full_fn(c, x, wg, w1, w3, w2)
    got = _manual_moe_layer(c, x, wg, w1, w3, w2, perm_shuffle_seed=shuffle)
    np.testing.assert_allclose(got, np.asarray(want), rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# attention + full forward
# ---------------------------------------------------------------------------


def test_attention_padding_rows_pass_through():
    rng = np.random.default_rng(2)
    c = CFG
    x = _x(rng, c.ctx, c.hidden)
    wqkv = _x(rng, c.hidden, 3 * c.hidden)
    wo = _x(rng, c.hidden, c.hidden)
    (y,) = model.attention_fn(c, x, wqkv, wo, jnp.int32(10))
    np.testing.assert_array_equal(np.asarray(y)[10:], np.asarray(x)[10:])
    # valid prefix must be independent of padding contents
    x2 = np.asarray(x).copy()
    x2[10:] = 123.0
    (y2,) = model.attention_fn(c, jnp.asarray(x2), wqkv, wo, jnp.int32(10))
    np.testing.assert_allclose(np.asarray(y2)[:10], np.asarray(y)[:10],
                               rtol=1e-5, atol=1e-5)


def test_attention_is_causal():
    rng = np.random.default_rng(3)
    c = CFG
    x = np.asarray(_x(rng, c.ctx, c.hidden))
    wqkv = _x(rng, c.hidden, 3 * c.hidden)
    wo = _x(rng, c.hidden, c.hidden)
    (y,) = model.attention_fn(c, jnp.asarray(x), wqkv, wo, jnp.int32(c.ctx))
    # perturb a late token: earlier outputs unchanged
    x2 = x.copy()
    x2[15] += 1.0
    (y2,) = model.attention_fn(c, jnp.asarray(x2), wqkv, wo,
                               jnp.int32(c.ctx))
    np.testing.assert_allclose(np.asarray(y2)[:15], np.asarray(y)[:15],
                               rtol=1e-5, atol=1e-5)
    assert not np.allclose(np.asarray(y2)[15], np.asarray(y)[15])


def test_forward_ref_shapes_and_determinism():
    c = CFG
    params = model.init_params(c, seed=7)
    ids = jnp.asarray(np.arange(c.ctx) % c.vocab, jnp.int32)
    lg1 = model.forward_ref(c, params, ids)
    lg2 = model.forward_ref(c, params, ids)
    assert lg1.shape == (c.ctx, c.vocab)
    np.testing.assert_array_equal(np.asarray(lg1), np.asarray(lg2))


def test_variants_table3_faithful():
    """Top-k and expert counts must match Table 3 of the paper."""
    v = model.VARIANTS
    assert (v["olmoe_tiny"].top_k, v["olmoe_tiny"].experts) == (8, 64)
    assert (v["dsv2_tiny"].top_k, v["dsv2_tiny"].experts) == (6, 64)
    assert (v["qwen3_tiny"].top_k, v["qwen3_tiny"].experts) == (8, 128)
    assert v["olmoe_tiny"].paper_layers == 16
    assert v["dsv2_tiny"].paper_layers == 26
    assert v["qwen3_tiny"].paper_layers == 48
