"""AOT pipeline tests: HLO text round-trip shape, manifest consistency,
and the incremental no-op behaviour of ``make artifacts``."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_is_parseable_entry_module():
    cfg = model.VARIANTS["olmoe_tiny"]
    name, fn, specs = model.artifact_specs(cfg)[0]
    text = aot.to_hlo_text(jax.jit(fn).lower(*specs))
    assert "ENTRY" in text
    assert "HloModule" in text
    # 64-bit-id protos are the failure mode we avoid; text must not be empty
    assert len(text) > 100


def test_manifest_matches_variant_configs():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        man = json.load(f)
    for vname, cfg in model.VARIANTS.items():
        v = man["variants"][vname]
        assert v["config"]["experts"] == cfg.experts
        assert v["config"]["top_k"] == cfg.top_k
        assert v["config"]["tile_m"] == cfg.tile_m
        for aname, _, specs in [(n, f, s) for n, f, s
                                in model.artifact_specs(cfg)]:
            art = v["artifacts"][aname]
            assert os.path.exists(os.path.join(ARTIFACTS, art["file"]))
            assert len(art["inputs"]) == len(specs)
            for got, spec in zip(art["inputs"], specs):
                assert got["shape"] == list(spec.shape)


def test_weight_blob_roundtrip():
    path = os.path.join(ARTIFACTS, "manifest.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        man = json.load(f)
    cfg = model.VARIANTS["olmoe_tiny"]
    v = man["variants"]["olmoe_tiny"]
    blob = np.fromfile(os.path.join(ARTIFACTS, v["weights"]["file"]),
                       dtype="<f4")
    params = model.init_params(cfg)
    for key, meta in v["weights"]["tensors"].items():
        a = np.asarray(params[key], np.float32).reshape(-1)
        off = meta["offset"]
        np.testing.assert_array_equal(blob[off:off + a.size], a)
        assert meta["shape"] == list(np.asarray(params[key]).shape)


def test_source_fingerprint_stable():
    assert aot._source_fingerprint() == aot._source_fingerprint()
