"""L1 kernel correctness: Pallas grouped FFN vs the pure-jnp oracle.

The CORE correctness signal of the compute stack: hypothesis sweeps shapes,
dtypes, and (pathological) size distributions and asserts allclose against
``ref.grouped_ffn_ref``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (align_dispatch, grouped_ffn_masked,
                             grouped_ffn_tiled, ref)

jax.config.update("jax_platform_name", "cpu")


def _mk(rng, T, H, F, E, dtype=np.float32):
    xs = rng.standard_normal((T, H)).astype(dtype)
    w1 = (rng.standard_normal((E, H, F)) * 0.1).astype(dtype)
    w3 = (rng.standard_normal((E, H, F)) * 0.1).astype(dtype)
    w2 = (rng.standard_normal((E, F, H)) * 0.1).astype(dtype)
    return xs, w1, w3, w2


def _sizes(rng, E, total):
    """Random per-expert sizes summing to <= total, incl. zeros."""
    cuts = np.sort(rng.integers(0, total + 1, size=E - 1))
    sizes = np.diff(np.concatenate([[0], cuts, [rng.integers(0, total + 1)]]))
    sizes = np.maximum(sizes, 0)
    while sizes.sum() > total:
        i = int(np.argmax(sizes))
        sizes[i] -= sizes.sum() - total
    return sizes.astype(np.int32)


# ---------------------------------------------------------------------------
# masked variant
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tiles=st.integers(1, 6),
    tile_m=st.sampled_from([4, 8, 16]),
    H=st.sampled_from([8, 16, 32]),
    F=st.sampled_from([8, 24, 64]),
    E=st.integers(1, 9),
)
def test_masked_matches_ref(seed, tiles, tile_m, H, F, E):
    rng = np.random.default_rng(seed)
    T = tiles * tile_m
    xs, w1, w3, w2 = _mk(rng, T, H, F, E)
    sizes = _sizes(rng, E, T)
    want = ref.grouped_ffn_ref(jnp.asarray(xs), jnp.asarray(sizes),
                               w1, w3, w2)
    got = grouped_ffn_masked(jnp.asarray(xs), jnp.asarray(sizes),
                             w1, w3, w2, tile_m=tile_m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_masked_all_padding():
    rng = np.random.default_rng(0)
    xs, w1, w3, w2 = _mk(rng, 32, 16, 24, 4)
    sizes = np.zeros(4, np.int32)
    got = grouped_ffn_masked(jnp.asarray(xs), jnp.asarray(sizes),
                             w1, w3, w2, tile_m=8)
    np.testing.assert_array_equal(np.asarray(got), 0.0)


def test_masked_single_expert_equals_dense_ffn():
    rng = np.random.default_rng(1)
    xs, w1, w3, w2 = _mk(rng, 32, 16, 24, 1)
    sizes = np.array([32], np.int32)
    got = grouped_ffn_masked(jnp.asarray(xs), jnp.asarray(sizes),
                             w1, w3, w2, tile_m=8)
    want = ref.expert_ffn_ref(jnp.asarray(xs), w1[0], w3[0], w2[0])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_masked_rejects_misaligned_T():
    rng = np.random.default_rng(2)
    xs, w1, w3, w2 = _mk(rng, 30, 16, 24, 2)
    with pytest.raises(ValueError):
        grouped_ffn_masked(jnp.asarray(xs), jnp.zeros(2, jnp.int32),
                           w1, w3, w2, tile_m=8)


# ---------------------------------------------------------------------------
# tiled (expert-aligned, scalar-prefetch) variant — the production kernel
# ---------------------------------------------------------------------------


def _run_tiled(rng, T, H, F, E, tile_m, cap_tiles):
    xs, w1, w3, w2 = _mk(rng, T, H, F, E)
    sizes = _sizes(rng, E, T)
    total = int(sizes.sum())
    eid = np.repeat(np.arange(E), sizes)
    perm, tile_expert, dst = align_dispatch(eid, tile_m, cap_tiles)
    xa = np.zeros((cap_tiles * tile_m, H), np.float32)
    live = perm >= 0
    xa[live] = xs[perm[live]]
    ya = np.asarray(grouped_ffn_tiled(
        jnp.asarray(xa), jnp.asarray(tile_expert), w1, w3, w2,
        tile_m=tile_m))
    out = np.zeros((T, H), np.float32)
    out[dst[live]] = ya[live]
    want = np.asarray(ref.grouped_ffn_ref(
        jnp.asarray(xs), jnp.asarray(sizes), w1, w3, w2))
    return out[:total], want[:total]


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    tile_m=st.sampled_from([4, 8]),
    H=st.sampled_from([8, 16]),
    F=st.sampled_from([8, 24]),
    E=st.integers(1, 8),
)
def test_tiled_matches_ref(seed, tile_m, H, F, E):
    rng = np.random.default_rng(seed)
    T = 48
    # worst-case alignment pad: one (tile_m - 1) per live expert
    cap_tiles = (T + E * (tile_m - 1)) // tile_m + 1
    got, want = _run_tiled(rng, T, H, F, E, tile_m, cap_tiles)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_tiled_padding_tiles_emit_zeros():
    rng = np.random.default_rng(3)
    H, F, E, tile_m = 16, 24, 3, 8
    xs, w1, w3, w2 = _mk(rng, 16, H, F, E)
    eid = np.array([0] * 16)
    perm, tile_expert, dst = align_dispatch(eid, tile_m, capacity_tiles=6)
    assert list(tile_expert) == [0, 0, -1, -1, -1, -1]
    xa = np.zeros((48, H), np.float32)
    xa[perm >= 0] = xs[perm[perm >= 0]]
    # poison padding-tile inputs: output must still be exactly zero there
    xa[16:] = 7.7
    ya = np.asarray(grouped_ffn_tiled(
        jnp.asarray(xa), jnp.asarray(tile_expert), w1, w3, w2,
        tile_m=tile_m))
    np.testing.assert_array_equal(ya[16:], 0.0)


def test_tiled_bf16_close_to_f32():
    rng = np.random.default_rng(4)
    H, F, E, tile_m = 16, 24, 2, 8
    xs, w1, w3, w2 = _mk(rng, 16, H, F, E)
    eid = np.array([0] * 10 + [1] * 6)
    perm, tile_expert, dst = align_dispatch(eid, tile_m, capacity_tiles=4)
    xa = np.zeros((32, H), np.float32)
    xa[perm >= 0] = xs[perm[perm >= 0]]
    y32 = np.asarray(grouped_ffn_tiled(
        jnp.asarray(xa), jnp.asarray(tile_expert), w1, w3, w2,
        tile_m=tile_m))
    yb = np.asarray(grouped_ffn_tiled(
        jnp.asarray(xa, jnp.bfloat16), jnp.asarray(tile_expert),
        jnp.asarray(w1, jnp.bfloat16), jnp.asarray(w3, jnp.bfloat16),
        jnp.asarray(w2, jnp.bfloat16), tile_m=tile_m)).astype(np.float32)
    np.testing.assert_allclose(yb, y32, rtol=0.1, atol=0.1)


# ---------------------------------------------------------------------------
# align_dispatch properties (host-side layout helper)
# ---------------------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(0, 100),
    E=st.integers(1, 10),
    tile_m=st.sampled_from([2, 4, 8]),
)
def test_align_dispatch_properties(seed, n, E, tile_m):
    rng = np.random.default_rng(seed)
    eid = rng.integers(0, E, size=n)
    cap = (n + E * (tile_m - 1)) // tile_m + 1
    perm, tile_expert, dst = align_dispatch(eid, tile_m, cap)
    assert perm.shape == (cap * tile_m,)
    assert tile_expert.shape == (cap,)
    live = perm >= 0
    # every source row appears exactly once
    assert sorted(perm[live].tolist()) == list(range(n))
    # each live slot's tile expert equals its source row's expert
    for slot in np.nonzero(live)[0]:
        assert tile_expert[slot // tile_m] == eid[perm[slot]]
    # dst inverts perm for live slots; padding slots map to the drop slot n
    assert (dst[live] == perm[live]).all()
    assert (dst[~live] == n).all()


def test_align_dispatch_capacity_error():
    with pytest.raises(ValueError):
        align_dispatch(np.array([0, 1, 2, 3]), 4, capacity_tiles=2)
