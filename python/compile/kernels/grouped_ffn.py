"""L1 Pallas kernels: grouped expert FFN (the MoE compute hot-spot).

This is the TPU re-think of the MegaBlocks/Triton grouped GEMM the paper
builds on (see DESIGN.md §Hardware-Adaptation):

* the Triton version assigns one *threadblock* per (expert block, tile) and
  uses shared memory for operand staging; here the same schedule is a Pallas
  ``grid`` whose ``BlockSpec`` index maps stream token tiles HBM→VMEM,
* accumulation happens in VMEM-resident output blocks (f32),
* tiles are shaped in MXU-friendly multiples (the tiny CPU-interpret configs
  use smaller tiles, but the BlockSpec structure is identical),
* the scatter/combine step is done outside the kernel with a segment-sum
  (TPUs have no fast global atomics).

Two variants are provided and tested against ``ref.grouped_ffn_ref``:

``grouped_ffn_masked``
    grid = (m_tiles, E): every (tile, expert) pair computes the full tile
    FFN and accumulates a row-masked result. Simple, shape-agnostic, and the
    fallback used when expert alignment is unavailable. Compute cost is
    ``T × E`` tile-FFNs.

``grouped_ffn_tiled``
    grid = (m_tiles,): the dispatch buffer is *expert-aligned* (each
    expert's rows padded to a tile multiple) and a scalar-prefetched
    ``tile_expert`` map drives the weight ``BlockSpec`` index map, so each
    tile loads exactly one expert's weights. Compute cost is ``T`` tile-FFNs
    — this is the production variant lowered into the AOT artifacts.

All kernels run with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom calls); real-TPU resource estimates are derived from the BlockSpecs
in ``python/compile/kernels/ANALYSIS.md``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref


def _silu(x):
    return x * jax.nn.sigmoid(x)


# ---------------------------------------------------------------------------
# Variant 1: masked accumulation, grid = (m_tiles, E)
# ---------------------------------------------------------------------------


def _masked_kernel(offs_ref, x_ref, w1_ref, w3_ref, w2_ref, o_ref, *,
                   tile_m: int):
    """One (token-tile, expert) step of the masked grouped FFN."""
    m = pl.program_id(0)
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    start = offs_ref[e]
    end = offs_ref[e + 1]
    row = m * tile_m + jax.lax.broadcasted_iota(jnp.int32, (tile_m, 1), 0)
    mask = (row >= start) & (row < end)  # [tile_m, 1]

    x = x_ref[...]
    w1 = w1_ref[0]
    w3 = w3_ref[0]
    w2 = w2_ref[0]
    h = _silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
    y = jnp.dot(h, w2, preferred_element_type=jnp.float32)
    o_ref[...] += jnp.where(mask, y, 0.0).astype(o_ref.dtype)


def grouped_ffn_masked(xs: jax.Array, sizes: jax.Array, w1: jax.Array,
                       w3: jax.Array, w2: jax.Array,
                       tile_m: int = 32) -> jax.Array:
    """Grouped expert FFN over a sorted dispatch buffer (masked variant).

    Args / returns match :func:`ref.grouped_ffn_ref`.
    """
    T, H = xs.shape
    E, _, F = w1.shape
    if T % tile_m != 0:
        raise ValueError(f"T={T} must be a multiple of tile_m={tile_m}")
    m_tiles = T // tile_m
    # offs[e] .. offs[e+1] is expert e's row range in the sorted buffer.
    offs = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(sizes).astype(jnp.int32)])

    kernel = functools.partial(_masked_kernel, tile_m=tile_m)
    return pl.pallas_call(
        kernel,
        grid=(m_tiles, E),
        in_specs=[
            pl.BlockSpec((E + 1,), lambda m, e: (0,)),         # offsets
            pl.BlockSpec((tile_m, H), lambda m, e: (m, 0)),    # x tile
            pl.BlockSpec((1, H, F), lambda m, e: (e, 0, 0)),   # w1[e]
            pl.BlockSpec((1, H, F), lambda m, e: (e, 0, 0)),   # w3[e]
            pl.BlockSpec((1, F, H), lambda m, e: (e, 0, 0)),   # w2[e]
        ],
        out_specs=pl.BlockSpec((tile_m, H), lambda m, e: (m, 0)),
        out_shape=jax.ShapeDtypeStruct((T, H), xs.dtype),
        interpret=True,
    )(offs, xs, w1, w3, w2)


# ---------------------------------------------------------------------------
# Variant 2: expert-aligned tiles, grid = (m_tiles,)
# ---------------------------------------------------------------------------


def _tiled_kernel(te_ref, x_ref, w1_ref, w3_ref, w2_ref, o_ref, *,
                  tile_m: int):
    """One token-tile step; the tile's expert weights were selected by the
    scalar-prefetch-driven BlockSpec index maps, so the body is a dense
    tile FFN. Tiles whose expert id is E (padding tiles) emit zeros."""
    m = pl.program_id(0)
    is_pad = te_ref[m] < 0
    x = x_ref[...]
    w1 = w1_ref[0]
    w3 = w3_ref[0]
    w2 = w2_ref[0]
    h = _silu(jnp.dot(x, w1, preferred_element_type=jnp.float32))
    h = h * jnp.dot(x, w3, preferred_element_type=jnp.float32)
    y = jnp.dot(h, w2, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.where(is_pad, 0.0, y).astype(o_ref.dtype)


def grouped_ffn_tiled(xs: jax.Array, tile_expert: jax.Array, w1: jax.Array,
                      w3: jax.Array, w2: jax.Array,
                      tile_m: int = 32) -> jax.Array:
    """Grouped expert FFN over an *expert-aligned* dispatch buffer.

    Args:
      xs: ``[T, H]`` dispatch buffer in which every tile of ``tile_m`` rows
        belongs to a single expert (the dispatcher pads each expert's rows
        to a multiple of ``tile_m``).
      tile_expert: ``[T / tile_m]`` i32; expert id of each tile, ``-1`` for
        all-padding tiles.
      w1, w3: ``[E, H, F]``; w2: ``[E, F, H]``.
    Returns:
      ``[T, H]``; rows of padding tiles are zero. Rows that are padding
      *within* a live tile compute garbage and must be dropped by the
      combine step (their ``dst`` is the drop slot) — this mirrors the
      MegaBlocks contract.
    """
    T, H = xs.shape
    E, _, F = w1.shape
    if T % tile_m != 0:
        raise ValueError(f"T={T} must be a multiple of tile_m={tile_m}")
    m_tiles = T // tile_m
    if tile_expert.shape != (m_tiles,):
        raise ValueError(f"tile_expert must be [{m_tiles}]")

    kernel = functools.partial(_tiled_kernel, tile_m=tile_m)

    # `tile_expert` doubles as the scalar prefetch operand: the weight
    # BlockSpec index maps read it to select the expert block for each tile.
    # Padding tiles (-1) clamp to expert 0; the kernel masks their output.
    def widx(m, te):
        return (jnp.maximum(te[m], 0), 0, 0)

    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(m_tiles,),
            in_specs=[
                pl.BlockSpec((tile_m, H), lambda m, te: (m, 0)),
                pl.BlockSpec((1, H, F), widx),
                pl.BlockSpec((1, H, F), widx),
                pl.BlockSpec((1, F, H), lambda m, te:
                             (jnp.maximum(te[m], 0), 0, 0)),
            ],
            out_specs=pl.BlockSpec((tile_m, H), lambda m, te: (m, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((T, H), xs.dtype),
        interpret=True,
    )(tile_expert, xs, w1, w3, w2)


def align_dispatch(eid, tile_m: int, capacity_tiles: int):
    """Host-side helper: build an expert-aligned layout from per-row expert
    ids (numpy; used by tests and by the rust engine's python mirror).

    Returns (perm, tile_expert, dst) where ``perm[i]`` is the source row for
    aligned slot ``i`` (or -1 for padding), ``tile_expert`` the per-tile
    expert map, and ``dst`` the inverse scatter map.
    """
    import numpy as np

    eid = np.asarray(eid)
    E = int(eid.max(initial=-1)) + 1
    slots = []
    tile_expert = []
    for e in range(E):
        rows = np.nonzero(eid == e)[0]
        if len(rows) == 0:
            continue
        pad = (-len(rows)) % tile_m
        slots.extend(rows.tolist() + [-1] * pad)
        tile_expert.extend([e] * ((len(rows) + pad) // tile_m))
    total_tiles = capacity_tiles
    if len(tile_expert) > total_tiles:
        raise ValueError("capacity exceeded")
    slots.extend([-1] * ((total_tiles - len(tile_expert)) * tile_m))
    tile_expert.extend([-1] * (total_tiles - len(tile_expert)))
    perm = np.asarray(slots, dtype=np.int32)
    tile_expert = np.asarray(tile_expert, dtype=np.int32)
    n_rows = len(eid)
    dst = np.full(perm.shape, n_rows, dtype=np.int32)  # n_rows == drop slot
    live = perm >= 0
    dst[live] = perm[live]
    return perm, tile_expert, dst
