"""Pure-jnp reference oracle for the L1 Pallas kernels.

Everything in this file is deliberately written with plain `jax.numpy`
primitives (no pallas, no custom calls) so that it can serve as the
correctness oracle for the kernels in this package. The pytest suite in
``python/tests`` asserts ``assert_allclose(kernel(...), ref(...))`` over
randomized shapes and dtypes (hypothesis sweeps).

Conventions
-----------
The grouped expert FFN operates on a *dispatch buffer*: a ``[T, H]`` array of
token copies that has already been sorted by destination expert. ``sizes[e]``
gives the number of rows assigned to local expert ``e``; rows beyond
``sum(sizes)`` are padding and must map to zeros in the output. Experts use
the SwiGLU parameterisation ``y = (silu(x @ w1) * (x @ w3)) @ w2`` used by
OLMoE / DeepSeek-V2 / Qwen3 (Table 3 of the paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def silu(x: jax.Array) -> jax.Array:
    """Numerically plain SiLU: x * sigmoid(x)."""
    return x * jax.nn.sigmoid(x)


def expert_ffn_ref(x: jax.Array, w1: jax.Array, w3: jax.Array,
                   w2: jax.Array) -> jax.Array:
    """Single-expert SwiGLU FFN: ``(silu(x w1) * (x w3)) w2``.

    Args:
      x: ``[T, H]`` tokens.
      w1, w3: ``[H, F]`` up/gate projections.
      w2: ``[F, H]`` down projection.
    Returns:
      ``[T, H]``.
    """
    return (silu(x @ w1) * (x @ w3)) @ w2


def sizes_to_expert_ids(sizes: jax.Array, total_rows: int) -> jax.Array:
    """Expand per-expert row counts into a per-row expert id vector.

    Rows past ``sum(sizes)`` get id ``E`` (one past the last expert) so that
    they can be masked out. Implemented with a cumulative-sum comparison so it
    stays jit-friendly (no dynamic shapes).
    """
    ends = jnp.cumsum(sizes)  # [E]
    row = jnp.arange(total_rows)[:, None]  # [T, 1]
    # Number of expert-ends that are <= row index == expert id of the row.
    return jnp.sum(row >= ends[None, :], axis=1)


def grouped_ffn_ref(xs: jax.Array, sizes: jax.Array, w1: jax.Array,
                    w3: jax.Array, w2: jax.Array) -> jax.Array:
    """Reference grouped expert FFN over a sorted dispatch buffer.

    Args:
      xs: ``[T, H]`` dispatch buffer, rows sorted by expert; rows beyond
        ``sum(sizes)`` are padding.
      sizes: ``[E]`` int32 per-expert row counts (may contain zeros).
      w1, w3: ``[E, H, F]`` expert up/gate weights.
      w2: ``[E, F, H]`` expert down weights.
    Returns:
      ``[T, H]``; padding rows are exactly zero.
    """
    T = xs.shape[0]
    E = sizes.shape[0]
    eid = sizes_to_expert_ids(sizes, T)  # [T], == E for padding rows
    out = jnp.zeros_like(xs)
    for e in range(E):
        y = expert_ffn_ref(xs, w1[e], w3[e], w2[e])
        out = jnp.where((eid == e)[:, None], y, out)
    return out


def topk_iterative(probs: jax.Array, k: int):
    """Top-k by k rounds of argmax + masking.

    Functionally equivalent to ``jax.lax.top_k`` (ties broken toward the
    lower index, like top_k), but lowers to plain reduce/select HLO ops.
    This matters for the AOT path: jax ≥ 0.7 lowers ``lax.top_k`` to a
    ``topk(…, largest=true)`` HLO instruction that xla_extension 0.5.1's
    text parser rejects; the iterative form round-trips cleanly.
    """
    T = probs.shape[0]
    p = probs
    vals, idxs = [], []
    rows = jnp.arange(T)
    for _ in range(k):
        i = jnp.argmax(p, axis=-1)
        v = p[rows, i]
        vals.append(v)
        idxs.append(i)
        p = p.at[rows, i].set(-jnp.inf)
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def gate_ref(x: jax.Array, wg: jax.Array, k: int):
    """Reference top-k softmax gate (softmax-then-topk, renormalised).

    Args:
      x: ``[T, H]`` tokens.
      wg: ``[H, E]`` gate projection.
      k: number of experts per token.
    Returns:
      ``(weights [T, k], indices [T, k] i32)`` with weights summing to 1
      across k (OLMoE-style renormalisation).
    """
    logits = x @ wg
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = topk_iterative(probs, k)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)
    return topw.astype(x.dtype), topi.astype(jnp.int32)


def combine_ref(ys: jax.Array, gate_w: jax.Array, dst: jax.Array,
                num_tokens: int) -> jax.Array:
    """Reference combine: weighted scatter-add of expert outputs.

    Args:
      ys: ``[Td, H]`` per-copy expert outputs (dispatch order).
      gate_w: ``[Td]`` gate weight per copy.
      dst: ``[Td]`` i32 destination token slot per copy; ``num_tokens`` (one
        past the end) marks padding copies, which are dropped.
      num_tokens: number of output token slots.
    Returns:
      ``[num_tokens, H]``.
    """
    weighted = ys * gate_w[:, None]
    return jax.ops.segment_sum(weighted, dst, num_segments=num_tokens + 1)[
        :num_tokens]


def attention_ref(x: jax.Array, wqkv: jax.Array, wo: jax.Array,
                  n_heads: int, valid_len=None) -> jax.Array:
    """Reference pre-LN causal self-attention block with residual.

    Args:
      x: ``[T, H]``.
      wqkv: ``[H, 3H]`` fused QKV projection.
      wo: ``[H, H]`` output projection.
      n_heads: head count (H must divide evenly).
      valid_len: optional number of valid (non-padding) rows; padding rows
        are masked out of the attention and pass through unchanged.
    Returns:
      ``[T, H]`` = x + attn(LN(x)).
    """
    T, H = x.shape
    hd = H // n_heads
    xn = layernorm_ref(x)
    qkv = xn @ wqkv
    q, kk, v = jnp.split(qkv, 3, axis=-1)

    def heads(a):
        return a.reshape(T, n_heads, hd).transpose(1, 0, 2)  # [nh, T, hd]

    q, kk, v = heads(q), heads(kk), heads(v)
    scale = 1.0 / jnp.sqrt(jnp.array(hd, dtype=x.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", q, kk) * scale
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    if valid_len is not None:
        vl = jnp.asarray(valid_len)
        mask = mask & (jnp.arange(T) < vl)[None, :]
    scores = jnp.where(mask[None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, v)
    ctx = ctx.transpose(1, 0, 2).reshape(T, H)
    out = x + ctx @ wo
    if valid_len is not None:
        vl = jnp.asarray(valid_len)
        out = jnp.where((jnp.arange(T) < vl)[:, None], out, x)
    return out


def layernorm_ref(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """Plain layernorm (no learned scale/shift) used by the tiny models."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def attention_prefill_ref(x: jax.Array, wqkv: jax.Array, wo: jax.Array,
                          n_heads: int, valid_len):
    """Full-prefix attention that also returns the K/V rows to cache.

    The K/V of position ``p`` depend only on row ``p``'s layer input
    (layernorm and the QKV matmul are row-wise), so the rows computed here
    are exactly the rows :func:`attention_step_ref` would have produced one
    token at a time — that identity is what makes incremental decode exact.

    Args:
      x: ``[C, H]`` ctx-padded layer input.
      wqkv: ``[H, 3H]``; wo: ``[H, H]``.
      n_heads: head count.
      valid_len: number of valid rows; cache rows at or past it are zeroed.
    Returns:
      ``(out [C, H], k_cache [C, H], v_cache [C, H])`` where ``out`` is
      bit-identical to :func:`attention_ref` on the same inputs.
    """
    out = attention_ref(x, wqkv, wo, n_heads, valid_len)
    qkv = layernorm_ref(x) @ wqkv
    _, k, v = jnp.split(qkv, 3, axis=-1)
    vl = jnp.asarray(valid_len)
    live = (jnp.arange(x.shape[0]) < vl)[:, None]
    return out, jnp.where(live, k, 0.0), jnp.where(live, v, 0.0)


def attention_step_ref(x_row: jax.Array, k_cache: jax.Array,
                       v_cache: jax.Array, wqkv: jax.Array, wo: jax.Array,
                       n_heads: int, pos):
    """One incremental attention step against a K/V cache.

    Computes the attended output for the single new token at position
    ``pos``, given caches holding the K/V rows of positions ``< pos`` (rows
    at and past ``pos`` are ignored and overwritten). Because softmax over
    the causal window ``0..=pos`` sees exactly the keys the full-prefix
    path sees for row ``pos``, the output row equals row ``pos`` of
    :func:`attention_ref` up to float-reduction reassociation (the two
    paths lower differently-shaped einsums); greedy token output is
    identical — the parity tests pin both.

    Args:
      x_row: ``[1, H]`` the new token's layer input.
      k_cache, v_cache: ``[C, H]`` caches; rows ``< pos`` must be populated.
      wqkv: ``[H, 3H]``; wo: ``[H, H]``.
      n_heads: head count.
      pos: index of the new token (i32 scalar, ``0 <= pos < C``).
    Returns:
      ``(out [1, H], k_cache [C, H], v_cache [C, H])`` — the attended
      residual row plus the caches with row ``pos`` appended.
    """
    C, H = k_cache.shape
    hd = H // n_heads
    p = jnp.asarray(pos)
    qkv = layernorm_ref(x_row) @ wqkv  # [1, 3H]
    q, k_new, v_new = jnp.split(qkv, 3, axis=-1)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k_new, (p, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v_new, (p, 0))

    def heads(a):  # [C, H] -> [nh, C, hd]
        return a.reshape(-1, n_heads, hd).transpose(1, 0, 2)

    qh = heads(q)  # [nh, 1, hd]
    kh, vh = heads(k_cache), heads(v_cache)  # [nh, C, hd]
    scale = 1.0 / jnp.sqrt(jnp.array(hd, dtype=x_row.dtype))
    scores = jnp.einsum("hqd,hkd->hqk", qh, kh) * scale  # [nh, 1, C]
    mask = (jnp.arange(C) <= p)[None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("hqk,hkd->hqd", probs, vh)  # [nh, 1, hd]
    ctx = ctx.transpose(1, 0, 2).reshape(1, H)
    return x_row + ctx @ wo, k_cache, v_cache
