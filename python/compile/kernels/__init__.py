# L1: Pallas kernel(s) for the paper's compute hot-spot (grouped expert
# FFN), plus the pure-jnp oracle used by the pytest suite.
from . import ref  # noqa: F401
from .grouped_ffn import (  # noqa: F401
    align_dispatch,
    grouped_ffn_masked,
    grouped_ffn_tiled,
)
