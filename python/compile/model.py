"""L2: the JAX MoE transformer used by the rust engine.

This module defines three *architecture-faithful but scaled-down* MoE model
variants mirroring Table 3 of the paper (same top-k and expert counts,
reduced hidden dims / layer counts so the CPU-PJRT interpret path stays
fast), plus the jit-able computations that ``aot.py`` lowers to HLO text:

=====================  =====================================================
artifact               computation
=====================  =====================================================
``{V}_gate``           pre-LN + top-k softmax gate (returns the normalised
                       activations so rust can dispatch them directly)
``{V}_grouped_ffn``    the L1 Pallas grouped expert FFN over an
                       expert-aligned dispatch buffer (one per-GPU call)
``{V}_expert_ffn``     single-expert SwiGLU FFN (per-expert baseline path +
                       compute-cost calibration)
``{V}_attention``      causal self-attention block with valid-length mask
``{V}_attention_prefill``  full-prefix attention that also emits the K/V
                       rows to seed a per-sequence cache
``{V}_attention_step`` incremental attention: one new-token row against a
                       cached ``[ctx, hidden]`` K/V pair → attended row +
                       updated caches (the KV-cached decode hot path)
``{V}_embed``          token embedding lookup
``{V}_lmhead``         tied-embedding logits
``{V}_lmhead_row``     tied-embedding logits for a single row (cached
                       decode emits one row per live sequence)
``{V}_moe_layer_full`` the whole MoE layer on one device — the *lossless
                       oracle* the rust engine checks distributed execution
                       against (paper §1: "lossless co-optimization")
=====================  =====================================================

The rust side never imports python; it reads ``artifacts/manifest.json``
(written by ``aot.py``) for all shape metadata.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from .kernels import grouped_ffn_tiled, ref


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture of one tiny model variant.

    Attributes mirror Table 3 of the paper: ``experts``/``top_k``/(real)
    ``paper_layers`` are faithful; ``hidden``/``ffn``/``layers`` are scaled
    down for the CPU interpret path. ``tile_t`` is the padded token tile the
    gate/FFN artifacts are compiled for; ``tile_m`` the Pallas row-tile;
    ``cap_tiles`` the per-call dispatch capacity of the grouped FFN
    artifact; ``ctx`` the attention context capacity.
    """

    name: str
    experts: int
    top_k: int
    layers: int
    paper_layers: int
    hidden: int
    ffn: int
    heads: int
    vocab: int
    tile_t: int = 64
    tile_m: int = 8
    cap_tiles: int = 96
    ctx: int = 192

    @property
    def cap_rows(self) -> int:
        return self.cap_tiles * self.tile_m


#: Table 3 of the paper, scaled: same TOP_K / EXPERTS; layer counts and
#: hidden dims reduced (paper values kept in ``paper_layers`` and mirrored
#: in the rust simulator configs, which use the full-scale numbers).
VARIANTS: dict[str, ModelConfig] = {
    "olmoe_tiny": ModelConfig(
        name="olmoe_tiny", experts=64, top_k=8, layers=4, paper_layers=16,
        hidden=64, ffn=128, heads=4, vocab=512),
    "dsv2_tiny": ModelConfig(
        name="dsv2_tiny", experts=64, top_k=6, layers=4, paper_layers=26,
        hidden=64, ffn=96, heads=4, vocab=512),
    "qwen3_tiny": ModelConfig(
        name="qwen3_tiny", experts=128, top_k=8, layers=4, paper_layers=48,
        hidden=64, ffn=128, heads=4, vocab=512),
}


# ---------------------------------------------------------------------------
# Per-artifact computations (all pure functions of their array arguments)
# ---------------------------------------------------------------------------


def gate_fn(cfg: ModelConfig, x, wg):
    """Pre-LN + top-k gate. Returns (xn, topw, topi)."""
    xn = ref.layernorm_ref(x)
    topw, topi = ref.gate_ref(xn, wg, cfg.top_k)
    return xn, topw, topi


def grouped_ffn_fn(cfg: ModelConfig, xa, tile_expert, w1, w3, w2):
    """The L1 Pallas kernel over an expert-aligned per-GPU dispatch buffer."""
    return (grouped_ffn_tiled(xa, tile_expert, w1, w3, w2,
                              tile_m=cfg.tile_m),)


def expert_ffn_fn(cfg: ModelConfig, x, w1, w3, w2):
    """Single-expert FFN (used by per-expert engine mode + calibration)."""
    del cfg
    return (ref.expert_ffn_ref(x, w1, w3, w2),)


def attention_fn(cfg: ModelConfig, x, wqkv, wo, valid_len):
    return (ref.attention_ref(x, wqkv, wo, cfg.heads, valid_len),)


def attention_prefill_fn(cfg: ModelConfig, x, wqkv, wo, valid_len):
    """Full-prefix attention + the K/V rows that seed a sequence's cache."""
    return ref.attention_prefill_ref(x, wqkv, wo, cfg.heads, valid_len)


def attention_step_fn(cfg: ModelConfig, x_row, k_cache, v_cache, wqkv, wo,
                      pos):
    """Incremental attention for one new token against a K/V cache."""
    return ref.attention_step_ref(x_row, k_cache, v_cache, wqkv, wo,
                                  cfg.heads, pos)


def embed_fn(cfg: ModelConfig, ids, emb):
    del cfg
    return (jnp.take(emb, ids, axis=0),)


def lmhead_fn(cfg: ModelConfig, x, emb):
    del cfg
    return (x @ emb.T,)


def moe_layer_full_fn(cfg: ModelConfig, x, wg, w1, w3, w2):
    """Whole MoE layer (LN → gate → all experts → combine → residual) on a
    single device. This is the lossless oracle: any distributed placement
    and routing must reproduce these numerics bit-for-bit up to float
    reassociation."""
    xn = ref.layernorm_ref(x)
    topw, topi = ref.gate_ref(xn, wg, cfg.top_k)
    # Dense evaluation of every expert on every token, then a sparse
    # combine with the top-k weight matrix.
    h = ref.silu(jnp.einsum("th,ehf->etf", xn, w1))
    h = h * jnp.einsum("th,ehf->etf", xn, w3)
    y_all = jnp.einsum("etf,efh->eth", h, w2)  # [E, T, H]
    T = x.shape[0]
    sel = jnp.zeros((T, cfg.experts), x.dtype)
    sel = sel.at[jnp.arange(T)[:, None], topi].set(topw)
    y = jnp.einsum("te,eth->th", sel, y_all)
    return (x + y,)


def moe_block_fn(cfg: ModelConfig, x, wqkv, wo, wg, w1, w3, w2, valid_len):
    """attention + full MoE layer (single-device reference block)."""
    (a,) = attention_fn(cfg, x, wqkv, wo, valid_len)
    return moe_layer_full_fn(cfg, a, wg, w1, w3, w2)


# ---------------------------------------------------------------------------
# Whole-model single-device reference (used by python tests and by the
# end-to-end losslessness check)
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, seed: int = 0):
    """Deterministic random weights for one variant.

    Weights cross the python→rust boundary as plain f32 little-endian
    binary blobs written by ``aot.py`` next to the HLO artifacts
    (``{V}_weights.bin`` + manifest entries), so both sides share bytes
    rather than having to agree on an RNG implementation.
    """
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 7)
    c = cfg
    s_h = 1.0 / jnp.sqrt(c.hidden)
    s_f = 1.0 / jnp.sqrt(c.ffn)
    return {
        "emb": jax.random.normal(ks[0], (c.vocab, c.hidden)) * 0.02,
        "wqkv": jax.random.normal(
            ks[1], (c.layers, c.hidden, 3 * c.hidden)) * s_h,
        "wo": jax.random.normal(ks[2], (c.layers, c.hidden, c.hidden)) * s_h,
        "wg": jax.random.normal(ks[3], (c.layers, c.hidden, c.experts)) * s_h,
        "w1": jax.random.normal(
            ks[4], (c.layers, c.experts, c.hidden, c.ffn)) * s_h,
        "w3": jax.random.normal(
            ks[5], (c.layers, c.experts, c.hidden, c.ffn)) * s_h,
        "w2": jax.random.normal(
            ks[6], (c.layers, c.experts, c.ffn, c.hidden)) * s_f,
    }


def forward_ref(cfg: ModelConfig, params, ids, valid_len=None):
    """Single-device full forward pass: ids [C] → logits [C, V]."""
    (x,) = embed_fn(cfg, ids, params["emb"])
    for l in range(cfg.layers):
        (x,) = moe_block_fn(cfg, x, params["wqkv"][l], params["wo"][l],
                            params["wg"][l], params["w1"][l],
                            params["w3"][l], params["w2"][l],
                            valid_len if valid_len is not None
                            else ids.shape[0])
    (logits,) = lmhead_fn(cfg, x, params["emb"])
    return logits


# ---------------------------------------------------------------------------
# Artifact registry consumed by aot.py
# ---------------------------------------------------------------------------


def artifact_specs(cfg: ModelConfig):
    """(name, fn, [ShapeDtypeStruct…]) for every artifact of one variant."""
    c = cfg
    f32 = jnp.float32
    i32 = jnp.int32

    def S(shape, dt=f32):
        return jax.ShapeDtypeStruct(shape, dt)

    return [
        ("gate",
         functools.partial(gate_fn, c),
         [S((c.tile_t, c.hidden)), S((c.hidden, c.experts))]),
        ("grouped_ffn",
         functools.partial(grouped_ffn_fn, c),
         [S((c.cap_rows, c.hidden)), S((c.cap_tiles,), i32),
          S((c.experts, c.hidden, c.ffn)), S((c.experts, c.hidden, c.ffn)),
          S((c.experts, c.ffn, c.hidden))]),
        ("expert_ffn",
         functools.partial(expert_ffn_fn, c),
         [S((c.tile_t, c.hidden)), S((c.hidden, c.ffn)),
          S((c.hidden, c.ffn)), S((c.ffn, c.hidden))]),
        ("attention",
         functools.partial(attention_fn, c),
         [S((c.ctx, c.hidden)), S((c.hidden, 3 * c.hidden)),
          S((c.hidden, c.hidden)), S((), i32)]),
        ("attention_prefill",
         functools.partial(attention_prefill_fn, c),
         [S((c.ctx, c.hidden)), S((c.hidden, 3 * c.hidden)),
          S((c.hidden, c.hidden)), S((), i32)]),
        ("attention_step",
         functools.partial(attention_step_fn, c),
         [S((1, c.hidden)), S((c.ctx, c.hidden)), S((c.ctx, c.hidden)),
          S((c.hidden, 3 * c.hidden)), S((c.hidden, c.hidden)),
          S((), i32)]),
        ("embed",
         functools.partial(embed_fn, c),
         [S((c.ctx,), i32), S((c.vocab, c.hidden))]),
        ("lmhead",
         functools.partial(lmhead_fn, c),
         [S((c.ctx, c.hidden)), S((c.vocab, c.hidden))]),
        ("lmhead_row",
         functools.partial(lmhead_fn, c),
         [S((1, c.hidden)), S((c.vocab, c.hidden))]),
        ("moe_layer_full",
         functools.partial(moe_layer_full_fn, c),
         [S((c.tile_t, c.hidden)), S((c.hidden, c.experts)),
          S((c.experts, c.hidden, c.ffn)), S((c.experts, c.hidden, c.ffn)),
          S((c.experts, c.ffn, c.hidden))]),
    ]
