"""AOT compile path: lower every L2 computation to HLO *text* artifacts.

Run once by ``make artifacts``; python never appears on the request path.

Interchange format is HLO text, NOT ``lowered.compile().serialize()`` —
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs (under ``artifacts/``):

* ``{variant}_{artifact}.hlo.txt``  — one HLO module per computation,
* ``{variant}_weights.bin``         — deterministic f32-LE weight blob,
* ``manifest.json``                 — shapes, dims, weight offsets; the
  single source of truth the rust side parses (rust/src/runtime/manifest.rs).

The manifest also records an input fingerprint so ``make artifacts`` is a
no-op when nothing changed.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_json(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(np.dtype(s.dtype))}


def _source_fingerprint() -> str:
    """Hash of every python source that feeds the artifacts."""
    h = hashlib.sha256()
    base = os.path.dirname(os.path.abspath(__file__))
    for root, _, files in sorted(os.walk(base)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def build_variant(cfg: model.ModelConfig, outdir: str, manifest: dict,
                  verbose: bool = True) -> None:
    arts = {}
    for name, fn, specs in model.artifact_specs(cfg):
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{cfg.name}_{name}.hlo.txt"
        with open(os.path.join(outdir, fname), "w") as f:
            f.write(text)
        arts[name] = {
            "file": fname,
            "inputs": [_spec_json(s) for s in specs],
        }
        if verbose:
            print(f"  {fname}: {len(text)} chars")

    # Weight blob: concatenated f32-LE tensors in a fixed order, with
    # offsets (in floats) recorded in the manifest.
    params = model.init_params(cfg)
    order = ["emb", "wqkv", "wo", "wg", "w1", "w3", "w2"]
    offsets = {}
    pos = 0
    chunks = []
    for key in order:
        a = np.asarray(params[key], dtype=np.float32)
        offsets[key] = {"offset": pos, "shape": list(a.shape)}
        pos += a.size
        chunks.append(a.reshape(-1))
    blob = np.concatenate(chunks).astype("<f4")
    wfile = f"{cfg.name}_weights.bin"
    blob.tofile(os.path.join(outdir, wfile))
    if verbose:
        print(f"  {wfile}: {blob.size * 4} bytes")

    manifest["variants"][cfg.name] = {
        "config": {
            "experts": cfg.experts, "top_k": cfg.top_k,
            "layers": cfg.layers, "paper_layers": cfg.paper_layers,
            "hidden": cfg.hidden, "ffn": cfg.ffn, "heads": cfg.heads,
            "vocab": cfg.vocab, "tile_t": cfg.tile_t, "tile_m": cfg.tile_m,
            "cap_tiles": cfg.cap_tiles, "ctx": cfg.ctx,
        },
        "artifacts": arts,
        "weights": {"file": wfile, "tensors": offsets},
    }


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    ap.add_argument("--variants", default=",".join(model.VARIANTS),
                    help="comma-separated variant names")
    ap.add_argument("--force", action="store_true",
                    help="rebuild even if the fingerprint matches")
    args = ap.parse_args()

    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest_path = os.path.join(outdir, "manifest.json")
    fp = _source_fingerprint()

    if not args.force and os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                old = json.load(f)
            if old.get("fingerprint") == fp and all(
                    v in old.get("variants", {})
                    for v in args.variants.split(",")):
                print(f"artifacts up to date (fingerprint {fp[:12]}…)")
                return 0
        except (json.JSONDecodeError, OSError):
            pass

    variants = args.variants.split(",")
    unknown = [v for v in variants if v not in model.VARIANTS]
    if unknown:
        # Fail fast before any (slow) lowering happens.
        ap.error(f"unknown variant(s) {', '.join(unknown)} "
                 f"(have: {', '.join(model.VARIANTS)})")

    manifest = {"fingerprint": fp, "variants": {}}
    for vname in variants:
        cfg = model.VARIANTS[vname]
        print(f"building {vname} "
              f"(E={cfg.experts} K={cfg.top_k} L={cfg.layers} "
              f"H={cfg.hidden} F={cfg.ffn})")
        build_variant(cfg, outdir, manifest)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"wrote {manifest_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
