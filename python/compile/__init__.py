"""GRACE-MoE python compile path (L1 Pallas kernels + L2 JAX model +
AOT lowering). The rust engine never imports this package at run time; it
consumes the HLO-text artifacts written by ``python -m compile.aot``
(driven by ``make artifacts``)."""
