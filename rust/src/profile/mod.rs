//! Profiling: turn gate traces into the statistics the offline phase
//! consumes (paper Fig. 2a): per-layer **expert affinity matrices**
//! (co-activation frequency) and **load statistics**.
//!
//! Definitions (paper §3 and footnote 1):
//! * *affinity* `A[i][j]` — frequency with which experts `i` and `j` are
//!   co-activated by the same token,
//! * *load* of an expert — number of tokens assigned to it; of a group /
//!   GPU — the sum over its experts.

use crate::linalg::Matrix;
use crate::trace::{GateTrace, LayerTrace};

/// Per-layer profiling output.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    /// Symmetric co-activation counts, `experts × experts`, zero diagonal.
    pub affinity: Matrix,
    /// Tokens assigned to each expert.
    pub load: Vec<f64>,
    /// Tokens profiled.
    pub tokens: usize,
}

/// Whole-model profile (one [`LayerProfile`] per MoE layer).
#[derive(Clone, Debug)]
pub struct ModelProfile {
    /// One profile per MoE layer.
    pub layers: Vec<LayerProfile>,
}

impl LayerProfile {
    /// Count affinity pairs and per-expert loads from one layer's trace.
    pub fn from_trace(layer: &LayerTrace) -> LayerProfile {
        let e = layer.experts;
        let mut affinity = Matrix::zeros(e, e);
        let mut load = vec![0.0; e];
        for tok in &layer.tokens {
            for (i, &a) in tok.iter().enumerate() {
                load[a as usize] += 1.0;
                for &b in &tok[i + 1..] {
                    affinity[(a as usize, b as usize)] += 1.0;
                    affinity[(b as usize, a as usize)] += 1.0;
                }
            }
        }
        LayerProfile { affinity, load, tokens: layer.tokens.len() }
    }

    /// Experts profiled.
    pub fn experts(&self) -> usize {
        self.load.len()
    }

    /// Total load of an expert subset.
    pub fn group_load(&self, group: &[usize]) -> f64 {
        group.iter().map(|&e| self.load[e]).sum()
    }

    /// Load skew factor ρ = W_max / W̄ over a grouping (paper §4.2).
    pub fn load_skew(&self, groups: &[Vec<usize>]) -> f64 {
        assert!(!groups.is_empty());
        let loads: Vec<f64> =
            groups.iter().map(|g| self.group_load(g)).collect();
        let mean = loads.iter().sum::<f64>() / loads.len() as f64;
        if mean == 0.0 {
            return 1.0;
        }
        loads.iter().cloned().fold(0.0, f64::max) / mean
    }

    /// Index of the heaviest group.
    pub fn heaviest_group(&self, groups: &[Vec<usize>]) -> usize {
        (0..groups.len())
            .max_by(|&a, &b| {
                self.group_load(&groups[a])
                    .partial_cmp(&self.group_load(&groups[b]))
                    .unwrap()
            })
            .expect("non-empty groups")
    }

    /// Intra-group affinity utilization U(r) (paper Eq. 1): the fraction
    /// of total pairwise affinity captured inside groups.
    pub fn affinity_utilization(&self, groups: &[Vec<usize>]) -> f64 {
        let e = self.experts();
        let mut total = 0.0;
        for i in 0..e {
            for j in (i + 1)..e {
                total += self.affinity[(i, j)];
            }
        }
        if total == 0.0 {
            return 1.0;
        }
        let mut intra = 0.0;
        for g in groups {
            for (gi, &i) in g.iter().enumerate() {
                for &j in &g[gi + 1..] {
                    intra += self.affinity[(i, j)];
                }
            }
        }
        intra / total
    }
}

impl ModelProfile {
    /// Profile every layer of a gate trace.
    pub fn from_trace(trace: &GateTrace) -> ModelProfile {
        ModelProfile {
            layers: trace
                .layers
                .iter()
                .map(LayerProfile::from_trace)
                .collect(),
        }
    }

    /// Layers profiled.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }
}

/// Group-size deviation S(r) (paper Eq. 2): RMS deviation of group sizes
/// from the ideal `E = n / D`.
pub fn size_deviation(groups: &[Vec<usize>], experts: usize) -> f64 {
    let d = groups.len() as f64;
    let ideal = experts as f64 / d;
    let ss: f64 = groups
        .iter()
        .map(|g| {
            let diff = g.len() as f64 - ideal;
            diff * diff
        })
        .sum();
    (ss / d).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{LayerTrace, Profile, TraceGen};

    fn tiny_layer() -> LayerTrace {
        LayerTrace {
            experts: 4,
            top_k: 2,
            tokens: vec![
                vec![0, 1],
                vec![0, 1],
                vec![0, 2],
                vec![3, 2],
            ],
        }
    }

    #[test]
    fn affinity_counts_pairs_symmetrically() {
        let p = LayerProfile::from_trace(&tiny_layer());
        assert_eq!(p.affinity[(0, 1)], 2.0);
        assert_eq!(p.affinity[(1, 0)], 2.0);
        assert_eq!(p.affinity[(0, 2)], 1.0);
        assert_eq!(p.affinity[(2, 3)], 1.0);
        assert_eq!(p.affinity[(0, 3)], 0.0);
        assert_eq!(p.affinity[(0, 0)], 0.0, "zero diagonal");
    }

    #[test]
    fn load_counts_tokens_per_expert() {
        let p = LayerProfile::from_trace(&tiny_layer());
        assert_eq!(p.load, vec![3.0, 2.0, 2.0, 1.0]);
        assert_eq!(p.tokens, 4);
    }

    #[test]
    fn group_load_and_skew() {
        let p = LayerProfile::from_trace(&tiny_layer());
        let groups = vec![vec![0, 1], vec![2, 3]];
        assert_eq!(p.group_load(&groups[0]), 5.0);
        assert_eq!(p.group_load(&groups[1]), 3.0);
        assert!((p.load_skew(&groups) - 5.0 / 4.0).abs() < 1e-12);
        assert_eq!(p.heaviest_group(&groups), 0);
    }

    #[test]
    fn affinity_utilization_bounds() {
        let p = LayerProfile::from_trace(&tiny_layer());
        let all_in_one = vec![vec![0, 1, 2, 3]];
        assert!((p.affinity_utilization(&all_in_one) - 1.0).abs() < 1e-12);
        let singletons: Vec<Vec<usize>> =
            (0..4).map(|e| vec![e]).collect();
        assert_eq!(p.affinity_utilization(&singletons), 0.0);
        let mixed = vec![vec![0, 1], vec![2, 3]];
        let u = p.affinity_utilization(&mixed);
        assert!(u > 0.0 && u < 1.0);
    }

    #[test]
    fn size_deviation_matches_eq2() {
        // 4 experts, 2 groups, sizes (3,1): ideal 2, dev = sqrt((1+1)/2)=1
        let groups = vec![vec![0, 1, 2], vec![3]];
        assert!((size_deviation(&groups, 4) - 1.0).abs() < 1e-12);
        let even = vec![vec![0, 1], vec![2, 3]];
        assert_eq!(size_deviation(&even, 4), 0.0);
    }

    #[test]
    fn profile_from_generated_trace_is_consistent() {
        let trace = TraceGen {
            experts: 32,
            top_k: 4,
            layers: 2,
            profile: Profile::Text,
            seed: 11,
        }
        .generate(256);
        let p = ModelProfile::from_trace(&trace);
        assert_eq!(p.num_layers(), 2);
        for lp in &p.layers {
            // total load = tokens * k
            let total: f64 = lp.load.iter().sum();
            assert_eq!(total, 256.0 * 4.0);
            // affinity total = tokens * C(k,2) * 2 (symmetric)
            let mut aff = 0.0;
            for i in 0..32 {
                for j in 0..32 {
                    aff += lp.affinity[(i, j)];
                }
            }
            assert_eq!(aff, 256.0 * 6.0 * 2.0);
            assert!(lp.affinity.is_symmetric(0.0));
        }
    }
}
