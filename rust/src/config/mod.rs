//! Experiment configuration: model specs (paper Table 3), workloads
//! (§6.2), and the GPU compute-cost model the simulator uses.
//!
//! Two families of model descriptions exist on purpose:
//!
//! * [`ModelSpec`] — the *paper-scale* architectures (full hidden dims and
//!   layer counts) used by the timing simulator, where per-token costs are
//!   analytic;
//! * the *tiny* variants in `artifacts/manifest.json` (same top-k and
//!   expert counts, scaled-down dims) used by the execute-mode engine for
//!   real numerics through PJRT ([`crate::runtime`]).

use crate::configio::Value;
use crate::stats::{Exponential, Rng};

/// Paper-scale MoE model architecture (Table 3 + public model cards).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelSpec {
    /// Model name (CLI values and report labels).
    pub name: &'static str,
    /// Matching tiny-variant name in artifacts/manifest.json.
    pub tiny_variant: &'static str,
    /// Experts per MoE layer.
    pub experts: usize,
    /// Experts each token activates.
    pub top_k: usize,
    /// MoE layers in the model.
    pub moe_layers: usize,
    /// Hidden (model) dimension.
    pub hidden: usize,
    /// Per-expert FFN intermediate dim.
    pub ffn: usize,
    /// Activation bytes per element (bf16 inference, §6.1).
    pub act_bytes: usize,
}

impl ModelSpec {
    /// OLMoE: 64 experts, top-8, 16 MoE layers, 6.92 B params.
    pub fn olmoe() -> Self {
        ModelSpec {
            name: "olmoe",
            tiny_variant: "olmoe_tiny",
            experts: 64,
            top_k: 8,
            moe_layers: 16,
            hidden: 2048,
            ffn: 1024,
            act_bytes: 2,
        }
    }

    /// DeepSeek-V2-Lite-Chat: 64 experts, top-6, 26 MoE layers, 15.7 B.
    pub fn dsv2_lite() -> Self {
        ModelSpec {
            name: "dsv2_lite",
            tiny_variant: "dsv2_tiny",
            experts: 64,
            top_k: 6,
            moe_layers: 26,
            hidden: 2048,
            ffn: 1408,
            act_bytes: 2,
        }
    }

    /// Qwen3-30B-A3B: 128 experts, top-8, 48 MoE layers, 30.5 B.
    pub fn qwen3() -> Self {
        ModelSpec {
            name: "qwen3",
            tiny_variant: "qwen3_tiny",
            experts: 128,
            top_k: 8,
            moe_layers: 48,
            hidden: 2048,
            ffn: 768,
            act_bytes: 2,
        }
    }

    /// The three evaluated architectures (paper Table 3).
    pub fn all() -> Vec<ModelSpec> {
        vec![Self::olmoe(), Self::dsv2_lite(), Self::qwen3()]
    }

    /// Look a model up by [`ModelSpec::name`].
    pub fn by_name(name: &str) -> Option<ModelSpec> {
        Self::all().into_iter().find(|m| m.name == name)
    }

    /// Bytes moved per token copy in A2A dispatch (one hidden vector).
    pub fn token_bytes(&self) -> f64 {
        (self.hidden * self.act_bytes) as f64
    }

    /// FLOPs of one expert FFN applied to one token (3 GEMMs, 2 flops/MAC).
    pub fn expert_flops_per_token(&self) -> f64 {
        (3 * 2 * self.hidden * self.ffn) as f64
    }

    /// Parameter bytes of one expert (w1, w3, w2 in bf16).
    pub fn expert_bytes(&self) -> f64 {
        (3 * self.hidden * self.ffn * 2) as f64
    }

    /// FLOPs of the dense (attention + norms) part per token per layer.
    pub fn dense_flops_per_token(&self) -> f64 {
        // qkv + out projections dominate: 4·H² MACs → 8·H² flops
        (8 * self.hidden * self.hidden) as f64
    }
}

/// GPU compute model for the simulator: A100-SXM4 bf16.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuModel {
    /// Peak bf16 throughput, FLOP/s.
    pub peak_flops: f64,
    /// Achieved fraction of peak for grouped expert GEMMs.
    pub moe_efficiency: f64,
    /// Achieved fraction of peak for dense attention blocks.
    pub dense_efficiency: f64,
    /// Fixed per-layer kernel overhead, seconds.
    pub layer_overhead: f64,
}

impl GpuModel {
    /// A100-SXM4 bf16 cost model (the paper's testbed GPU).
    pub fn a100() -> Self {
        GpuModel {
            peak_flops: 312e12,
            moe_efficiency: 0.32,
            dense_efficiency: 0.50,
            layer_overhead: 30e-6,
        }
    }

    /// Seconds to run `tokens` token-expert FFNs of `spec` on one GPU.
    pub fn moe_time(&self, spec: &ModelSpec, tokens: f64) -> f64 {
        tokens * spec.expert_flops_per_token()
            / (self.peak_flops * self.moe_efficiency)
    }

    /// Seconds for the dense (attention) part over `tokens` tokens.
    pub fn dense_time(&self, spec: &ModelSpec, tokens: f64) -> f64 {
        tokens * spec.dense_flops_per_token()
            / (self.peak_flops * self.dense_efficiency)
    }
}

/// Inference workload (paper §6.2): `batch` sequences, `prefill` prompt
/// tokens each, `decode` generated tokens each.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Workload {
    /// Concurrent sequences.
    pub batch: usize,
    /// Prompt tokens per sequence.
    pub prefill: usize,
    /// Generated tokens per sequence.
    pub decode: usize,
}

impl Workload {
    /// Workload (i) of §6.2: bs=256, prefill=128, decode=16.
    pub fn heavy_i() -> Self {
        Workload { batch: 256, prefill: 128, decode: 16 }
    }

    /// Workload (ii) of §6.2: bs=512, prefill=64, decode=32.
    pub fn heavy_ii() -> Self {
        Workload { batch: 512, prefill: 64, decode: 32 }
    }

    /// Appendix A.5 light workloads (2×4 cluster).
    pub fn light_i() -> Self {
        Workload { batch: 64, prefill: 128, decode: 16 }
    }

    /// Appendix A.5 light workload (ii).
    pub fn light_ii() -> Self {
        Workload { batch: 128, prefill: 64, decode: 32 }
    }

    /// Compact label for tables (`bs…-pf…-dec…`).
    pub fn label(&self) -> String {
        format!("bs{}-pf{}-dec{}", self.batch, self.prefill, self.decode)
    }

    /// Total tokens pushed through every MoE layer.
    pub fn total_tokens(&self) -> usize {
        self.batch * (self.prefill + self.decode)
    }

    /// Parse from a JSON-style config object.
    pub fn from_value(v: &Value) -> Result<Workload, String> {
        Ok(Workload {
            batch: v.req_usize("batch").map_err(|e| e.to_string())?,
            prefill: v.req_usize("prefill").map_err(|e| e.to_string())?,
            decode: v.req_usize("decode").map_err(|e| e.to_string())?,
        })
    }

    /// Serialise to a JSON-style config object.
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("batch", Value::from(self.batch)),
            ("prefill", Value::from(self.prefill)),
            ("decode", Value::from(self.decode)),
        ])
    }
}

/// Arrival process of a serving workload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalProcess {
    /// Closed loop: every request is enqueued at t = 0 (the benchmark
    /// drain workloads).
    Closed,
    /// Open loop: Poisson arrivals at `rate` requests/second
    /// (exponential interarrival gaps via
    /// [`crate::stats::Exponential`]).
    Poisson {
        /// Requests per second.
        rate: f64,
    },
}

/// Serving-side workload description — what the execute-mode serving
/// front (`grace-moe serve`) and `benches/serving.rs` replay: request
/// count and shape plus the arrival process.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeLoad {
    /// Requests in the workload.
    pub requests: usize,
    /// Prompt tokens per request.
    pub prompt: usize,
    /// Tokens to generate per request.
    pub new_tokens: usize,
    /// When requests reach the admission queue.
    pub arrival: ArrivalProcess,
}

impl ServeLoad {
    /// Arrival times (seconds, ascending) for the workload — all zero
    /// for the closed loop, cumulative exponential gaps for Poisson.
    pub fn arrival_times(&self, rng: &mut Rng) -> Vec<f64> {
        match self.arrival {
            ArrivalProcess::Closed => vec![0.0; self.requests],
            ArrivalProcess::Poisson { rate } => {
                let exp = Exponential::new(rate);
                let mut t = 0.0;
                (0..self.requests)
                    .map(|_| {
                        t += exp.sample(rng);
                        t
                    })
                    .collect()
            }
        }
    }

    /// Compact label for tables.
    pub fn label(&self) -> String {
        let arr = match self.arrival {
            ArrivalProcess::Closed => "closed".to_string(),
            ArrivalProcess::Poisson { rate } => format!("{rate}rps"),
        };
        format!("n{}-pf{}-gen{}-{arr}", self.requests, self.prompt,
                self.new_tokens)
    }

    /// Loud shape validation: a zero-length trace or a non-positive
    /// Poisson rate would otherwise produce an empty replay or an
    /// infinite/NaN arrival schedule deep inside a driver.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.requests > 0,
                        "serve load needs at least one request");
        anyhow::ensure!(self.prompt > 0,
                        "prompt length must be at least 1 token");
        if let ArrivalProcess::Poisson { rate } = self.arrival {
            anyhow::ensure!(rate.is_finite() && rate > 0.0,
                            "Poisson arrival rate must be finite and \
                             positive, got {rate}");
        }
        Ok(())
    }
}

/// Knobs of the predictive-prefetch / weight-tier machinery
/// ([`crate::engine::prefetch`]). `None` at the driver level means the
/// whole subsystem is off and every expert weight is permanently
/// resident (the pre-tier behaviour, bit-identical to older runs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PrefetchConfig {
    /// Run the cross-layer predictor and issue background staging for
    /// its top-k picks. `false` keeps the tiered cache and demand
    /// staging (the prefetch-*off* arm benches compare against).
    pub predictive: bool,
    /// How many predicted next-layer experts to prefetch per round
    /// (`--prefetch-k`).
    pub k: usize,
    /// Hot-tier capacity in experts per GPU (`--weight-budget`);
    /// lookups past it evict LRU into the cold tier.
    pub weight_budget: usize,
    /// EWMA smoothing factor of the co-activation predictor
    /// (`--prefetch-alpha`), in `(0, 1]`.
    pub alpha: f64,
}

impl Default for PrefetchConfig {
    fn default() -> Self {
        PrefetchConfig { predictive: true, k: 4, weight_budget: 8,
                         alpha: 0.3 }
    }
}

impl PrefetchConfig {
    /// Loud shape validation against the model being served: a zero
    /// weight budget can hold no expert at all, a prefetch depth past
    /// the expert count can never be satisfied, and a NaN alpha would
    /// silently poison every EWMA in the predictor.
    pub fn validate(&self, experts_per_layer: usize)
                    -> anyhow::Result<()> {
        anyhow::ensure!(self.weight_budget >= 1,
                        "the hot tier must hold at least one expert, \
                         got --weight-budget 0");
        anyhow::ensure!(self.k >= 1,
                        "--prefetch-k must be at least 1 (use \
                         --prefetch off to disable prediction)");
        anyhow::ensure!(self.k <= experts_per_layer,
                        "--prefetch-k {} exceeds the {} experts per \
                         layer — nothing left to predict",
                        self.k, experts_per_layer);
        anyhow::ensure!(self.alpha.is_finite() && self.alpha > 0.0
                        && self.alpha <= 1.0,
                        "--prefetch-alpha must be a finite value in \
                         (0, 1], got {}", self.alpha);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_is_faithful() {
        let o = ModelSpec::olmoe();
        assert_eq!((o.experts, o.top_k, o.moe_layers), (64, 8, 16));
        let d = ModelSpec::dsv2_lite();
        assert_eq!((d.experts, d.top_k, d.moe_layers), (64, 6, 26));
        let q = ModelSpec::qwen3();
        assert_eq!((q.experts, q.top_k, q.moe_layers), (128, 8, 48));
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(ModelSpec::by_name("qwen3").unwrap().experts, 128);
        assert!(ModelSpec::by_name("gpt5").is_none());
    }

    #[test]
    fn cost_model_sane() {
        let spec = ModelSpec::olmoe();
        let gpu = GpuModel::a100();
        // one token through one expert: 6·2048·1024 ≈ 12.6 MFLOP
        assert!((spec.expert_flops_per_token() - 12_582_912.0).abs() < 1.0);
        let t = gpu.moe_time(&spec, 1000.0);
        assert!(t > 0.0 && t < 1e-2, "1000 token-experts ≈ {t}s");
        assert!(gpu.dense_time(&spec, 1.0) < gpu.moe_time(&spec, 8.0));
        assert_eq!(spec.token_bytes(), 4096.0);
    }

    #[test]
    fn workload_roundtrip() {
        let w = Workload::heavy_i();
        assert_eq!(w.total_tokens(), 256 * 144);
        assert_eq!(w.label(), "bs256-pf128-dec16");
        let v = w.to_value();
        assert_eq!(Workload::from_value(&v).unwrap(), w);
    }

    #[test]
    fn workload_from_bad_value_errors() {
        let v = Value::object(vec![("batch", Value::from(1usize))]);
        assert!(Workload::from_value(&v).is_err());
    }

    #[test]
    fn serve_load_arrival_schedules() {
        let closed = ServeLoad {
            requests: 4,
            prompt: 16,
            new_tokens: 8,
            arrival: ArrivalProcess::Closed,
        };
        let mut rng = Rng::new(1);
        assert_eq!(closed.arrival_times(&mut rng), vec![0.0; 4]);
        assert_eq!(closed.label(), "n4-pf16-gen8-closed");

        let open = ServeLoad {
            arrival: ArrivalProcess::Poisson { rate: 50.0 },
            requests: 2000,
            ..closed
        };
        let times = open.arrival_times(&mut rng);
        assert_eq!(times.len(), 2000);
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "ascending");
        // Mean interarrival ≈ 1/rate over a long schedule.
        let mean_gap = times.last().unwrap() / 2000.0;
        assert!((mean_gap - 0.02).abs() < 0.004, "mean gap {mean_gap}");
        assert!(open.validate().is_ok());
        // Deterministic per seed.
        let again = open.arrival_times(&mut Rng::new(1));
        let first = {
            let mut rng = Rng::new(1);
            let _ = closed.arrival_times(&mut rng); // closed draws nothing
            open.arrival_times(&mut rng)
        };
        assert_eq!(again, first);
    }

    #[test]
    fn serve_load_validation_is_loud() {
        let good = ServeLoad {
            requests: 4,
            prompt: 16,
            new_tokens: 8,
            arrival: ArrivalProcess::Closed,
        };
        assert!(good.validate().is_ok());
        assert!(ServeLoad { requests: 0, ..good }.validate().is_err());
        assert!(ServeLoad { prompt: 0, ..good }.validate().is_err());
        for rate in [0.0, -1.0, f64::NAN, f64::INFINITY] {
            let bad = ServeLoad {
                arrival: ArrivalProcess::Poisson { rate },
                ..good
            };
            assert!(bad.validate().is_err(), "rate {rate} accepted");
        }
    }

    #[test]
    fn prefetch_config_validation_is_loud() {
        let good = PrefetchConfig::default();
        assert!(good.validate(64).is_ok());
        // Non-predictive arm still has to satisfy the tier knobs.
        assert!(PrefetchConfig { predictive: false, ..good }
            .validate(64)
            .is_ok());

        let zero_budget = PrefetchConfig { weight_budget: 0, ..good };
        let msg = zero_budget.validate(64).unwrap_err().to_string();
        assert!(msg.contains("--weight-budget 0"), "msg: {msg}");

        let deep = PrefetchConfig { k: 65, ..good };
        let msg = deep.validate(64).unwrap_err().to_string();
        assert!(msg.contains("--prefetch-k 65"), "msg: {msg}");
        assert!(PrefetchConfig { k: 64, ..good }.validate(64).is_ok());
        assert!(PrefetchConfig { k: 0, ..good }.validate(64).is_err());

        for alpha in [f64::NAN, 0.0, -0.5, 1.5, f64::INFINITY] {
            let bad = PrefetchConfig { alpha, ..good };
            assert!(bad.validate(64).is_err(), "alpha {alpha} accepted");
        }
        assert!(PrefetchConfig { alpha: 1.0, ..good }.validate(64)
            .is_ok());
    }
}
