//! Sampling distributions used by the trace generators and routing.
//!
//! * [`Zipf`] — the skewed expert-popularity law ("hot" vs "cold" experts,
//!   paper §1); bounded support so we precompute the normalized pmf.
//! * [`AliasTable`] — Walker/Vose O(1) categorical sampling; this is also
//!   the weighted-random-choice primitive behind the paper's Algorithm 3
//!   (weighted round-robin replica selection).
//! * [`Exponential`] — interarrival gaps of the serving front's open-loop
//!   Poisson arrival generator (`--arrival-rate`).

use super::rng::Rng;

/// Zipf(n, s): `P(k) ∝ 1 / (k+1)^s` over `k ∈ [0, n)`.
#[derive(Clone, Debug)]
pub struct Zipf {
    pmf: Vec<f64>,
    alias: AliasTable,
}

impl Zipf {
    /// Zipf over `n` ranks with exponent `s` (`s = 0` is uniform).
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0, "Zipf over empty support");
        let mut pmf: Vec<f64> = (0..n)
            .map(|k| 1.0 / ((k + 1) as f64).powf(s))
            .collect();
        let z: f64 = pmf.iter().sum();
        for p in &mut pmf {
            *p /= z;
        }
        let alias = AliasTable::new(&pmf);
        Zipf { pmf, alias }
    }

    /// Probability of rank `k`.
    pub fn pmf(&self, k: usize) -> f64 {
        self.pmf[k]
    }

    /// Size of the support.
    pub fn support(&self) -> usize {
        self.pmf.len()
    }

    /// Draw one rank in `[0, n)`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        self.alias.sample(rng)
    }
}

/// Walker/Vose alias method: O(n) build, O(1) sample.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    /// Build from unnormalised non-negative weights (at least one > 0).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table over empty weights");
        let total: f64 = weights.iter().sum();
        assert!(
            total > 0.0 && weights.iter().all(|w| *w >= 0.0),
            "weights must be non-negative with positive sum"
        );
        let mut scaled: Vec<f64> =
            weights.iter().map(|w| w / total * n as f64).collect();
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &p) in scaled.iter().enumerate() {
            if p < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        let mut prob = vec![1.0; n];
        let mut alias: Vec<usize> = (0..n).collect();
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        // Leftovers are ~1.0 up to float error.
        for &i in small.iter().chain(large.iter()) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    /// Draw one index with probability proportional to its weight.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.index(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Number of categories.
    pub fn len(&self) -> usize {
        self.prob.len()
    }

    /// Always `false` (construction requires non-empty weights).
    pub fn is_empty(&self) -> bool {
        self.prob.is_empty()
    }
}

/// Exponential(rate): interarrival gaps of a Poisson process with `rate`
/// events per second — the open-loop arrival model of the serving bench
/// and the CLI's `--arrival-rate`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Exponential {
    rate: f64,
}

impl Exponential {
    /// Exponential distribution with `rate` events per unit time.
    pub fn new(rate: f64) -> Exponential {
        assert!(rate > 0.0 && rate.is_finite(),
                "Exponential rate must be positive, got {rate}");
        Exponential { rate }
    }

    /// Events per unit time.
    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Mean gap (`1 / rate`).
    pub fn mean(&self) -> f64 {
        1.0 / self.rate
    }

    /// Draw one gap via inverse-CDF (`-ln(1 - u) / rate`); `u < 1`
    /// always, so the draw is finite.
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        -(1.0 - rng.f64()).ln() / self.rate
    }
}

/// Weighted choice without table build (O(n)); fine for tiny candidate
/// sets like per-tier replica lists in TAR.
pub fn weighted_choice(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    assert!(total > 0.0, "weighted_choice: zero total weight");
    let mut x = rng.f64() * total;
    for (i, &w) in weights.iter().enumerate() {
        x -= w;
        if x <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn empirical(table: &AliasTable, n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut counts = vec![0usize; table.len()];
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        counts.iter().map(|&c| c as f64 / n as f64).collect()
    }

    #[test]
    fn alias_matches_weights() {
        let w = [1.0, 2.0, 3.0, 4.0];
        let t = AliasTable::new(&w);
        let emp = empirical(&t, 100_000, 1);
        for (i, &wi) in w.iter().enumerate() {
            let want = wi / 10.0;
            assert!((emp[i] - want).abs() < 0.01, "i={i} emp={emp:?}");
        }
    }

    #[test]
    fn alias_handles_zero_weights() {
        let t = AliasTable::new(&[0.0, 1.0, 0.0]);
        let mut rng = Rng::new(2);
        for _ in 0..1000 {
            assert_eq!(t.sample(&mut rng), 1);
        }
    }

    #[test]
    fn alias_single_element() {
        let t = AliasTable::new(&[3.3]);
        let mut rng = Rng::new(3);
        assert_eq!(t.sample(&mut rng), 0);
    }

    #[test]
    #[should_panic]
    fn alias_rejects_all_zero() {
        AliasTable::new(&[0.0, 0.0]);
    }

    #[test]
    fn zipf_is_monotone_and_normalised() {
        let z = Zipf::new(64, 1.2);
        let total: f64 = (0..64).map(|k| z.pmf(k)).sum();
        assert!((total - 1.0).abs() < 1e-12);
        for k in 1..64 {
            assert!(z.pmf(k) <= z.pmf(k - 1));
        }
    }

    #[test]
    fn zipf_sampling_matches_pmf_head() {
        let z = Zipf::new(16, 1.0);
        let mut rng = Rng::new(4);
        let n = 200_000;
        let mut counts = vec![0usize; 16];
        for _ in 0..n {
            counts[z.sample(&mut rng)] += 1;
        }
        for k in 0..4 {
            let emp = counts[k] as f64 / n as f64;
            assert!((emp - z.pmf(k)).abs() < 0.01, "k={k}");
        }
    }

    #[test]
    fn zipf_s_zero_is_uniform() {
        let z = Zipf::new(10, 0.0);
        for k in 0..10 {
            assert!((z.pmf(k) - 0.1).abs() < 1e-12);
        }
    }

    #[test]
    fn exponential_mean_and_support() {
        let exp = Exponential::new(4.0);
        assert_eq!(exp.mean(), 0.25);
        let mut rng = Rng::new(8);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = exp.sample(&mut rng);
            assert!(x >= 0.0 && x.is_finite());
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean={mean}");
    }

    #[test]
    #[should_panic]
    fn exponential_rejects_zero_rate() {
        Exponential::new(0.0);
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut rng = Rng::new(5);
        let w = [0.0, 5.0, 5.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[weighted_choice(&mut rng, &w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!((counts[1] as f64 - 5_000.0).abs() < 300.0);
    }
}
