//! Summary statistics for metric reporting: mean/std/min/max/percentiles.
//!
//! Every table in the paper reports either means, standard deviations (the
//! "AVG. GPU LOAD STD." metric), or tail latencies; this is the shared
//! accumulator behind all of them.

/// Immutable summary over a sample of f64s.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    sorted: Vec<f64>,
    mean: f64,
    std: f64,
}

impl Summary {
    /// Summarise a non-empty sample.
    pub fn of(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "Summary over empty sample");
        let mut sorted = values.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in summary"));
        let n = sorted.len() as f64;
        let mean = sorted.iter().sum::<f64>() / n;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n;
        Summary { sorted, mean, std: var.sqrt() }
    }

    /// Sample mean.
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population standard deviation (paper's load-std metric).
    pub fn std(&self) -> f64 {
        self.std
    }

    /// Smallest sample value.
    pub fn min(&self) -> f64 {
        self.sorted[0]
    }

    /// Largest sample value.
    pub fn max(&self) -> f64 {
        *self.sorted.last().unwrap()
    }

    /// Sum of the sample.
    pub fn sum(&self) -> f64 {
        self.mean * self.sorted.len() as f64
    }

    /// Sample size.
    pub fn len(&self) -> usize {
        self.sorted.len()
    }

    /// Always `false` (construction requires a non-empty sample).
    pub fn is_empty(&self) -> bool {
        false // construction requires non-empty
    }

    /// Linear-interpolated percentile, `q ∈ [0, 100]`.
    pub fn percentile(&self, q: f64) -> f64 {
        assert!((0.0..=100.0).contains(&q));
        let n = self.sorted.len();
        if n == 1 {
            return self.sorted[0];
        }
        let pos = q / 100.0 * (n - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        self.sorted[lo] * (1.0 - frac) + self.sorted[hi] * frac
    }

    /// Median.
    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    /// 95th percentile (serving-SLO tail).
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    /// 99th percentile (tail latency).
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Coefficient of variation — scale-free imbalance measure.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 { 0.0 } else { self.std / self.mean }
    }
}

/// Relative change `(new - base) / base`, the form Table 1 reports
/// ("-35.19%" == -0.3519). Returns 0 when the base is 0.
pub fn rel_change(base: f64, new: f64) -> f64 {
    if base == 0.0 {
        0.0
    } else {
        (new - base) / base
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean(), 2.5);
        assert!((s.std() - 1.118_033_988_749_895).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
        assert_eq!(s.sum(), 10.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let s = Summary::of(&[0.0, 10.0]);
        assert_eq!(s.percentile(0.0), 0.0);
        assert_eq!(s.percentile(50.0), 5.0);
        assert_eq!(s.percentile(100.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.mean(), 7.0);
        assert_eq!(s.std(), 0.0);
        assert_eq!(s.p95(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.p50(), 2.0);
    }

    #[test]
    fn cv_zero_mean() {
        let s = Summary::of(&[0.0, 0.0]);
        assert_eq!(s.cv(), 0.0);
    }

    #[test]
    fn rel_change_forms() {
        assert!((rel_change(100.0, 64.81) + 0.3519).abs() < 1e-12);
        assert_eq!(rel_change(0.0, 5.0), 0.0);
        assert!((rel_change(2.0, 4.0) - 1.0).abs() < 1e-12);
    }
}
