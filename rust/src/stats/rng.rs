//! Deterministic PRNG: xoshiro256\*\* with SplitMix64 seeding.
//!
//! Every stochastic component in the crate (trace generation, WRR's
//! weighted random choice, workload arrival jitter, property tests) draws
//! from this generator so that runs are reproducible from a single `u64`
//! seed recorded in the experiment logs.

/// SplitMix64 step — used to expand a single seed into the xoshiro state
/// and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256\*\* (Blackman & Vigna) — fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached spare gaussian from Box–Muller.
    spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Derive an independent stream (for per-layer / per-GPU generators).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare: None }
    }

    /// Next raw 64-bit output of the generator.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift with rejection).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard gaussian via Box–Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            let u2 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "counts={counts:?}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            s += z;
            s2 += z * z;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(10, 4);
        assert_eq!(s.len(), 4);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 4);
        assert!(s.iter().all(|&i| i < 10));
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(42);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
