//! Statistics substrate: deterministic PRNG, sampling distributions, and
//! summary statistics.
//!
//! The offline registry has no `rand`/`rand_distr`, so this module
//! implements the pieces the rest of the crate needs from scratch:
//!
//! * [`rng::Rng`] — xoshiro256\*\* seeded through SplitMix64,
//! * [`dist`] — Zipf (the paper's skewed expert-popularity model), alias
//!   tables for fast categorical sampling, Box–Muller gaussians,
//! * [`summary`] — mean / std / percentiles used by every metric table.

pub mod dist;
pub mod rng;
pub mod summary;

pub use dist::{AliasTable, Exponential, Zipf};
pub use rng::Rng;
pub use summary::Summary;
