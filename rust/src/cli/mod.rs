//! Minimal CLI argument parser (no `clap` offline): subcommands,
//! `--key value` / `--key=value` options, `--flag` booleans, positionals.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// First bare token (the subcommand).
    pub subcommand: Option<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Boolean `--flag`s seen (must be listed in `known_flags`).
    pub flags: Vec<String>,
    /// Remaining bare tokens after the subcommand.
    pub positionals: Vec<String>,
}

/// Argument-parsing errors.
#[derive(Debug)]
pub enum CliError {
    /// `--key` appeared as the final token with no value following.
    MissingValue(String),
    /// `--key value` failed to parse as the requested type.
    BadValue { key: String, msg: String },
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::MissingValue(name) => {
                write!(f, "option --{name} requires a value")
            }
            CliError::BadValue { key, msg } => {
                write!(f, "option --{key}: {msg}")
            }
        }
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw argv (excluding the program name). `known_flags` lists
    /// boolean options that never take a value; everything else starting
    /// with `--` consumes the next token (or its `=`-suffix).
    pub fn parse<I, S>(argv: I, known_flags: &[&str]) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let toks: Vec<String> = argv.into_iter().map(Into::into).collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(name) = t.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&name) {
                    args.flags.push(name.to_string());
                } else if i + 1 < toks.len() {
                    args.options
                        .insert(name.to_string(), toks[i + 1].clone());
                    i += 1;
                } else {
                    return Err(CliError::MissingValue(name.to_string()));
                }
            } else if args.subcommand.is_none() && args.positionals.is_empty()
            {
                args.subcommand = Some(t.clone());
            } else {
                args.positionals.push(t.clone());
            }
            i += 1;
        }
        Ok(args)
    }

    /// Whether boolean flag `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option with a default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.options.get(key).map(String::as_str).unwrap_or(default)
    }

    /// Raw option value, if present.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    /// `usize` option with a default; malformed values are errors.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                msg: format!("'{s}' is not a non-negative integer"),
            }),
        }
    }

    /// `f64` option with a default; malformed values are errors.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                msg: format!("'{s}' is not a number"),
            }),
        }
    }

    /// `u64` option with a default; malformed values are errors.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.options.get(key) {
            None => Ok(default),
            Some(s) => s.parse().map_err(|_| CliError::BadValue {
                key: key.into(),
                msg: format!("'{s}' is not a u64"),
            }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_subcommand_options_flags() {
        let a = Args::parse(
            vec!["serve", "--model", "olmoe_tiny", "--verbose",
                 "--nodes=2", "input.json"],
            &["verbose"],
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.str_or("model", "x"), "olmoe_tiny");
        assert_eq!(a.usize_or("nodes", 1).unwrap(), 2);
        assert!(a.flag("verbose"));
        assert_eq!(a.positionals, vec!["input.json"]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--model"], &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = Args::parse(vec!["--n", "abc"], &[]).unwrap();
        assert!(a.usize_or("n", 1).is_err());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(Vec::<String>::new(), &[]).unwrap();
        assert_eq!(a.usize_or("n", 7).unwrap(), 7);
        assert_eq!(a.str_or("s", "d"), "d");
        assert!(!a.flag("x"));
    }
}
