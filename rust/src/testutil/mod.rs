//! Property-testing helper ("proptest-lite"): no `proptest` crate is
//! available offline, so this provides the 10% of it the test suite needs —
//! seeded random case generation with automatic failing-seed reporting.
//!
//! ```ignore
//! testutil::check(200, |rng| {
//!     let n = 1 + rng.index(64);
//!     let part = some_partition(n, rng);
//!     prop_assert(is_partition(&part, n), "partition broken")
//! });
//! ```

use crate::stats::Rng;

/// Outcome of a single property case.
pub type PropResult = Result<(), String>;

/// Assert helper for property bodies.
pub fn prop_assert(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Approximate float equality helper.
pub fn prop_close(a: f64, b: f64, tol: f64, what: &str) -> PropResult {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

/// Run `cases` random property cases; panics with the failing case's seed
/// (re-run just that seed with [`check_seed`] while debugging).
pub fn check(cases: usize, prop: impl Fn(&mut Rng) -> PropResult) {
    // Base seed is fixed for reproducible CI; per-case forks decorrelate.
    for case in 0..cases {
        let seed = 0x5EED_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property failed (case {case}, seed {seed:#x}): {msg}");
        }
    }
}

/// Re-run a single failing seed.
pub fn check_seed(seed: u64, prop: impl Fn(&mut Rng) -> PropResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property failed (seed {seed:#x}): {msg}");
    }
}

/// Deterministic stand-in for greedy decode: the "next token" is a hash
/// of the prefix, so the output depends only on the sequence — never on
/// batch composition — exactly the independence the real per-row
/// decoder has. Shared by the scheduler unit tests, `tests/serving.rs`,
/// and `benches/serving.rs` so all three provably exercise the same
/// fake engine.
pub fn fake_decode_token(ids: &[i32]) -> i32 {
    (ids.iter()
        .fold(7i64, |a, &t| a.wrapping_mul(31).wrapping_add(t as i64))
        .rem_euclid(97)) as i32
}

/// Deterministic KV-aware fake serving engine for the scheduler harness
/// ([`crate::server::sched::simulate_serve`]): decoded tokens come from
/// [`fake_decode_token`] — a pure function of the prefix — so outputs are
/// identical with the cache on or off (the sim-level analogue of the
/// real engine's cached/recompute parity), while the *cost* follows the
/// real packing rule: per step, `layers × ⌈computed / tile_t⌉` dispatch
/// rounds, where `computed` is the sum of uncached suffixes with the
/// cache on and of full prefix lengths with it off. It also mirrors the
/// per-request cache lifecycle (populate on step, evict on retirement)
/// so eviction tests can assert no cache outlives its request. Shared by
/// `tests/serving.rs` and `benches/kv_cache.rs`.
pub struct FakeKvEngine {
    layers: usize,
    tile_t: usize,
    kv: bool,
    /// Live "caches": request id → cached prefix length.
    caches: std::collections::HashMap<u64, usize>,
    /// High-water mark of simultaneously live caches.
    peak_caches: usize,
}

impl FakeKvEngine {
    /// Engine with the given layer count and MoE tile size; `kv` picks
    /// cached or full-recompute costing.
    pub fn new(layers: usize, tile_t: usize, kv: bool) -> FakeKvEngine {
        FakeKvEngine {
            layers,
            tile_t,
            kv,
            caches: std::collections::HashMap::new(),
            peak_caches: 0,
        }
    }

    /// One serving step over `(id, prefix, cached length)` microbatch
    /// triples — the [`crate::server::sched::simulate_serve`] interface.
    /// Errors if the scheduler's cached-length pricing ever disagrees
    /// with the engine's own cache state (the lockstep the real server
    /// debug-asserts).
    pub fn step(&mut self, seqs: &[(u64, &[i32], usize)])
                -> anyhow::Result<(Vec<i32>, usize)> {
        let mut computed = 0usize;
        for &(id, ids, cached) in seqs {
            if self.kv {
                let have = self.caches.get(&id).copied().unwrap_or(0);
                anyhow::ensure!(
                    have == cached,
                    "request {id}: scheduler prices {cached} cached \
                     tokens, engine cache holds {have}"
                );
                computed += ids.len() - cached;
                self.caches.insert(id, ids.len());
            } else {
                computed += ids.len();
            }
        }
        self.peak_caches = self.peak_caches.max(self.caches.len());
        let rounds = self.layers * computed.div_ceil(self.tile_t);
        Ok((seqs.iter().map(|&(_, ids, _)| fake_decode_token(ids))
                .collect(),
            rounds))
    }

    /// Evict a retired request's cache (wire to the harness's
    /// retirement hook).
    pub fn retire(&mut self, id: u64) {
        self.caches.remove(&id);
    }

    /// Mirror a scheduler preemption: when the scheduler dropped the
    /// victim's cache (over the retain cap, or KV off), free the
    /// engine-side entry too — exactly what `server::drive` does on
    /// [`crate::server::SchedEvent::Preempted`]. A retained cache stays
    /// warm for resume.
    pub fn preempt(&mut self, id: u64, cache_dropped: bool) {
        if cache_dropped {
            self.caches.remove(&id);
        }
    }

    /// Total cached tokens currently held (live + retained).
    pub fn cached_tokens(&self) -> usize {
        self.caches.values().sum()
    }

    /// Caches currently live.
    pub fn live_caches(&self) -> usize {
        self.caches.len()
    }

    /// Most caches ever simultaneously live.
    pub fn peak_caches(&self) -> usize {
        self.peak_caches
    }
}

/// Generate a random partition sizing: `k` non-negative integers summing to
/// `total` (common generator for load/size vectors).
pub fn random_sizes(rng: &mut Rng, k: usize, total: usize) -> Vec<usize> {
    assert!(k > 0);
    let mut cuts: Vec<usize> =
        (0..k - 1).map(|_| rng.index(total + 1)).collect();
    cuts.sort_unstable();
    let mut sizes = Vec::with_capacity(k);
    let mut prev = 0;
    for c in cuts {
        sizes.push(c - prev);
        prev = c;
    }
    sizes.push(total - prev);
    sizes
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        // interior mutability via Cell to count invocations
        let counter = std::cell::Cell::new(0usize);
        check(50, |_rng| {
            counter.set(counter.get() + 1);
            Ok(())
        });
        count += counter.get();
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        check(10, |rng| prop_assert(rng.f64() < 0.5, "coin came up heads"));
    }

    #[test]
    fn random_sizes_sum_and_len() {
        check(100, |rng| {
            let k = 1 + rng.index(10);
            let total = rng.index(1000);
            let s = random_sizes(rng, k, total);
            prop_assert(s.len() == k, "len")?;
            prop_assert(s.iter().sum::<usize>() == total, "sum")
        });
    }

    #[test]
    fn prop_close_tolerance() {
        assert!(prop_close(1.0, 1.0001, 0.001, "x").is_ok());
        assert!(prop_close(1.0, 1.1, 0.001, "x").is_err());
    }
}
