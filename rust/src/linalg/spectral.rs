//! Normalized spectral clustering (Ng–Jordan–Weiss) on affinity matrices.
//!
//! This is the clustering primitive behind the paper's §4.1: "Spectral
//! clustering produces groups with dense intra-connections and sparse
//! inter-connections, aligning with our communication-centric goal."
//!
//! Pipeline: symmetric-normalized Laplacian `L = I − D^{-1/2} A D^{-1/2}`
//! → k smallest eigenvectors ([`crate::linalg::eigh`]) → row-normalize →
//! k-means++ on the embedding.

use super::jacobi::eigh;
use super::kmeans::kmeans;
use super::matrix::Matrix;
use crate::stats::Rng;

/// Spectral embedding: rows of the k smallest normalized-Laplacian
/// eigenvectors, row-normalized to the unit sphere.
pub fn spectral_embedding(affinity: &Matrix, k: usize) -> Vec<Vec<f64>> {
    let n = affinity.rows();
    assert!(affinity.is_symmetric(1e-9), "affinity must be symmetric");
    assert!(k >= 1 && k <= n);

    // Degree (add a tiny floor so isolated experts don't divide by zero).
    let deg: Vec<f64> = (0..n)
        .map(|i| affinity.row(i).iter().sum::<f64>().max(1e-12))
        .collect();
    let mut lap = Matrix::from_fn(n, n, |i, j| {
        let norm = -affinity[(i, j)] / (deg[i] * deg[j]).sqrt();
        if i == j { 1.0 + norm } else { norm }
    });
    // Symmetrize against float error before Jacobi.
    for i in 0..n {
        for j in (i + 1)..n {
            let m = 0.5 * (lap[(i, j)] + lap[(j, i)]);
            lap[(i, j)] = m;
            lap[(j, i)] = m;
        }
    }

    let (_vals, vecs) = eigh(&lap);
    // k smallest eigenvalues = first k columns (eigh sorts ascending).
    let mut rows: Vec<Vec<f64>> = (0..n)
        .map(|i| (0..k).map(|c| vecs[(i, c)]).collect())
        .collect();
    for r in &mut rows {
        let norm = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm > 1e-12 {
            for x in r.iter_mut() {
                *x /= norm;
            }
        }
    }
    rows
}

/// Full spectral clustering: returns a cluster id in `[0, k)` per node.
///
/// Runs k-means++ `restarts` times and keeps the lowest-inertia result
/// (spectral + Lloyd is sensitive to seeding; restarts make the offline
/// grouping phase stable).
pub fn spectral_cluster(affinity: &Matrix, k: usize, rng: &mut Rng,
                        restarts: usize) -> Vec<usize> {
    let emb = spectral_embedding(affinity, k);
    let mut best: Option<(f64, Vec<usize>)> = None;
    for _ in 0..restarts.max(1) {
        let r = kmeans(&emb, k, rng, 100);
        if best.as_ref().map_or(true, |(bi, _)| r.inertia < *bi) {
            best = Some((r.inertia, r.assignment));
        }
    }
    best.unwrap().1
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Block-diagonal affinity with `k` planted communities.
    fn planted(n_per: usize, k: usize, p_in: f64, p_out: f64,
               rng: &mut Rng) -> Matrix {
        let n = n_per * k;
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                let same = i / n_per == j / n_per;
                let w = if same { p_in } else { p_out } * (0.5 + rng.f64());
                a[(i, j)] = w;
                a[(j, i)] = w;
            }
        }
        a
    }

    #[test]
    fn recovers_planted_communities() {
        let mut rng = Rng::new(31);
        let a = planted(8, 3, 1.0, 0.02, &mut rng);
        let ids = spectral_cluster(&a, 3, &mut rng, 5);
        for b in 0..3 {
            let block: Vec<usize> =
                (b * 8..(b + 1) * 8).map(|i| ids[i]).collect();
            assert!(
                block.iter().all(|&c| c == block[0]),
                "block {b} split: {block:?}"
            );
        }
        // blocks land in distinct clusters
        let mut reps: Vec<usize> = (0..3).map(|b| ids[b * 8]).collect();
        reps.sort_unstable();
        reps.dedup();
        assert_eq!(reps.len(), 3);
    }

    #[test]
    fn embedding_rows_unit_norm() {
        let mut rng = Rng::new(37);
        let a = planted(5, 2, 1.0, 0.1, &mut rng);
        let emb = spectral_embedding(&a, 2);
        for r in emb {
            let norm: f64 = r.iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((norm - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn isolated_nodes_dont_crash() {
        let a = Matrix::zeros(6, 6);
        let mut rng = Rng::new(41);
        let ids = spectral_cluster(&a, 2, &mut rng, 2);
        assert_eq!(ids.len(), 6);
        assert!(ids.iter().all(|&c| c < 2));
    }
}
