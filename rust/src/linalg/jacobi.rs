//! Cyclic Jacobi eigendecomposition for real symmetric matrices.
//!
//! Affinity matrices here are at most 128×128 (Qwen3's expert count), where
//! the classic Jacobi rotation sweep converges in a handful of passes with
//! near-machine accuracy and needs no pivoting heuristics.

use super::matrix::Matrix;

/// Eigendecomposition `A = V diag(λ) Vᵀ` of a symmetric matrix.
///
/// Returns `(eigenvalues, eigenvectors)` sorted by ascending eigenvalue;
/// `eigenvectors.col(k)` (column k) is the unit eigenvector of `λ_k`.
pub fn eigh(a: &Matrix) -> (Vec<f64>, Matrix) {
    assert!(a.is_symmetric(1e-9), "eigh requires a symmetric matrix");
    let n = a.rows();
    let mut m = a.clone();
    let mut v = Matrix::identity(n);

    let max_sweeps = 64;
    let tol = 1e-12_f64;
    for _sweep in 0..max_sweeps {
        if m.offdiag_max() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < tol {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                // Rotation angle: tan(2θ) = 2 a_pq / (a_pp - a_qq)
                let theta = 0.5 * (2.0 * apq).atan2(app - aqq);
                let (s, c) = theta.sin_cos();

                // Apply Gᵀ A G in place (rows/cols p and q).
                for k in 0..n {
                    let akp = m[(k, p)];
                    let akq = m[(k, q)];
                    m[(k, p)] = c * akp + s * akq;
                    m[(k, q)] = -s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = m[(p, k)];
                    let aqk = m[(q, k)];
                    m[(p, k)] = c * apk + s * aqk;
                    m[(q, k)] = -s * apk + c * aqk;
                }
                // Accumulate eigenvectors.
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp + s * vkq;
                    v[(k, q)] = -s * vkp + c * vkq;
                }
            }
        }
    }

    // Extract and sort ascending.
    let mut order: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    order.sort_by(|&i, &j| evals[i].partial_cmp(&evals[j]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| evals[i]).collect();
    let sorted_vecs =
        Matrix::from_fn(n, n, |r, c| v[(r, order[c])]);
    (sorted_vals, sorted_vecs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::Rng;

    fn reconstruct(vals: &[f64], vecs: &Matrix) -> Matrix {
        let n = vals.len();
        let mut lam = Matrix::zeros(n, n);
        for i in 0..n {
            lam[(i, i)] = vals[i];
        }
        vecs.matmul(&lam).matmul(&vecs.transpose())
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::from_rows(&[
            vec![3.0, 0.0, 0.0],
            vec![0.0, 1.0, 0.0],
            vec![0.0, 0.0, 2.0],
        ]);
        let (vals, _) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 2.0).abs() < 1e-10);
        assert!((vals[2] - 3.0).abs() < 1e-10);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 1 and 3.
        let a = Matrix::from_rows(&[vec![2.0, 1.0], vec![1.0, 2.0]]);
        let (vals, vecs) = eigh(&a);
        assert!((vals[0] - 1.0).abs() < 1e-10);
        assert!((vals[1] - 3.0).abs() < 1e-10);
        // eigenvector of 3 is (1,1)/√2 up to sign
        let v = vecs.col(1);
        assert!((v[0].abs() - std::f64::consts::FRAC_1_SQRT_2).abs() < 1e-8);
        assert!((v[0] - v[1]).abs() < 1e-8);
    }

    #[test]
    fn random_reconstruction() {
        let mut rng = Rng::new(17);
        for n in [3usize, 8, 20, 50] {
            let mut a = Matrix::zeros(n, n);
            for i in 0..n {
                for j in i..n {
                    let x = rng.gaussian();
                    a[(i, j)] = x;
                    a[(j, i)] = x;
                }
            }
            let (vals, vecs) = eigh(&a);
            let r = reconstruct(&vals, &vecs);
            for i in 0..n {
                for j in 0..n {
                    assert!(
                        (r[(i, j)] - a[(i, j)]).abs() < 1e-7,
                        "n={n} ({i},{j}): {} vs {}",
                        r[(i, j)],
                        a[(i, j)]
                    );
                }
            }
            // ascending order
            for k in 1..n {
                assert!(vals[k] >= vals[k - 1] - 1e-10);
            }
        }
    }

    #[test]
    fn eigenvectors_orthonormal() {
        let mut rng = Rng::new(23);
        let n = 16;
        let mut a = Matrix::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                let x = rng.f64();
                a[(i, j)] = x;
                a[(j, i)] = x;
            }
        }
        let (_, vecs) = eigh(&a);
        let vtv = vecs.transpose().matmul(&vecs);
        for i in 0..n {
            for j in 0..n {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((vtv[(i, j)] - want).abs() < 1e-8);
            }
        }
    }
}
