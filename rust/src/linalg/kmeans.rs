//! k-means++ clustering on embedded rows (final step of spectral
//! clustering). Deterministic given the caller-supplied RNG seed.

use crate::stats::Rng;

/// Result of a k-means run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    /// Cluster id per point, in `[0, k)`.
    pub assignment: Vec<usize>,
    /// Final centroids, `k × dim`.
    pub centroids: Vec<Vec<f64>>,
    /// Sum of squared distances to assigned centroids.
    pub inertia: f64,
    /// Iterations executed until convergence (or the cap).
    pub iterations: usize,
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// k-means++ with Lloyd iterations.
///
/// Empty clusters are re-seeded with the point farthest from its centroid,
/// so the result always uses exactly `k` clusters when `points.len() >= k`.
pub fn kmeans(points: &[Vec<f64>], k: usize, rng: &mut Rng,
              max_iters: usize) -> KMeansResult {
    let n = points.len();
    assert!(k > 0 && n >= k, "kmeans: need at least k={k} points, got {n}");
    let dim = points[0].len();

    // k-means++ seeding.
    let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
    centroids.push(points[rng.index(n)].clone());
    let mut d2: Vec<f64> =
        points.iter().map(|p| dist2(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 0.0 {
            // all points coincide with some centroid; pick any
            rng.index(n)
        } else {
            let mut x = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                x -= d;
                if x <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centroids.push(points[next].clone());
        for (i, p) in points.iter().enumerate() {
            let d = dist2(p, centroids.last().unwrap());
            if d < d2[i] {
                d2[i] = d;
            }
        }
    }

    let mut assignment = vec![0usize; n];
    let mut iterations = 0;
    for it in 0..max_iters {
        iterations = it + 1;
        // Assign.
        let mut changed = false;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, cen) in centroids.iter().enumerate() {
                let d = dist2(p, cen);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // Update.
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            counts[assignment[i]] += 1;
            for (s, &x) in sums[assignment[i]].iter_mut().zip(p) {
                *s += x;
            }
        }
        for c in 0..k {
            if counts[c] == 0 {
                // Re-seed with the farthest point from its centroid.
                let far = (0..n)
                    .max_by(|&a, &b| {
                        dist2(&points[a], &centroids[assignment[a]])
                            .partial_cmp(&dist2(
                                &points[b],
                                &centroids[assignment[b]],
                            ))
                            .unwrap()
                    })
                    .unwrap();
                centroids[c] = points[far].clone();
                changed = true;
            } else {
                for (j, s) in sums[c].iter().enumerate() {
                    centroids[c][j] = s / counts[c] as f64;
                }
            }
        }
        if !changed && it > 0 {
            break;
        }
    }

    let inertia = points
        .iter()
        .enumerate()
        .map(|(i, p)| dist2(p, &centroids[assignment[i]]))
        .sum();
    KMeansResult { assignment, centroids, inertia, iterations }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(center: (f64, f64), n: usize, rng: &mut Rng) -> Vec<Vec<f64>> {
        (0..n)
            .map(|_| {
                vec![
                    center.0 + 0.1 * rng.gaussian(),
                    center.1 + 0.1 * rng.gaussian(),
                ]
            })
            .collect()
    }

    #[test]
    fn separates_obvious_blobs() {
        let mut rng = Rng::new(1);
        let mut pts = blob((0.0, 0.0), 20, &mut rng);
        pts.extend(blob((10.0, 10.0), 20, &mut rng));
        pts.extend(blob((0.0, 10.0), 20, &mut rng));
        let r = kmeans(&pts, 3, &mut rng, 50);
        // points in the same blob share a cluster id
        for chunk in [0..20, 20..40, 40..60] {
            let ids: Vec<usize> =
                chunk.clone().map(|i| r.assignment[i]).collect();
            assert!(ids.iter().all(|&c| c == ids[0]), "{chunk:?}: {ids:?}");
        }
        assert!(r.inertia < 5.0);
    }

    #[test]
    fn k_equals_n() {
        let mut rng = Rng::new(2);
        let pts: Vec<Vec<f64>> =
            (0..5).map(|i| vec![i as f64 * 3.0]).collect();
        let r = kmeans(&pts, 5, &mut rng, 20);
        let mut ids = r.assignment.clone();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 5, "each point its own cluster");
        assert!(r.inertia < 1e-12);
    }

    #[test]
    fn identical_points_dont_crash() {
        let mut rng = Rng::new(3);
        let pts = vec![vec![1.0, 1.0]; 10];
        let r = kmeans(&pts, 3, &mut rng, 20);
        assert_eq!(r.assignment.len(), 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let pts: Vec<Vec<f64>> = (0..30)
            .map(|i| vec![(i % 7) as f64, (i % 3) as f64])
            .collect();
        let a = kmeans(&pts, 4, &mut Rng::new(9), 50);
        let b = kmeans(&pts, 4, &mut Rng::new(9), 50);
        assert_eq!(a.assignment, b.assignment);
    }
}
