//! Linear-algebra substrate for spectral clustering.
//!
//! The paper's grouping phase (§4.1) applies spectral clustering to the
//! expert affinity matrix. No BLAS/LAPACK crates are available offline, so
//! this module implements the needed pieces directly:
//!
//! * [`matrix::Matrix`] — dense row-major f64 matrix,
//! * [`jacobi::eigh`] — cyclic Jacobi eigendecomposition for symmetric
//!   matrices (affinity matrices are ≤ 128×128, where Jacobi is both
//!   simple and accurate),
//! * [`kmeans`] — k-means++ on embedded rows,
//! * [`spectral`] — normalized-Laplacian spectral embedding
//!   (Ng–Jordan–Weiss).

pub mod jacobi;
pub mod kmeans;
pub mod matrix;
pub mod spectral;

pub use jacobi::eigh;
pub use kmeans::{kmeans, KMeansResult};
pub use matrix::Matrix;
pub use spectral::{spectral_cluster, spectral_embedding};
