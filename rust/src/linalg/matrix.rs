//! Dense row-major f64 matrix — just enough for spectral clustering.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// n×n identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from row vectors (all must share a length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        assert!(!rows.is_empty());
        let cols = rows[0].len();
        assert!(rows.iter().all(|r| r.len() == cols), "ragged rows");
        Matrix {
            rows: rows.len(),
            cols,
            data: rows.iter().flatten().copied().collect(),
        }
    }

    /// Build element-wise from `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize,
                   f: impl Fn(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Row count.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Column count.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Row `i` as a slice.
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Row `i` as a mutable slice.
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column `j`, copied out.
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }

    /// Dense product `self · other` (sparsity-skipping inner loop).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for j in 0..orow.len() {
                    out_row[j] += a * orow[j];
                }
            }
        }
        out
    }

    /// Whether the matrix is symmetric within `tol`.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        if self.rows != self.cols {
            return false;
        }
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    /// Max |a_ij| over off-diagonal entries (Jacobi convergence check).
    pub fn offdiag_max(&self) -> f64 {
        let mut m: f64 = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                if i != j {
                    m = m.max(self[(i, j)].abs());
                }
            }
        }
        m
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Matrix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            writeln!(
                f,
                "  {:?}",
                self.row(i).iter().take(8).collect::<Vec<_>>()
            )?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let i = Matrix::identity(2);
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c[(0, 0)], 19.0);
        assert_eq!(c[(0, 1)], 22.0);
        assert_eq!(c[(1, 0)], 43.0);
        assert_eq!(c[(1, 1)], 50.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = Matrix::from_fn(3, 5, |i, j| (i * 5 + j) as f64);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn symmetry_check() {
        let s = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.0, 3.0]]);
        assert!(s.is_symmetric(0.0));
        let a = Matrix::from_rows(&[vec![1.0, 2.0], vec![2.5, 3.0]]);
        assert!(!a.is_symmetric(0.1));
    }
}
