//! Metric accumulators for the paper's evaluation quantities (§6.1):
//! All-to-All time and traffic, GPU idle time, mean per-layer GPU-load
//! standard deviation, MoE layer time, and end-to-end latency.

use crate::stats::Summary;

/// Metrics of one inference run (one model × system × workload × cluster).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Total All-to-All communication time, seconds.
    pub a2a_time: f64,
    /// Cross-node bytes moved by A2A.
    pub cross_bytes: f64,
    /// Intra-node bytes moved by A2A.
    pub intra_bytes: f64,
    /// Total GPU idle time (sum over GPUs of sync-wait), seconds.
    pub idle_time: f64,
    /// Per-layer GPU-load standard deviations (tokens) — the paper
    /// reports the mean over layers.
    pub layer_load_std: Vec<f64>,
    /// Total MoE-layer time (comm + expert compute + sync), seconds.
    pub moe_layer_time: f64,
    /// End-to-end latency, seconds.
    pub e2e_time: f64,
    /// Collective launches issued.
    pub launches: usize,
    /// Tokens processed (MoE tokens across all layers).
    pub tokens: usize,
    /// Expert-weight bytes copied by online re-planning migrations
    /// (zero for every static system).
    pub migration_bytes: f64,
    /// Re-planning deltas applied (epochs that actually migrated).
    pub replans: usize,
    /// Weight-staging counters of the prefetch/tier machinery (all
    /// zero when no `--weight-budget` tier is configured).
    pub prefetch: PrefetchStats,
}

/// Counters of the predictive-prefetch and weight-tier machinery
/// ([`crate::engine::prefetch`]): how often a needed expert weight was
/// already resident (*hit*), how often serving had to block on a
/// cold-tier load (*stall*), and how much staging traffic prediction
/// spent vs wasted.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PrefetchStats {
    /// Predictive staging transfers issued (background, overlapped).
    pub prefetches: usize,
    /// Weight lookups satisfied from the resident hot tier.
    pub hits: usize,
    /// Weight lookups that blocked on a cold-tier load (demand stage
    /// on the critical path).
    pub stalls: usize,
    /// Layer rounds that stalled at least once (the bench's
    /// stall-step count — one slow round is one stall-step however
    /// many experts it waited for).
    pub stall_steps: usize,
    /// Hot-tier evictions (LRU victim pushed back to the cold tier).
    pub evictions: usize,
    /// Bytes staged by predictive prefetch.
    pub prefetch_bytes: f64,
    /// Bytes staged on demand (stalls).
    pub demand_bytes: f64,
    /// Prefetched bytes evicted (or left over) without ever being
    /// used — the overprediction cost the bench bounds.
    pub wasted_bytes: f64,
}

impl PrefetchStats {
    /// Accumulate another segment's counters.
    pub fn accumulate(&mut self, other: &PrefetchStats) {
        self.prefetches += other.prefetches;
        self.hits += other.hits;
        self.stalls += other.stalls;
        self.stall_steps += other.stall_steps;
        self.evictions += other.evictions;
        self.prefetch_bytes += other.prefetch_bytes;
        self.demand_bytes += other.demand_bytes;
        self.wasted_bytes += other.wasted_bytes;
    }

    /// Hit fraction of all resident-tier lookups (0 before any lookup).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.stalls;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

impl RunMetrics {
    /// Mean over layers of the per-layer GPU-load standard deviation
    /// (the paper's "AVG. GPU LOAD STD." metric).
    pub fn mean_load_std(&self) -> f64 {
        if self.layer_load_std.is_empty() {
            0.0
        } else {
            Summary::of(&self.layer_load_std).mean()
        }
    }

    /// Accumulate another run segment (e.g. decode steps onto prefill).
    pub fn accumulate(&mut self, other: &RunMetrics) {
        self.a2a_time += other.a2a_time;
        self.cross_bytes += other.cross_bytes;
        self.intra_bytes += other.intra_bytes;
        self.idle_time += other.idle_time;
        self.layer_load_std
            .extend(other.layer_load_std.iter().copied());
        self.moe_layer_time += other.moe_layer_time;
        self.e2e_time += other.e2e_time;
        self.launches += other.launches;
        self.tokens += other.tokens;
        self.migration_bytes += other.migration_bytes;
        self.replans += other.replans;
        self.prefetch.accumulate(&other.prefetch);
    }

    /// The five Table-1 metrics as (name, value) pairs.
    pub fn table1_metrics(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("all_to_all_time", self.a2a_time),
            ("cross_node_traffic", self.cross_bytes),
            ("intra_node_traffic", self.intra_bytes),
            ("gpu_idle_time", self.idle_time),
            ("avg_gpu_load_std", self.mean_load_std()),
        ]
    }
}

/// Contention diagnostics of one discrete-event network replay
/// ([`crate::comm::sim`]): how hard each simulated link was driven and
/// how much time transfers spent queued behind one another — the
/// quantities the analytic α–β models cannot see.
///
/// Link order matches [`crate::comm::sim::NetworkSim`]: per-GPU egress
/// ports, per-GPU ingress ports, per-node NIC-out, per-node NIC-in.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ContentionReport {
    /// Busy fraction of each link over the replay horizon (first submit
    /// → last departure).
    pub per_link_utilization: Vec<f64>,
    /// Utilization of the hottest link (the saturation indicator).
    pub max_utilization: f64,
    /// Median link queue depth sampled at transfer arrivals.
    pub queue_depth_p50: f64,
    /// 95th-percentile arrival-sampled queue depth.
    pub queue_depth_p95: f64,
    /// 99th-percentile arrival-sampled queue depth.
    pub queue_depth_p99: f64,
    /// Deepest queue observed on any link.
    pub queue_depth_max: usize,
    /// Seconds transfers spent waiting behind earlier transfers, summed
    /// over all links (zero on uncontended traffic).
    pub queued_wait_s: f64,
    /// Seconds lost to straggler synchronization across all collectives.
    pub straggler_stall_s: f64,
    /// Point-to-point transfers replayed.
    pub transfers: u64,
    /// Typed events processed by the event loop.
    pub events: u64,
    /// FNV-1a digest of the full event log — two runs with the same seed
    /// must agree bit-for-bit (the `des-smoke` CI gate).
    pub event_digest: u64,
}

/// Timing of one served request on the driver clock (wall-clock seconds
/// in the real server, virtual seconds in the scheduler harness). The
/// logical step indices make admission ordering assertable without
/// depending on machine speed.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RequestTiming {
    /// The request's id.
    pub id: u64,
    /// The request's priority class (0 = most urgent).
    pub priority: usize,
    /// Enqueue → admission into the live batch, seconds.
    pub queue_wait: f64,
    /// Enqueue → first generated token (TTFT), seconds; for a request
    /// that generates nothing this is its completion latency.
    pub ttft: f64,
    /// Enqueue → completion, seconds.
    pub latency: f64,
    /// Mean time per output token after the first (TPOT), seconds;
    /// zero when fewer than two tokens were generated.
    pub tpot: f64,
    /// Scheduler step at which the request was admitted.
    pub admit_step: usize,
    /// Scheduler step that produced the request's first token.
    pub first_token_step: usize,
    /// Times the request was evicted mid-decode and later resumed.
    pub preemptions: usize,
    /// Tokens the request generated (0 for a zero-budget request).
    pub tokens: usize,
}

/// Serving-side metrics: per-request latency/TTFT/TPOT/queue-wait
/// distributions plus scheduler-level counters (steps, dispatch rounds).
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Per-request end-to-end latencies, seconds.
    pub latencies: Vec<f64>,
    /// Per-request time-to-first-token, seconds (requests that generated
    /// at least one token).
    pub ttft: Vec<f64>,
    /// Per-request mean time per output token, seconds (requests that
    /// generated at least two tokens).
    pub tpot: Vec<f64>,
    /// Per-request queue wait (enqueue → admission), seconds.
    pub queue_wait: Vec<f64>,
    /// Per-request timings, sorted by request id.
    pub per_request: Vec<RequestTiming>,
    /// Tokens generated.
    pub generated_tokens: usize,
    /// Wall-clock of the serving window, seconds.
    pub wall_time: f64,
    /// Scheduler steps executed (one batched forward each).
    pub steps: usize,
    /// Dispatch rounds issued across all steps and layers.
    pub dispatch_rounds: usize,
    /// Tokens actually computed across all steps: uncached suffixes
    /// under KV-cached decode (prompt at prefill, one per sequence per
    /// step after), full prefixes under recompute.
    pub computed_tokens: usize,
    /// Prefix tokens served from the per-sequence KV cache instead of
    /// being recomputed (0 with the cache off).
    pub cached_tokens: usize,
    /// Mid-decode evictions performed by the priority scheduler.
    pub preemptions: usize,
    /// Preempted sequences re-admitted into the live batch.
    pub resumes: usize,
    /// Request ids shed by SLO admission control (sorted); these never
    /// entered the live batch and have no response or timing record.
    pub rejected: Vec<u64>,
}

impl ServeMetrics {
    /// Latency distribution summary (`None` with no completed requests).
    pub fn latency_summary(&self) -> Option<Summary> {
        Self::summarise(&self.latencies)
    }

    /// TTFT distribution summary (`None` when nothing was generated).
    pub fn ttft_summary(&self) -> Option<Summary> {
        Self::summarise(&self.ttft)
    }

    /// TPOT distribution summary (`None` when no request generated two
    /// or more tokens).
    pub fn tpot_summary(&self) -> Option<Summary> {
        Self::summarise(&self.tpot)
    }

    /// Queue-wait distribution summary (`None` with no admissions).
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        Self::summarise(&self.queue_wait)
    }

    fn summarise(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            None
        } else {
            Some(Summary::of(xs))
        }
    }

    /// Generated tokens per wall-clock second.
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_time <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_time
        }
    }

    /// Dispatch rounds per generated token — the density win of batched
    /// decode (`benches/serving.rs` compares this across schedulers).
    pub fn rounds_per_token(&self) -> f64 {
        if self.generated_tokens == 0 {
            0.0
        } else {
            self.dispatch_rounds as f64 / self.generated_tokens as f64
        }
    }

    /// Priority classes present among completed requests, ascending.
    pub fn priority_classes(&self) -> Vec<usize> {
        let mut classes: Vec<usize> =
            self.per_request.iter().map(|t| t.priority).collect();
        classes.sort_unstable();
        classes.dedup();
        classes
    }

    /// TTFT summary restricted to one priority class (`None` when no
    /// request of that class generated a token).
    pub fn ttft_summary_class(&self, class: usize) -> Option<Summary> {
        let xs: Vec<f64> = self
            .per_request
            .iter()
            .filter(|t| t.priority == class && t.tokens > 0)
            .map(|t| t.ttft)
            .collect();
        Self::summarise(&xs)
    }

    /// TPOT summary restricted to one priority class (`None` when no
    /// request of that class generated two or more tokens).
    pub fn tpot_summary_class(&self, class: usize) -> Option<Summary> {
        let xs: Vec<f64> = self
            .per_request
            .iter()
            .filter(|t| t.priority == class && t.tokens >= 2)
            .map(|t| t.tpot)
            .collect();
        Self::summarise(&xs)
    }

    /// Fraction of step-fed prefix tokens served from the KV cache:
    /// `cached / (cached + computed)`. 0 with the cache off (or before
    /// any step); approaches 1 as prefixes outgrow the per-step work.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cached_tokens + self.computed_tokens;
        if total == 0 {
            0.0
        } else {
            self.cached_tokens as f64 / total as f64
        }
    }

    /// Fold another replica's metrics into this one — the fleet-wide
    /// aggregation of `server::shard`: distributions and per-request
    /// records concatenate, counters add, and `wall_time` takes the
    /// max (replicas serve concurrently, so fleet wall-clock is the
    /// slowest replica, not the sum). The caller re-sorts `per_request`
    /// and `rejected` once after merging every replica.
    pub fn merge(&mut self, other: &ServeMetrics) {
        self.latencies.extend_from_slice(&other.latencies);
        self.ttft.extend_from_slice(&other.ttft);
        self.tpot.extend_from_slice(&other.tpot);
        self.queue_wait.extend_from_slice(&other.queue_wait);
        self.per_request.extend_from_slice(&other.per_request);
        self.generated_tokens += other.generated_tokens;
        self.wall_time = self.wall_time.max(other.wall_time);
        self.steps += other.steps;
        self.dispatch_rounds += other.dispatch_rounds;
        self.computed_tokens += other.computed_tokens;
        self.cached_tokens += other.cached_tokens;
        self.preemptions += other.preemptions;
        self.resumes += other.resumes;
        self.rejected.extend_from_slice(&other.rejected);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_load_std() {
        let m = RunMetrics {
            layer_load_std: vec![1.0, 3.0],
            ..Default::default()
        };
        assert_eq!(m.mean_load_std(), 2.0);
        assert_eq!(RunMetrics::default().mean_load_std(), 0.0);
    }

    #[test]
    fn accumulate_sums() {
        let mut a = RunMetrics {
            a2a_time: 1.0,
            cross_bytes: 10.0,
            layer_load_std: vec![1.0],
            tokens: 5,
            ..Default::default()
        };
        let b = a.clone();
        a.accumulate(&b);
        assert_eq!(a.a2a_time, 2.0);
        assert_eq!(a.cross_bytes, 20.0);
        assert_eq!(a.layer_load_std.len(), 2);
        assert_eq!(a.tokens, 10);
    }

    #[test]
    fn prefetch_stats_accumulate_and_hit_rate() {
        let mut a = PrefetchStats {
            prefetches: 3,
            hits: 6,
            stalls: 2,
            stall_steps: 1,
            evictions: 4,
            prefetch_bytes: 100.0,
            demand_bytes: 50.0,
            wasted_bytes: 25.0,
        };
        a.accumulate(&a.clone());
        assert_eq!(a.prefetches, 6);
        assert_eq!(a.hits, 12);
        assert_eq!(a.stalls, 4);
        assert_eq!(a.stall_steps, 2);
        assert_eq!(a.evictions, 8);
        assert_eq!(a.prefetch_bytes, 200.0);
        assert_eq!(a.demand_bytes, 100.0);
        assert_eq!(a.wasted_bytes, 50.0);
        assert_eq!(a.hit_rate(), 0.75);
        assert_eq!(PrefetchStats::default().hit_rate(), 0.0);

        // RunMetrics carries the counters through its own accumulate.
        let mut m = RunMetrics::default();
        m.prefetch.stalls = 1;
        m.accumulate(&m.clone());
        assert_eq!(m.prefetch.stalls, 2);
    }

    #[test]
    fn table1_exposes_five_metrics() {
        let m = RunMetrics::default();
        let t = m.table1_metrics();
        assert_eq!(t.len(), 5);
        assert_eq!(t[0].0, "all_to_all_time");
    }

    #[test]
    fn serve_throughput() {
        let s = ServeMetrics {
            latencies: vec![0.1, 0.2],
            generated_tokens: 100,
            wall_time: 2.0,
            ..Default::default()
        };
        assert_eq!(s.throughput_tps(), 50.0);
        assert!(s.latency_summary().unwrap().mean() > 0.0);
        assert_eq!(ServeMetrics::default().throughput_tps(), 0.0);
    }

    #[test]
    fn serve_distributions_and_round_density() {
        let s = ServeMetrics {
            latencies: vec![0.4, 0.5],
            ttft: vec![0.1, 0.3],
            tpot: vec![0.02],
            queue_wait: vec![0.0, 0.2],
            generated_tokens: 20,
            wall_time: 1.0,
            steps: 10,
            dispatch_rounds: 40,
            ..Default::default()
        };
        assert_eq!(s.ttft_summary().unwrap().mean(), 0.2);
        assert_eq!(s.tpot_summary().unwrap().mean(), 0.02);
        assert_eq!(s.queue_wait_summary().unwrap().max(), 0.2);
        assert_eq!(s.rounds_per_token(), 2.0);
        let empty = ServeMetrics::default();
        assert!(empty.ttft_summary().is_none());
        assert!(empty.tpot_summary().is_none());
        assert!(empty.queue_wait_summary().is_none());
        assert_eq!(empty.rounds_per_token(), 0.0);
    }

    #[test]
    fn merge_concatenates_and_takes_max_wall_time() {
        let t = |id: u64| RequestTiming {
            id,
            tokens: 2,
            ..Default::default()
        };
        let mut a = ServeMetrics {
            latencies: vec![0.4],
            ttft: vec![0.1],
            tpot: vec![0.02],
            queue_wait: vec![0.0],
            per_request: vec![t(2)],
            generated_tokens: 10,
            wall_time: 2.0,
            steps: 5,
            dispatch_rounds: 20,
            computed_tokens: 30,
            cached_tokens: 12,
            preemptions: 1,
            resumes: 1,
            rejected: vec![9],
            ..Default::default()
        };
        let b = ServeMetrics {
            latencies: vec![0.5, 0.6],
            ttft: vec![0.2],
            tpot: vec![0.03],
            queue_wait: vec![0.1],
            per_request: vec![t(1)],
            generated_tokens: 7,
            wall_time: 3.5, // slowest replica sets fleet wall-clock
            steps: 4,
            dispatch_rounds: 16,
            computed_tokens: 21,
            cached_tokens: 8,
            preemptions: 0,
            resumes: 0,
            rejected: vec![5],
            ..Default::default()
        };
        a.merge(&b);
        assert_eq!(a.latencies, vec![0.4, 0.5, 0.6]);
        assert_eq!(a.ttft, vec![0.1, 0.2]);
        assert_eq!(a.generated_tokens, 17);
        assert_eq!(a.wall_time, 3.5);
        assert_eq!(a.steps, 9);
        assert_eq!(a.dispatch_rounds, 36);
        assert_eq!(a.computed_tokens, 51);
        assert_eq!(a.cached_tokens, 20);
        assert_eq!(a.preemptions, 1);
        assert_eq!(a.resumes, 1);
        assert_eq!(a.rejected, vec![9, 5]);
        assert_eq!(a.per_request.len(), 2);
        // Merging the empty default is an identity on counters.
        let snapshot_tokens = a.generated_tokens;
        a.merge(&ServeMetrics::default());
        assert_eq!(a.generated_tokens, snapshot_tokens);
        assert_eq!(a.wall_time, 3.5);
    }

    #[test]
    fn per_class_summaries_filter_by_priority() {
        let t = |priority: usize, ttft: f64, tpot: f64, tokens: usize| {
            RequestTiming { priority, ttft, tpot, tokens,
                            ..Default::default() }
        };
        let s = ServeMetrics {
            per_request: vec![
                t(0, 0.1, 0.01, 4),
                t(0, 0.3, 0.03, 4),
                t(1, 0.8, 0.05, 4),
                t(1, 0.0, 0.0, 0), // zero-token: excluded everywhere
            ],
            ..Default::default()
        };
        assert_eq!(s.priority_classes(), vec![0, 1]);
        let c0 = s.ttft_summary_class(0).unwrap();
        assert!((c0.mean() - 0.2).abs() < 1e-12);
        let c1 = s.ttft_summary_class(1).unwrap();
        assert_eq!(c1.mean(), 0.8);
        assert!(s.ttft_summary_class(2).is_none());
        assert_eq!(s.tpot_summary_class(1).unwrap().mean(), 0.05);
    }

    #[test]
    fn cache_hit_rate_splits_cached_from_computed() {
        let s = ServeMetrics {
            computed_tokens: 25,
            cached_tokens: 75,
            ..Default::default()
        };
        assert_eq!(s.cache_hit_rate(), 0.75);
        assert_eq!(ServeMetrics::default().cache_hit_rate(), 0.0);
    }
}
