//! Metric accumulators for the paper's evaluation quantities (§6.1):
//! All-to-All time and traffic, GPU idle time, mean per-layer GPU-load
//! standard deviation, MoE layer time, and end-to-end latency.

use crate::stats::Summary;

/// Metrics of one inference run (one model × system × workload × cluster).
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    /// Total All-to-All communication time, seconds.
    pub a2a_time: f64,
    /// Cross-node bytes moved by A2A.
    pub cross_bytes: f64,
    /// Intra-node bytes moved by A2A.
    pub intra_bytes: f64,
    /// Total GPU idle time (sum over GPUs of sync-wait), seconds.
    pub idle_time: f64,
    /// Per-layer GPU-load standard deviations (tokens) — the paper
    /// reports the mean over layers.
    pub layer_load_std: Vec<f64>,
    /// Total MoE-layer time (comm + expert compute + sync), seconds.
    pub moe_layer_time: f64,
    /// End-to-end latency, seconds.
    pub e2e_time: f64,
    /// Collective launches issued.
    pub launches: usize,
    /// Tokens processed (MoE tokens across all layers).
    pub tokens: usize,
    /// Expert-weight bytes copied by online re-planning migrations
    /// (zero for every static system).
    pub migration_bytes: f64,
    /// Re-planning deltas applied (epochs that actually migrated).
    pub replans: usize,
}

impl RunMetrics {
    /// Mean over layers of the per-layer GPU-load standard deviation
    /// (the paper's "AVG. GPU LOAD STD." metric).
    pub fn mean_load_std(&self) -> f64 {
        if self.layer_load_std.is_empty() {
            0.0
        } else {
            Summary::of(&self.layer_load_std).mean()
        }
    }

    /// Accumulate another run segment (e.g. decode steps onto prefill).
    pub fn accumulate(&mut self, other: &RunMetrics) {
        self.a2a_time += other.a2a_time;
        self.cross_bytes += other.cross_bytes;
        self.intra_bytes += other.intra_bytes;
        self.idle_time += other.idle_time;
        self.layer_load_std
            .extend(other.layer_load_std.iter().copied());
        self.moe_layer_time += other.moe_layer_time;
        self.e2e_time += other.e2e_time;
        self.launches += other.launches;
        self.tokens += other.tokens;
        self.migration_bytes += other.migration_bytes;
        self.replans += other.replans;
    }

    /// The five Table-1 metrics as (name, value) pairs.
    pub fn table1_metrics(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("all_to_all_time", self.a2a_time),
            ("cross_node_traffic", self.cross_bytes),
            ("intra_node_traffic", self.intra_bytes),
            ("gpu_idle_time", self.idle_time),
            ("avg_gpu_load_std", self.mean_load_std()),
        ]
    }
}

/// Serving-side metrics (per-request latencies, throughput).
#[derive(Clone, Debug, Default)]
pub struct ServeMetrics {
    /// Per-request end-to-end latencies, seconds.
    pub latencies: Vec<f64>,
    /// Tokens generated.
    pub generated_tokens: usize,
    /// Wall-clock of the serving window, seconds.
    pub wall_time: f64,
}

impl ServeMetrics {
    /// Latency distribution summary (`None` with no completed requests).
    pub fn latency_summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(Summary::of(&self.latencies))
        }
    }

    /// Generated tokens per wall-clock second.
    pub fn throughput_tps(&self) -> f64 {
        if self.wall_time <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_time
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_load_std() {
        let m = RunMetrics {
            layer_load_std: vec![1.0, 3.0],
            ..Default::default()
        };
        assert_eq!(m.mean_load_std(), 2.0);
        assert_eq!(RunMetrics::default().mean_load_std(), 0.0);
    }

    #[test]
    fn accumulate_sums() {
        let mut a = RunMetrics {
            a2a_time: 1.0,
            cross_bytes: 10.0,
            layer_load_std: vec![1.0],
            tokens: 5,
            ..Default::default()
        };
        let b = a.clone();
        a.accumulate(&b);
        assert_eq!(a.a2a_time, 2.0);
        assert_eq!(a.cross_bytes, 20.0);
        assert_eq!(a.layer_load_std.len(), 2);
        assert_eq!(a.tokens, 10);
    }

    #[test]
    fn table1_exposes_five_metrics() {
        let m = RunMetrics::default();
        let t = m.table1_metrics();
        assert_eq!(t.len(), 5);
        assert_eq!(t[0].0, "all_to_all_time");
    }

    #[test]
    fn serve_throughput() {
        let s = ServeMetrics {
            latencies: vec![0.1, 0.2],
            generated_tokens: 100,
            wall_time: 2.0,
        };
        assert_eq!(s.throughput_tps(), 50.0);
        assert!(s.latency_summary().unwrap().mean() > 0.0);
        assert_eq!(ServeMetrics::default().throughput_tps(), 0.0);
    }
}
