//! Online load measurement: per-layer EWMAs of dispatched expert loads.
//!
//! One [`LoadEstimator`] is the shared measurement substrate behind both
//! online feedback loops of the crate:
//!
//! * [`crate::routing::LoadAware`] folds one estimator round per dispatch
//!   round and recomputes its Eq.-4 polling weights from it (PR-2's
//!   within-placement feedback), and
//! * [`crate::replan::Replanner`] aggregates finished
//!   [`crate::routing::DispatchPlan`]s into the same estimator and, at
//!   epoch boundaries, recomputes the *replication decision itself* from
//!   the measured loads (the cross-placement feedback loop).
//!
//! Measurements are taken pre-replication: every assignment is counted
//! where its expert's *primary* GPU lives (the load Eq. 4 starts from)
//! and per expert (the online `W_r`), exactly as the paper's offline
//! profiling counts them — so live estimates and profiling-time loads are
//! directly comparable.

use crate::placement::LayerPlacement;
use crate::routing::DispatchPlan;

/// Per-layer EWMA state of one estimator.
#[derive(Clone, Debug, Default)]
struct LayerLoads {
    /// EWMA of measured pre-replication per-GPU loads.
    ewma_pre: Vec<f64>,
    /// EWMA of measured per-expert loads (online `W_r` ingredients).
    ewma_expert: Vec<f64>,
    /// Current-round pre-replication per-GPU counts.
    pre_round: Vec<f64>,
    /// Current-round per-expert counts.
    expert_round: Vec<f64>,
    /// Completed (non-empty) measurement rounds.
    rounds: u64,
}

/// EWMA tracker of measured per-layer loads, keyed by MoE layer index.
///
/// Layers never share state: placements, replication decisions, and load
/// profiles differ layer to layer, so one blended estimate would
/// misattribute Eq. 4's `W_max`/`W_r`. The first non-empty round seeds
/// the EWMA directly (`α = 1`) so a long-idle layer never averages
/// against stale zero history.
#[derive(Clone, Debug)]
pub struct LoadEstimator {
    alpha: f64,
    layers: Vec<LayerLoads>,
}

impl LoadEstimator {
    /// Estimator with EWMA smoothing factor `alpha ∈ [0, 1]` (the weight
    /// of the newest round; [`crate::routing::LoadAware::DEFAULT_ALPHA`]
    /// is the shared default).
    pub fn new(alpha: f64) -> LoadEstimator {
        assert!((0.0..=1.0).contains(&alpha), "alpha in [0, 1]");
        LoadEstimator { alpha, layers: Vec::new() }
    }

    fn ensure(&mut self, layer: usize, n_gpus: usize, experts: usize) {
        if self.layers.len() <= layer {
            self.layers.resize_with(layer + 1, LayerLoads::default);
        }
        let st = &mut self.layers[layer];
        if st.ewma_pre.len() < n_gpus {
            st.ewma_pre.resize(n_gpus, 0.0);
            st.pre_round.resize(n_gpus, 0.0);
        }
        if st.ewma_expert.len() < experts {
            st.ewma_expert.resize(experts, 0.0);
            st.expert_round.resize(experts, 0.0);
        }
    }

    /// Record one expert assignment of the current round: counted on the
    /// expert's primary GPU (pre-replication) and per expert.
    pub fn record(&mut self, layer: usize, lp: &LayerPlacement,
                  expert: usize) {
        self.ensure(layer, lp.num_gpus(), lp.instances.len());
        let st = &mut self.layers[layer];
        st.pre_round[lp.primary[expert]] += 1.0;
        st.expert_round[expert] += 1.0;
    }

    /// Record every assignment of a routed batch and close the round —
    /// one finished [`DispatchPlan`] is one measurement round.
    pub fn record_plan(&mut self, layer: usize, lp: &LayerPlacement,
                       plan: &DispatchPlan) {
        for r in plan.assignments() {
            self.record(layer, lp, r.expert);
        }
        self.end_round(layer, lp.num_gpus(), lp.instances.len());
    }

    /// Close the layer's current measurement round, folding it into the
    /// EWMAs. Returns `false` (estimate kept unchanged) for empty rounds.
    pub fn end_round(&mut self, layer: usize, n_gpus: usize,
                     experts: usize) -> bool {
        self.ensure(layer, n_gpus, experts);
        let st = &mut self.layers[layer];
        if st.pre_round.iter().sum::<f64>() <= 0.0 {
            return false; // empty round — keep the current estimate
        }
        st.rounds += 1;
        // First round seeds the EWMA directly (no stale zero history).
        let a = if st.rounds == 1 { 1.0 } else { self.alpha };
        for (e, m) in st.ewma_pre.iter_mut().zip(&st.pre_round) {
            *e = (1.0 - a) * *e + a * m;
        }
        for (e, m) in st.ewma_expert.iter_mut().zip(&st.expert_round) {
            *e = (1.0 - a) * *e + a * m;
        }
        st.pre_round.iter_mut().for_each(|x| *x = 0.0);
        st.expert_round.iter_mut().for_each(|x| *x = 0.0);
        true
    }

    /// Completed measurement rounds for `layer`.
    pub fn rounds(&self, layer: usize) -> u64 {
        self.layers.get(layer).map_or(0, |s| s.rounds)
    }

    /// Maximum completed rounds across layers (the epoch clock).
    pub fn max_rounds(&self) -> u64 {
        self.layers.iter().map(|s| s.rounds).max().unwrap_or(0)
    }

    /// EWMA pre-replication per-GPU loads (`None` until a round closed).
    pub fn pre_loads(&self, layer: usize) -> Option<&[f64]> {
        let st = self.layers.get(layer)?;
        (st.rounds > 0).then_some(&st.ewma_pre[..])
    }

    /// EWMA per-expert loads (`None` until a round closed).
    pub fn expert_loads(&self, layer: usize) -> Option<&[f64]> {
        let st = self.layers.get(layer)?;
        (st.rounds > 0).then_some(&st.ewma_expert[..])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::placement::ReplicationMode;
    use crate::profile::LayerProfile;

    fn fixture() -> LayerPlacement {
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![4.0, 3.0, 2.0, 1.0],
            tokens: 10,
        };
        LayerPlacement::build(
            &profile,
            vec![vec![0], vec![1], vec![2], vec![3]],
            ReplicationMode::None,
        )
    }

    #[test]
    fn first_round_seeds_exactly() {
        let lp = fixture();
        let mut est = LoadEstimator::new(0.3);
        for _ in 0..5 {
            est.record(0, &lp, 0);
        }
        est.record(0, &lp, 2);
        assert!(est.pre_loads(0).is_none(), "no closed round yet");
        assert!(est.end_round(0, 4, 4));
        assert_eq!(est.pre_loads(0).unwrap(), &[5.0, 0.0, 1.0, 0.0]);
        assert_eq!(est.expert_loads(0).unwrap(),
                   &[5.0, 0.0, 1.0, 0.0]);
        assert_eq!(est.rounds(0), 1);
    }

    #[test]
    fn ewma_folds_later_rounds() {
        let lp = fixture();
        let mut est = LoadEstimator::new(0.5);
        est.record(0, &lp, 0);
        est.end_round(0, 4, 4);
        est.record(0, &lp, 1);
        est.end_round(0, 4, 4);
        // 0.5·[1,0,0,0] + 0.5·[0,1,0,0]
        assert_eq!(est.pre_loads(0).unwrap(), &[0.5, 0.5, 0.0, 0.0]);
        assert_eq!(est.rounds(0), 2);
    }

    #[test]
    fn empty_rounds_keep_estimate() {
        let lp = fixture();
        let mut est = LoadEstimator::new(0.3);
        est.record(0, &lp, 3);
        assert!(est.end_round(0, 4, 4));
        let before = est.pre_loads(0).unwrap().to_vec();
        assert!(!est.end_round(0, 4, 4), "empty round must not fold");
        assert_eq!(est.pre_loads(0).unwrap(), &before[..]);
        assert_eq!(est.rounds(0), 1);
    }

    #[test]
    fn layers_are_independent() {
        let lp = fixture();
        let mut est = LoadEstimator::new(0.3);
        est.record(0, &lp, 0);
        est.end_round(0, 4, 4);
        est.record(2, &lp, 3);
        est.end_round(2, 4, 4);
        assert_eq!(est.rounds(0), 1);
        assert_eq!(est.rounds(1), 0);
        assert_eq!(est.rounds(2), 1);
        assert_eq!(est.max_rounds(), 1);
        assert!(est.pre_loads(1).is_none());
        assert_eq!(est.pre_loads(2).unwrap(), &[0.0, 0.0, 0.0, 1.0]);
    }
}
