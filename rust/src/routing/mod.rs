//! Online routing policies — which replica executes a token's expert
//! (paper §4.3, Algorithms 3–4).
//!
//! * [`RoutingPolicy::Primary`] — no choice: the expert's primary GPU
//!   (every non-replicated system).
//! * [`RoutingPolicy::Wrr`] — Algorithm 3: weighted round-robin over all
//!   instances, weights inversely proportional to Eq.-4-predicted loads.
//! * [`RoutingPolicy::Tar`] — Algorithm 4: topology-aware locality
//!   preference. (i) an instance on the token's own GPU wins outright;
//!   (ii) otherwise WRR among same-node instances; (iii) otherwise WRR
//!   among all instances.

use crate::cluster::{GpuId, Topology};
use crate::placement::LayerPlacement;
use crate::stats::{dist::weighted_choice, Rng};

/// Replica-selection policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    Primary,
    Wrr,
    Tar,
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::Primary => "primary",
            RoutingPolicy::Wrr => "wrr",
            RoutingPolicy::Tar => "tar",
        }
    }
}

/// Router over one layer's placement. Holds no mutable state beyond the
/// caller's RNG, so it is freely shareable across worker threads.
pub struct Router<'a> {
    pub placement: &'a LayerPlacement,
    pub topo: &'a Topology,
    pub policy: RoutingPolicy,
}

impl<'a> Router<'a> {
    pub fn new(placement: &'a LayerPlacement, topo: &'a Topology,
               policy: RoutingPolicy) -> Self {
        Router { placement, topo, policy }
    }

    /// Select the GPU that executes `expert` for a token residing on
    /// `src_gpu`.
    pub fn route(&self, src_gpu: GpuId, expert: usize,
                 rng: &mut Rng) -> GpuId {
        let instances = &self.placement.instances[expert];
        debug_assert!(!instances.is_empty());
        if instances.len() == 1 {
            return instances[0];
        }
        match self.policy {
            RoutingPolicy::Primary => instances[0],
            RoutingPolicy::Wrr => self.wrr(instances, rng),
            RoutingPolicy::Tar => self.tar(src_gpu, instances, rng),
        }
    }

    /// Algorithm 3: WeightedRandomChoice(gpus, polling weights).
    fn wrr(&self, candidates: &[GpuId], rng: &mut Rng) -> GpuId {
        let weights: Vec<f64> = candidates
            .iter()
            .map(|&g| self.placement.polling[g])
            .collect();
        let total: f64 = weights.iter().sum();
        if total <= 0.0 {
            return candidates[0];
        }
        candidates[weighted_choice(rng, &weights)]
    }

    /// Algorithm 4: locality-first tiers, WRR within a tier.
    fn tar(&self, src_gpu: GpuId, instances: &[GpuId],
           rng: &mut Rng) -> GpuId {
        // Tier (i): same GPU.
        if instances.contains(&src_gpu) {
            return src_gpu;
        }
        // Tier (ii): same node.
        let node = self.topo.node_of(src_gpu);
        let local: Vec<GpuId> = instances
            .iter()
            .copied()
            .filter(|&g| self.topo.node_of(g) == node)
            .collect();
        if !local.is_empty() {
            return self.wrr(&local, rng);
        }
        // Tier (iii): anywhere.
        self.wrr(instances, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Grouping;
    use crate::linalg::Matrix;
    use crate::placement::{LayerPlacement, ReplicationMode};
    use crate::profile::LayerProfile;
    use crate::replication::Replication;
    use crate::testutil::{check, prop_assert};

    /// Hand-built placement on 2×2: expert 0 hot on gpu 0, replicated to
    /// gpus 1 (same node) and 2 (remote); experts 1–3 primary-only on
    /// gpus 1,2,3.
    fn fixture() -> LayerPlacement {
        let groups: Grouping =
            vec![vec![0], vec![1], vec![2], vec![3]];
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![90.0, 30.0, 20.0, 10.0],
            tokens: 150,
        };
        let mut p = LayerPlacement::build(&profile, groups,
                                          ReplicationMode::None);
        p.replication = Replication {
            hot_experts: vec![0],
            replica_gpus: vec![1, 2],
            n_replica: 2,
            w_max: 90.0,
            w_r: 90.0,
        };
        p.instances[0] = vec![0, 1, 2];
        // simple polling weights favouring gpu 3 then 2 then 1 then 0
        p.polling = vec![0.1, 0.2, 0.3, 0.4];
        p
    }

    fn topo() -> Topology {
        Topology::two_by_two()
    }

    #[test]
    fn primary_policy_ignores_replicas() {
        let p = fixture();
        let t = topo();
        let r = Router::new(&p, &t, RoutingPolicy::Primary);
        let mut rng = Rng::new(1);
        for src in 0..4 {
            assert_eq!(r.route(src, 0, &mut rng), 0);
        }
    }

    #[test]
    fn unreplicated_experts_always_primary() {
        let p = fixture();
        let t = topo();
        for policy in [RoutingPolicy::Wrr, RoutingPolicy::Tar] {
            let r = Router::new(&p, &t, policy);
            let mut rng = Rng::new(2);
            for _ in 0..50 {
                assert_eq!(r.route(3, 2, &mut rng), 2);
            }
        }
    }

    #[test]
    fn wrr_frequencies_match_polling_weights() {
        let p = fixture();
        let t = topo();
        let r = Router::new(&p, &t, RoutingPolicy::Wrr);
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 4];
        let n = 60_000;
        for _ in 0..n {
            counts[r.route(3, 0, &mut rng)] += 1;
        }
        // instances {0,1,2} with weights {0.1,0.2,0.3} → 1/6, 2/6, 3/6
        assert_eq!(counts[3], 0);
        for (g, want) in [(0, 1.0 / 6.0), (1, 2.0 / 6.0), (2, 3.0 / 6.0)] {
            let emp = counts[g] as f64 / n as f64;
            assert!((emp - want).abs() < 0.01, "gpu {g}: {emp} vs {want}");
        }
    }

    #[test]
    fn tar_tier1_same_gpu_wins() {
        let p = fixture();
        let t = topo();
        let r = Router::new(&p, &t, RoutingPolicy::Tar);
        let mut rng = Rng::new(4);
        for src in [0, 1, 2] {
            for _ in 0..20 {
                assert_eq!(r.route(src, 0, &mut rng), src,
                           "instance on src gpu must be chosen");
            }
        }
    }

    #[test]
    fn tar_tier2_prefers_same_node() {
        let p = fixture();
        let t = topo();
        let r = Router::new(&p, &t, RoutingPolicy::Tar);
        let mut rng = Rng::new(5);
        // src gpu 3 (node 1): instance gpus {0,1} are node 0, {2} node 1
        for _ in 0..100 {
            assert_eq!(r.route(3, 0, &mut rng), 2,
                       "same-node replica must win");
        }
    }

    #[test]
    fn tar_tier3_falls_back_to_global_wrr() {
        let mut p = fixture();
        // strip the node-1 replica: instances {0, 1}, both node 0
        p.instances[0] = vec![0, 1];
        let t = topo();
        let r = Router::new(&p, &t, RoutingPolicy::Tar);
        let mut rng = Rng::new(6);
        let mut counts = [0usize; 4];
        for _ in 0..30_000 {
            counts[r.route(3, 0, &mut rng)] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0);
        // weights 0.1 vs 0.2 → 1:2
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn property_tar_never_leaves_node_when_local_replica_exists() {
        check(100, |rng| {
            let p = fixture();
            let t = topo();
            let r = Router::new(&p, &t, RoutingPolicy::Tar);
            let src = rng.index(4);
            let dst = r.route(src, 0, rng);
            let local_exists = p.instances[0]
                .iter()
                .any(|&g| t.node_of(g) == t.node_of(src));
            if local_exists {
                prop_assert(
                    t.node_of(dst) == t.node_of(src),
                    format!("src {src} routed off-node to {dst}"),
                )?;
            }
            prop_assert(p.instances[0].contains(&dst),
                        "must route to an instance")
        });
    }

    #[test]
    fn property_wrr_routes_only_to_instances() {
        check(100, |rng| {
            let p = fixture();
            let t = topo();
            let r = Router::new(&p, &t, RoutingPolicy::Wrr);
            let src = rng.index(4);
            let e = rng.index(4);
            let dst = r.route(src, e, rng);
            prop_assert(p.instances[e].contains(&dst), "non-instance gpu")
        });
    }
}
