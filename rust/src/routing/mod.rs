//! Online routing — which replica executes a token's expert (paper §4.3,
//! Algorithms 3–4) — as an object-safe policy trait plus a batched
//! dispatcher.
//!
//! The online phase has two halves:
//!
//! * **policy** ([`RoutePolicy`]) — the per-assignment replica choice.
//!   Implementations:
//!   * [`Primary`] — no choice: the expert's primary GPU (every
//!     non-replicated system),
//!   * [`Wrr`] — Algorithm 3: weighted random choice over all instances,
//!     weights the frozen Eq.-4 polling weights of the placement,
//!   * [`Tar`] — Algorithm 4: topology-aware locality preference. (i) an
//!     instance on the token's own GPU wins outright; (ii) otherwise WRR
//!     among same-node instances; (iii) otherwise WRR among all instances,
//!   * [`LoadAware`] — TAR's locality tiers, but the tier-(ii)/(iii)
//!     choice is *online*: within a round, weighted least-in-flight over
//!     the tier's candidates; across rounds, per-layer EWMAs of measured
//!     loads feed an Eq.-4 recomputation instead of the placement-time
//!     prediction frozen into `polling`.
//! * **dispatch** ([`Dispatcher`] → [`DispatchPlan`], in [`dispatch`]) —
//!   a whole batch of `(token, expert, src_gpu)` assignments is routed in
//!   one call and grouped into per-`(src, dst)` transfer lists with byte
//!   accounting, which the engines hand to the communication models as
//!   batched transfers.
//! * **prediction** ([`CrossLayerPredictor`], in [`predict`]) — finished
//!   plans additionally feed per-transition co-activation EWMAs, from
//!   which the prefetch stage ([`crate::engine::prefetch`]) ranks the
//!   experts layer `l+1` is about to activate.
//!
//! [`RoutingPolicy`] is the plain-data configuration enum (what a
//! [`crate::baselines::SystemSpec`] or CLI flag names);
//! [`RoutingPolicy::build`] instantiates the trait object executing it.
//! Policies are constructed per run by [`crate::coordinator`], so stateful
//! policies ([`LoadAware`]) carry their estimates across rounds and layers
//! of one serving run without leaking between runs.

pub mod dispatch;
pub mod load;
pub mod predict;

pub use dispatch::{Assignment, DispatchPlan, Dispatcher, Routed};
pub use load::LoadEstimator;
pub use predict::CrossLayerPredictor;

use crate::cluster::{GpuId, Topology};
use crate::placement::LayerPlacement;
use crate::replication::{polling_weights, predict_loads, Replication};
use crate::stats::{dist::weighted_choice, Rng};

/// Replica-selection policy configuration (plain data; see
/// [`RoutingPolicy::build`] for the executable form).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Always the expert's primary GPU (non-replicated systems).
    Primary,
    /// Algorithm 3: weighted random choice over all instances.
    Wrr,
    /// Algorithm 4: topology-aware locality tiers over WRR.
    Tar,
    /// TAR with online load prediction (Eq. 4 recomputed per round).
    LoadAware,
}

impl RoutingPolicy {
    /// Stable policy name (CLI values and report labels).
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::Primary => "primary",
            RoutingPolicy::Wrr => "wrr",
            RoutingPolicy::Tar => "tar",
            RoutingPolicy::LoadAware => "load-aware",
        }
    }

    /// Instantiate the policy object executing this configuration.
    pub fn build(&self) -> Box<dyn RoutePolicy> {
        match self {
            RoutingPolicy::Primary => Box::new(Primary),
            RoutingPolicy::Wrr => Box::new(Wrr),
            RoutingPolicy::Tar => Box::new(Tar),
            RoutingPolicy::LoadAware => Box::new(LoadAware::new()),
        }
    }
}

/// Immutable per-layer context a policy selects against: the layer's
/// placement (instances + frozen polling weights), the cluster topology
/// (locality tiers), and the MoE layer index (stateful policies keep
/// separate estimates per layer — placements and replication decisions
/// differ layer to layer).
pub struct RouteCtx<'a> {
    /// The layer's placement (instances + frozen polling weights).
    pub placement: &'a LayerPlacement,
    /// Cluster topology for locality-tier decisions.
    pub topo: &'a Topology,
    /// MoE layer index (keys stateful policies' per-layer estimates).
    pub layer: usize,
}

/// Object-safe replica-selection policy.
///
/// `select` is called once per expert assignment, in batch order, by the
/// [`Dispatcher`]; `end_round` once per dispatched batch. Stateless
/// policies ignore `end_round`; [`LoadAware`] uses the pair to measure
/// per-round loads and refresh its online polling weights.
pub trait RoutePolicy {
    fn name(&self) -> &'static str;

    /// Select the GPU that executes `expert` for a token residing on
    /// `src_gpu`.
    fn select(&mut self, ctx: &RouteCtx<'_>, src_gpu: GpuId, expert: usize,
              rng: &mut Rng) -> GpuId;

    /// One dispatch round (batch) is complete; update online state.
    fn end_round(&mut self, _ctx: &RouteCtx<'_>) {}
}

/// Algorithm 3's weighted random choice over `candidates`, reading each
/// candidate GPU's weight from `weight_of` (indexed by GPU id). A
/// degenerate all-zero weight vector falls back to a *uniform* choice —
/// deterministically returning the first candidate would silently bias
/// toward the primary replica.
fn wrr_over(candidates: &[GpuId], weight_of: &[f64], rng: &mut Rng)
            -> GpuId {
    let weights: Vec<f64> =
        candidates.iter().map(|&g| weight_of[g]).collect();
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return candidates[rng.index(candidates.len())];
    }
    candidates[weighted_choice(rng, &weights)]
}

/// Outcome of the Algorithm-4 locality tier walk.
enum TierChoice<'a> {
    /// The tier rules force this GPU (single instance, or tier (i):
    /// an instance on the token's own GPU).
    Decided(GpuId),
    /// The tier's candidate set — tier (ii) same-node instances when any
    /// exist, tier (iii) all instances otherwise; the caller's weighting
    /// rule picks among them.
    Among(std::borrow::Cow<'a, [GpuId]>),
}

/// Algorithm 4's locality-first tier walk, shared by every tiered policy
/// ([`Tar`] resolves `Among` with frozen-weight WRR, [`LoadAware`] with
/// weighted least-in-flight) so the tier rules live in exactly one place.
fn locality_tiers<'a>(ctx: &RouteCtx<'_>, src_gpu: GpuId,
                      instances: &'a [GpuId]) -> TierChoice<'a> {
    if instances.len() == 1 {
        return TierChoice::Decided(instances[0]);
    }
    // Tier (i): same GPU.
    if instances.contains(&src_gpu) {
        return TierChoice::Decided(src_gpu);
    }
    // Tier (ii): same node.
    let node = ctx.topo.node_of(src_gpu);
    let local: Vec<GpuId> = instances
        .iter()
        .copied()
        .filter(|&g| ctx.topo.node_of(g) == node)
        .collect();
    if local.is_empty() {
        // Tier (iii): anywhere.
        TierChoice::Among(std::borrow::Cow::Borrowed(instances))
    } else {
        TierChoice::Among(std::borrow::Cow::Owned(local))
    }
}

/// No choice: the expert's primary GPU.
pub struct Primary;

impl RoutePolicy for Primary {
    fn name(&self) -> &'static str {
        "primary"
    }

    fn select(&mut self, ctx: &RouteCtx<'_>, _src_gpu: GpuId,
              expert: usize, _rng: &mut Rng) -> GpuId {
        ctx.placement.instances[expert][0]
    }
}

/// Algorithm 3: weighted random choice over all instances under the
/// placement's frozen Eq.-4 polling weights.
pub struct Wrr;

impl RoutePolicy for Wrr {
    fn name(&self) -> &'static str {
        "wrr"
    }

    fn select(&mut self, ctx: &RouteCtx<'_>, _src_gpu: GpuId,
              expert: usize, rng: &mut Rng) -> GpuId {
        let instances = &ctx.placement.instances[expert];
        debug_assert!(!instances.is_empty());
        if instances.len() == 1 {
            return instances[0];
        }
        wrr_over(instances, &ctx.placement.polling, rng)
    }
}

/// Algorithm 4: locality tiers with the frozen polling weights.
pub struct Tar;

impl RoutePolicy for Tar {
    fn name(&self) -> &'static str {
        "tar"
    }

    fn select(&mut self, ctx: &RouteCtx<'_>, src_gpu: GpuId,
              expert: usize, rng: &mut Rng) -> GpuId {
        let instances = &ctx.placement.instances[expert];
        debug_assert!(!instances.is_empty());
        match locality_tiers(ctx, src_gpu, instances) {
            TierChoice::Decided(g) => g,
            TierChoice::Among(c) => {
                wrr_over(&c, &ctx.placement.polling, rng)
            }
        }
    }
}

/// Weighted least-in-flight choice (weighted least-connections): the
/// candidate with the fewest in-flight tokens per unit of polling
/// weight. Deterministic; under steady flow the per-candidate counts
/// track the weight distribution (deficit round-robin), and a GPU that
/// other experts have already flooded this round is avoided immediately
/// instead of after the round closes.
fn weighted_least_inflight(candidates: &[GpuId], weight_of: &[f64],
                           inflight: &[f64]) -> GpuId {
    let mut best = candidates[0];
    let mut best_key = f64::INFINITY;
    for &g in candidates {
        let key = (inflight[g] + 1.0) / (weight_of[g] + 1e-12);
        if key < best_key {
            best_key = key;
            best = g;
        }
    }
    best
}

/// Load-predictive routing: TAR's locality tiers driven by an *online*
/// per-GPU load estimate instead of the placement-time prediction.
///
/// Two feedback loops, one inside the round and one across rounds:
///
/// * **in-flight (intra-round)** — tier-(ii)/(iii) choice is weighted
///   least-in-flight: among the tier's candidates, pick the GPU with the
///   fewest tokens routed to it so far this round per unit of polling
///   weight, so a burst landing on one replica host diverts follow-up
///   traffic immediately;
/// * **EWMA + Eq. 4 (cross-round)** — every `select` measures where the
///   assignment's primary would place it and its per-expert count; at
///   `end_round` the measurements fold into per-layer EWMAs and Eq. 4 is
///   recomputed over the *measured* loads (the placement's replication
///   decision stays fixed, only the load numbers are live), yielding the
///   polling weights for the next round.
///
/// State is kept per MoE layer ([`RouteCtx::layer`]) — placements,
/// replication decisions, and load profiles differ layer to layer, so
/// one blended estimate would misattribute Eq. 4's `W_max`/`W_r`. The
/// measurement itself lives in the shared [`LoadEstimator`] — the same
/// machinery the epoch re-planner ([`crate::replan`]) aggregates
/// finished plans into.
///
/// Under a stationary load that matches the profiling trace, the online
/// weights converge to the placement's static Eq.-4 polling weights (the
/// `load_aware_*` tests pin this); under drifted load they track the
/// drift, which static WRR/TAR cannot.
pub struct LoadAware {
    /// Shared per-layer EWMA measurement of dispatched loads.
    est: LoadEstimator,
    /// Tokens routed to each GPU in the current round (reset at
    /// `end_round`; rounds never interleave layers, so this is shared).
    inflight: Vec<f64>,
    /// Online Eq.-4 polling weights per layer; the placement's frozen
    /// weights are used until the layer's first round completes.
    polling: Vec<Option<Vec<f64>>>,
}

impl Default for LoadAware {
    fn default() -> Self {
        Self::new()
    }
}

impl LoadAware {
    /// Default EWMA smoothing: the last ~3 rounds dominate the estimate.
    pub const DEFAULT_ALPHA: f64 = 0.3;

    /// LoadAware with [`LoadAware::DEFAULT_ALPHA`] smoothing.
    pub fn new() -> LoadAware {
        Self::with_alpha(Self::DEFAULT_ALPHA)
    }

    /// LoadAware with an explicit EWMA smoothing factor `alpha ∈ [0, 1]`.
    pub fn with_alpha(alpha: f64) -> LoadAware {
        LoadAware {
            est: LoadEstimator::new(alpha),
            inflight: Vec::new(),
            polling: Vec::new(),
        }
    }

    /// The online polling weights in force for `layer` (`None` until one
    /// of its rounds has completed — the placement's frozen weights apply
    /// meanwhile).
    pub fn online_polling(&self, layer: usize) -> Option<&[f64]> {
        self.polling.get(layer)?.as_deref()
    }

    /// Completed measurement rounds for `layer`.
    pub fn rounds(&self, layer: usize) -> u64 {
        self.est.rounds(layer)
    }

    fn ensure_sized(&mut self, layer: usize, n_gpus: usize) {
        if self.inflight.len() < n_gpus {
            self.inflight.resize(n_gpus, 0.0);
        }
        if self.polling.len() <= layer {
            self.polling.resize(layer + 1, None);
        }
    }
}

impl RoutePolicy for LoadAware {
    fn name(&self) -> &'static str {
        "load-aware"
    }

    fn select(&mut self, ctx: &RouteCtx<'_>, src_gpu: GpuId, expert: usize,
              _rng: &mut Rng) -> GpuId {
        let lp = ctx.placement;
        self.ensure_sized(ctx.layer, lp.num_gpus());
        // Measure the assignment where its primary would place it (the
        // pre-replication load Eq. 4 starts from) and per expert.
        self.est.record(ctx.layer, lp, expert);

        let instances = &lp.instances[expert];
        debug_assert!(!instances.is_empty());
        let dst = match locality_tiers(ctx, src_gpu, instances) {
            TierChoice::Decided(g) => g,
            TierChoice::Among(c) => {
                let weights = self.polling[ctx.layer]
                    .as_deref()
                    .unwrap_or(&lp.polling);
                weighted_least_inflight(&c, weights, &self.inflight)
            }
        };
        self.inflight[dst] += 1.0;
        dst
    }

    fn end_round(&mut self, ctx: &RouteCtx<'_>) {
        let lp = ctx.placement;
        self.ensure_sized(ctx.layer, lp.num_gpus());
        self.inflight.iter_mut().for_each(|x| *x = 0.0);
        if !self.est.end_round(ctx.layer, lp.num_gpus(),
                               lp.instances.len()) {
            return; // empty round — keep the current estimate
        }
        let ewma_pre = self.est.pre_loads(ctx.layer).expect("round closed");

        // Eq. 4 over the measured loads: the placement's replication
        // decision with live W_max / W_r / per-GPU loads.
        let rep = &lp.replication;
        let predicted = if rep.is_none() {
            ewma_pre.to_vec()
        } else {
            // Hot experts all live in the heaviest group, so its GPU is
            // their shared primary.
            let ewma_expert =
                self.est.expert_loads(ctx.layer).expect("round closed");
            let heavy = lp.primary[rep.hot_experts[0]];
            let online = Replication {
                hot_experts: rep.hot_experts.clone(),
                replica_gpus: rep.replica_gpus.clone(),
                n_replica: rep.n_replica,
                w_max: ewma_pre[heavy],
                w_r: rep
                    .hot_experts
                    .iter()
                    .map(|&e| ewma_expert[e])
                    .sum(),
                computed: true,
            };
            predict_loads(ewma_pre, heavy, &online)
                .into_iter()
                .map(|w| w.max(0.0))
                .collect()
        };
        self.polling[ctx.layer] = Some(polling_weights(&predicted));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::Grouping;
    use crate::linalg::Matrix;
    use crate::placement::{LayerPlacement, ReplicationMode};
    use crate::profile::LayerProfile;
    use crate::replication::Replication;
    use crate::testutil::{check, prop_assert};

    /// Hand-built placement on 2×2: expert 0 hot on gpu 0, replicated to
    /// gpus 1 (same node) and 2 (remote); experts 1–3 primary-only on
    /// gpus 1,2,3.
    fn fixture() -> LayerPlacement {
        let groups: Grouping =
            vec![vec![0], vec![1], vec![2], vec![3]];
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![90.0, 30.0, 20.0, 10.0],
            tokens: 150,
        };
        let mut p = LayerPlacement::build(&profile, groups,
                                          ReplicationMode::None);
        p.replication = Replication {
            hot_experts: vec![0],
            replica_gpus: vec![1, 2],
            n_replica: 2,
            w_max: 90.0,
            w_r: 90.0,
            computed: true,
        };
        p.instances[0] = vec![0, 1, 2];
        // simple polling weights favouring gpu 3 then 2 then 1 then 0
        p.polling = vec![0.1, 0.2, 0.3, 0.4];
        p
    }

    fn topo() -> Topology {
        Topology::two_by_two()
    }

    fn route(policy: &mut dyn RoutePolicy, p: &LayerPlacement,
             t: &Topology, src: GpuId, expert: usize, rng: &mut Rng)
             -> GpuId {
        policy.select(&RouteCtx { placement: p, topo: t, layer: 0 }, src,
                      expert, rng)
    }

    #[test]
    fn primary_policy_ignores_replicas() {
        let p = fixture();
        let t = topo();
        let mut pol = RoutingPolicy::Primary.build();
        let mut rng = Rng::new(1);
        for src in 0..4 {
            assert_eq!(route(pol.as_mut(), &p, &t, src, 0, &mut rng), 0);
        }
    }

    #[test]
    fn unreplicated_experts_always_primary() {
        let p = fixture();
        let t = topo();
        for policy in [RoutingPolicy::Wrr, RoutingPolicy::Tar,
                       RoutingPolicy::LoadAware] {
            let mut pol = policy.build();
            let mut rng = Rng::new(2);
            for _ in 0..50 {
                assert_eq!(route(pol.as_mut(), &p, &t, 3, 2, &mut rng), 2);
            }
        }
    }

    #[test]
    fn wrr_frequencies_match_polling_weights() {
        let p = fixture();
        let t = topo();
        let mut pol = Wrr;
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 4];
        let n = 60_000;
        for _ in 0..n {
            counts[route(&mut pol, &p, &t, 3, 0, &mut rng)] += 1;
        }
        // instances {0,1,2} with weights {0.1,0.2,0.3} → 1/6, 2/6, 3/6
        assert_eq!(counts[3], 0);
        for (g, want) in [(0, 1.0 / 6.0), (1, 2.0 / 6.0), (2, 3.0 / 6.0)] {
            let emp = counts[g] as f64 / n as f64;
            assert!((emp - want).abs() < 0.01, "gpu {g}: {emp} vs {want}");
        }
    }

    #[test]
    fn wrr_zero_weight_falls_back_to_uniform() {
        // Regression: `total <= 0` used to return candidates[0]
        // deterministically, silently biasing toward the primary replica.
        let mut p = fixture();
        p.polling = vec![0.0; 4];
        let t = topo();
        let mut pol = Wrr;
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 4];
        let n = 30_000;
        for _ in 0..n {
            counts[route(&mut pol, &p, &t, 3, 0, &mut rng)] += 1;
        }
        assert_eq!(counts[3], 0, "non-instance gpu");
        for g in [0, 1, 2] {
            let emp = counts[g] as f64 / n as f64;
            assert!((emp - 1.0 / 3.0).abs() < 0.02,
                    "gpu {g}: {emp} not uniform");
        }
    }

    #[test]
    fn tar_tier1_same_gpu_wins() {
        let p = fixture();
        let t = topo();
        let mut pol = Tar;
        let mut rng = Rng::new(4);
        for src in [0, 1, 2] {
            for _ in 0..20 {
                assert_eq!(route(&mut pol, &p, &t, src, 0, &mut rng), src,
                           "instance on src gpu must be chosen");
            }
        }
    }

    #[test]
    fn tar_tier2_prefers_same_node() {
        let p = fixture();
        let t = topo();
        let mut pol = Tar;
        let mut rng = Rng::new(5);
        // src gpu 3 (node 1): instance gpus {0,1} are node 0, {2} node 1
        for _ in 0..100 {
            assert_eq!(route(&mut pol, &p, &t, 3, 0, &mut rng), 2,
                       "same-node replica must win");
        }
    }

    #[test]
    fn tar_tier3_falls_back_to_global_wrr() {
        let mut p = fixture();
        // strip the node-1 replica: instances {0, 1}, both node 0
        p.instances[0] = vec![0, 1];
        let t = topo();
        let mut pol = Tar;
        let mut rng = Rng::new(6);
        let mut counts = [0usize; 4];
        for _ in 0..30_000 {
            counts[route(&mut pol, &p, &t, 3, 0, &mut rng)] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0);
        // weights 0.1 vs 0.2 → 1:2
        let ratio = counts[1] as f64 / counts[0] as f64;
        assert!((ratio - 2.0).abs() < 0.2, "ratio {ratio}");
    }

    #[test]
    fn property_tar_never_leaves_node_when_local_replica_exists() {
        check(100, |rng| {
            let p = fixture();
            let t = topo();
            let mut pol = Tar;
            let src = rng.index(4);
            let dst = route(&mut pol, &p, &t, src, 0, rng);
            let local_exists = p.instances[0]
                .iter()
                .any(|&g| t.node_of(g) == t.node_of(src));
            if local_exists {
                prop_assert(
                    t.node_of(dst) == t.node_of(src),
                    format!("src {src} routed off-node to {dst}"),
                )?;
            }
            prop_assert(p.instances[0].contains(&dst),
                        "must route to an instance")
        });
    }

    #[test]
    fn property_policies_route_only_to_instances() {
        check(100, |rng| {
            let p = fixture();
            let t = topo();
            for policy in [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                           RoutingPolicy::Tar, RoutingPolicy::LoadAware] {
                let mut pol = policy.build();
                let src = rng.index(4);
                let e = rng.index(4);
                let dst = route(pol.as_mut(), &p, &t, src, e, rng);
                prop_assert(p.instances[e].contains(&dst),
                            format!("{}: non-instance gpu", policy.name()))?;
            }
            Ok(())
        });
    }

    // --- LoadAware ------------------------------------------------------

    /// Replaying the *profiling sample itself* as the serving load is the
    /// perfectly stationary case: the measured loads equal the profile
    /// loads exactly, so the online Eq.-4 recomputation must land on the
    /// placement's static polling weights (up to summation order) — for
    /// every layer independently (the per-layer state must not blend one
    /// layer's loads into another's Eq. 4).
    #[test]
    fn load_aware_converges_to_static_polling_under_stationary_load() {
        use crate::baselines::GroupingStrategy;
        use crate::coordinator::Coordinator;
        use crate::config::ModelSpec;
        use crate::placement::ReplicationMode;
        use crate::trace::Profile;

        let topo = topo();
        let coord = Coordinator::new(
            GroupingStrategy::Hierarchical { r: 0.15 },
            ReplicationMode::Dynamic,
            RoutingPolicy::LoadAware,
            topo.clone(),
            11,
        );
        let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
        let trace = coord.profile_synthetic(&model, Profile::Math, 2048);
        let placement = coord.place(&trace);

        let mut la = LoadAware::new();
        let mut rng = Rng::new(3);
        for _round in 0..8 {
            // One round per layer per step, interleaved like the engines.
            for (l, layer) in trace.layers.iter().enumerate() {
                let ctx = RouteCtx {
                    placement: &placement.layers[l],
                    topo: &topo,
                    layer: l,
                };
                for (t, experts) in layer.tokens.iter().enumerate() {
                    let src = t * topo.num_gpus() / layer.tokens.len();
                    for &e in experts {
                        la.select(&ctx, src, e as usize, &mut rng);
                    }
                }
                la.end_round(&ctx);
            }
        }
        for (l, lp) in placement.layers.iter().enumerate() {
            let online = la.online_polling(l).expect("rounds completed");
            for (g, (&o, &s)) in online.iter().zip(&lp.polling).enumerate()
            {
                assert!(
                    (o - s).abs() < 1e-9,
                    "layer {l} gpu {g}: online polling {o} != static {s}"
                );
            }
        }
    }

    /// Resampled (not replayed) stationary traffic: the measurement is
    /// noisy but unbiased, so the online weights still approach the
    /// static prediction.
    #[test]
    fn load_aware_tracks_static_polling_under_resampled_load() {
        use crate::baselines::GroupingStrategy;
        use crate::coordinator::Coordinator;
        use crate::config::ModelSpec;
        use crate::placement::ReplicationMode;
        use crate::trace::{Profile, TraceGen};

        let topo = topo();
        let coord = Coordinator::new(
            GroupingStrategy::Hierarchical { r: 0.15 },
            ReplicationMode::Dynamic,
            RoutingPolicy::LoadAware,
            topo.clone(),
            11,
        );
        let model = ModelSpec { moe_layers: 1, ..ModelSpec::olmoe() };
        let placement = coord.place(
            &coord.profile_synthetic(&model, Profile::Math, 4096),
        );
        let lp = &placement.layers[0];

        let mut la = LoadAware::new();
        let ctx = RouteCtx { placement: lp, topo: &topo, layer: 0 };
        let mut rng = Rng::new(5);
        for round in 0..10u64 {
            let serve = TraceGen {
                experts: model.experts,
                top_k: model.top_k,
                layers: 1,
                profile: Profile::Math,
                seed: 9000 + round,
            }
            .generate(4096);
            let layer = &serve.layers[0];
            for (t, experts) in layer.tokens.iter().enumerate() {
                let src = t * topo.num_gpus() / layer.tokens.len();
                for &e in experts {
                    la.select(&ctx, src, e as usize, &mut rng);
                }
            }
            la.end_round(&ctx);
        }
        let online = la.online_polling(0).unwrap();
        for (g, (&o, &s)) in online.iter().zip(&lp.polling).enumerate() {
            assert!(
                (o - s).abs() < 0.05,
                "gpu {g}: online polling {o} vs static {s}"
            );
        }
    }

    /// Skewed synthetic trace on a single node (so tier-(ii) spans every
    /// instance): the placement's frozen weights are stale — a background
    /// stream overloads one replica host — and the online recomputation
    /// must shift replica traffic away from it, reducing the max per-GPU
    /// load share vs static WRR.
    #[test]
    fn load_aware_reduces_max_load_share_vs_static_wrr() {
        let groups: Grouping = vec![vec![0], vec![2], vec![1], vec![3]];
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![25.0, 25.0, 25.0, 25.0],
            tokens: 100,
        };
        let mut p = LayerPlacement::build(&profile, groups,
                                          ReplicationMode::None);
        // Expert 0 replicated to gpus 1 and 2; the *stale* prediction
        // says all four GPUs are equally loaded.
        p.replication = Replication {
            hot_experts: vec![0],
            replica_gpus: vec![1, 2],
            n_replica: 2,
            w_max: 25.0,
            w_r: 25.0,
            computed: true,
        };
        p.instances[0] = vec![0, 1, 2];
        p.polling = vec![0.25; 4];
        let t = Topology::paper_testbed(1, 4);

        // Serving round: B expert-1 tokens (primary-forced onto gpu 2 —
        // the background hotspot the frozen weights don't know about) and
        // B expert-0 tokens from gpu 3 (tier-ii choice over {0,1,2}).
        let round: Vec<(usize, usize)> = (0..1000)
            .flat_map(|_| [(1usize, 2usize), (0, 3)])
            .collect();

        fn max_share(policy: &mut dyn RoutePolicy, p: &LayerPlacement,
                     t: &Topology, round: &[(usize, usize)]) -> f64 {
            let ctx = RouteCtx { placement: p, topo: t, layer: 0 };
            let mut rng = Rng::new(17);
            let mut copies = [0.0f64; 4];
            for _ in 0..10 {
                for &(e, src) in round {
                    copies[policy.select(&ctx, src, e, &mut rng)] += 1.0;
                }
                policy.end_round(&ctx);
            }
            let total: f64 = copies.iter().sum();
            copies.iter().cloned().fold(0.0, f64::max) / total
        }

        let wrr = max_share(&mut Wrr, &p, &t, &round);
        let la = max_share(&mut LoadAware::new(), &p, &t, &round);
        // Static WRR keeps sending 1/3 of the replica traffic to the
        // overloaded gpu 2 (max share → 2/3); LoadAware diverts it.
        assert!(
            la < wrr - 0.05,
            "load-aware max share {la} !< wrr {wrr} - 0.05"
        );
    }

    #[test]
    fn load_aware_empty_round_keeps_estimate() {
        let p = fixture();
        let t = topo();
        let ctx = RouteCtx { placement: &p, topo: &t, layer: 0 };
        let mut la = LoadAware::new();
        let mut rng = Rng::new(1);
        la.select(&ctx, 3, 0, &mut rng);
        la.end_round(&ctx);
        assert_eq!(la.rounds(0), 1);
        let before = la.online_polling(0).unwrap().to_vec();
        la.end_round(&ctx); // no traffic since last round
        assert_eq!(la.rounds(0), 1);
        assert_eq!(la.online_polling(0).unwrap(), &before[..]);
    }
}
