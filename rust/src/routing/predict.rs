//! Cross-layer activation prediction: which experts layer `l+1` will
//! activate, conditioned on the gate outcome just observed at layer `l`.
//!
//! The paper's replication machinery (Eq. 3/4) and the online
//! re-planner decide *where* expert copies live; this module predicts
//! *when* a copy will be needed next, so the prefetch stage
//! ([`crate::engine::prefetch`]) can stage weights while the current
//! layer's FFNs are still running. The estimator mirrors the
//! [`LoadEstimator`](crate::routing::LoadEstimator) measurement
//! substrate: per-transition EWMAs fed from finished
//! [`DispatchPlan`]s, one plan = one measurement round, first
//! non-empty round seeds the EWMA directly (`α = 1`).
//!
//! The measured quantity is the *co-activation* count: for each token,
//! every (expert at layer `l`, expert at layer `l+1`) pair of its gate
//! picks. `P(e' active at l+1 | e active at l)` is then the EWMA joint
//! count over the EWMA marginal of `e` — the conditional the
//! [`CrossLayerPredictor::predict`] score sums over the currently
//! active experts. Transitions wrap around: layer `L−1` predicts layer
//! `0` of the *next* step, so the pipeline's first layer is
//! prefetchable too (per-token pairing across the wrap is a heuristic —
//! different tokens — but it captures exactly the hot-set persistence
//! a decode loop exhibits).

use crate::routing::DispatchPlan;

/// EWMA state of one layer transition `l → (l+1) mod L`.
#[derive(Clone, Debug, Default)]
struct Transition {
    /// EWMA of per-round joint co-activation counts, row-major
    /// `[prev_expert * experts + next_expert]`.
    ewma_joint: Vec<f64>,
    /// EWMA of per-round previous-layer activation counts (the
    /// marginal the conditional divides by).
    ewma_prev: Vec<f64>,
    /// Completed (non-empty) measurement rounds.
    rounds: u64,
}

/// Per-transition EWMA estimator of cross-layer expert co-activation,
/// plus the most recent gate outcome per layer — everything
/// [`CrossLayerPredictor::predict`] needs to rank next-layer experts.
///
/// Layers never share state: co-activation structure differs per
/// transition, so one blended estimate would smear a sharp `l → l+1`
/// correlation across the whole stack.
#[derive(Clone, Debug)]
pub struct CrossLayerPredictor {
    alpha: f64,
    layers: usize,
    experts: usize,
    transitions: Vec<Transition>,
    /// Most recent per-token expert picks observed at each layer
    /// (token-major, as routed). `None` until the layer's first plan.
    last: Vec<Option<Vec<Vec<u16>>>>,
}

impl CrossLayerPredictor {
    /// Predictor over `layers` MoE layers of `experts` experts each,
    /// with EWMA smoothing factor `alpha ∈ (0, 1]` (the weight of the
    /// newest round).
    pub fn new(layers: usize, experts: usize, alpha: f64)
               -> CrossLayerPredictor {
        assert!(layers > 0 && experts > 0, "non-degenerate model");
        assert!(alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
                "alpha in (0, 1]");
        CrossLayerPredictor {
            alpha,
            layers,
            experts,
            transitions: vec![Transition::default(); layers],
            last: vec![None; layers],
        }
    }

    /// The layer a prediction made at `layer` targets: `(l+1) mod L`
    /// (wrap-around — the last layer predicts the next step's first).
    pub fn next_layer(&self, layer: usize) -> usize {
        (layer + 1) % self.layers
    }

    /// Completed measurement rounds of the transition out of `layer`.
    pub fn rounds(&self, layer: usize) -> u64 {
        self.transitions[layer].rounds
    }

    /// Feed one finished [`DispatchPlan`] of `layer` (one measurement
    /// round): fold the co-activation counts against the previous
    /// layer's remembered outcome, then remember this layer's outcome
    /// for the next transition.
    pub fn observe_plan(&mut self, layer: usize, plan: &DispatchPlan) {
        let mut sets: Vec<Vec<u16>> = Vec::new();
        let mut current: Option<usize> = None;
        for r in plan.assignments() {
            if current != Some(r.token) {
                sets.push(Vec::new());
                current = Some(r.token);
            }
            sets.last_mut().expect("pushed").push(r.expert as u16);
        }
        self.observe_sets(layer, &sets);
    }

    /// [`Self::observe_plan`] on raw token-major expert picks (what a
    /// gate trace holds before routing; pruning-free path for tests
    /// and trace-driven engines).
    pub fn observe_sets(&mut self, layer: usize, sets: &[Vec<u16>]) {
        assert!(layer < self.layers, "layer out of range");
        if sets.iter().all(|s| s.is_empty()) {
            return; // empty round — keep the current estimate
        }
        let e_n = self.experts;
        let prev_layer = (layer + self.layers - 1) % self.layers;
        if let Some(prev) = &self.last[prev_layer] {
            // Per-token pairing (min length guards cross-step chunk
            // size changes on the wrap transition).
            let n = prev.len().min(sets.len());
            let mut joint = vec![0.0f64; e_n * e_n];
            let mut marginal = vec![0.0f64; e_n];
            for t in 0..n {
                for &pe in &prev[t] {
                    marginal[pe as usize] += 1.0;
                    for &e in &sets[t] {
                        joint[pe as usize * e_n + e as usize] += 1.0;
                    }
                }
            }
            if marginal.iter().sum::<f64>() > 0.0 {
                let tr = &mut self.transitions[prev_layer];
                if tr.ewma_joint.is_empty() {
                    tr.ewma_joint = vec![0.0; e_n * e_n];
                    tr.ewma_prev = vec![0.0; e_n];
                }
                tr.rounds += 1;
                // First round seeds the EWMA directly (no stale zero
                // history), exactly like the load estimator.
                let a = if tr.rounds == 1 { 1.0 } else { self.alpha };
                for (e, m) in tr.ewma_joint.iter_mut().zip(&joint) {
                    *e = (1.0 - a) * *e + a * m;
                }
                for (e, m) in tr.ewma_prev.iter_mut().zip(&marginal) {
                    *e = (1.0 - a) * *e + a * m;
                }
            }
        }
        self.last[layer] = Some(sets.to_vec());
    }

    /// Estimated `P(next active | prev active)` for the transition out
    /// of `layer`; `None` until a round of that transition closed.
    pub fn conditional(&self, layer: usize, prev: usize, next: usize)
                       -> Option<f64> {
        let tr = &self.transitions[layer];
        if tr.rounds == 0 {
            return None;
        }
        let m = tr.ewma_prev[prev];
        if m <= 0.0 {
            return Some(0.0);
        }
        Some(tr.ewma_joint[prev * self.experts + next] / m)
    }

    /// Top-`k` experts predicted active at [`Self::next_layer`]`(layer)`,
    /// most likely first (ties break to the lower expert index, so the
    /// ranking is deterministic). Scores sum the learned conditionals
    /// over the experts just observed active at `layer`, weighted by
    /// how often each fired. Empty until both the transition has a
    /// closed round and `layer` has an observed outcome — no
    /// prediction means no prefetch, never a guess.
    pub fn predict(&self, layer: usize, k: usize) -> Vec<usize> {
        let tr = &self.transitions[layer];
        let (Some(cur), true) = (&self.last[layer], tr.rounds > 0) else {
            return Vec::new();
        };
        let e_n = self.experts;
        let mut activity = vec![0.0f64; e_n];
        for set in cur {
            for &e in set {
                activity[e as usize] += 1.0;
            }
        }
        let mut scores = vec![0.0f64; e_n];
        for (pe, &act) in activity.iter().enumerate() {
            if act <= 0.0 || tr.ewma_prev[pe] <= 0.0 {
                continue;
            }
            let inv = act / tr.ewma_prev[pe];
            let row = &tr.ewma_joint[pe * e_n..(pe + 1) * e_n];
            for (s, &j) in scores.iter_mut().zip(row) {
                *s += inv * j;
            }
        }
        let mut order: Vec<usize> = (0..e_n).collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .expect("scores are finite")
                .then(a.cmp(&b))
        });
        // Zero score = zero evidence: staging such an expert would be
        // a pure guess, so it is not a prediction at all.
        order.retain(|&e| scores[e] > 0.0);
        order.truncate(k.min(e_n));
        order
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::linalg::Matrix;
    use crate::placement::{LayerPlacement, ReplicationMode};
    use crate::profile::LayerProfile;
    use crate::routing::{Assignment, Dispatcher, RoutingPolicy};
    use crate::stats::Rng;

    const E: usize = 8;

    /// Token-major picks where every token at layer 0 takes `e` and at
    /// layer 1 takes `(e + shift) % E`.
    fn shifted_round(hot: &[u16], shift: u16)
                     -> (Vec<Vec<u16>>, Vec<Vec<u16>>) {
        let l0: Vec<Vec<u16>> = hot.iter().map(|&e| vec![e]).collect();
        let l1: Vec<Vec<u16>> = hot
            .iter()
            .map(|&e| vec![(e + shift) % E as u16])
            .collect();
        (l0, l1)
    }

    #[test]
    fn converges_to_true_conditional_on_correlated_trace() {
        // Two layers, deterministic structure: expert e at layer 0 ⇒
        // expert (e+3)%8 at layer 1. The EWMA conditional must converge
        // to exactly 1 on the shifted pair and 0 elsewhere.
        let mut pred = CrossLayerPredictor::new(2, E, 0.3);
        for round in 0..12u16 {
            let hot = [round % 4, 4 + round % 4];
            let (l0, l1) = shifted_round(&hot, 3);
            pred.observe_sets(0, &l0);
            pred.observe_sets(1, &l1);
        }
        assert!(pred.rounds(0) > 0);
        for pe in 0..4usize {
            let on = pred.conditional(0, pe, (pe + 3) % E).unwrap();
            assert!((on - 1.0).abs() < 1e-9,
                    "P({} | {pe}) = {on}, want 1", (pe + 3) % E);
            let off = pred.conditional(0, pe, (pe + 4) % E).unwrap();
            assert!(off.abs() < 1e-9, "spurious co-activation {off}");
        }
    }

    #[test]
    fn predicts_the_shifted_hot_set() {
        let mut pred = CrossLayerPredictor::new(2, E, 0.5);
        for _ in 0..4 {
            let (l0, l1) = shifted_round(&[1, 5], 2);
            pred.observe_sets(0, &l0);
            pred.observe_sets(1, &l1);
        }
        let mut top = pred.predict(0, 2);
        top.sort_unstable();
        assert_eq!(top, vec![3, 7],
                   "layer-1 prediction must be the shifted hot set");
    }

    #[test]
    fn uniform_trace_gives_uniform_conditionals() {
        // Every token activates every expert at both layers: the
        // conditional must be 1 for every pair (no spurious structure)
        // and predict() must still return exactly k valid experts.
        let all: Vec<Vec<u16>> =
            (0..4).map(|_| (0..E as u16).collect()).collect();
        let mut pred = CrossLayerPredictor::new(2, E, 0.3);
        for _ in 0..5 {
            pred.observe_sets(0, &all);
            pred.observe_sets(1, &all);
        }
        for pe in 0..E {
            for e in 0..E {
                let c = pred.conditional(0, pe, e).unwrap();
                assert!((c - 1.0).abs() < 1e-9,
                        "P({e} | {pe}) = {c} under uniform traffic");
            }
        }
        let top = pred.predict(0, 3);
        assert_eq!(top.len(), 3);
        assert!(top.iter().all(|&e| e < E));
        // Deterministic tie-break: uniform scores rank by index.
        assert_eq!(top, vec![0, 1, 2]);
    }

    #[test]
    fn cold_predictor_predicts_nothing() {
        let pred = CrossLayerPredictor::new(4, E, 0.3);
        assert!(pred.predict(0, 4).is_empty(),
                "no rounds ⇒ no prediction ⇒ no prefetch");
        assert!(pred.conditional(0, 0, 1).is_none());

        // One layer-0 observation alone closes no transition round.
        let mut pred = CrossLayerPredictor::new(4, E, 0.3);
        pred.observe_sets(0, &[vec![1]]);
        assert_eq!(pred.rounds(0), 0);
        assert!(pred.predict(0, 2).is_empty());
    }

    #[test]
    fn wraparound_transition_predicts_next_steps_first_layer() {
        // L = 2: observing layer 1 then layer 0 (next step) feeds the
        // 1 → 0 transition; a persistent hot set must become
        // predictable across the wrap.
        let mut pred = CrossLayerPredictor::new(2, E, 0.5);
        for _ in 0..4 {
            pred.observe_sets(0, &[vec![2]]);
            pred.observe_sets(1, &[vec![6]]);
        }
        assert!(pred.rounds(1) > 0, "wrap transition never folded");
        assert_eq!(pred.predict(1, 1), vec![2],
                   "layer 1 must predict the next step's layer-0 set");
    }

    #[test]
    fn observe_plan_matches_observe_sets() {
        // The DispatchPlan feed must measure exactly what the raw gate
        // sets would: route an identical batch both ways.
        fn fixture() -> LayerPlacement {
            let profile = LayerProfile {
                affinity: Matrix::zeros(4, 4),
                load: vec![4.0, 3.0, 2.0, 1.0],
                tokens: 10,
            };
            LayerPlacement::build(
                &profile,
                vec![vec![0], vec![1], vec![2], vec![3]],
                ReplicationMode::None,
            )
        }
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let sets0: Vec<Vec<u16>> = vec![vec![0, 1], vec![2], vec![3, 0]];
        let sets1: Vec<Vec<u16>> = vec![vec![1], vec![3, 2], vec![0]];
        let mut via_plan = CrossLayerPredictor::new(2, 4, 0.4);
        let mut via_sets = CrossLayerPredictor::new(2, 4, 0.4);
        let mut d = Dispatcher::new(topo, RoutingPolicy::Primary.build(),
                                    1.0);
        let mut rng = Rng::new(9);
        for (layer, sets) in [(0usize, &sets0), (1, &sets1)] {
            let batch: Vec<Assignment> = sets
                .iter()
                .enumerate()
                .flat_map(|(t, es)| {
                    es.iter().map(move |&e| Assignment {
                        token: t,
                        expert: e as usize,
                        src: t % 4,
                    })
                })
                .collect();
            let plan = d.dispatch(&lp, layer, &batch, &mut rng);
            via_plan.observe_plan(layer, &plan);
            via_sets.observe_sets(layer, sets);
        }
        assert_eq!(via_plan.rounds(0), via_sets.rounds(0));
        for pe in 0..4 {
            for e in 0..4 {
                assert_eq!(via_plan.conditional(0, pe, e),
                           via_sets.conditional(0, pe, e),
                           "plan feed diverged at ({pe}, {e})");
            }
        }
        assert_eq!(via_plan.predict(0, 2), via_sets.predict(0, 2));
    }

    #[test]
    #[should_panic(expected = "alpha")]
    fn nan_alpha_is_rejected() {
        let _ = CrossLayerPredictor::new(2, E, f64::NAN);
    }
}
