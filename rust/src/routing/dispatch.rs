//! Batched dispatch: route a whole batch of expert assignments in one
//! call and group the result into per-`(src, dst)` transfer lists.
//!
//! The scalar per-token loops the engines used to hand-roll around the
//! router are replaced by one [`Dispatcher::dispatch`] call per layer
//! round: the dispatcher applies its [`RoutePolicy`] to every
//! [`Assignment`] of the batch (in batch order, so the policy's RNG
//! stream is identical to the old scalar walk) and emits a
//! [`DispatchPlan`] holding three synchronized views of the decision:
//!
//! * **assignments** — the routed `(token, expert, src → dst)` records in
//!   batch order (what the execute engine's combine step walks),
//! * **transfer lists** — assignments grouped per `(src, dst)` GPU pair
//!   with byte accounting (what an A2A backend would enqueue as one
//!   buffer per pair),
//! * **per-token dispatches** — the legacy token-major [`Dispatch`] view
//!   the communication traffic models consume (their dedup semantics are
//!   per token).
//!
//! Routing one batch also defines one *round* for stateful policies: the
//! dispatcher calls [`RoutePolicy::end_round`] after the batch, which is
//! where [`crate::routing::LoadAware`] refreshes its online Eq.-4
//! weights.

use super::{RouteCtx, RoutePolicy};
use crate::cluster::{GpuId, Topology};
use crate::comm::traffic::Dispatch;
use crate::placement::LayerPlacement;
use crate::stats::Rng;
use std::sync::OnceLock;

/// One unrouted expert assignment: token `token` residing on GPU `src`
/// selected expert `expert`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Assignment {
    /// Token index within the batch.
    pub token: usize,
    /// Expert the token's gate selected.
    pub expert: usize,
    /// GPU the token resides on (data parallelism).
    pub src: GpuId,
}

/// One routed assignment within a [`DispatchPlan`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Routed {
    /// Position of this assignment in the dispatched batch (stable handle
    /// for caller-side side data, e.g. gate weights).
    pub index: usize,
    /// Token index within the batch.
    pub token: usize,
    /// Expert the token's gate selected.
    pub expert: usize,
    /// GPU the token resides on.
    pub src: GpuId,
    /// GPU the policy routed the assignment to (an instance host).
    pub dst: GpuId,
}

/// The routed batch: every `(token, expert)` assignment appears in
/// exactly one per-`(src, dst)` transfer list (token conservation — the
/// `plan_*` property tests pin this).
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    n_gpus: usize,
    token_bytes: f64,
    /// Routed assignments in batch order.
    assignments: Vec<Routed>,
    /// Per `(src, dst)` pair (row-major `src * n_gpus + dst`): indices
    /// into `assignments`, in batch order.
    transfers: Vec<Vec<u32>>,
    /// Token-major legacy view for the traffic models, derived lazily
    /// from `assignments` — the execute-engine hot path never reads it,
    /// so it should not pay one small `Vec` per token per round.
    per_token: OnceLock<Vec<Dispatch>>,
    /// Routed copies per destination GPU (compute load).
    copies: Vec<usize>,
}

impl DispatchPlan {
    /// GPUs the plan's transfer lists span.
    pub fn num_gpus(&self) -> usize {
        self.n_gpus
    }

    /// Bytes one token copy moves (the model's hidden activation).
    pub fn token_bytes(&self) -> f64 {
        self.token_bytes
    }

    /// Routed assignments in batch order.
    pub fn assignments(&self) -> &[Routed] {
        &self.assignments
    }

    /// Routed assignments in the plan.
    pub fn num_assignments(&self) -> usize {
        self.assignments.len()
    }

    /// Distinct tokens routed (tokens whose every assignment was pruned
    /// before dispatch do not appear).
    pub fn num_tokens(&self) -> usize {
        self.per_token().len()
    }

    /// The token-major per-token view ([`Dispatch`] per token, in first-
    /// appearance order) — what the traffic models consume (their dedup
    /// semantics are per token). Built on first use from the batch-order
    /// assignments and cached.
    pub fn per_token(&self) -> &[Dispatch] {
        self.per_token.get_or_init(|| {
            let mut view: Vec<Dispatch> = Vec::new();
            let mut current: Option<(usize, GpuId)> = None;
            for r in &self.assignments {
                if current != Some((r.token, r.src)) {
                    view.push(Dispatch { src: r.src, dsts: Vec::new() });
                    current = Some((r.token, r.src));
                }
                view.last_mut().unwrap().dsts.push(r.dst);
            }
            // The grouping above assumes the batch was token-major (one
            // contiguous run per token); a scattered batch would split a
            // token into several Dispatch entries and silently break the
            // traffic models' per-token dedup.
            #[cfg(debug_assertions)]
            {
                let distinct: std::collections::HashSet<(usize, GpuId)> =
                    self.assignments
                        .iter()
                        .map(|r| (r.token, r.src))
                        .collect();
                debug_assert_eq!(
                    view.len(),
                    distinct.len(),
                    "dispatched batch was not token-major"
                );
            }
            view
        })
    }

    /// Routed copies per destination GPU.
    pub fn copies_per_gpu(&self) -> &[usize] {
        &self.copies
    }

    /// The `(src, dst)` transfer list: routed assignments moving from
    /// `src` to `dst`, in batch order.
    pub fn transfer(&self, src: GpuId, dst: GpuId)
                    -> impl Iterator<Item = &Routed> + '_ {
        self.transfers[src * self.n_gpus + dst]
            .iter()
            .map(|&i| &self.assignments[i as usize])
    }

    /// Copies in the `(src, dst)` transfer list.
    pub fn transfer_len(&self, src: GpuId, dst: GpuId) -> usize {
        self.transfers[src * self.n_gpus + dst].len()
    }

    /// Per-copy bytes of the `(src, dst)` transfer list.
    pub fn transfer_bytes(&self, src: GpuId, dst: GpuId) -> f64 {
        self.transfer_len(src, dst) as f64 * self.token_bytes
    }

    /// All assignments destined for `dst`, grouped by source GPU (the
    /// order one rank's receive buffers would arrive in).
    pub fn for_rank(&self, dst: GpuId)
                    -> impl Iterator<Item = &Routed> + '_ {
        (0..self.n_gpus).flat_map(move |src| self.transfer(src, dst))
    }

    /// Total per-copy bytes, counting the free same-GPU diagonal.
    pub fn total_bytes(&self) -> f64 {
        self.assignments.len() as f64 * self.token_bytes
    }

    /// Per-copy bytes that actually cross a link (off-diagonal).
    pub fn moved_bytes(&self) -> f64 {
        self.assignments
            .iter()
            .filter(|r| r.src != r.dst)
            .count() as f64
            * self.token_bytes
    }
}

/// Batched router: applies one [`RoutePolicy`] to whole batches of
/// assignments against a per-layer placement. Build one per run through
/// [`crate::coordinator::OnlineCoordinator::dispatcher`] so stateful
/// policies keep their online estimates across rounds.
pub struct Dispatcher {
    topo: Topology,
    policy: Box<dyn RoutePolicy>,
    token_bytes: f64,
}

impl Dispatcher {
    /// Dispatcher executing `policy` over `topo`, accounting
    /// `token_bytes` per routed copy.
    pub fn new(topo: Topology, policy: Box<dyn RoutePolicy>,
               token_bytes: f64) -> Dispatcher {
        Dispatcher { topo, policy, token_bytes }
    }

    /// The topology routing decisions are made against.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// Name of the policy this dispatcher executes.
    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    /// Bytes one token copy moves (plan byte accounting).
    pub fn token_bytes(&self) -> f64 {
        self.token_bytes
    }

    /// Route one batch (= one policy round) against `placement`, the
    /// layer-`layer` placement of the model (stateful policies keep
    /// per-layer estimates — see [`RouteCtx::layer`]).
    ///
    /// Assignments are routed in batch order; callers pass batches in
    /// token-major order so the per-token view groups each token's
    /// contiguous run of assignments into one [`Dispatch`].
    pub fn dispatch(&mut self, placement: &LayerPlacement, layer: usize,
                    batch: &[Assignment], rng: &mut Rng) -> DispatchPlan {
        let n = self.topo.num_gpus();
        debug_assert_eq!(placement.num_gpus(), n);
        let ctx = RouteCtx { placement, topo: &self.topo, layer };

        let mut assignments = Vec::with_capacity(batch.len());
        let mut transfers = vec![Vec::new(); n * n];
        let mut copies = vec![0usize; n];

        for (index, a) in batch.iter().enumerate() {
            let dst = self.policy.select(&ctx, a.src, a.expert, rng);
            debug_assert!(placement.instances[a.expert].contains(&dst),
                          "policy routed off the instance set");
            assignments.push(Routed {
                index,
                token: a.token,
                expert: a.expert,
                src: a.src,
                dst,
            });
            transfers[a.src * n + dst].push(index as u32);
            copies[dst] += 1;
        }
        self.policy.end_round(&ctx);

        DispatchPlan {
            n_gpus: n,
            token_bytes: self.token_bytes,
            assignments,
            transfers,
            per_token: OnceLock::new(),
            copies,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::GroupingStrategy;
    use crate::config::ModelSpec;
    use crate::coordinator::Coordinator;
    use crate::placement::{Placement, ReplicationMode};
    use crate::routing::RoutingPolicy;
    use crate::testutil::{check, prop_assert};
    use crate::trace::{GateTrace, Profile};

    fn pipeline(policy: RoutingPolicy, seed: u64)
                -> (Coordinator, Placement, GateTrace) {
        let topo = Topology::two_by_two();
        let coord = Coordinator::new(
            GroupingStrategy::Hierarchical { r: 0.15 },
            ReplicationMode::Dynamic,
            policy,
            topo,
            seed,
        );
        let model = ModelSpec { moe_layers: 1, ..ModelSpec::olmoe() };
        let trace = coord.profile_synthetic(&model, Profile::Math, 512);
        let placement = coord.place(&trace);
        (coord, placement, trace)
    }

    fn batch_of(trace: &GateTrace, n_gpus: usize) -> Vec<Assignment> {
        let layer = &trace.layers[0];
        let chunk = layer.tokens.len();
        let mut batch = Vec::new();
        for (t, experts) in layer.tokens.iter().enumerate() {
            let src = t * n_gpus / chunk;
            for &e in experts {
                batch.push(Assignment { token: t, expert: e as usize, src });
            }
        }
        batch
    }

    #[test]
    fn plan_conserves_tokens_across_transfer_lists() {
        // Property: every (token, expert) assignment of the batch appears
        // in exactly one (src, dst) transfer list, and every destination
        // hosts an instance of the expert.
        check(25, |rng| {
            let policy = [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                          RoutingPolicy::Tar, RoutingPolicy::LoadAware]
                [rng.index(4)];
            let (coord, placement, trace) =
                pipeline(policy, rng.next_u64());
            let lp = &placement.layers[0];
            let batch = batch_of(&trace, coord.topo().num_gpus());
            let mut d = coord.dispatcher(4096.0);
            let plan = d.dispatch(lp, 0, &batch, rng);

            prop_assert(plan.num_assignments() == batch.len(),
                        "assignment count")?;
            // Exactly-once: collect assignment indices over all lists.
            let n = plan.num_gpus();
            let mut seen = vec![false; batch.len()];
            for src in 0..n {
                for dst in 0..n {
                    for r in plan.transfer(src, dst) {
                        prop_assert(r.src == src && r.dst == dst,
                                    "transfer list misfiled")?;
                        prop_assert(!seen[r.index], "duplicate index")?;
                        seen[r.index] = true;
                        let a = batch[r.index];
                        prop_assert(
                            r.token == a.token && r.expert == a.expert,
                            "transfer list corrupted the assignment",
                        )?;
                        prop_assert(
                            lp.instances[r.expert].contains(&r.dst),
                            "destination is not an instance",
                        )?;
                    }
                }
            }
            prop_assert(seen.iter().all(|&s| s), "assignment dropped")
        });
    }

    #[test]
    fn plan_views_are_consistent() {
        check(25, |rng| {
            let (coord, placement, trace) =
                pipeline(RoutingPolicy::Tar, rng.next_u64());
            let lp = &placement.layers[0];
            let batch = batch_of(&trace, coord.topo().num_gpus());
            let mut d = coord.dispatcher(100.0);
            let plan = d.dispatch(lp, 0, &batch, rng);

            // copies_per_gpu ≡ per-dst assignment counts ≡ per-token dsts.
            let n = plan.num_gpus();
            let mut by_dst = vec![0usize; n];
            for r in plan.assignments() {
                by_dst[r.dst] += 1;
            }
            prop_assert(by_dst == plan.copies_per_gpu(), "copies view")?;
            let from_tokens: usize =
                plan.per_token().iter().map(|d| d.dsts.len()).sum();
            prop_assert(from_tokens == plan.num_assignments(),
                        "per-token view")?;
            let from_ranks: usize =
                (0..n).map(|g| plan.for_rank(g).count()).sum();
            prop_assert(from_ranks == plan.num_assignments(),
                        "for_rank view")?;
            // byte accounting
            let pair_bytes: f64 = (0..n)
                .flat_map(|s| (0..n).map(move |d| (s, d)))
                .map(|(s, d)| plan.transfer_bytes(s, d))
                .sum();
            prop_assert(
                (pair_bytes - plan.total_bytes()).abs() < 1e-6,
                "byte accounting",
            )?;
            prop_assert(plan.moved_bytes() <= plan.total_bytes(),
                        "moved exceeds total")
        });
    }

    #[test]
    fn per_token_view_matches_scalar_walk() {
        // The per-token view must reproduce the old scalar engine loop's
        // Vec<Dispatch> exactly (token-major, dsts in expert order).
        let (coord, placement, trace) = pipeline(RoutingPolicy::Wrr, 7);
        let lp = &placement.layers[0];
        let n_gpus = coord.topo().num_gpus();
        let batch = batch_of(&trace, n_gpus);

        let mut d = coord.dispatcher(1.0);
        let mut rng = crate::stats::Rng::new(99);
        let plan = d.dispatch(lp, 0, &batch, &mut rng);

        // Scalar reference: same policy object semantics, same RNG seed.
        let mut pol = RoutingPolicy::Wrr.build();
        let ctx = RouteCtx { placement: lp, topo: coord.topo(), layer: 0 };
        let mut rng2 = crate::stats::Rng::new(99);
        let layer = &trace.layers[0];
        let chunk = layer.tokens.len();
        let mut want: Vec<Dispatch> = Vec::new();
        for (t, experts) in layer.tokens.iter().enumerate() {
            let src = t * n_gpus / chunk;
            let dsts = experts
                .iter()
                .map(|&e| pol.select(&ctx, src, e as usize, &mut rng2))
                .collect();
            want.push(Dispatch { src, dsts });
        }
        assert_eq!(plan.num_tokens(), want.len());
        for (got, want) in plan.per_token().iter().zip(&want) {
            assert_eq!(got.src, want.src);
            assert_eq!(got.dsts, want.dsts);
        }
    }

    #[test]
    fn dispatch_is_deterministic_per_seed() {
        // WRR: every replicated choice draws from the rng.
        let (coord, placement, trace) = pipeline(RoutingPolicy::Wrr, 3);
        let lp = &placement.layers[0];
        let batch = batch_of(&trace, coord.topo().num_gpus());
        let run = |seed: u64| {
            let mut d = coord.dispatcher(8.0);
            let mut rng = crate::stats::Rng::new(seed);
            d.dispatch(lp, 0, &batch, &mut rng)
                .assignments()
                .iter()
                .map(|r| r.dst)
                .collect::<Vec<_>>()
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6), "routing must actually use the rng");
    }
}
