//! Placement: the per-layer expert → GPU map assembled from grouping +
//! replication, with the predicted-load polling weights routing consumes,
//! and HBM memory accounting.

use crate::cluster::{GpuId, Topology};
use crate::grouping::Grouping;
use crate::profile::{LayerProfile, ModelProfile};
use crate::replication::{self, Replication};
use std::sync::atomic::{AtomicU64, Ordering};

/// How replicas are chosen when building a placement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplicationMode {
    /// No replicas (pure grouping).
    None,
    /// Fixed single-replica baseline (FR).
    Fixed,
    /// Dynamic replication driven by load skew (DR, Eq. 3).
    Dynamic,
}

/// Expert placement for one MoE layer.
#[derive(Clone, Debug)]
pub struct LayerPlacement {
    /// Primary expert set per GPU (`groups[gpu]`).
    pub groups: Grouping,
    /// Primary GPU per expert.
    pub primary: Vec<GpuId>,
    /// All instances per expert, primary first (secondaries appended in
    /// replica-GPU order).
    pub instances: Vec<Vec<GpuId>>,
    /// The replication decision that produced `instances`.
    pub replication: Replication,
    /// Pre-replication per-GPU loads (profiling units: tokens).
    pub pre_loads: Vec<f64>,
    /// Eq. 4 predicted post-replication per-GPU loads.
    pub predicted: Vec<f64>,
    /// WRR polling weights (inverse predicted loads, normalized).
    pub polling: Vec<f64>,
}

/// Whole-model placement plan.
#[derive(Clone, Debug)]
pub struct Placement {
    /// Per-MoE-layer placements, indexed by layer.
    pub layers: Vec<LayerPlacement>,
    /// Experts per layer.
    pub experts: usize,
    /// GPUs the placement spans.
    pub num_gpus: usize,
}

/// Expand a primary map + replication decision into the per-expert
/// instance lists (primary first, secondaries appended in replica-GPU
/// order). The one place this rule lives: [`LayerPlacement::build`] and
/// the online re-planner's [`crate::replan::apply_delta`] both call it,
/// so a replanned layer can never disagree with an offline-built one.
pub fn instances_for(primary: &[GpuId], replication: &Replication)
                     -> Vec<Vec<GpuId>> {
    INSTANCES_BUILDS.fetch_add(1, Ordering::Relaxed);
    let mut instances: Vec<Vec<GpuId>> =
        primary.iter().map(|&p| vec![p]).collect();
    for &e in &replication.hot_experts {
        for &g in &replication.replica_gpus {
            if !instances[e].contains(&g) {
                instances[e].push(g);
            }
        }
    }
    instances
}

/// Process-wide count of [`instances_for`] table builds — the
/// allocation-per-rollout self-check handle of `benches/hotpath.rs`.
/// Each build allocates one `Vec` per expert, so the *count* is the
/// allocation story; [`crate::replan::PreparedDelta`] exists to keep it
/// at one build per changed layer per rollout instead of one per
/// replica.
static INSTANCES_BUILDS: AtomicU64 = AtomicU64::new(0);

/// Monotone snapshot of the process-wide [`instances_for`] build count.
/// Benchmarks difference two snapshots around a code path to pin how
/// many instance-table rebuilds it performed.
pub fn instances_build_count() -> u64 {
    INSTANCES_BUILDS.load(Ordering::Relaxed)
}

impl LayerPlacement {
    /// Assemble one layer's placement: invert `groups` into the primary
    /// map, run the configured replication pass, and derive the Eq.-4
    /// predicted loads and polling weights.
    pub fn build(profile: &LayerProfile, groups: Grouping,
                 mode: ReplicationMode) -> LayerPlacement {
        let experts = profile.experts();
        let mut primary = vec![usize::MAX; experts];
        for (gpu, g) in groups.iter().enumerate() {
            for &e in g {
                primary[e] = gpu;
            }
        }
        assert!(primary.iter().all(|&p| p != usize::MAX),
                "groups must cover all experts");

        let replication = match mode {
            ReplicationMode::None => Replication::none(),
            ReplicationMode::Fixed => {
                replication::fixed_replication(profile, &groups)
            }
            ReplicationMode::Dynamic => {
                replication::dynamic_replication(profile, &groups)
            }
        };

        let instances = instances_for(&primary, &replication);

        let pre_loads: Vec<f64> =
            groups.iter().map(|g| profile.group_load(g)).collect();
        let heavy = profile.heaviest_group(&groups);
        let predicted =
            replication::predict_loads(&pre_loads, heavy, &replication);
        let polling = replication::polling_weights(&predicted);

        LayerPlacement {
            groups,
            primary,
            instances,
            replication,
            pre_loads,
            predicted,
            polling,
        }
    }

    /// GPUs this layer's placement spans.
    pub fn num_gpus(&self) -> usize {
        self.groups.len()
    }

    /// Total expert instances hosted by `gpu` (primaries + secondaries).
    pub fn instances_on(&self, gpu: GpuId) -> usize {
        self.instances.iter().filter(|is| is.contains(&gpu)).count()
    }
}

impl Placement {
    /// Build a whole-model placement by applying `group_fn` per layer.
    pub fn build(profile: &ModelProfile, mode: ReplicationMode,
                 mut group_fn: impl FnMut(&LayerProfile) -> Grouping)
                 -> Placement {
        let layers: Vec<LayerPlacement> = profile
            .layers
            .iter()
            .map(|lp| LayerPlacement::build(lp, group_fn(lp), mode))
            .collect();
        let experts = layers[0].primary.len();
        let num_gpus = layers[0].num_gpus();
        Placement { layers, experts, num_gpus }
    }

    /// Peak per-GPU expert-instance count across layers (memory proxy).
    pub fn max_instances_per_gpu(&self) -> usize {
        (0..self.num_gpus)
            .map(|g| {
                self.layers
                    .iter()
                    .map(|l| l.instances_on(g))
                    .max()
                    .unwrap_or(0)
            })
            .max()
            .unwrap_or(0)
    }

    /// Total parameter bytes per GPU given per-expert weight bytes
    /// (summed over layers — each layer's experts are distinct tensors).
    pub fn bytes_per_gpu(&self, expert_bytes: f64) -> Vec<f64> {
        (0..self.num_gpus)
            .map(|g| {
                self.layers
                    .iter()
                    .map(|l| l.instances_on(g) as f64 * expert_bytes)
                    .sum()
            })
            .collect()
    }

    /// Check the placement fits in HBM (paper §6.3: "keeping the
    /// parameter footprint within device memory limits").
    pub fn check_memory(&self, topo: &Topology, expert_bytes: f64)
                        -> Result<(), String> {
        for (g, &b) in self.bytes_per_gpu(expert_bytes).iter().enumerate() {
            if b > topo.hbm_bytes {
                return Err(format!(
                    "gpu {g}: {b:.3e} B of experts exceeds HBM \
                     {:.3e} B",
                    topo.hbm_bytes
                ));
            }
        }
        Ok(())
    }

    /// Replication overhead: secondary instances / primary instances.
    pub fn replication_overhead(&self) -> f64 {
        let mut primaries = 0usize;
        let mut secondaries = 0usize;
        for l in &self.layers {
            for is in &l.instances {
                primaries += 1;
                secondaries += is.len() - 1;
            }
        }
        secondaries as f64 / primaries as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping;
    use crate::stats::Rng;
    use crate::testutil::{check, prop_assert};
    use crate::trace::{Profile, TraceGen};

    fn model_profile(experts: usize, layers: usize) -> ModelProfile {
        let t = TraceGen {
            experts,
            top_k: 4,
            layers,
            profile: Profile::Math,
            seed: 77,
        }
        .generate(512);
        ModelProfile::from_trace(&t)
    }

    fn hg_placement(mode: ReplicationMode) -> Placement {
        let mp = model_profile(32, 3);
        let topo = Topology::two_by_two();
        let mut rng = Rng::new(1);
        Placement::build(&mp, mode, |lp| {
            grouping::hierarchical(lp, &topo, 0.15, &mut rng)
        })
    }

    #[test]
    fn primary_map_inverts_groups() {
        let p = hg_placement(ReplicationMode::None);
        for l in &p.layers {
            for (gpu, g) in l.groups.iter().enumerate() {
                for &e in g {
                    assert_eq!(l.primary[e], gpu);
                    assert_eq!(l.instances[e], vec![gpu]);
                }
            }
        }
    }

    #[test]
    fn dynamic_adds_secondaries_only_for_hot_experts() {
        let p = hg_placement(ReplicationMode::Dynamic);
        let mut any = false;
        for l in &p.layers {
            for (e, is) in l.instances.iter().enumerate() {
                if is.len() > 1 {
                    any = true;
                    assert!(l.replication.hot_experts.contains(&e));
                    assert_eq!(is[0], l.primary[e], "primary stays first");
                    for &g in &is[1..] {
                        assert!(l.replication.replica_gpus.contains(&g));
                    }
                }
            }
        }
        assert!(any, "skewed profile should trigger replication");
    }

    #[test]
    fn replication_overhead_is_bounded() {
        let none = hg_placement(ReplicationMode::None);
        let dr = hg_placement(ReplicationMode::Dynamic);
        assert_eq!(none.replication_overhead(), 0.0);
        let o = dr.replication_overhead();
        assert!(o > 0.0 && o < 1.0,
                "DR should replicate a small subset, got {o}");
    }

    #[test]
    fn replication_provenance_survives_placement_build() {
        // Mode::None ⇒ not configured; Mode::Dynamic ⇒ a pass ran, even
        // when it replicated nothing (the old is_none() conflation).
        let none = hg_placement(ReplicationMode::None);
        assert!(none.layers.iter().all(|l| !l.replication.was_computed()));
        let dr = hg_placement(ReplicationMode::Dynamic);
        assert!(dr.layers.iter().all(|l| l.replication.was_computed()));
    }

    #[test]
    fn memory_check_flags_tiny_hbm() {
        let p = hg_placement(ReplicationMode::Dynamic);
        let mut topo = Topology::two_by_two();
        assert!(p.check_memory(&topo, 1e6).is_ok());
        topo.hbm_bytes = 1.0;
        assert!(p.check_memory(&topo, 1e6).is_err());
    }

    #[test]
    fn bytes_per_gpu_counts_instances() {
        let p = hg_placement(ReplicationMode::None);
        let bytes = p.bytes_per_gpu(10.0);
        let total: f64 = bytes.iter().sum();
        // no replication: every expert exactly once per layer
        assert_eq!(total, (32 * 3) as f64 * 10.0);
    }

    #[test]
    fn property_instances_distinct_and_primary_first() {
        check(20, |rng| {
            let mp = model_profile(16 + 16 * rng.index(2), 2);
            let topo = Topology::two_by_two();
            let mode = [ReplicationMode::Fixed, ReplicationMode::Dynamic]
                [rng.index(2)];
            let p = Placement::build(&mp, mode, |lp| {
                grouping::hierarchical(lp, &topo, 0.2, rng)
            });
            for l in &p.layers {
                for (e, is) in l.instances.iter().enumerate() {
                    let mut d = is.clone();
                    d.sort_unstable();
                    d.dedup();
                    prop_assert(d.len() == is.len(), "dup instance gpus")?;
                    prop_assert(is[0] == l.primary[e], "primary first")?;
                }
                let s: f64 = l.polling.iter().sum();
                prop_assert((s - 1.0).abs() < 1e-9, "polling normalized")?;
            }
            Ok(())
        });
    }
}
