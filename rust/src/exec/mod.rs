//! Execution substrate: a small fixed-size thread pool + bounded channels
//! (tokio is unavailable offline; the serving front needs worker
//! parallelism and backpressure, not an async reactor).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    queue: VecDeque<Job>,
    shutdown: bool,
    in_flight: usize,
}

struct PoolShared {
    state: Mutex<PoolState>,
    work_ready: Condvar,
    all_done: Condvar,
}

/// Fixed-size worker pool with `join`-until-idle semantics.
pub struct ThreadPool {
    shared: Arc<PoolShared>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn a pool of `threads` workers.
    pub fn new(threads: usize) -> ThreadPool {
        assert!(threads > 0);
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                shutdown: false,
                in_flight: 0,
            }),
            work_ready: Condvar::new(),
            all_done: Condvar::new(),
        });
        let workers = (0..threads)
            .map(|i| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("grace-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { shared, workers }
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut st = self.shared.state.lock().unwrap();
        assert!(!st.shutdown, "submit after shutdown");
        st.queue.push_back(Box::new(job));
        drop(st);
        self.shared.work_ready.notify_one();
    }

    /// Block until every submitted job has finished.
    pub fn join(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !st.queue.is_empty() || st.in_flight > 0 {
            st = self.shared.all_done.wait(st).unwrap();
        }
    }

    /// Map a slice in parallel, preserving order.
    pub fn map<T, R>(&self, items: Vec<T>,
                     f: impl Fn(T) -> R + Send + Sync + 'static) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
    {
        let n = items.len();
        let results: Arc<Mutex<Vec<Option<R>>>> =
            Arc::new(Mutex::new((0..n).map(|_| None).collect()));
        let f = Arc::new(f);
        for (i, item) in items.into_iter().enumerate() {
            let results = results.clone();
            let f = f.clone();
            self.submit(move || {
                let r = f(item);
                results.lock().unwrap()[i] = Some(r);
            });
        }
        self.join();
        Arc::try_unwrap(results)
            .unwrap_or_else(|_| panic!("outstanding refs"))
            .into_inner()
            .unwrap()
            .into_iter()
            .map(|o| o.expect("job dropped"))
            .collect()
    }

    /// Submit a fire-and-forget job and get back a [`JobHandle`] that
    /// signals its completion — the overlap primitive behind async
    /// weight staging: the caller keeps computing and only `wait`s at
    /// first use of the staged result.
    pub fn submit_tracked(&self, job: impl FnOnce() + Send + 'static)
                          -> JobHandle {
        let state = Arc::new((Mutex::new(false), Condvar::new()));
        let signal = state.clone();
        self.submit(move || {
            job();
            let (lock, cvar) = (&signal.0, &signal.1);
            *lock.lock().unwrap() = true;
            cvar.notify_all();
        });
        JobHandle { state }
    }
}

/// Completion signal of one job submitted through
/// [`ThreadPool::submit_tracked`]. Cloning shares the signal; the job
/// runs regardless of whether any handle is ever polled or waited on
/// (fire-and-forget), so dropping every clone leaks nothing.
#[derive(Clone)]
pub struct JobHandle {
    state: Arc<(Mutex<bool>, Condvar)>,
}

impl JobHandle {
    /// Whether the job has finished (non-blocking — the prefetch *hit*
    /// probe).
    pub fn is_done(&self) -> bool {
        *self.state.0.lock().unwrap()
    }

    /// Block until the job finishes (the prefetch *stall* path: first
    /// use of a still-in-flight staged weight).
    pub fn wait(&self) {
        let (lock, cvar) = (&self.state.0, &self.state.1);
        let mut done = lock.lock().unwrap();
        while !*done {
            done = cvar.wait(done).unwrap();
        }
    }
}

fn worker_loop(shared: Arc<PoolShared>) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(j) = st.queue.pop_front() {
                    st.in_flight += 1;
                    break j;
                }
                if st.shutdown {
                    return;
                }
                st = shared.work_ready.wait(st).unwrap();
            }
        };
        job();
        let mut st = shared.state.lock().unwrap();
        st.in_flight -= 1;
        if st.queue.is_empty() && st.in_flight == 0 {
            shared.all_done.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work_ready.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Bounded MPSC channel with blocking `send` (backpressure for the
/// serving front's admission queue).
pub struct BoundedQueue<T> {
    inner: Arc<QueueShared<T>>,
}

struct QueueShared<T> {
    state: Mutex<(VecDeque<T>, bool)>, // (items, closed)
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl<T> Clone for BoundedQueue<T> {
    fn clone(&self) -> Self {
        BoundedQueue { inner: self.inner.clone() }
    }
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `cap` items (senders block beyond it).
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0);
        BoundedQueue {
            inner: Arc::new(QueueShared {
                state: Mutex::new((VecDeque::new(), false)),
                not_full: Condvar::new(),
                not_empty: Condvar::new(),
                cap,
            }),
        }
    }

    /// Blocking send; returns Err(item) if the queue is closed.
    pub fn send(&self, item: T) -> Result<(), T> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if st.1 {
                return Err(item);
            }
            if st.0.len() < self.inner.cap {
                st.0.push_back(item);
                self.inner.not_empty.notify_one();
                return Ok(());
            }
            st = self.inner.not_full.wait(st).unwrap();
        }
    }

    /// Blocking receive; None when closed and drained.
    pub fn recv(&self) -> Option<T> {
        let mut st = self.inner.state.lock().unwrap();
        loop {
            if let Some(item) = st.0.pop_front() {
                self.inner.not_full.notify_one();
                return Some(item);
            }
            if st.1 {
                return None;
            }
            st = self.inner.not_empty.wait(st).unwrap();
        }
    }

    /// Non-blocking receive: `None` when the queue is currently empty,
    /// whether or not it is closed (callers that need to distinguish
    /// "drained and closed" block on [`BoundedQueue::recv`] instead).
    pub fn try_recv(&self) -> Option<T> {
        let mut st = self.inner.state.lock().unwrap();
        let item = st.0.pop_front();
        if item.is_some() {
            self.inner.not_full.notify_one();
        }
        item
    }

    /// Drain up to `max` items without blocking beyond the first.
    /// `recv_batch(0)` asks for nothing and returns nothing — it never
    /// consumes an item it cannot hand back.
    pub fn recv_batch(&self, max: usize) -> Vec<T> {
        if max == 0 {
            return Vec::new();
        }
        let mut out = Vec::new();
        if let Some(first) = self.recv() {
            out.push(first);
            let mut st = self.inner.state.lock().unwrap();
            while out.len() < max {
                match st.0.pop_front() {
                    Some(x) => out.push(x),
                    None => break,
                }
            }
            self.inner.not_full.notify_all();
        }
        out
    }

    /// Close the queue: senders fail, receivers drain then get `None`.
    pub fn close(&self) {
        let mut st = self.inner.state.lock().unwrap();
        st.1 = true;
        self.inner.not_empty.notify_all();
        self.inner.not_full.notify_all();
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.state.lock().unwrap().0.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<usize>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn tracked_job_signals_completion() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        let h = pool.submit_tracked(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            c.fetch_add(1, Ordering::SeqCst);
        });
        h.wait();
        assert!(h.is_done());
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        // A second wait on a finished job returns immediately.
        h.wait();
    }

    #[test]
    fn tracked_handles_are_independent_and_cloneable() {
        let pool = ThreadPool::new(4);
        let handles: Vec<JobHandle> = (0..16)
            .map(|_| pool.submit_tracked(|| {}))
            .collect();
        let clones: Vec<JobHandle> = handles.clone();
        pool.join();
        for (h, c) in handles.iter().zip(&clones) {
            assert!(h.is_done(), "joined pool left a job unfinished");
            assert!(c.is_done(), "clone must share the signal");
        }
    }

    #[test]
    fn dropped_tracked_handle_still_runs_the_job() {
        let pool = ThreadPool::new(1);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = counter.clone();
        drop(pool.submit_tracked(move || {
            c.fetch_add(1, Ordering::SeqCst);
        }));
        pool.join();
        assert_eq!(counter.load(Ordering::SeqCst), 1,
                   "fire-and-forget: the job must not be cancelled");
    }

    #[test]
    fn queue_roundtrip_and_close() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        q.send(1).unwrap();
        q.send(2).unwrap();
        assert_eq!(q.recv(), Some(1));
        q.close();
        assert!(q.send(3).is_err());
        assert_eq!(q.recv(), Some(2));
        assert_eq!(q.recv(), None);
    }

    #[test]
    fn queue_backpressure_blocks_until_drained() {
        let q: BoundedQueue<usize> = BoundedQueue::new(1);
        q.send(0).unwrap();
        let q2 = q.clone();
        let t = std::thread::spawn(move || q2.send(1).is_ok());
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(q.recv(), Some(0));
        assert!(t.join().unwrap());
        assert_eq!(q.recv(), Some(1));
    }

    #[test]
    fn recv_batch_drains_up_to_max() {
        let q: BoundedQueue<usize> = BoundedQueue::new(16);
        for i in 0..10 {
            q.send(i).unwrap();
        }
        let b = q.recv_batch(4);
        assert_eq!(b, vec![0, 1, 2, 3]);
        assert_eq!(q.len(), 6);
    }

    #[test]
    fn recv_batch_boundary_semantics() {
        let q: BoundedQueue<usize> = BoundedQueue::new(16);
        for i in 0..3 {
            q.send(i).unwrap();
        }
        // max = 0 returns empty WITHOUT consuming (the old code popped
        // one item it could never hand back).
        assert!(q.recv_batch(0).is_empty());
        assert_eq!(q.len(), 3);
        // max beyond the queued count drains exactly what is there.
        assert_eq!(q.recv_batch(10), vec![0, 1, 2]);
        assert!(q.is_empty());
        // closed + drained: the batch is empty, not a hang.
        q.close();
        assert!(q.recv_batch(4).is_empty());
    }

    #[test]
    fn try_recv_never_blocks() {
        let q: BoundedQueue<usize> = BoundedQueue::new(4);
        assert_eq!(q.try_recv(), None);
        q.send(9).unwrap();
        assert_eq!(q.try_recv(), Some(9));
        assert_eq!(q.try_recv(), None);
        q.close();
        assert_eq!(q.try_recv(), None);
    }

    #[test]
    fn close_wakes_blocked_receiver_and_sender() {
        // Blocked receiver: close() must deliver the terminal None.
        let q: BoundedQueue<usize> = BoundedQueue::new(1);
        let q2 = q.clone();
        let recv = std::thread::spawn(move || q2.recv());
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(recv.join().unwrap(), None);

        // Blocked sender (queue full): close() must fail the send and
        // hand the item back.
        let q: BoundedQueue<usize> = BoundedQueue::new(1);
        q.send(1).unwrap();
        let q2 = q.clone();
        let send = std::thread::spawn(move || q2.send(2));
        std::thread::sleep(std::time::Duration::from_millis(20));
        q.close();
        assert_eq!(send.join().unwrap(), Err(2));
    }

    #[test]
    fn mpmc_stress_on_thread_pool() {
        // 4 producers × 500 items against 4 consumers, with a deliberately
        // tiny capacity so both sides block constantly. Producers run on
        // one pool, consumers on another (a single pool could strand the
        // producers behind blocked consumer jobs).
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 500;
        let q: BoundedQueue<usize> = BoundedQueue::new(2);

        let consumed: Arc<Mutex<Vec<usize>>> =
            Arc::new(Mutex::new(Vec::new()));
        let consumers = ThreadPool::new(4);
        for _ in 0..4 {
            let q = q.clone();
            let consumed = consumed.clone();
            consumers.submit(move || {
                while let Some(x) = q.recv() {
                    consumed.lock().unwrap().push(x);
                }
            });
        }

        let producers = ThreadPool::new(PRODUCERS);
        for p in 0..PRODUCERS {
            let q = q.clone();
            producers.submit(move || {
                for i in 0..PER_PRODUCER {
                    q.send(p * PER_PRODUCER + i).unwrap();
                }
            });
        }
        producers.join();
        q.close();
        consumers.join();

        let mut got = Arc::try_unwrap(consumed)
            .expect("consumers done")
            .into_inner()
            .unwrap();
        got.sort_unstable();
        let want: Vec<usize> = (0..PRODUCERS * PER_PRODUCER).collect();
        assert_eq!(got, want, "every item delivered exactly once");
    }
}
