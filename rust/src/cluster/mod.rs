//! Cluster topology model: nodes, GPUs, and the bandwidth hierarchy.
//!
//! The paper's testbed is 2 nodes × 4 A100s: NVLink inside a node
//! (50 GB/s per direction) and 25 Gbps Ethernet across nodes. All
//! communication models in [`crate::comm`] and all locality decisions in
//! [`crate::routing`] are parameterised by this topology.
//!
//! GPU ids are globally dense: gpu `g` lives on node `g / gpus_per_node`.

/// Global GPU identifier.
pub type GpuId = usize;
/// Node identifier.
pub type NodeId = usize;

/// Physical cluster description + link parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct Topology {
    /// Nodes in the cluster.
    pub nodes: usize,
    /// GPUs per node (rail-aligned; global GPU ids are dense).
    pub gpus_per_node: usize,
    /// Intra-node (NVLink) bandwidth, bytes/second per GPU pair direction.
    pub intra_bw: f64,
    /// Cross-node NIC bandwidth, bytes/second per node (shared by all its
    /// GPUs — the paper's scarce resource).
    pub inter_bw: f64,
    /// Per-message intra-node latency floor, seconds.
    pub intra_lat: f64,
    /// Per-message cross-node latency floor, seconds.
    pub inter_lat: f64,
    /// Per-collective-stage kernel launch + sync overhead, seconds.
    pub launch_overhead: f64,
    /// Relative straggler jitter (std of per-rank slowdown); cross-node
    /// global synchronization pays the *max* over ranks of this.
    pub jitter: f64,
    /// Per-GPU HBM capacity in bytes (placement/replication accounting).
    pub hbm_bytes: f64,
}

impl Topology {
    /// Paper testbed defaults: NVLink 50 GB/s, 25 Gbps Ethernet, A100-80GB.
    pub fn paper_testbed(nodes: usize, gpus_per_node: usize) -> Self {
        Topology {
            nodes,
            gpus_per_node,
            intra_bw: 50e9,
            inter_bw: 25e9 / 8.0, // 25 Gbps = 3.125 GB/s
            intra_lat: 5e-6,
            inter_lat: 50e-6,
            launch_overhead: 20e-6,
            jitter: 0.08,
            hbm_bytes: 80e9,
        }
    }

    /// The paper's two evaluation scales.
    pub fn two_by_two() -> Self {
        Self::paper_testbed(2, 2)
    }

    /// The paper's larger testbed: 2 nodes × 4 GPUs.
    pub fn two_by_four() -> Self {
        Self::paper_testbed(2, 4)
    }

    /// Total GPUs in the cluster.
    pub fn num_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node hosting `gpu`.
    #[inline]
    pub fn node_of(&self, gpu: GpuId) -> NodeId {
        gpu / self.gpus_per_node
    }

    /// Whether two GPUs share a node (NVLink reach).
    #[inline]
    pub fn same_node(&self, a: GpuId, b: GpuId) -> bool {
        self.node_of(a) == self.node_of(b)
    }

    /// GPUs hosted on `node`.
    pub fn gpus_of(&self, node: NodeId) -> std::ops::Range<GpuId> {
        node * self.gpus_per_node..(node + 1) * self.gpus_per_node
    }

    /// Locality tier of a transfer (the hierarchy of §4.3):
    /// 0 = same GPU, 1 = same node, 2 = cross node.
    pub fn tier(&self, src: GpuId, dst: GpuId) -> u8 {
        if src == dst {
            0
        } else if self.same_node(src, dst) {
            1
        } else {
            2
        }
    }

    /// Point-to-point bandwidth for a (src, dst) pair, bytes/sec.
    /// Same-GPU moves are treated as free (HBM-local).
    pub fn bw(&self, src: GpuId, dst: GpuId) -> f64 {
        match self.tier(src, dst) {
            0 => f64::INFINITY,
            1 => self.intra_bw,
            _ => self.inter_bw,
        }
    }

    /// Per-message latency floor for a pair, seconds.
    pub fn lat(&self, src: GpuId, dst: GpuId) -> f64 {
        match self.tier(src, dst) {
            0 => 0.0,
            1 => self.intra_lat,
            _ => self.inter_lat,
        }
    }

    /// Validate invariants (used by config loading).
    pub fn validate(&self) -> Result<(), String> {
        if self.nodes == 0 || self.gpus_per_node == 0 {
            return Err("topology must have ≥1 node and ≥1 gpu/node".into());
        }
        if self.intra_bw <= 0.0 || self.inter_bw <= 0.0 {
            return Err("bandwidths must be positive".into());
        }
        if self.inter_bw > self.intra_bw {
            return Err(
                "cross-node bw exceeding intra-node bw is outside the \
                 paper's regime"
                    .into(),
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_testbed_shape() {
        let t = Topology::two_by_four();
        assert_eq!(t.num_gpus(), 8);
        assert_eq!(t.node_of(0), 0);
        assert_eq!(t.node_of(3), 0);
        assert_eq!(t.node_of(4), 1);
        assert!(t.same_node(5, 7));
        assert!(!t.same_node(3, 4));
        assert_eq!(t.gpus_of(1).collect::<Vec<_>>(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn tiers_and_links() {
        let t = Topology::two_by_two();
        assert_eq!(t.tier(1, 1), 0);
        assert_eq!(t.tier(0, 1), 1);
        assert_eq!(t.tier(1, 2), 2);
        assert_eq!(t.bw(1, 1), f64::INFINITY);
        assert_eq!(t.bw(0, 1), 50e9);
        assert!((t.bw(0, 2) - 3.125e9).abs() < 1.0);
        assert!(t.lat(0, 2) > t.lat(0, 1));
    }

    #[test]
    fn validation() {
        assert!(Topology::two_by_two().validate().is_ok());
        let mut bad = Topology::two_by_two();
        bad.inter_bw = bad.intra_bw * 2.0;
        assert!(bad.validate().is_err());
        bad = Topology::two_by_two();
        bad.nodes = 0;
        assert!(bad.validate().is_err());
    }
}
