//! Gate-trace generation: synthetic expert-selection traces with the
//! skew and co-activation structure the paper profiles on real datasets.
//!
//! The paper's offline phase consumes only *expert selection traces*
//! (which experts each token activated, per layer). Real model weights +
//! datasets are unavailable here, so we generate traces from a planted
//! model that reproduces the two empirical properties GRACE-MoE exploits:
//!
//! 1. **popularity skew** — a few "hot" experts receive most tokens
//!    (Zipf-distributed expert popularity; paper §1, Fig. 3b), and
//! 2. **co-activation structure** — experts cluster into latent groups
//!    that tend to be selected together by the same token (paper §3,
//!    "strong co-activation patterns" per C2R).
//!
//! Each *dataset profile* (`text`, `math`, `code`, mirroring WikiText-2 /
//! MATH / Pile-GitHub) uses different skew, cluster count, and coherence
//! parameters plus a disjoint permutation of expert identities — so
//! cross-profile transfer (paper Fig. 6) is a real distribution shift.

use crate::stats::{Rng, Zipf};

/// A dataset-like trace distribution profile.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Profile {
    /// WikiText-2-like: moderate skew, broad clusters.
    Text,
    /// MATH-like: high skew (few specialist experts), tight clusters.
    Math,
    /// Pile-GitHub-like: highest skew, medium clusters.
    Code,
    /// Mixed-profile sampling (paper's mixed-dataset profiling).
    Mixed,
}

impl Profile {
    /// The three single-dataset profiles (Mixed samples from these).
    pub const ALL: [Profile; 3] = [Profile::Text, Profile::Math,
                                   Profile::Code];

    /// Stable profile name (CLI values and report labels).
    pub fn name(&self) -> &'static str {
        match self {
            Profile::Text => "text",
            Profile::Math => "math",
            Profile::Code => "code",
            Profile::Mixed => "mixed",
        }
    }

    /// Parse a profile by its [`Profile::name`].
    pub fn from_name(s: &str) -> Option<Profile> {
        match s {
            "text" => Some(Profile::Text),
            "math" => Some(Profile::Math),
            "code" => Some(Profile::Code),
            "mixed" => Some(Profile::Mixed),
            _ => None,
        }
    }

    /// (zipf skew over clusters, clusters per 32 experts, coherence =
    /// probability that each extra expert pick stays in the token's
    /// cluster, expert-level zipf within cluster).
    fn params(&self) -> (f64, usize, f64, f64) {
        match self {
            Profile::Text => (0.85, 4, 0.74, 0.9),
            Profile::Math => (1.05, 4, 0.82, 1.1),
            Profile::Code => (1.15, 4, 0.78, 1.2),
            Profile::Mixed => unreachable!("Mixed samples sub-profiles"),
        }
    }
}

/// Expert selections for one MoE layer: `tokens[t]` = the k distinct
/// experts token `t` activated.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerTrace {
    /// Experts the layer's gate selects over.
    pub experts: usize,
    /// Experts each token activates.
    pub top_k: usize,
    /// Per-token selections: `tokens[t]` = the k distinct expert ids.
    pub tokens: Vec<Vec<u16>>,
}

/// Whole-model trace (one [`LayerTrace`] per MoE layer).
#[derive(Clone, Debug)]
pub struct GateTrace {
    /// One trace per MoE layer.
    pub layers: Vec<LayerTrace>,
}

impl GateTrace {
    /// MoE layers traced.
    pub fn num_layers(&self) -> usize {
        self.layers.len()
    }

    /// Tokens traced (per layer).
    pub fn num_tokens(&self) -> usize {
        self.layers.first().map_or(0, |l| l.tokens.len())
    }

    /// Rotate every expert id by `shift` (mod the expert count), in
    /// every layer — the drifting-workload fixture: the trace keeps its
    /// skew and co-activation *structure* but the hot-expert identities
    /// move, exactly the shift a placement frozen on the original trace
    /// cannot serve well (see [`crate::replan`]).
    pub fn shift_experts(&self, shift: usize) -> GateTrace {
        GateTrace {
            layers: self
                .layers
                .iter()
                .map(|l| LayerTrace {
                    experts: l.experts,
                    top_k: l.top_k,
                    tokens: l
                        .tokens
                        .iter()
                        .map(|tok| {
                            tok.iter()
                                .map(|&e| {
                                    ((e as usize + shift)
                                        % l.experts)
                                        as u16
                                })
                                .collect()
                        })
                        .collect(),
                })
                .collect(),
        }
    }
}

/// Generator parameters (derived from a profile, overridable in tests).
#[derive(Clone, Debug)]
pub struct TraceGen {
    /// Experts per layer.
    pub experts: usize,
    /// Experts each token activates.
    pub top_k: usize,
    /// MoE layers to trace.
    pub layers: usize,
    /// Dataset profile driving skew and co-activation.
    pub profile: Profile,
    /// Base seed; combined with (profile, layer) for decorrelated streams.
    pub seed: u64,
}

/// The latent structure of one layer under one profile: a permuted planted
/// clustering with Zipf popularity over clusters and experts.
struct LayerModel {
    clusters: Vec<Vec<u16>>,
    cluster_pop: Zipf,
    within: Vec<Zipf>,
    coherence: f64,
    expert_perm: Vec<u16>,
}

impl LayerModel {
    /// `structure_rng` seeds the *profile-independent* latent clustering
    /// (which experts belong together — the paper's Fig. 6 finding is
    /// that this co-activation structure is stable across datasets);
    /// `profile_rng` seeds the *profile-specific* popularity: which
    /// clusters (and which experts within them) are hot.
    fn build(experts: usize, profile: Profile, structure_rng: &mut Rng,
             profile_rng: &mut Rng) -> LayerModel {
        let (cl_skew, cl_per_32, coherence, ex_skew) = profile.params();
        let n_clusters = ((experts / 32).max(1) * cl_per_32).min(experts);
        // Random cluster sizes ≥ 1 (non-uniform on purpose: affinity-based
        // grouping should discover non-uniform structure). Shared across
        // profiles.
        let mut sizes = vec![1usize; n_clusters];
        for _ in 0..experts - n_clusters {
            sizes[structure_rng.index(n_clusters)] += 1;
        }
        let mut perm: Vec<u16> = (0..experts as u16).collect();
        structure_rng.shuffle(&mut perm);
        let mut clusters = Vec::with_capacity(n_clusters);
        let mut at = 0;
        for &s in &sizes {
            // profile-specific *order* within the cluster (which members
            // are hottest) over profile-independent *membership*; the
            // reshuffle is partial — real datasets share most of their
            // hot experts (the stability Fig. 6 relies on)
            let mut members = perm[at..at + s].to_vec();
            partial_shuffle(profile_rng, &mut members);
            clusters.push(members);
            at += s;
        }
        // profile-specific cluster popularity: partially permute which
        // cluster gets which Zipf rank
        let mut order: Vec<usize> = (0..n_clusters).collect();
        partial_shuffle(profile_rng, &mut order);
        let mut reordered = Vec::with_capacity(n_clusters);
        for &c in &order {
            reordered.push(std::mem::take(&mut clusters[c]));
        }
        let within =
            reordered.iter().map(|c| Zipf::new(c.len(), ex_skew)).collect();
        LayerModel {
            clusters: reordered,
            cluster_pop: Zipf::new(n_clusters, cl_skew),
            within,
            coherence,
            expert_perm: perm,
        }
    }

    /// Sample one token's k distinct experts.
    fn sample_token(&self, k: usize, rng: &mut Rng) -> Vec<u16> {
        let home = self.cluster_pop.sample(rng);
        let mut picked: Vec<u16> = Vec::with_capacity(k);
        let mut guard = 0;
        while picked.len() < k && guard < 10_000 {
            guard += 1;
            let c = if rng.chance(self.coherence) {
                home
            } else {
                self.cluster_pop.sample(rng)
            };
            let e = self.clusters[c][self.within[c].sample(rng)];
            if !picked.contains(&e) {
                picked.push(e);
            }
        }
        // Degenerate fallback (k close to expert count): fill with the
        // globally first unpicked experts.
        if picked.len() < k {
            for &e in &self.expert_perm {
                if !picked.contains(&e) {
                    picked.push(e);
                    if picked.len() == k {
                        break;
                    }
                }
            }
        }
        picked
    }
}

impl TraceGen {
    /// Generate `n_tokens` tokens of trace per layer.
    pub fn generate(&self, n_tokens: usize) -> GateTrace {
        assert!(self.top_k <= self.experts);
        let mut root = Rng::new(self.seed ^ 0xC0FFEE);
        let layers = (0..self.layers)
            .map(|l| {
                let mut lrng = root.fork(l as u64);
                match self.profile {
                    Profile::Mixed => self.gen_mixed(l, n_tokens, &mut lrng),
                    p => self.gen_single(p, l, n_tokens, &mut lrng),
                }
            })
            .collect();
        GateTrace { layers }
    }

    fn gen_single(&self, profile: Profile, layer: usize, n_tokens: usize,
                  lrng: &mut Rng) -> LayerTrace {
        // The latent model depends on (profile, layer) but NOT on the
        // caller seed: two traces of the same profile with different seeds
        // are different samples from the SAME distribution (this is what
        // makes offline profiling → online serving meaningful). The
        // cluster *structure* additionally excludes the profile, so
        // different datasets share co-activation structure (Fig. 6).
        let mut structure_rng =
            Rng::new(hash3(0x57AB1E, layer as u64, self.experts as u64));
        let mut profile_rng =
            Rng::new(hash3(profile as u64, layer as u64,
                           self.experts as u64));
        let model = LayerModel::build(self.experts, profile,
                                      &mut structure_rng,
                                      &mut profile_rng);
        let tokens = (0..n_tokens)
            .map(|_| model.sample_token(self.top_k, lrng))
            .collect();
        LayerTrace { experts: self.experts, top_k: self.top_k, tokens }
    }

    fn gen_mixed(&self, layer: usize, n_tokens: usize,
                 lrng: &mut Rng) -> LayerTrace {
        // Mixed-dataset profiling: interleave tokens from the three
        // single profiles (paper §6.4).
        let parts = Profile::ALL;
        let mut models: Vec<LayerModel> = parts
            .iter()
            .map(|&p| {
                let mut sr = Rng::new(hash3(0x57AB1E, layer as u64,
                                            self.experts as u64));
                let mut pr = Rng::new(hash3(p as u64, layer as u64,
                                            self.experts as u64));
                LayerModel::build(self.experts, p, &mut sr, &mut pr)
            })
            .collect();
        let tokens = (0..n_tokens)
            .map(|i| models[i % parts.len()].sample_token(self.top_k, lrng))
            .collect();
        models.clear();
        LayerTrace { experts: self.experts, top_k: self.top_k, tokens }
    }
}

/// Bounded distribution shift: profiles disagree on *some* of the warm
/// ranks but share the hottest one (real MoEs exhibit universally-hot
/// experts — cf. OLMoE's routing analyses — which is exactly why the
/// paper's placements transfer across datasets with ≤ ~5% regression).
fn partial_shuffle<T>(rng: &mut Rng, xs: &mut [T]) {
    if xs.len() < 3 {
        return;
    }
    let swaps = (xs.len() / 6).max(1);
    for _ in 0..swaps {
        let i = 1 + rng.index(xs.len() - 1);
        let j = 1 + rng.index(xs.len() - 1);
        xs.swap(i, j);
    }
}

fn hash3(a: u64, b: u64, c: u64) -> u64 {
    let mut s = a
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(b)
        .wrapping_mul(0xBF58_476D_1CE4_E5B9)
        .wrapping_add(c);
    crate::stats::rng::splitmix64(&mut s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{check, prop_assert};

    fn gen(profile: Profile, seed: u64) -> GateTrace {
        TraceGen {
            experts: 64,
            top_k: 8,
            layers: 3,
            profile,
            seed,
        }
        .generate(512)
    }

    #[test]
    fn shape_and_distinctness() {
        let t = gen(Profile::Text, 1);
        assert_eq!(t.num_layers(), 3);
        assert_eq!(t.num_tokens(), 512);
        for layer in &t.layers {
            for tok in &layer.tokens {
                assert_eq!(tok.len(), 8);
                let mut d = tok.clone();
                d.sort_unstable();
                d.dedup();
                assert_eq!(d.len(), 8, "experts must be distinct");
                assert!(tok.iter().all(|&e| (e as usize) < 64));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        assert_eq!(gen(Profile::Math, 7).layers[0].tokens,
                   gen(Profile::Math, 7).layers[0].tokens);
        assert_ne!(gen(Profile::Math, 7).layers[0].tokens,
                   gen(Profile::Math, 8).layers[0].tokens);
    }

    #[test]
    fn same_profile_different_seed_same_distribution() {
        // expert popularity histograms of two seeds must be close
        let a = gen(Profile::Code, 1);
        let b = gen(Profile::Code, 2);
        for l in 0..3 {
            let ha = hist(&a.layers[l]);
            let hb = hist(&b.layers[l]);
            let dist: f64 = ha
                .iter()
                .zip(&hb)
                .map(|(x, y)| (x - y).abs())
                .sum::<f64>()
                / 2.0;
            assert!(dist < 0.15, "layer {l}: total-variation {dist}");
        }
    }

    fn hist(l: &LayerTrace) -> Vec<f64> {
        let mut h = vec![0.0; l.experts];
        let total = (l.tokens.len() * l.top_k) as f64;
        for t in &l.tokens {
            for &e in t {
                h[e as usize] += 1.0 / total;
            }
        }
        h
    }

    #[test]
    fn profiles_are_skewed_and_differ() {
        let mut maxima = Vec::new();
        for p in Profile::ALL {
            let t = gen(p, 3);
            let h = hist(&t.layers[0]);
            let mx = h.iter().cloned().fold(0.0, f64::max);
            // uniform would be 1/64 ≈ 0.0156; hot experts must stand out
            assert!(mx > 0.03, "{p:?} not skewed: max share {mx}");
            maxima.push((p, h));
        }
        // different profiles disagree about WHICH experts are hot
        let top = |h: &Vec<f64>| {
            let mut idx: Vec<usize> = (0..h.len()).collect();
            idx.sort_by(|&i, &j| h[j].partial_cmp(&h[i]).unwrap());
            idx[..8].to_vec()
        };
        let t_text = top(&maxima[0].1);
        let t_math = top(&maxima[1].1);
        let overlap =
            t_text.iter().filter(|e| t_math.contains(e)).count();
        assert!(overlap < 8, "profiles should have distinct hot sets");
    }

    #[test]
    fn coactivation_structure_exists() {
        // experts from the same latent cluster co-occur more than chance
        let t = gen(Profile::Math, 5);
        let l = &t.layers[0];
        let mut co = vec![0.0f64; 64 * 64];
        for tok in &l.tokens {
            for i in 0..tok.len() {
                for j in (i + 1)..tok.len() {
                    let (a, b) = (tok[i] as usize, tok[j] as usize);
                    co[a * 64 + b] += 1.0;
                    co[b * 64 + a] += 1.0;
                }
            }
        }
        let mean = co.iter().sum::<f64>() / (64.0 * 63.0);
        let max = co.iter().cloned().fold(0.0, f64::max);
        assert!(max > mean * 8.0, "no co-activation: max {max} mean {mean}");
    }

    #[test]
    fn top_k_equal_experts_degenerate_case() {
        let t = TraceGen {
            experts: 8,
            top_k: 8,
            layers: 1,
            profile: Profile::Text,
            seed: 1,
        }
        .generate(16);
        for tok in &t.layers[0].tokens {
            let mut d = tok.clone();
            d.sort_unstable();
            assert_eq!(d, (0..8).collect::<Vec<u16>>());
        }
    }

    #[test]
    fn shift_experts_rotates_identities_only() {
        let t = gen(Profile::Math, 4);
        let s = t.shift_experts(10);
        assert_eq!(s.num_layers(), t.num_layers());
        assert_eq!(s.num_tokens(), t.num_tokens());
        for (ls, lt) in s.layers.iter().zip(&t.layers) {
            for (ts, tt) in ls.tokens.iter().zip(&lt.tokens) {
                for (&a, &b) in ts.iter().zip(tt) {
                    assert_eq!(a as usize, (b as usize + 10) % 64);
                }
            }
        }
        // Full rotation is the identity.
        let full = t.shift_experts(64);
        assert_eq!(full.layers[0].tokens, t.layers[0].tokens);
    }

    #[test]
    fn mixed_profile_generates() {
        let t = gen(Profile::Mixed, 9);
        assert_eq!(t.num_tokens(), 512);
    }

    #[test]
    fn property_all_tokens_valid_across_configs() {
        check(30, |rng| {
            let experts = 8 + rng.index(120);
            let top_k = 1 + rng.index(experts.min(8));
            let t = TraceGen {
                experts,
                top_k,
                layers: 1,
                profile: Profile::ALL[rng.index(3)],
                seed: rng.next_u64(),
            }
            .generate(32);
            for tok in &t.layers[0].tokens {
                prop_assert(tok.len() == top_k, "k")?;
                prop_assert(
                    tok.iter().all(|&e| (e as usize) < experts),
                    "range",
                )?;
            }
            Ok(())
        });
    }
}
