//! Rolling rollout of one [`ReplanDelta`] across a replica-sharded
//! fleet: at most one replica swaps per epoch, the other N−1 keep
//! serving, and the rebuilt instance tables are shared instead of being
//! recomputed once per replica.
//!
//! Two pieces:
//!
//! * [`PreparedDelta`] — a [`ReplanDelta`] with every changed layer's
//!   instance table prebuilt **once** via
//!   [`crate::placement::instances_for`]. Applying it to a replica whose
//!   primary map matches the one it was prepared against clones the
//!   cached table (zero rebuilds); a replica with a different primary map
//!   (class-specialised fleets) falls back to a fresh build, so the
//!   cache can never produce a placement [`super::apply_delta`] would
//!   not. An empty delta prepares and applies with **zero** rebuilds —
//!   the hot-path win `benches/hotpath.rs` pins via
//!   [`crate::placement::instances_build_count`].
//! * [`RollingReplan`] — the rollout state machine: `begin` freezes one
//!   prepared delta, then each replica commits its swap at its own step
//!   boundary, cursor order 0‥N, gated to **at most one swap per epoch
//!   index**. While a rollout is in flight no new delta may begin, so
//!   the fleet never holds two placement generations plus a pending
//!   third. With N = 1 the single replica swaps in the same epoch the
//!   decision fired — exactly the pre-sharding immediate apply.

use crate::cluster::GpuId;
use crate::placement::{instances_for, Placement};

use super::ReplanDelta;

/// A [`ReplanDelta`] plus the per-changed-layer instance tables built
/// once at preparation time, ready to be applied to every replica of a
/// fleet without re-running [`instances_for`] per replica.
#[derive(Clone, Debug)]
pub struct PreparedDelta {
    delta: ReplanDelta,
    /// Per changed layer (same order as `delta.layers`): the primary
    /// map the table was built against, and the prebuilt instance
    /// table. The primary copy is the safety interlock — replicas whose
    /// primaries diverged rebuild instead of reusing a wrong table.
    prepared: Vec<(Vec<GpuId>, Vec<Vec<GpuId>>)>,
}

impl PreparedDelta {
    /// Prepare `delta` against `base` (the placement the replanner
    /// evaluated): one [`instances_for`] build per changed layer,
    /// shared by every subsequent [`PreparedDelta::apply`]. An empty
    /// delta builds nothing.
    pub fn new(base: &Placement, delta: ReplanDelta) -> PreparedDelta {
        let prepared = delta
            .layers
            .iter()
            .map(|ld| {
                let primary = base.layers[ld.layer].primary.clone();
                let inst = instances_for(&primary, &ld.replication);
                (primary, inst)
            })
            .collect();
        PreparedDelta { delta, prepared }
    }

    /// The wrapped decision (migration pricing reads its traffic).
    pub fn delta(&self) -> &ReplanDelta {
        &self.delta
    }

    /// `true` when applying changes nothing.
    pub fn is_empty(&self) -> bool {
        self.delta.is_empty()
    }

    /// Apply to one replica's active placement. Structurally identical
    /// to [`super::apply_delta`], but a replica whose primary map equals
    /// the prepared one clones the cached instance table instead of
    /// rebuilding it — the per-replica rebuild the rolling rollout would
    /// otherwise pay N times.
    pub fn apply(&self, p: &Placement) -> Placement {
        let mut out = p.clone();
        for (ld, (primary, inst)) in
            self.delta.layers.iter().zip(&self.prepared)
        {
            let lp = &mut out.layers[ld.layer];
            lp.instances = if lp.primary == *primary {
                inst.clone()
            } else {
                instances_for(&lp.primary, &ld.replication)
            };
            lp.replication = ld.replication.clone();
            lp.predicted = ld.predicted.clone();
            lp.polling = ld.polling.clone();
        }
        out
    }
}

/// Rollout state machine: one in-flight [`PreparedDelta`] swapped into
/// replicas 0‥N in cursor order, at most one replica per epoch index.
/// The driver asks [`RollingReplan::due`] at each replica's step
/// boundary and calls [`RollingReplan::commit`] after pricing and
/// applying the swap; everything here is bookkeeping, so the machine
/// stays deterministic and engine-free (unit-testable without a fleet).
#[derive(Clone, Debug)]
pub struct RollingReplan {
    replicas: usize,
    pending: Option<PreparedDelta>,
    cursor: usize,
    last_swap_epoch: Option<u64>,
    rollouts: u64,
    swaps: u64,
    log: Vec<(u64, usize)>,
}

impl RollingReplan {
    /// Rollout machine for a fleet of `replicas` shards (≥ 1 — enforced
    /// upstream by the fleet config validation).
    pub fn new(replicas: usize) -> RollingReplan {
        RollingReplan {
            replicas: replicas.max(1),
            pending: None,
            cursor: 0,
            last_swap_epoch: None,
            rollouts: 0,
            swaps: 0,
            log: Vec::new(),
        }
    }

    /// A rollout is mid-flight: some replicas run the new placement,
    /// the rest still serve the old one. New deltas are refused until
    /// the cursor has visited every replica.
    pub fn in_flight(&self) -> bool {
        self.pending.is_some()
    }

    /// Start rolling `prepared` out. Refused (returns `false`, dropping
    /// the delta) while a rollout is in flight or when the delta is
    /// empty — the replanner re-evaluates from live loads once the
    /// current rollout completes, so a dropped decision is never stale
    /// state, just a skipped epoch.
    pub fn begin(&mut self, prepared: PreparedDelta) -> bool {
        if self.in_flight() || prepared.is_empty() {
            return false;
        }
        self.pending = Some(prepared);
        self.cursor = 0;
        true
    }

    /// May replica `replica` swap at its current step boundary, given
    /// the fleet is at `epoch`? True only when it is the rollout
    /// cursor's turn *and* no replica has swapped at this epoch index
    /// yet — the "≥ N−1 replicas serving every epoch" invariant.
    pub fn due(&self, replica: usize, epoch: u64) -> bool {
        self.pending.is_some()
            && self.cursor == replica
            && self.last_swap_epoch != Some(epoch)
    }

    /// The in-flight prepared delta, if any.
    pub fn prepared(&self) -> Option<&PreparedDelta> {
        self.pending.as_ref()
    }

    /// Record that `replica` swapped at `epoch`: advance the cursor,
    /// and when the last replica has swapped, complete the rollout.
    /// Call only after [`RollingReplan::due`] returned `true`.
    pub fn commit(&mut self, replica: usize, epoch: u64) {
        debug_assert!(self.due(replica, epoch),
                      "commit without a due swap");
        self.last_swap_epoch = Some(epoch);
        self.swaps += 1;
        self.log.push((epoch, replica));
        self.cursor += 1;
        if self.cursor >= self.replicas {
            self.pending = None;
            self.cursor = 0;
            self.rollouts += 1;
        }
    }

    /// Completed rollouts (every replica swapped).
    pub fn rollouts(&self) -> u64 {
        self.rollouts
    }

    /// Per-replica swaps committed (N × rollouts once all complete).
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Full swap history as `(epoch index, replica)` in commit order —
    /// what the fleet tests assert the one-swap-per-epoch invariant on.
    pub fn log(&self) -> &[(u64, usize)] {
        &self.log
    }
}

#[cfg(test)]
mod tests {
    use super::super::{LayerDelta, ReplanDelta};
    use super::*;
    use crate::placement::LayerPlacement;
    use crate::replication::Replication;

    /// Hand-built 4-expert / 2-GPU placement (no profiling pipeline —
    /// the machine under test is pure bookkeeping).
    fn tiny_placement() -> Placement {
        let groups = vec![vec![0usize, 1], vec![2, 3]];
        let mut primary = vec![0usize; 4];
        for (g, es) in groups.iter().enumerate() {
            for &e in es {
                primary[e] = g;
            }
        }
        let replication = Replication::none();
        let instances = instances_for(&primary, &replication);
        let layer = LayerPlacement {
            groups,
            primary,
            instances,
            replication,
            pre_loads: vec![10.0, 10.0],
            predicted: vec![10.0, 10.0],
            polling: vec![0.5, 0.5],
        };
        Placement { layers: vec![layer], experts: 4, num_gpus: 2 }
    }

    fn tiny_delta() -> ReplanDelta {
        let replication = Replication {
            hot_experts: vec![0],
            replica_gpus: vec![1],
            n_replica: 1,
            w_max: 10.0,
            w_r: 5.0,
            computed: true,
        };
        let ld = LayerDelta {
            layer: 0,
            replication,
            added: vec![(0, 1)],
            removed: vec![],
            predicted: vec![7.5, 12.5],
            polling: vec![0.6, 0.4],
            rho_live: 2.0,
            migration_bytes: 64.0,
            benefit_s: 1.0,
            cost_s: 0.1,
        };
        ReplanDelta { layers: vec![ld], migration_bytes: 64.0,
                      benefit_s: 1.0, cost_s: 0.1 }
    }

    // Exact instances_for build counts (1 per changed layer per
    // rollout, 0 for empty deltas) are pinned in benches/hotpath.rs via
    // placement::instances_build_count — the counter is process-global,
    // so a parallel `cargo test` run cannot assert exact deltas here.
    #[test]
    fn prepared_apply_matches_apply_delta_for_every_replica() {
        let p = tiny_placement();
        let delta = tiny_delta();
        let reference = super::super::apply_delta(&p, &delta);
        let prep = PreparedDelta::new(&p, delta);
        for a in (0..4).map(|_| prep.apply(&p)) {
            assert_eq!(a.layers[0].instances, reference.layers[0].instances);
            assert_eq!(a.layers[0].replication,
                       reference.layers[0].replication);
            assert_eq!(a.layers[0].polling, reference.layers[0].polling);
        }
    }

    #[test]
    fn empty_delta_is_an_identity_apply() {
        let p = tiny_placement();
        let prep = PreparedDelta::new(&p, ReplanDelta::default());
        assert!(prep.is_empty());
        let out = prep.apply(&p);
        assert_eq!(out.layers[0].instances, p.layers[0].instances);
    }

    #[test]
    fn diverged_primary_falls_back_to_a_fresh_build() {
        let p = tiny_placement();
        let prep = PreparedDelta::new(&p, tiny_delta());
        // A replica whose expert 0 lives on GPU 1 instead of 0: the
        // cached table (built for primary [0,0,1,1]) must NOT be
        // reused — the fallback rebuild keeps primary-first intact.
        let mut other = p.clone();
        other.layers[0].primary = vec![1, 0, 1, 0];
        let out = prep.apply(&other);
        assert_eq!(out.layers[0].instances[0][0], 1,
                   "primary-first invariant holds for the diverged map");
        assert_eq!(
            out.layers[0].instances,
            super::super::apply_delta(&other, prep.delta()).layers[0]
                .instances,
            "fallback path must agree with apply_delta"
        );
    }

    #[test]
    fn rollout_visits_every_replica_once_one_epoch_apart() {
        let p = tiny_placement();
        let mut roll = RollingReplan::new(3);
        assert!(!roll.in_flight());
        assert!(roll.begin(PreparedDelta::new(&p, tiny_delta())));
        // Same-epoch double swap is refused; cursor order is enforced.
        assert!(roll.due(0, 5));
        assert!(!roll.due(1, 5), "only the cursor replica is due");
        roll.commit(0, 5);
        assert!(!roll.due(1, 5), "second swap in epoch 5 must wait");
        assert!(roll.due(1, 6));
        roll.commit(1, 6);
        assert!(roll.in_flight());
        assert!(roll.due(2, 7));
        roll.commit(2, 7);
        assert!(!roll.in_flight(), "rollout completes after replica N−1");
        assert_eq!(roll.rollouts(), 1);
        assert_eq!(roll.swaps(), 3);
        assert_eq!(roll.log(), &[(5, 0), (6, 1), (7, 2)]);
    }

    #[test]
    fn busy_machine_refuses_new_deltas_and_empty_ones() {
        let p = tiny_placement();
        let mut roll = RollingReplan::new(2);
        assert!(!roll.begin(PreparedDelta::new(&p, ReplanDelta::default())),
                "an empty delta must not start a rollout");
        assert!(roll.begin(PreparedDelta::new(&p, tiny_delta())));
        assert!(!roll.begin(PreparedDelta::new(&p, tiny_delta())),
                "a second delta must wait for the in-flight rollout");
        roll.commit(0, 1);
        roll.commit(1, 2);
        assert!(roll.begin(PreparedDelta::new(&p, tiny_delta())),
                "a completed rollout frees the machine");
    }

    #[test]
    fn single_replica_swaps_in_the_decision_epoch() {
        // N = 1: the pre-sharding immediate apply — begin and commit in
        // the same epoch, machine free again right after.
        let p = tiny_placement();
        let mut roll = RollingReplan::new(1);
        assert!(roll.begin(PreparedDelta::new(&p, tiny_delta())));
        assert!(roll.due(0, 9));
        roll.commit(0, 9);
        assert!(!roll.in_flight());
        assert_eq!(roll.rollouts(), 1);
    }
}
