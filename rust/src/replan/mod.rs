//! Epoch-based online re-planning: the feedback arrow from measured load
//! back into dynamic replication.
//!
//! The paper's Grouping & Replication phase (§4.2) is *dynamic* with
//! respect to the profiling trace, but the offline pipeline runs once:
//! after [`crate::coordinator::Coordinator::place`] the hot-expert set
//! and replica GPUs are frozen. When the serving workload drifts away
//! from the profiled distribution (a different dataset mix, a rotated
//! hot-expert set), the frozen replication keeps balancing yesterday's
//! load. This module closes the loop:
//!
//! ```text
//!   DispatchPlan ──▶ LoadEstimator (EWMA per layer) ──▶ epoch_tick
//!        ▲                                                  │
//!        │            Eq. 3/4 recomputed on live loads      │
//!   Dispatcher ◀── apply_delta (new replicas + polling) ◀───┘
//! ```
//!
//! * [`Replanner::observe`] aggregates finished
//!   [`DispatchPlan`]s into the same EWMA machinery the
//!   [`crate::routing::LoadAware`] policy uses
//!   ([`crate::routing::LoadEstimator`]).
//! * [`Replanner::epoch_tick`] fires every
//!   [`ReplanConfig::epoch_rounds`] measurement rounds: per layer it
//!   recomputes Eq.-3 replication
//!   ([`crate::replication::dynamic_replication`]) over the *measured*
//!   loads, compares the decision structurally against the active
//!   [`Replication`], and gates the swap twice — a drift gate (the
//!   predicted max-GPU-load improvement must exceed
//!   [`ReplanConfig::min_drift`], so sampling noise never churns
//!   replicas) and a migration cost gate (the predicted compute-seconds
//!   saved next epoch must repay the expert-weight copy bytes, scaled by
//!   [`ReplanConfig::payback`]).
//! * [`apply_delta`] rebuilds the affected
//!   [`crate::placement::LayerPlacement`]s (instances, Eq.-4 predicted
//!   loads, polling weights); [`migration_traffic`] exposes the weight
//!   copies as a [`TrafficMatrix`] so the engines can price them through
//!   [`crate::comm::model`] — migration shows up in simulated latency,
//!   not as a free teleport.
//!
//! On a perfectly stationary workload the recomputed decision equals the
//! active one every epoch and the delta is empty — the re-planned path is
//! bit-identical to static GRACE (pinned by `tests/replan.rs`).
//! The re-planner assumes ρ-driven dynamic replication
//! ([`crate::placement::ReplicationMode::Dynamic`], the `grace-dyn`
//! system); grouping is never changed online — regrouping would migrate
//! primary weights wholesale, which the cost model prices out.

pub mod rolling;

pub use rolling::{PreparedDelta, RollingReplan};

use crate::cluster::{GpuId, Topology};
use crate::comm::traffic::TrafficMatrix;
use crate::config::{GpuModel, ModelSpec};
use crate::linalg::Matrix;
use crate::metrics::ServeMetrics;
use crate::placement::{instances_for, LayerPlacement, Placement};
use crate::profile::LayerProfile;
use crate::replication::{self, polling_weights, predict_loads,
                         Replication};
use crate::routing::{DispatchPlan, LoadEstimator};
use crate::runtime::manifest::TinyConfig;

/// Epoch cadence and gating thresholds of the online re-planner.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplanConfig {
    /// Measurement rounds per epoch (one round = one dispatched batch
    /// per layer); `epoch_tick` is a no-op between boundaries.
    pub epoch_rounds: u64,
    /// Drift gate: minimum relative improvement of the predicted max
    /// per-GPU load (`(t_active − t_cand) / t_active`) required before a
    /// recomputed replication is even considered. Filters sampling noise.
    pub min_drift: f64,
    /// Migration cost gate: the predicted compute-seconds saved over the
    /// next epoch must be at least `payback ×` the weight-copy cost.
    /// `0.0` disables the cost gate (drift gate still applies).
    pub payback: f64,
    /// EWMA smoothing factor of the measured-load estimator.
    pub alpha: f64,
}

impl Default for ReplanConfig {
    fn default() -> ReplanConfig {
        ReplanConfig {
            epoch_rounds: 4,
            min_drift: 0.1,
            payback: 1.0,
            alpha: crate::routing::LoadAware::DEFAULT_ALPHA,
        }
    }
}

impl ReplanConfig {
    /// Loud validation of the cadence and gates: a zero epoch cadence
    /// would never tick, and out-of-range gates silently disable the
    /// re-planner instead of erroring.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.epoch_rounds >= 1,
                        "replan epoch cadence must be at least 1 round");
        anyhow::ensure!(self.min_drift.is_finite() && self.min_drift >= 0.0,
                        "min_drift must be finite and non-negative");
        anyhow::ensure!(self.payback.is_finite() && self.payback >= 0.0,
                        "payback must be finite and non-negative");
        anyhow::ensure!(self.alpha > 0.0 && self.alpha <= 1.0,
                        "EWMA alpha must be in (0, 1], got {}",
                        self.alpha);
        Ok(())
    }
}

/// Physical constants of the migration cost model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostParams {
    /// Bytes of one expert's weights (what one added replica copies).
    pub expert_bytes: f64,
    /// Seconds of expert compute per routed assignment on one GPU (what
    /// one unit of max-load improvement is worth).
    pub moe_s_per_assignment: f64,
}

impl CostParams {
    /// Cost model for the paper-scale simulator: expert bytes from the
    /// [`ModelSpec`], per-assignment seconds from the [`GpuModel`] under
    /// the system's compute-efficiency factor.
    pub fn paper(model: &ModelSpec, gpu: &GpuModel, compute_eff: f64)
                 -> CostParams {
        CostParams {
            expert_bytes: model.expert_bytes(),
            moe_s_per_assignment: gpu.moe_time(model, 1.0) / compute_eff,
        }
    }

    /// Cost model for the execute-mode tiny variants: f32 expert weights
    /// from the [`TinyConfig`], a nominal per-assignment time for the
    /// CPU-interpret PJRT path (one `expert_ffn` call amortised over a
    /// tile — a modeling knob, not a measurement).
    pub fn tiny(cfg: &TinyConfig) -> CostParams {
        CostParams {
            expert_bytes: (3 * cfg.hidden * cfg.ffn * 4) as f64,
            moe_s_per_assignment: 100e-6,
        }
    }

    /// Cost model observed from a serving window: `secs` of measured
    /// step time over `computed_tokens` computed tokens (each token is
    /// [`ModelSpec::top_k`] routed assignments). `None` when the window
    /// is empty or the measurement degenerate — callers then keep their
    /// previous cost model.
    pub fn from_observed(model: &ModelSpec, secs: f64,
                         computed_tokens: usize) -> Option<CostParams> {
        if computed_tokens == 0 || !secs.is_finite() || secs <= 0.0 {
            return None;
        }
        let assignments = (computed_tokens * model.top_k) as f64;
        Some(CostParams {
            expert_bytes: model.expert_bytes(),
            moe_s_per_assignment: secs / assignments,
        })
    }

    /// Cost model from measured serving metrics: prefers the TPOT
    /// distribution (mean seconds per decoded token, i.e. per computed
    /// token under KV-cached decode), falling back to wall time over
    /// computed tokens when no request decoded two tokens. The payback
    /// gate then prices migrations with the deployment's *measured*
    /// speed instead of the a-priori GPU model.
    pub fn from_measured(model: &ModelSpec, serve: &ServeMetrics)
                         -> Option<CostParams> {
        if let Some(tpot) = serve.tpot_summary() {
            return Self::from_observed(model, tpot.mean(), 1);
        }
        Self::from_observed(model, serve.wall_time,
                            serve.computed_tokens)
    }
}

/// One layer's accepted re-replication for an epoch.
#[derive(Clone, Debug)]
pub struct LayerDelta {
    /// MoE layer index.
    pub layer: usize,
    /// The replication decision recomputed from measured loads (replaces
    /// the layer's active [`Replication`] wholesale).
    pub replication: Replication,
    /// Secondary `(expert, gpu)` instances to create — each one copies
    /// the expert's weights from its primary GPU.
    pub added: Vec<(usize, GpuId)>,
    /// Secondary `(expert, gpu)` instances to drop (free).
    pub removed: Vec<(usize, GpuId)>,
    /// Eq.-4 predicted per-GPU loads under the new replication and the
    /// measured traffic.
    pub predicted: Vec<f64>,
    /// Polling weights derived from `predicted`.
    pub polling: Vec<f64>,
    /// Load-skew factor ρ measured over the live loads (diagnostics).
    pub rho_live: f64,
    /// Weight bytes this layer's migration copies.
    pub migration_bytes: f64,
    /// Predicted compute-seconds saved over the next epoch.
    pub benefit_s: f64,
    /// Estimated seconds the weight copies cost.
    pub cost_s: f64,
}

/// Whole-model re-planning decision for one epoch. Empty when the epoch
/// boundary has not been reached, when no layer drifted past the gates,
/// or when no migration paid for itself.
#[derive(Clone, Debug, Default)]
pub struct ReplanDelta {
    /// Accepted per-layer changes.
    pub layers: Vec<LayerDelta>,
    /// Total weight bytes migration copies across layers.
    pub migration_bytes: f64,
    /// Total predicted benefit across layers, seconds.
    pub benefit_s: f64,
    /// Total estimated migration cost across layers, seconds.
    pub cost_s: f64,
}

impl ReplanDelta {
    /// `true` when this epoch changes nothing.
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }
}

/// Same structural replication decision (hot set, replica hosts, count)?
/// `w_max`/`w_r` are measurement-scale-dependent and deliberately
/// ignored: a replayed stationary trace reproduces the decision exactly
/// but at EWMA scale rather than whole-trace scale.
fn same_decision(a: &Replication, b: &Replication) -> bool {
    fn sorted(xs: &[usize]) -> Vec<usize> {
        let mut v = xs.to_vec();
        v.sort_unstable();
        v
    }
    a.n_replica == b.n_replica
        && sorted(&a.hot_experts) == sorted(&b.hot_experts)
        && sorted(&a.replica_gpus) == sorted(&b.replica_gpus)
}

/// Apply an epoch's accepted delta to a placement, returning the new
/// active placement: per changed layer the replication, instance map,
/// predicted loads, and polling weights are replaced; groups, primaries,
/// and profiling-time pre-loads are untouched (grouping never changes
/// online).
pub fn apply_delta(p: &Placement, delta: &ReplanDelta) -> Placement {
    let mut out = p.clone();
    for ld in &delta.layers {
        let lp = &mut out.layers[ld.layer];
        lp.instances = instances_for(&lp.primary, &ld.replication);
        lp.replication = ld.replication.clone();
        lp.predicted = ld.predicted.clone();
        lp.polling = ld.polling.clone();
    }
    out
}

/// The weight copies a delta implies, as a byte matrix over GPU pairs:
/// each added `(expert, gpu)` replica moves `expert_bytes` from the
/// expert's primary GPU (read from the pre-delta `active` placement) to
/// the new host. Feed the result to [`crate::comm::model`] to price the
/// migration like any other transfer.
pub fn migration_traffic(delta: &ReplanDelta, active: &Placement,
                         expert_bytes: f64) -> TrafficMatrix {
    migration_traffic_resident(delta, active, expert_bytes,
                               &|_, _, _| false)
}

/// [`migration_traffic`] with a residency probe: an added replica whose
/// destination already holds the expert's weights — staged earlier by
/// the prefetcher ([`crate::engine::prefetch`]) or left in the hot tier
/// by a previous epoch — copies nothing, so its bytes are skipped
/// instead of being billed a second time. `resident(layer, expert,
/// gpu)` answers whether `gpu`'s tier already holds that expert.
pub fn migration_traffic_resident(
    delta: &ReplanDelta, active: &Placement, expert_bytes: f64,
    resident: &dyn Fn(usize, usize, GpuId) -> bool) -> TrafficMatrix {
    let mut m = TrafficMatrix::zeros(active.num_gpus);
    for ld in &delta.layers {
        let primary = &active.layers[ld.layer].primary;
        for &(e, g) in &ld.added {
            if resident(ld.layer, e, g) {
                continue;
            }
            m.add(primary[e], g, expert_bytes);
        }
    }
    m
}

/// The epoch-based online re-planner: owns the measured-load estimator
/// and the gating logic. One per serving run, held either directly by an
/// engine driver ([`crate::engine::sim::simulate_rounds`]) or by the
/// [`crate::coordinator::OnlineCoordinator`] serving surface.
#[derive(Clone, Debug)]
pub struct Replanner {
    cfg: ReplanConfig,
    cost: CostParams,
    topo: Topology,
    est: LoadEstimator,
    /// Measured assignment volume per layer since the last tick (what an
    /// epoch of traffic is worth to the benefit estimate).
    epoch_assign: Vec<f64>,
    last_tick_rounds: u64,
    epochs: u64,
    rejected: u64,
}

impl Replanner {
    /// Re-planner over `topo` with the given cadence/gates and migration
    /// cost model.
    pub fn new(topo: Topology, cfg: ReplanConfig, cost: CostParams)
               -> Replanner {
        Replanner {
            est: LoadEstimator::new(cfg.alpha),
            epoch_assign: Vec::new(),
            last_tick_rounds: 0,
            epochs: 0,
            rejected: 0,
            cfg,
            cost,
            topo,
        }
    }

    /// The configured cadence and gates.
    pub fn config(&self) -> ReplanConfig {
        self.cfg
    }

    /// The configured migration cost model.
    pub fn cost(&self) -> CostParams {
        self.cost
    }

    /// Replace the migration cost model mid-run — the measured-feedback
    /// path ([`CostParams::from_measured`] /
    /// [`CostParams::from_observed`]): serving drivers refresh the
    /// payback gate with observed per-step wall time so gating tracks
    /// the deployment's real speed.
    pub fn update_cost(&mut self, cost: CostParams) {
        self.cost = cost;
    }

    /// Epochs evaluated so far (ticks that reached the boundary).
    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// Candidate layer swaps rejected by the drift or cost gate.
    pub fn rejected(&self) -> u64 {
        self.rejected
    }

    /// The live load estimator (shared-machinery read access).
    pub fn estimator(&self) -> &LoadEstimator {
        &self.est
    }

    /// Feed one finished dispatch round: every assignment of `plan` is
    /// measured against `lp` (the layer placement it was routed with)
    /// and the round is folded into the layer's EWMA. Purely passive —
    /// never touches the engine's RNG or the plan itself.
    pub fn observe(&mut self, layer: usize, lp: &LayerPlacement,
                   plan: &DispatchPlan) {
        if self.epoch_assign.len() <= layer {
            self.epoch_assign.resize(layer + 1, 0.0);
        }
        self.epoch_assign[layer] += plan.num_assignments() as f64;
        self.est.record_plan(layer, lp, plan);
    }

    /// Evaluate the epoch against the active placement. Returns an empty
    /// delta between epoch boundaries; at a boundary, recomputes Eq. 3/4
    /// per layer over the measured loads and keeps only the layer swaps
    /// that pass both gates.
    pub fn epoch_tick(&mut self, active: &Placement) -> ReplanDelta {
        let rounds = self.est.max_rounds();
        if rounds < self.last_tick_rounds + self.cfg.epoch_rounds {
            return ReplanDelta::default();
        }
        self.last_tick_rounds = rounds;
        self.epochs += 1;
        let volumes = std::mem::take(&mut self.epoch_assign);

        let mut delta = ReplanDelta::default();
        for (l, lp) in active.layers.iter().enumerate() {
            // Clone the EWMA snapshot out of the estimator so the layer
            // evaluation (which counts gate rejections on `self`) can
            // borrow mutably.
            let Some(expert_loads) =
                self.est.expert_loads(l).map(<[f64]>::to_vec)
            else {
                continue;
            };
            let volume = volumes.get(l).copied().unwrap_or(0.0);
            if let Some(ld) = self.evaluate_layer(l, lp, &expert_loads,
                                                  volume) {
                delta.migration_bytes += ld.migration_bytes;
                delta.benefit_s += ld.benefit_s;
                delta.cost_s += ld.cost_s;
                delta.layers.push(ld);
            }
        }
        delta
    }

    /// One layer's drift evaluation (see [`Replanner::epoch_tick`]).
    fn evaluate_layer(&mut self, layer: usize, lp: &LayerPlacement,
                      expert_loads: &[f64], volume: f64)
                      -> Option<LayerDelta> {
        let experts = expert_loads.len();
        let live = LayerProfile {
            affinity: Matrix::zeros(experts, experts),
            load: expert_loads.to_vec(),
            tokens: 0,
        };
        let pre: Vec<f64> =
            lp.groups.iter().map(|g| live.group_load(g)).collect();
        let total: f64 = pre.iter().sum();
        if total <= 0.0 {
            return None;
        }

        // Eq. 3 recomputed on live loads (grouping held fixed).
        let cand = replication::dynamic_replication(&live, &lp.groups);
        if same_decision(&cand, &lp.replication) {
            return None; // no structural drift — the common case
        }

        // Predicted max per-GPU load: active replication re-priced with
        // live `W_max`/`W_r` vs the candidate (both via Eq. 4).
        let pred_active = predict_live(&pre, lp, &lp.replication,
                                       expert_loads);
        let heavy_live = live.heaviest_group(&lp.groups);
        let pred_cand = predict_loads(&pre, heavy_live, &cand);
        let t_active = pred_active.iter().cloned().fold(0.0, f64::max);
        let t_cand = pred_cand.iter().cloned().fold(0.0, f64::max);
        if t_active <= 0.0 {
            return None;
        }
        let improvement = (t_active - t_cand) / t_active;
        if improvement < self.cfg.min_drift {
            self.rejected += 1;
            return None;
        }

        // Migration set: secondary instances the candidate adds/drops.
        let new_instances = instances_for(&lp.primary, &cand);
        let mut added = Vec::new();
        let mut removed = Vec::new();
        for e in 0..experts {
            for &g in &new_instances[e][1..] {
                if !lp.instances[e].contains(&g) {
                    added.push((e, g));
                }
            }
            for &g in &lp.instances[e][1..] {
                if !new_instances[e].contains(&g) {
                    removed.push((e, g));
                }
            }
        }

        // Cost gate: copy bytes over the actual links vs the predicted
        // compute-seconds the flatter load buys over one epoch of the
        // measured traffic volume.
        let migration_bytes =
            added.len() as f64 * self.cost.expert_bytes;
        let mut cost_s = if added.is_empty() {
            0.0
        } else {
            self.topo.launch_overhead
        };
        for &(e, g) in &added {
            cost_s += self.cost.expert_bytes
                / self.topo.bw(lp.primary[e], g);
        }
        let benefit_s = (t_active - t_cand) / total * volume
            * self.cost.moe_s_per_assignment;
        if benefit_s < self.cfg.payback * cost_s {
            self.rejected += 1;
            return None;
        }

        let mean = total / pre.len() as f64;
        Some(LayerDelta {
            layer,
            rho_live: pre[heavy_live] / mean,
            polling: polling_weights(&pred_cand),
            predicted: pred_cand,
            replication: cand,
            added,
            removed,
            migration_bytes,
            benefit_s,
            cost_s,
        })
    }
}

/// Eq. 4 over live loads for the *active* replication: the decision's
/// hot set and replica hosts re-priced with measured `W_max`/`W_r`
/// (mirrors [`crate::routing::LoadAware`]'s online recomputation).
fn predict_live(pre: &[f64], lp: &LayerPlacement, rep: &Replication,
                expert_loads: &[f64]) -> Vec<f64> {
    if rep.is_none() {
        return pre.to_vec();
    }
    // Hot experts all live in the heaviest group of the decision, so
    // their shared primary is the heavy GPU.
    let heavy = lp.primary[rep.hot_experts[0]];
    let online = Replication {
        hot_experts: rep.hot_experts.clone(),
        replica_gpus: rep.replica_gpus.clone(),
        n_replica: rep.n_replica,
        w_max: pre[heavy],
        w_r: rep.hot_experts.iter().map(|&e| expert_loads[e]).sum(),
        computed: true,
    };
    predict_loads(pre, heavy, &online)
        .into_iter()
        .map(|w| w.max(0.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::ReplicationMode;
    use crate::routing::{Assignment, Dispatcher, RoutingPolicy};
    use crate::stats::Rng;

    /// 4 experts, one per GPU on a single 4-GPU node.
    fn placement_from_loads(loads: Vec<f64>) -> Placement {
        let profile = LayerProfile {
            affinity: Matrix::zeros(loads.len(), loads.len()),
            load: loads,
            tokens: 100,
        };
        let lp = LayerPlacement::build(
            &profile,
            vec![vec![0], vec![1], vec![2], vec![3]],
            ReplicationMode::Dynamic,
        );
        Placement { layers: vec![lp], experts: 4, num_gpus: 4 }
    }

    fn topo() -> Topology {
        Topology::paper_testbed(1, 4)
    }

    /// Route `counts[e]` assignments of expert `e` through a primary
    /// dispatcher and observe the plan.
    fn observe_round(rp: &mut Replanner, p: &Placement,
                     counts: &[usize]) {
        let mut batch = Vec::new();
        let mut t = 0usize;
        for (e, &c) in counts.iter().enumerate() {
            for _ in 0..c {
                batch.push(Assignment { token: t, expert: e, src: t % 4 });
                t += 1;
            }
        }
        let mut d = Dispatcher::new(topo(),
                                    RoutingPolicy::Primary.build(), 1.0);
        let plan = d.dispatch(&p.layers[0], 0, &batch, &mut Rng::new(1));
        rp.observe(0, &p.layers[0], &plan);
    }

    fn cfg_every_round(payback: f64) -> ReplanConfig {
        ReplanConfig {
            epoch_rounds: 1,
            min_drift: 0.05,
            payback,
            ..ReplanConfig::default()
        }
    }

    fn cheap_cost() -> CostParams {
        CostParams { expert_bytes: 8.0, moe_s_per_assignment: 1e-3 }
    }

    #[test]
    fn stationary_loads_produce_empty_delta() {
        // Live loads replay the profiling loads exactly → the recomputed
        // decision is structurally identical → empty delta, regardless
        // of the gates.
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        assert!(!p.layers[0].replication.is_none(), "fixture replicates");
        let mut rp =
            Replanner::new(topo(), cfg_every_round(0.0), cheap_cost());
        for _ in 0..3 {
            observe_round(&mut rp, &p, &[280, 60, 40, 20]);
            let d = rp.epoch_tick(&p);
            assert!(d.is_empty(), "stationary epoch produced {d:?}");
        }
        assert_eq!(rp.epochs(), 3);
        assert_eq!(rp.rejected(), 0, "skipped before the gates");
    }

    #[test]
    fn rotated_hot_expert_is_detected_and_applied() {
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        assert_eq!(p.layers[0].replication.hot_experts, vec![0]);
        let mut rp =
            Replanner::new(topo(), cfg_every_round(0.0), cheap_cost());
        // Load rotated onto expert 3; a few rounds so the EWMA crosses.
        let mut delta = ReplanDelta::default();
        for _ in 0..6 {
            observe_round(&mut rp, &p, &[20, 40, 60, 280]);
            let d = rp.epoch_tick(&p);
            if !d.is_empty() {
                delta = d;
                break;
            }
        }
        assert!(!delta.is_empty(), "drift never detected");
        let ld = &delta.layers[0];
        assert_eq!(ld.replication.hot_experts, vec![3]);
        assert!(ld.added.iter().all(|&(e, _)| e == 3));
        assert!(!ld.added.is_empty());
        assert!(ld.removed.iter().all(|&(e, _)| e == 0),
                "old replicas of the cold expert must be dropped");
        assert!(ld.rho_live > 1.0);
        assert!(delta.migration_bytes > 0.0);

        // Applying it rebuilds a consistent layer placement.
        let next = apply_delta(&p, &delta);
        let lp = &next.layers[0];
        assert_eq!(lp.groups, p.layers[0].groups, "grouping untouched");
        assert_eq!(lp.primary, p.layers[0].primary);
        assert!(lp.instances[3].len() > 1, "new hot expert replicated");
        assert_eq!(lp.instances[0], vec![0], "old replicas dropped");
        for (e, inst) in lp.instances.iter().enumerate() {
            assert_eq!(inst[0], lp.primary[e], "primary first");
            let mut d = inst.clone();
            d.sort_unstable();
            d.dedup();
            assert_eq!(d.len(), inst.len(), "distinct instance gpus");
        }
        let s: f64 = lp.polling.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "polling normalized");

        // And once applied, the same live loads no longer drift.
        let mut rp2 =
            Replanner::new(topo(), cfg_every_round(0.0), cheap_cost());
        for _ in 0..3 {
            observe_round(&mut rp2, &next, &[20, 40, 60, 280]);
            assert!(rp2.epoch_tick(&next).is_empty(),
                    "replanned placement must be a fixed point");
        }
    }

    #[test]
    fn cost_gate_withholds_unprofitable_migrations() {
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        // Expensive weights, negligible compute value per assignment:
        // the same drift that the zero-payback test applies must now be
        // rejected by the cost gate.
        let dear = CostParams {
            expert_bytes: 1e12,
            moe_s_per_assignment: 1e-12,
        };
        let mut rp = Replanner::new(topo(), cfg_every_round(1.0), dear);
        for _ in 0..6 {
            observe_round(&mut rp, &p, &[20, 40, 60, 280]);
            assert!(rp.epoch_tick(&p).is_empty(),
                    "unprofitable migration must be withheld");
        }
        assert!(rp.rejected() > 0, "gate must have actually fired");
    }

    #[test]
    fn tick_between_epoch_boundaries_is_empty() {
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        let cfg = ReplanConfig {
            epoch_rounds: 3,
            min_drift: 0.05,
            payback: 0.0,
            ..ReplanConfig::default()
        };
        let mut rp = Replanner::new(topo(), cfg, cheap_cost());
        for round in 1..=7u64 {
            observe_round(&mut rp, &p, &[20, 40, 60, 280]);
            let d = rp.epoch_tick(&p);
            if round % 3 != 0 {
                assert!(d.is_empty(), "mid-epoch tick at round {round}");
            }
        }
        assert_eq!(rp.epochs(), 2, "epochs at rounds 3 and 6");
    }

    #[test]
    fn config_validation_is_loud() {
        assert!(ReplanConfig::default().validate().is_ok());
        let bad_epoch =
            ReplanConfig { epoch_rounds: 0, ..ReplanConfig::default() };
        assert!(bad_epoch.validate().is_err());
        let bad_drift = ReplanConfig { min_drift: f64::NAN,
                                       ..ReplanConfig::default() };
        assert!(bad_drift.validate().is_err());
        let bad_payback = ReplanConfig { payback: -1.0,
                                         ..ReplanConfig::default() };
        assert!(bad_payback.validate().is_err());
        let bad_alpha =
            ReplanConfig { alpha: 0.0, ..ReplanConfig::default() };
        assert!(bad_alpha.validate().is_err());
    }

    #[test]
    fn observed_cost_divides_secs_by_assignments() {
        let model = crate::config::ModelSpec::olmoe();
        let c = CostParams::from_observed(&model, 0.8, 100).unwrap();
        assert_eq!(c.expert_bytes, model.expert_bytes());
        // 100 tokens × top-8 = 800 assignments over 0.8 s → 1 ms each.
        assert!((c.moe_s_per_assignment - 1e-3).abs() < 1e-12);
        assert!(CostParams::from_observed(&model, 0.8, 0).is_none());
        assert!(CostParams::from_observed(&model, 0.0, 100).is_none());
        assert!(CostParams::from_observed(&model, f64::NAN, 100)
            .is_none());
    }

    #[test]
    fn measured_cost_prefers_tpot_then_wall_time() {
        let model = crate::config::ModelSpec::olmoe();
        let with_tpot = crate::metrics::ServeMetrics {
            tpot: vec![8e-3, 8e-3],
            wall_time: 100.0,
            computed_tokens: 10,
            ..Default::default()
        };
        let c = CostParams::from_measured(&model, &with_tpot).unwrap();
        // TPOT path: 8 ms per token / top-8 = 1 ms per assignment —
        // the wall-time fallback (100 s / 80) must NOT be used.
        assert!((c.moe_s_per_assignment - 1e-3).abs() < 1e-12);

        let no_tpot = crate::metrics::ServeMetrics {
            wall_time: 0.8,
            computed_tokens: 100,
            ..Default::default()
        };
        let c = CostParams::from_measured(&model, &no_tpot).unwrap();
        assert!((c.moe_s_per_assignment - 1e-3).abs() < 1e-12);

        let empty = crate::metrics::ServeMetrics::default();
        assert!(CostParams::from_measured(&model, &empty).is_none());
    }

    #[test]
    fn measured_cost_reopens_the_payback_gate() {
        // Regression for the measured-feedback path: the same drift
        // that a dear a-priori cost model withholds must be applied
        // once update_cost installs a measured model whose compute is
        // expensive enough to repay the copy. Mirrors
        // cost_gate_withholds_unprofitable_migrations.
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        let dear = CostParams {
            expert_bytes: 8.0,
            moe_s_per_assignment: 1e-12,
        };
        let mut rp = Replanner::new(topo(), cfg_every_round(1.0), dear);
        for _ in 0..6 {
            observe_round(&mut rp, &p, &[20, 40, 60, 280]);
            assert!(rp.epoch_tick(&p).is_empty(),
                    "dear cost model must withhold the migration");
        }
        assert!(rp.rejected() > 0);

        // A serving window measured at 1 ms per assignment: slow
        // compute, so flattening the load is worth the 8-byte copy.
        let model = crate::config::ModelSpec::olmoe();
        let measured =
            CostParams::from_observed(&model, 0.8, 100).unwrap();
        rp.update_cost(CostParams {
            expert_bytes: 8.0,
            moe_s_per_assignment: measured.moe_s_per_assignment,
        });
        assert_eq!(rp.cost().moe_s_per_assignment, 1e-3);
        let mut applied = false;
        for _ in 0..6 {
            observe_round(&mut rp, &p, &[20, 40, 60, 280]);
            if !rp.epoch_tick(&p).is_empty() {
                applied = true;
                break;
            }
        }
        assert!(applied,
                "measured cost model must reopen the payback gate");
    }

    #[test]
    fn migration_traffic_reads_primary_sources() {
        let p = placement_from_loads(vec![280.0, 60.0, 40.0, 20.0]);
        let delta = ReplanDelta {
            layers: vec![LayerDelta {
                layer: 0,
                replication: Replication::none(),
                added: vec![(3, 0), (3, 1)],
                removed: vec![],
                predicted: vec![],
                polling: vec![],
                rho_live: 1.0,
                migration_bytes: 2e6,
                benefit_s: 1.0,
                cost_s: 0.1,
            }],
            migration_bytes: 2e6,
            benefit_s: 1.0,
            cost_s: 0.1,
        };
        let m = migration_traffic(&delta, &p, 1e6);
        assert_eq!(m.get(3, 0), 1e6, "copied from expert 3's primary");
        assert_eq!(m.get(3, 1), 1e6);
        assert_eq!(m.total_bytes(), 2e6);

        // Residency-aware accounting: a replica the destination's hot
        // tier already holds (e.g. staged by the prefetcher) must not
        // be billed again.
        let filtered = migration_traffic_resident(
            &delta, &p, 1e6, &|l, e, g| l == 0 && e == 3 && g == 1);
        assert_eq!(filtered.get(3, 0), 1e6, "cold replica still copies");
        assert_eq!(filtered.get(3, 1), 0.0,
                   "resident replica must not be double-counted");
        assert_eq!(filtered.total_bytes(), 1e6);
        // A probe that knows nothing reproduces the plain accounting.
        let all = migration_traffic_resident(&delta, &p, 1e6,
                                             &|_, _, _| false);
        assert_eq!(all.total_bytes(), m.total_bytes());
    }
}
