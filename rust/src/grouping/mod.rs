//! Expert grouping — the paper's communication-centric optimization
//! (§4.1, Algorithms 1–2).
//!
//! * [`fully_nonuniform`] — spectral clustering on the affinity matrix
//!   with group sizes driven purely by affinity structure,
//! * [`controlled_nonuniform`] — Algorithm 2: sizes bounded to
//!   `[E−δ, E+δ]` with `δ = max(1, round(E·r))`,
//! * [`uniform`] — the Occult/C2R-style equal-size baseline (`δ = 0`),
//! * [`select_r`] — knee-point selection on the (S(r), U(r)) trade-off
//!   curve (Eqs. 1–2, Appendix A.1),
//! * [`hierarchical`] — two-level grouping for multi-node topologies:
//!   fully non-uniform across nodes (cross-node traffic is the scarce
//!   resource), controlled non-uniform across GPUs within a node.

use crate::cluster::Topology;
use crate::linalg::{spectral_cluster, Matrix};
use crate::profile::{size_deviation, LayerProfile};
use crate::stats::Rng;

/// A grouping of one layer's experts: `groups[d]` lists the expert ids of
/// group `d`. Always a partition of `0..experts`.
pub type Grouping = Vec<Vec<usize>>;

/// Intra-group affinity score of expert `e` against group `gs`
/// (Algorithm 1 restricted to one candidate expert).
pub fn affinity_to_group(aff: &Matrix, e: usize, gs: &[usize]) -> f64 {
    gs.iter().filter(|&&j| j != e).map(|&j| aff[(e, j)]).sum()
}

/// Total intra-group affinity score (Algorithm 1).
pub fn group_score(aff: &Matrix, gs: &[usize]) -> f64 {
    let mut s = 0.0;
    for (i, &a) in gs.iter().enumerate() {
        for &b in &gs[i + 1..] {
            s += aff[(a, b)];
        }
    }
    s
}

/// Check that `groups` is a partition of `0..experts` (test/debug aid and
/// a hard invariant of every public function here).
pub fn is_partition(groups: &Grouping, experts: usize) -> bool {
    let mut seen = vec![false; experts];
    let mut count = 0;
    for g in groups {
        for &e in g {
            if e >= experts || seen[e] {
                return false;
            }
            seen[e] = true;
            count += 1;
        }
    }
    count == experts
}

/// Fully non-uniform grouping: spectral clusters used as-is, except that
/// empty groups are repaired (each group must host ≥ `min_size` experts so
/// that every device owns at least one expert).
pub fn fully_nonuniform(profile: &LayerProfile, d: usize, min_size: usize,
                        rng: &mut Rng) -> Grouping {
    let e = profile.experts();
    assert!(d >= 1 && d * min_size.max(1) <= e,
            "cannot form {d} groups of ≥{min_size} from {e} experts");
    let assign = spectral_cluster(&profile.affinity, d, rng, 4);
    let mut groups: Grouping = vec![Vec::new(); d];
    for (ex, &g) in assign.iter().enumerate() {
        groups[g].push(ex);
    }
    repair_min_sizes(&mut groups, &profile.affinity, min_size.max(1));
    groups
}

/// Move weakest-affinity experts from the largest groups into groups that
/// are below `min_size`.
fn repair_min_sizes(groups: &mut Grouping, aff: &Matrix, min_size: usize) {
    loop {
        let Some(needy) =
            (0..groups.len()).find(|&g| groups[g].len() < min_size)
        else {
            break;
        };
        let donor = (0..groups.len())
            .filter(|&g| g != needy && groups[g].len() > min_size)
            .max_by_key(|&g| groups[g].len())
            .expect("no donor group while repairing sizes");
        // weakest member of the donor (least intra-group affinity)
        let (idx, _) = groups[donor]
            .iter()
            .enumerate()
            .map(|(i, &ex)| {
                (i, affinity_to_group(aff, ex, &groups[donor]))
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let ex = groups[donor].swap_remove(idx);
        groups[needy].push(ex);
    }
}

/// Controlled non-uniform grouping — Algorithm 2 of the paper.
///
/// Group sizes are restricted to `[max(1, E−δ), E+δ]` with
/// `E = ⌊n/D⌋`, `δ = max(1, round(E·r))`.
pub fn controlled_nonuniform(profile: &LayerProfile, d: usize, r: f64,
                             rng: &mut Rng) -> Grouping {
    let e_total = profile.experts();
    let e_ideal = e_total / d;
    assert!(e_ideal >= 1, "more groups than experts");
    let delta = ((e_ideal as f64 * r).round() as usize).max(1);
    let num_min = e_ideal.saturating_sub(delta).max(1);
    let num_max = e_ideal + delta;
    bounded_grouping(profile, d, num_min, num_max, rng)
}

/// Uniform grouping (Occult / C2R baseline): every group exactly `⌊n/D⌋`
/// or `⌈n/D⌉` (exactly equal when `D | n`, as in every paper config).
pub fn uniform(profile: &LayerProfile, d: usize, rng: &mut Rng) -> Grouping {
    let e_total = profile.experts();
    assert!(d <= e_total, "more groups than experts");
    let lo = e_total / d;
    let hi = e_total.div_ceil(d);
    bounded_grouping(profile, d, lo.max(1), hi, rng)
}

/// Shared size-bounded refinement: spectral seed → trim oversized groups
/// (keep top-`num_max` by affinity, overflow to Ω) → re-assign Ω to the
/// highest-affinity group with space → top up undersized groups from the
/// oversized ones (weakest-affinity members move).
fn bounded_grouping(profile: &LayerProfile, d: usize, num_min: usize,
                    num_max: usize, rng: &mut Rng) -> Grouping {
    let e_total = profile.experts();
    let aff = &profile.affinity;
    assert!(d * num_min <= e_total && e_total <= d * num_max,
            "bounds infeasible: {d} groups of [{num_min},{num_max}] for \
             {e_total} experts");

    let assign = spectral_cluster(aff, d, rng, 4);
    let mut groups: Grouping = vec![Vec::new(); d];
    for (ex, &g) in assign.iter().enumerate() {
        groups[g].push(ex);
    }

    // Trim oversized groups: keep the top-num_max experts by intra-group
    // affinity, push the rest to Ω.
    let mut omega: Vec<usize> = Vec::new();
    for g in groups.iter_mut() {
        if g.len() > num_max {
            let snapshot = g.clone();
            g.sort_by(|&a, &b| {
                affinity_to_group(aff, b, &snapshot)
                    .partial_cmp(&affinity_to_group(aff, a, &snapshot))
                    .unwrap()
            });
            omega.extend(g.split_off(num_max));
        }
    }

    // Assign Ω members to the group with highest affinity among those
    // with spare capacity.
    for ex in omega {
        let dst = (0..d)
            .filter(|&g| groups[g].len() < num_max)
            .max_by(|&a, &b| {
                affinity_to_group(aff, ex, &groups[a])
                    .partial_cmp(&affinity_to_group(aff, ex, &groups[b]))
                    .unwrap()
            })
            .expect("capacity must exist (d*num_max >= experts)");
        groups[dst].push(ex);
    }

    // Top up undersized groups by pulling the weakest-affinity experts out
    // of groups that have slack above num_min.
    loop {
        let Some(needy) = (0..d)
            .filter(|&g| groups[g].len() < num_min)
            .min_by_key(|&g| groups[g].len())
        else {
            break;
        };
        // donor: the group with most slack; tie-break by weakest member
        let donor = (0..d)
            .filter(|&g| g != needy && groups[g].len() > num_min)
            .max_by_key(|&g| groups[g].len())
            .expect("donor must exist (d*num_min <= experts)");
        let (idx, _) = groups[donor]
            .iter()
            .enumerate()
            .map(|(i, &ex)| {
                // prefer the member that most prefers the needy group
                let leave = affinity_to_group(aff, ex, &groups[donor]);
                let join = affinity_to_group(aff, ex, &groups[needy]);
                (i, leave - join)
            })
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap();
        let ex = groups[donor].swap_remove(idx);
        groups[needy].push(ex);
    }
    groups
}

/// Sweep candidate non-uniformity ratios and return
/// `(r, U(r), S(r))` triples (Eqs. 1–2).
pub fn tradeoff_curve(profile: &LayerProfile, d: usize, candidates: &[f64],
                      rng: &mut Rng) -> Vec<(f64, f64, f64)> {
    candidates
        .iter()
        .map(|&r| {
            let g = controlled_nonuniform(profile, d, r, rng);
            (
                r,
                profile.affinity_utilization(&g),
                size_deviation(&g, profile.experts()),
            )
        })
        .collect()
}

/// Knee-point selection of the non-uniformity ratio (Appendix A.1): on
/// the normalized (S, U) curve, pick the candidate with maximum distance
/// above the chord from the first to the last point — the point where
/// affinity gain per unit of size disparity starts saturating.
pub fn select_r(profile: &LayerProfile, d: usize, candidates: &[f64],
                rng: &mut Rng) -> f64 {
    assert!(!candidates.is_empty());
    let curve = tradeoff_curve(profile, d, candidates, rng);
    if curve.len() == 1 {
        return curve[0].0;
    }
    let (umin, umax) = min_max(curve.iter().map(|c| c.1));
    let (smin, smax) = min_max(curve.iter().map(|c| c.2));
    let nu = |u: f64| {
        if umax > umin { (u - umin) / (umax - umin) } else { 0.0 }
    };
    let ns = |s: f64| {
        if smax > smin { (s - smin) / (smax - smin) } else { 0.0 }
    };
    // Chord from first to last candidate in normalized (S, U) space.
    let (x0, y0) = (ns(curve[0].2), nu(curve[0].1));
    let (x1, y1) =
        (ns(curve[curve.len() - 1].2), nu(curve[curve.len() - 1].1));
    let mut best = (curve[0].0, f64::NEG_INFINITY);
    for &(r, u, s) in &curve {
        let (x, y) = (ns(s), nu(u));
        // signed distance above the chord
        let d = if (x1 - x0).abs() < 1e-12 {
            y - y0
        } else {
            y - (y0 + (y1 - y0) * (x - x0) / (x1 - x0))
        };
        if d > best.1 {
            best = (r, d);
        }
    }
    best.0
}

fn min_max(vals: impl Iterator<Item = f64>) -> (f64, f64) {
    vals.fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), v| {
        (lo.min(v), hi.max(v))
    })
}

/// Hierarchical grouping for one layer (paper §4.1 "Hierarchical Grouping
/// for Distributed Expert Placement"): fully non-uniform across nodes,
/// controlled non-uniform across GPUs within each node. Returns one group
/// per GPU, indexed by global GPU id.
pub fn hierarchical(profile: &LayerProfile, topo: &Topology, r: f64,
                    rng: &mut Rng) -> Grouping {
    let g_per_node = topo.gpus_per_node;
    // Level 1: node groups (each must be splittable into g_per_node
    // non-empty GPU groups).
    let node_groups = if topo.nodes == 1 {
        vec![(0..profile.experts()).collect::<Vec<usize>>()]
    } else {
        fully_nonuniform(profile, topo.nodes, g_per_node, rng)
    };

    // Level 2: split each node group into per-GPU groups with controlled
    // non-uniformity (local expert ids remapped through the node group).
    let mut out: Grouping = vec![Vec::new(); topo.num_gpus()];
    for (node, members) in node_groups.iter().enumerate() {
        let sub = sub_profile(profile, members);
        let local = controlled_nonuniform(&sub, g_per_node, r, rng);
        for (gi, lg) in local.into_iter().enumerate() {
            let gpu = node * g_per_node + gi;
            out[gpu] = lg.into_iter().map(|li| members[li]).collect();
        }
    }
    out
}

/// Restrict a layer profile to an expert subset (ids renumbered 0..len).
pub fn sub_profile(profile: &LayerProfile, members: &[usize])
                   -> LayerProfile {
    let m = members.len();
    let aff = Matrix::from_fn(m, m, |i, j| {
        profile.affinity[(members[i], members[j])]
    });
    LayerProfile {
        affinity: aff,
        load: members.iter().map(|&e| profile.load[e]).collect(),
        tokens: profile.tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::ModelProfile;
    use crate::testutil::{check, prop_assert};
    use crate::trace::{Profile, TraceGen};

    fn profile(experts: usize, top_k: usize, seed: u64) -> LayerProfile {
        let t = TraceGen {
            experts,
            top_k,
            layers: 1,
            profile: Profile::Text,
            seed,
        }
        .generate(512);
        ModelProfile::from_trace(&t).layers.remove(0)
    }

    #[test]
    fn uniform_sizes_exact() {
        let p = profile(64, 8, 1);
        let g = uniform(&p, 4, &mut Rng::new(1));
        assert!(is_partition(&g, 64));
        assert!(g.iter().all(|gr| gr.len() == 16));
    }

    #[test]
    fn controlled_sizes_within_bounds() {
        let p = profile(64, 8, 2);
        for r in [0.1, 0.15, 0.3, 0.5] {
            let g = controlled_nonuniform(&p, 4, r, &mut Rng::new(2));
            assert!(is_partition(&g, 64));
            let e = 16usize;
            let delta = ((e as f64 * r).round() as usize).max(1);
            for gr in &g {
                assert!(
                    gr.len() >= e - delta && gr.len() <= e + delta,
                    "r={r}: size {} outside [{},{}]",
                    gr.len(),
                    e - delta,
                    e + delta
                );
            }
        }
    }

    #[test]
    fn nonuniform_captures_more_affinity_than_uniform() {
        let p = profile(64, 8, 3);
        let mut rng = Rng::new(3);
        let gu = uniform(&p, 4, &mut rng);
        let gf = fully_nonuniform(&p, 4, 1, &mut rng);
        let gc = controlled_nonuniform(&p, 4, 0.3, &mut rng);
        let uu = p.affinity_utilization(&gu);
        let uf = p.affinity_utilization(&gf);
        let uc = p.affinity_utilization(&gc);
        // Fig. 1a ordering: relaxing the constraint exploits affinity
        assert!(uf >= uc - 0.02, "fully {uf} vs controlled {uc}");
        assert!(uc >= uu - 0.02, "controlled {uc} vs uniform {uu}");
        assert!(uf > uu, "fully {uf} must beat uniform {uu}");
    }

    #[test]
    fn fully_nonuniform_respects_min_size() {
        let p = profile(32, 4, 4);
        let g = fully_nonuniform(&p, 4, 2, &mut Rng::new(4));
        assert!(is_partition(&g, 32));
        assert!(g.iter().all(|gr| gr.len() >= 2));
    }

    #[test]
    fn hierarchical_partitions_across_gpus() {
        let p = profile(64, 8, 5);
        let topo = Topology::two_by_two();
        let g = hierarchical(&p, &topo, 0.15, &mut Rng::new(5));
        assert_eq!(g.len(), 4);
        assert!(is_partition(&g, 64));
        assert!(g.iter().all(|gr| !gr.is_empty()));
    }

    #[test]
    fn hierarchical_concentrates_affinity_within_nodes() {
        let p = profile(64, 8, 6);
        let topo = Topology::two_by_two();
        let g = hierarchical(&p, &topo, 0.15, &mut Rng::new(6));
        // node-level affinity utilization (union of a node's gpu groups)
        let node0: Vec<usize> =
            g[0].iter().chain(&g[1]).copied().collect();
        let node1: Vec<usize> =
            g[2].iter().chain(&g[3]).copied().collect();
        let u_nodes =
            p.affinity_utilization(&vec![node0, node1]);
        let u_gpus = p.affinity_utilization(&g);
        assert!(u_nodes >= u_gpus, "node-level captures ≥ gpu-level");
        // and both should beat random chance by a margin
        assert!(u_nodes > 0.5, "u_nodes={u_nodes}");
    }

    #[test]
    fn select_r_is_in_candidates_and_interior_on_curved_tradeoff() {
        let p = profile(64, 8, 7);
        let cands = [0.0, 0.1, 0.15, 0.25, 0.4, 0.6, 1.0];
        let r = select_r(&p, 4, &cands, &mut Rng::new(7));
        assert!(cands.contains(&r));
    }

    #[test]
    fn tradeoff_curve_monotone_in_s_bound() {
        let p = profile(64, 8, 8);
        let curve =
            tradeoff_curve(&p, 4, &[0.05, 0.5], &mut Rng::new(8));
        // allowing more deviation can only increase the S bound in effect;
        // empirical S should not shrink dramatically
        assert_eq!(curve.len(), 2);
        assert!(curve[1].2 >= curve[0].2 - 1e-9,
                "S(0.5) {} < S(0.05) {}", curve[1].2, curve[0].2);
    }

    #[test]
    fn group_score_matches_alg1() {
        let mut aff = Matrix::zeros(3, 3);
        aff[(0, 1)] = 2.0;
        aff[(1, 0)] = 2.0;
        aff[(1, 2)] = 5.0;
        aff[(2, 1)] = 5.0;
        assert_eq!(group_score(&aff, &[0, 1, 2]), 7.0);
        assert_eq!(affinity_to_group(&aff, 0, &[1, 2]), 2.0);
    }

    #[test]
    fn property_partition_invariant_across_configs() {
        check(25, |rng| {
            let experts = [16, 32, 64][rng.index(3)];
            let d = [2, 4, 8][rng.index(3)];
            let r = rng.f64();
            let p = profile(experts, 4, rng.next_u64());
            let g = controlled_nonuniform(&p, d, r, rng);
            prop_assert(is_partition(&g, experts), "not a partition")?;
            let e = experts / d;
            let delta = ((e as f64 * r).round() as usize).max(1);
            for gr in &g {
                prop_assert(
                    gr.len() >= e.saturating_sub(delta).max(1)
                        && gr.len() <= e + delta,
                    format!("size {} outside bounds", gr.len()),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn sub_profile_renumbers() {
        let p = profile(8, 2, 9);
        let sub = sub_profile(&p, &[3, 5, 7]);
        assert_eq!(sub.experts(), 3);
        assert_eq!(sub.load[0], p.load[3]);
        assert_eq!(sub.affinity[(0, 1)], p.affinity[(3, 5)]);
    }
}
