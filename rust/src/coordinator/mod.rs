//! L3 coordinator — the paper's orchestration layer (Fig. 2): one object
//! that owns the GRACE-MoE pipeline end to end and is the single way the
//! rest of the system assembles it.
//!
//! The pipeline has a strict offline → online shape:
//!
//! ```text
//! profiling trace ──▶ affinity/load profile ──▶ hierarchical grouping
//!        (§3)                 (Fig. 2a)                 (§4.1)
//!                                                          │
//!                     polling weights ◀── ρ-driven replication (§4.2)
//!                        (Eq. 4)                           │
//!                           │                              ▼
//!                           └──▶ per-layer Placement ──▶ Dispatcher (§4.3)
//! ```
//!
//! Two surfaces, two types:
//!
//! * [`Coordinator`] — the full pipeline. **offline**,
//!   [`Coordinator::place`] turns any gate trace (synthetic via
//!   [`Coordinator::profile_synthetic`], or real via
//!   [`crate::engine::real::profile_real`]) into a [`Placement`];
//!   **online**, [`Coordinator::dispatcher`] builds the batched
//!   [`Dispatcher`] that executes the configured [`RoutingPolicy`] over
//!   that placement. Which grouping strategy, replication mode, and
//!   routing policy apply is fixed once at construction
//!   ([`Coordinator::new`], [`Coordinator::for_system`],
//!   [`Coordinator::grace`]), so an engine cannot accidentally mix, say,
//!   GRACE grouping with baseline routing.
//! * [`OnlineCoordinator`] — the routing-only surface for serving against
//!   a *prebuilt* placement. It has no offline methods at all: a serving
//!   component constructed from a topology and a policy can no longer
//!   call `place()` with a default seed and silently produce a placement
//!   unrelated to the one it serves (the old `Coordinator::serving`
//!   footgun). Every full [`Coordinator`] converts into its online half
//!   via [`Coordinator::online`] / `From`. With a
//!   [`crate::replan::Replanner`] attached
//!   ([`OnlineCoordinator::with_replanner`]) the online half also closes
//!   the measured-load → replication feedback loop:
//!   [`OnlineCoordinator::observe`] per dispatch round,
//!   [`OnlineCoordinator::epoch_tick`] between rounds, and the returned
//!   [`crate::replan::ReplanDelta`] hot-swaps the placement the engines
//!   serve.
//!
//! Determinism: every offline decision derives from the construction
//! seed. The grouping RNG is decorrelated from trace generation with a
//! fixed tag so that profiling and clustering never share a stream.

use crate::baselines::{GroupingStrategy, SystemSpec};
use crate::cluster::Topology;
use crate::config::ModelSpec;
use crate::placement::{Placement, ReplicationMode};
use crate::profile::ModelProfile;
use crate::replan::{ReplanDelta, Replanner};
use crate::routing::{DispatchPlan, Dispatcher, RoutePolicy,
                     RoutingPolicy};
use crate::stats::Rng;
use crate::trace::{GateTrace, Profile, TraceGen};

/// Seed tag decorrelating the grouping/clustering RNG stream from the
/// profiling-trace stream (both are derived from the same run seed).
const GROUPING_SEED_TAG: u64 = 0x9A0C;

/// The online half of the pipeline: topology + routing policy + (when
/// enabled) the epoch re-planner — and nothing offline. This is the only
/// coordination surface serving components hold, so the offline methods
/// are unreachable from them by construction.
#[derive(Clone, Debug)]
pub struct OnlineCoordinator {
    topo: Topology,
    routing: RoutingPolicy,
    replan: Option<Replanner>,
}

impl OnlineCoordinator {
    /// Online coordinator for serving a prebuilt placement under
    /// `routing` on `topo` (re-planning off; see
    /// [`OnlineCoordinator::with_replanner`]).
    pub fn new(topo: Topology, routing: RoutingPolicy) -> OnlineCoordinator {
        OnlineCoordinator { topo, routing, replan: None }
    }

    /// Attach an epoch re-planner: observed dispatch rounds feed its
    /// load estimator and [`OnlineCoordinator::epoch_tick`] becomes
    /// live.
    pub fn with_replanner(mut self, replanner: Replanner)
                          -> OnlineCoordinator {
        self.replan = Some(replanner);
        self
    }

    /// The cluster topology serving routes against.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// The configured routing policy.
    pub fn routing(&self) -> RoutingPolicy {
        self.routing
    }

    /// The attached re-planner, if online re-planning is enabled.
    pub fn replanner(&self) -> Option<&Replanner> {
        self.replan.as_ref()
    }

    /// Mutable access to the attached re-planner (feed it observed
    /// [`DispatchPlan`]s via [`Replanner::observe`]).
    pub fn replanner_mut(&mut self) -> Option<&mut Replanner> {
        self.replan.as_mut()
    }

    /// Feed one finished dispatch round to the re-planner (no-op when
    /// re-planning is off). `lp` must be the layer placement the plan
    /// was routed with.
    pub fn observe(&mut self, layer: usize,
                   lp: &crate::placement::LayerPlacement,
                   plan: &DispatchPlan) {
        if let Some(r) = self.replan.as_mut() {
            r.observe(layer, lp, plan);
        }
    }

    /// Evaluate an epoch boundary against the active placement: returns
    /// the (possibly empty) [`ReplanDelta`] the caller should apply via
    /// [`crate::replan::apply_delta`]. Always empty when re-planning is
    /// off or between epoch boundaries. Call it only between dispatch
    /// rounds — never mid-round — so a plan is always executed against
    /// the placement it was routed with.
    pub fn epoch_tick(&mut self, active: &Placement) -> ReplanDelta {
        self.replan
            .as_mut()
            .map(|r| r.epoch_tick(active))
            .unwrap_or_default()
    }

    /// Instantiate the policy object executing the configured routing
    /// policy (stateful policies start fresh).
    pub fn policy(&self) -> Box<dyn RoutePolicy> {
        self.routing.build()
    }

    /// Batched dispatcher over this coordinator's topology and policy.
    /// `token_bytes` is the per-copy payload the plan's byte accounting
    /// uses (one hidden activation vector). Build one dispatcher per
    /// serving run: stateful policies ([`RoutingPolicy::LoadAware`])
    /// carry their online load estimates across its dispatch rounds.
    pub fn dispatcher(&self, token_bytes: f64) -> Dispatcher {
        Dispatcher::new(self.topo.clone(), self.policy(), token_bytes)
    }
}

impl From<&Coordinator> for OnlineCoordinator {
    fn from(c: &Coordinator) -> OnlineCoordinator {
        c.online()
    }
}

impl From<Coordinator> for OnlineCoordinator {
    fn from(c: Coordinator) -> OnlineCoordinator {
        OnlineCoordinator { topo: c.topo, routing: c.routing, replan: None }
    }
}

/// The L3 orchestration layer: offline placement construction + online
/// dispatcher construction under one immutable policy configuration.
#[derive(Clone, Debug)]
pub struct Coordinator {
    grouping: GroupingStrategy,
    replication: ReplicationMode,
    routing: RoutingPolicy,
    topo: Topology,
    seed: u64,
}

impl Coordinator {
    /// Coordinator with an explicit policy triple.
    pub fn new(grouping: GroupingStrategy, replication: ReplicationMode,
               routing: RoutingPolicy, topo: Topology, seed: u64)
               -> Coordinator {
        Coordinator { grouping, replication, routing, topo, seed }
    }

    /// Coordinator implementing a catalog system's placement/routing
    /// strategy (the engine-side knobs of the [`SystemSpec`] — collective
    /// choice, efficiency factors, pruning — stay with the engine).
    pub fn for_system(sys: &SystemSpec, topo: &Topology, seed: u64)
                      -> Coordinator {
        Coordinator::new(sys.grouping, sys.replication, sys.routing,
                         topo.clone(), seed)
    }

    /// The paper's shipped configuration: hierarchical non-uniform
    /// grouping at ratio `r`, ρ-driven dynamic replication, TAR routing.
    pub fn grace(topo: &Topology, r: f64, seed: u64) -> Coordinator {
        Coordinator::new(
            GroupingStrategy::Hierarchical { r },
            ReplicationMode::Dynamic,
            RoutingPolicy::Tar,
            topo.clone(),
            seed,
        )
    }

    /// The cluster topology the pipeline places and routes against.
    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    /// The configured grouping strategy (§4.1).
    pub fn grouping(&self) -> GroupingStrategy {
        self.grouping
    }

    /// The configured replication mode (§4.2).
    pub fn replication(&self) -> ReplicationMode {
        self.replication
    }

    /// The configured routing policy (§4.3).
    pub fn routing(&self) -> RoutingPolicy {
        self.routing
    }

    /// The construction seed every offline decision derives from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    // --- offline phase ---------------------------------------------------

    /// Synthetic profiling trace for paper-scale simulation (the planted
    /// trace model of [`crate::trace`]); execute mode profiles the real
    /// gate instead and feeds the result to [`Coordinator::place`].
    pub fn profile_synthetic(&self, model: &ModelSpec, profile: Profile,
                             tokens: usize) -> GateTrace {
        TraceGen {
            experts: model.experts,
            top_k: model.top_k,
            layers: model.moe_layers,
            profile,
            seed: self.seed,
        }
        .generate(tokens)
    }

    /// Offline phase from a gate trace: affinity/load statistics →
    /// grouping → replication → Eq.-4 polling weights.
    pub fn place(&self, trace: &GateTrace) -> Placement {
        self.place_profile(&ModelProfile::from_trace(trace))
    }

    /// Offline phase from precomputed profiling statistics.
    pub fn place_profile(&self, profile: &ModelProfile) -> Placement {
        let mut rng = Rng::new(self.seed ^ GROUPING_SEED_TAG);
        Placement::build(profile, self.replication, |lp| {
            self.grouping.build(lp, &self.topo, &mut rng)
        })
    }

    /// Whole offline phase for simulate mode: synthetic profiling followed
    /// by placement construction.
    pub fn offline_synthetic(&self, model: &ModelSpec, profile: Profile,
                             tokens: usize) -> Placement {
        self.place(&self.profile_synthetic(model, profile, tokens))
    }

    // --- online phase ----------------------------------------------------

    /// The routing-only half of this coordinator (what serving components
    /// hold — see [`OnlineCoordinator`]).
    pub fn online(&self) -> OnlineCoordinator {
        OnlineCoordinator::new(self.topo.clone(), self.routing)
    }

    /// Instantiate this coordinator's routing policy object.
    pub fn policy(&self) -> Box<dyn RoutePolicy> {
        self.routing.build()
    }

    /// Batched dispatcher executing this coordinator's routing policy
    /// (normally over a placement built by [`Coordinator::place`]); see
    /// [`OnlineCoordinator::dispatcher`].
    pub fn dispatcher(&self, token_bytes: f64) -> Dispatcher {
        self.online().dispatcher(token_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::is_partition;
    use crate::routing::{Assignment, RouteCtx};
    use crate::trace::Profile;

    fn coord(seed: u64) -> Coordinator {
        Coordinator::grace(&Topology::two_by_two(), 0.15, seed)
    }

    fn small_model() -> ModelSpec {
        ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() }
    }

    #[test]
    fn offline_is_deterministic_per_seed() {
        let model = small_model();
        let a = coord(7).offline_synthetic(&model, Profile::Text, 512);
        let b = coord(7).offline_synthetic(&model, Profile::Text, 512);
        let c = coord(8).offline_synthetic(&model, Profile::Text, 512);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.groups, lb.groups);
            assert_eq!(la.instances, lb.instances);
            assert_eq!(la.polling, lb.polling);
        }
        // A different seed profiles a different trace sample; the load
        // statistics (and hence the polling weights) must move somewhere.
        assert!(
            a.layers
                .iter()
                .zip(&c.layers)
                .any(|(x, y)| x.polling != y.polling),
            "different seeds must produce different load statistics"
        );
    }

    #[test]
    fn placement_invariants_hold() {
        let model = small_model();
        let p = coord(11).offline_synthetic(&model, Profile::Math, 512);
        assert_eq!(p.experts, model.experts);
        assert_eq!(p.num_gpus, 4);
        for lp in &p.layers {
            assert!(is_partition(&lp.groups, p.experts));
            for (e, inst) in lp.instances.iter().enumerate() {
                assert_eq!(inst[0], lp.primary[e], "primary first");
            }
            let s: f64 = lp.polling.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "polling normalized");
        }
    }

    #[test]
    fn for_system_copies_the_policy_triple() {
        let sys = SystemSpec::occult();
        let c = Coordinator::for_system(&sys, &Topology::two_by_two(), 1);
        assert_eq!(c.grouping(), sys.grouping);
        assert_eq!(c.replication(), sys.replication);
        assert_eq!(c.routing(), sys.routing);
    }

    #[test]
    fn online_half_copies_topology_and_policy() {
        let full = coord(9);
        let online = full.online();
        assert_eq!(online.routing(), full.routing());
        assert_eq!(online.topo(), full.topo());
        let via_from: OnlineCoordinator = (&full).into();
        assert_eq!(via_from.routing(), full.routing());
        assert_eq!(online.policy().name(), full.routing().name());
    }

    #[test]
    fn policy_honours_the_configured_routing() {
        // A TAR coordinator must keep replicated experts on the token's
        // own GPU; a Primary coordinator must ignore replicas entirely.
        let model = small_model();
        let place = coord(3).offline_synthetic(&model, Profile::Math, 512);
        let lp = place
            .layers
            .iter()
            .find(|lp| lp.instances.iter().any(|i| i.len() > 1))
            .expect("skewed profile must replicate something");
        let (expert, instances) = lp
            .instances
            .iter()
            .enumerate()
            .find(|(_, i)| i.len() > 1)
            .unwrap();

        let tar = coord(3);
        let ctx = RouteCtx { placement: lp, topo: tar.topo(), layer: 0 };
        let mut rng = Rng::new(1);
        let mut policy = tar.policy();
        for &src in instances {
            assert_eq!(policy.select(&ctx, src, expert, &mut rng), src);
        }

        let primary = Coordinator::new(
            GroupingStrategy::Hierarchical { r: 0.15 },
            ReplicationMode::Dynamic,
            RoutingPolicy::Primary,
            Topology::two_by_two(),
            3,
        );
        let mut policy = primary.policy();
        for src in 0..4 {
            assert_eq!(
                policy.select(&ctx, src, expert, &mut rng),
                lp.primary[expert]
            );
        }
    }

    #[test]
    fn dispatcher_executes_the_configured_policy() {
        let model = small_model();
        let c = coord(5);
        let place = c.offline_synthetic(&model, Profile::Math, 512);
        let lp = &place.layers[0];
        let mut d = c.dispatcher(model.token_bytes());
        assert_eq!(d.policy_name(), "tar");
        assert_eq!(d.token_bytes(), model.token_bytes());
        let batch: Vec<Assignment> = (0..64)
            .map(|t| Assignment { token: t, expert: t % 64, src: t % 4 })
            .collect();
        let mut rng = Rng::new(2);
        let plan = d.dispatch(lp, 0, &batch, &mut rng);
        assert_eq!(plan.num_assignments(), 64);
        for r in plan.assignments() {
            assert!(lp.instances[r.expert].contains(&r.dst));
        }
    }

    #[test]
    fn place_profile_and_place_agree() {
        let model = small_model();
        let c = coord(5);
        let trace = c.profile_synthetic(&model, Profile::Code, 256);
        let via_trace = c.place(&trace);
        let via_profile =
            c.place_profile(&ModelProfile::from_trace(&trace));
        for (a, b) in via_trace.layers.iter().zip(&via_profile.layers) {
            assert_eq!(a.groups, b.groups);
            assert_eq!(a.replication, b.replication);
        }
    }
}
