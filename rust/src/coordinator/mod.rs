//! L3 coordinator — the paper's orchestration layer (Fig. 2): one object
//! that owns the GRACE-MoE pipeline end to end and is the single way the
//! rest of the system assembles it.
//!
//! The pipeline has a strict offline → online shape:
//!
//! ```text
//! profiling trace ──▶ affinity/load profile ──▶ hierarchical grouping
//!        (§3)                 (Fig. 2a)                 (§4.1)
//!                                                          │
//!                     polling weights ◀── ρ-driven replication (§4.2)
//!                        (Eq. 4)                           │
//!                           │                              ▼
//!                           └────────▶ per-layer Placement ──▶ Router (§4.3)
//! ```
//!
//! Before this module existed, `main.rs`, the simulate engine, the real
//! engine, and the server each hand-wired that chain (trace generation,
//! RNG seeding, `Placement::build`, `Router::new`) with their own copies
//! of the glue. The [`Coordinator`] centralizes it:
//!
//! * **offline** — [`Coordinator::place`] turns any gate trace (synthetic
//!   via [`Coordinator::profile_synthetic`], or real via
//!   [`crate::engine::real::profile_real`]) into a [`Placement`],
//! * **online** — [`Coordinator::router`] builds the per-layer [`Router`]
//!   that executes the configured [`RoutingPolicy`] over that placement,
//! * **policy** — which grouping strategy, replication mode, and routing
//!   policy apply is fixed once at construction ([`Coordinator::new`],
//!   [`Coordinator::for_system`], [`Coordinator::grace`]), so an engine
//!   cannot accidentally mix, say, GRACE grouping with baseline routing.
//!
//! Determinism: every decision derives from the construction seed. The
//! grouping RNG is decorrelated from trace generation with a fixed tag so
//! that profiling and clustering never share a stream.

use crate::baselines::{GroupingStrategy, SystemSpec};
use crate::cluster::Topology;
use crate::config::ModelSpec;
use crate::placement::{LayerPlacement, Placement, ReplicationMode};
use crate::profile::ModelProfile;
use crate::routing::{Router, RoutingPolicy};
use crate::stats::Rng;
use crate::trace::{GateTrace, Profile, TraceGen};

/// Seed tag decorrelating the grouping/clustering RNG stream from the
/// profiling-trace stream (both are derived from the same run seed).
const GROUPING_SEED_TAG: u64 = 0x9A0C;

/// The L3 orchestration layer: offline placement construction + online
/// router construction under one immutable policy configuration.
#[derive(Clone, Debug)]
pub struct Coordinator {
    grouping: GroupingStrategy,
    replication: ReplicationMode,
    routing: RoutingPolicy,
    topo: Topology,
    seed: u64,
}

impl Coordinator {
    /// Coordinator with an explicit policy triple.
    pub fn new(grouping: GroupingStrategy, replication: ReplicationMode,
               routing: RoutingPolicy, topo: Topology, seed: u64)
               -> Coordinator {
        Coordinator { grouping, replication, routing, topo, seed }
    }

    /// Coordinator implementing a catalog system's placement/routing
    /// strategy (the engine-side knobs of the [`SystemSpec`] — collective
    /// choice, efficiency factors, pruning — stay with the engine).
    pub fn for_system(sys: &SystemSpec, topo: &Topology, seed: u64)
                      -> Coordinator {
        Coordinator::new(sys.grouping, sys.replication, sys.routing,
                         topo.clone(), seed)
    }

    /// The paper's shipped configuration: hierarchical non-uniform
    /// grouping at ratio `r`, ρ-driven dynamic replication, TAR routing.
    pub fn grace(topo: &Topology, r: f64, seed: u64) -> Coordinator {
        Coordinator::new(
            GroupingStrategy::Hierarchical { r },
            ReplicationMode::Dynamic,
            RoutingPolicy::Tar,
            topo.clone(),
            seed,
        )
    }

    /// Routing-side coordinator for serving against a prebuilt placement.
    /// Offline knobs inherit the paper's GRACE defaults from
    /// [`Coordinator::grace`] with seed 0 — do not call the offline
    /// methods on a serving coordinator; build placements with the
    /// coordinator that owns the run's actual seed and strategy instead.
    pub fn serving(topo: Topology, policy: RoutingPolicy) -> Coordinator {
        Coordinator { routing: policy, ..Coordinator::grace(&topo, 0.15, 0) }
    }

    pub fn topo(&self) -> &Topology {
        &self.topo
    }

    pub fn grouping(&self) -> GroupingStrategy {
        self.grouping
    }

    pub fn replication(&self) -> ReplicationMode {
        self.replication
    }

    pub fn routing(&self) -> RoutingPolicy {
        self.routing
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    // --- offline phase ---------------------------------------------------

    /// Synthetic profiling trace for paper-scale simulation (the planted
    /// trace model of [`crate::trace`]); execute mode profiles the real
    /// gate instead and feeds the result to [`Coordinator::place`].
    pub fn profile_synthetic(&self, model: &ModelSpec, profile: Profile,
                             tokens: usize) -> GateTrace {
        TraceGen {
            experts: model.experts,
            top_k: model.top_k,
            layers: model.moe_layers,
            profile,
            seed: self.seed,
        }
        .generate(tokens)
    }

    /// Offline phase from a gate trace: affinity/load statistics →
    /// grouping → replication → Eq.-4 polling weights.
    pub fn place(&self, trace: &GateTrace) -> Placement {
        self.place_profile(&ModelProfile::from_trace(trace))
    }

    /// Offline phase from precomputed profiling statistics.
    pub fn place_profile(&self, profile: &ModelProfile) -> Placement {
        let mut rng = Rng::new(self.seed ^ GROUPING_SEED_TAG);
        Placement::build(profile, self.replication, |lp| {
            self.grouping.build(lp, &self.topo, &mut rng)
        })
    }

    /// Whole offline phase for simulate mode: synthetic profiling followed
    /// by placement construction.
    pub fn offline_synthetic(&self, model: &ModelSpec, profile: Profile,
                             tokens: usize) -> Placement {
        self.place(&self.profile_synthetic(model, profile, tokens))
    }

    // --- online phase ----------------------------------------------------

    /// Per-layer router executing this coordinator's routing policy over a
    /// layer placement (normally one built by [`Coordinator::place`]).
    pub fn router<'a>(&'a self, layer: &'a LayerPlacement) -> Router<'a> {
        Router::new(layer, &self.topo, self.routing)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::is_partition;
    use crate::trace::Profile;

    fn coord(seed: u64) -> Coordinator {
        Coordinator::grace(&Topology::two_by_two(), 0.15, seed)
    }

    fn small_model() -> ModelSpec {
        ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() }
    }

    #[test]
    fn offline_is_deterministic_per_seed() {
        let model = small_model();
        let a = coord(7).offline_synthetic(&model, Profile::Text, 512);
        let b = coord(7).offline_synthetic(&model, Profile::Text, 512);
        let c = coord(8).offline_synthetic(&model, Profile::Text, 512);
        for (la, lb) in a.layers.iter().zip(&b.layers) {
            assert_eq!(la.groups, lb.groups);
            assert_eq!(la.instances, lb.instances);
            assert_eq!(la.polling, lb.polling);
        }
        // A different seed profiles a different trace sample; the load
        // statistics (and hence the polling weights) must move somewhere.
        assert!(
            a.layers
                .iter()
                .zip(&c.layers)
                .any(|(x, y)| x.polling != y.polling),
            "different seeds must produce different load statistics"
        );
    }

    #[test]
    fn placement_invariants_hold() {
        let model = small_model();
        let p = coord(11).offline_synthetic(&model, Profile::Math, 512);
        assert_eq!(p.experts, model.experts);
        assert_eq!(p.num_gpus, 4);
        for lp in &p.layers {
            assert!(is_partition(&lp.groups, p.experts));
            for (e, inst) in lp.instances.iter().enumerate() {
                assert_eq!(inst[0], lp.primary[e], "primary first");
            }
            let s: f64 = lp.polling.iter().sum();
            assert!((s - 1.0).abs() < 1e-9, "polling normalized");
        }
    }

    #[test]
    fn for_system_copies_the_policy_triple() {
        let sys = SystemSpec::occult();
        let c = Coordinator::for_system(&sys, &Topology::two_by_two(), 1);
        assert_eq!(c.grouping(), sys.grouping);
        assert_eq!(c.replication(), sys.replication);
        assert_eq!(c.routing(), sys.routing);
    }

    #[test]
    fn router_honours_the_configured_policy() {
        // A TAR coordinator must keep replicated experts on the token's
        // own GPU; a Primary coordinator must ignore replicas entirely.
        let model = small_model();
        let place = coord(3).offline_synthetic(&model, Profile::Math, 512);
        let lp = place
            .layers
            .iter()
            .find(|lp| lp.instances.iter().any(|i| i.len() > 1))
            .expect("skewed profile must replicate something");
        let (expert, instances) = lp
            .instances
            .iter()
            .enumerate()
            .find(|(_, i)| i.len() > 1)
            .unwrap();

        let tar = coord(3);
        let mut rng = Rng::new(1);
        for &src in instances {
            assert_eq!(tar.router(lp).route(src, expert, &mut rng), src);
        }

        let primary = Coordinator::new(
            GroupingStrategy::Hierarchical { r: 0.15 },
            ReplicationMode::Dynamic,
            RoutingPolicy::Primary,
            Topology::two_by_two(),
            3,
        );
        for src in 0..4 {
            assert_eq!(
                primary.router(lp).route(src, expert, &mut rng),
                lp.primary[expert]
            );
        }
    }

    #[test]
    fn place_profile_and_place_agree() {
        let model = small_model();
        let c = coord(5);
        let trace = c.profile_synthetic(&model, Profile::Code, 256);
        let via_trace = c.place(&trace);
        let via_profile =
            c.place_profile(&ModelProfile::from_trace(&trace));
        for (a, b) in via_trace.layers.iter().zip(&via_profile.layers) {
            assert_eq!(a.groups, b.groups);
            assert_eq!(a.replication, b.replication);
        }
    }
}
