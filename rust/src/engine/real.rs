//! Execute-mode engine: real numerics through PJRT on the tiny AOT model.
//!
//! The same placement/routing decisions as the simulator, but every
//! compute step is an actual XLA execution of the artifacts built by
//! `make artifacts`:
//!
//! * profiling runs the *real* gate over embedded tokens (the offline
//!   phase of Fig. 2a on genuine routing behaviour),
//! * the distributed MoE layer performs gate → dispatch (rust) →
//!   per-"GPU" Pallas grouped FFN → weighted combine (rust) → residual,
//! * losslessness is validated against the single-device
//!   `moe_layer_full` oracle artifact.
//!
//! "GPUs" here are logical ranks of the simulated cluster: each rank's
//! grouped-FFN call is a separate PJRT execution over exactly the token
//! copies routing sent to that rank, so numerics follow the distributed
//! dataflow faithfully.

use crate::baselines::GroupingStrategy;
use crate::cluster::{GpuId, Topology};
use crate::config::PrefetchConfig;
use crate::coordinator::{Coordinator, OnlineCoordinator};
use crate::exec::{JobHandle, ThreadPool};
use crate::metrics::PrefetchStats;
use crate::placement::Placement;
use crate::replan::ReplanDelta;
use crate::routing::{Assignment, CrossLayerPredictor, DispatchPlan,
                     Dispatcher, RoutingPolicy};
use crate::runtime::manifest::{Manifest, TinyConfig};
use crate::runtime::pjrt::{lit_f32, lit_i32, lit_scalar_i32, to_f32,
                           to_i32, PjrtEngine};
use crate::runtime::WeightStore;
use crate::server::even_src;
use crate::stats::Rng;
use crate::trace::{GateTrace, LayerTrace};
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Per-layer weight literals, built once at load.
struct LayerLits {
    wqkv: xla::Literal,
    wo: xla::Literal,
    wg: xla::Literal,
    w1: xla::Literal,
    w3: xla::Literal,
    w2: xla::Literal,
}

/// Counters of the execute-mode expert weight tier (see
/// [`RealModel::cache_stats`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Actual [`WeightStore`] fetches (literal builds). Staging an
    /// already-resident expert never increments this — re-stages are
    /// idempotent no-ops, so migration weight copies are paid once.
    pub cold_loads: usize,
    /// Lookups satisfied by a resident hot-tier entry.
    pub hits: usize,
    /// LRU evictions forced by the weight budget.
    pub evictions: usize,
}

/// One resident expert in the hot tier.
struct TierEntry {
    lits: Arc<(xla::Literal, xla::Literal, xla::Literal)>,
    /// Logical timestamp of the most recent lookup (LRU recency).
    last_use: u64,
}

/// The capacity-bounded hot tier behind [`RealModel`]'s expert weight
/// lookups. `budget = None` is the historical unbounded cache; with a
/// budget, least-recently-used entries spill back to the cold tier
/// (the [`WeightStore`]) and reload transparently on next use.
struct WeightTier {
    entries: HashMap<(usize, usize), TierEntry>,
    budget: Option<usize>,
    clock: u64,
    stats: CacheStats,
}

/// A tiny model variant loaded for execution.
pub struct RealModel {
    /// The PJRT engine executing this model's artifacts.
    pub eng: Arc<PjrtEngine>,
    /// Variant name in the artifact manifest (e.g. `olmoe_tiny`).
    pub variant: String,
    /// The variant's architecture.
    pub cfg: TinyConfig,
    emb: xla::Literal,
    layers: Vec<LayerLits>,
    ws: WeightStore,
    expert_cache: Mutex<WeightTier>,
}

/// Which executable computes a rank's expert FFNs (§Perf).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FfnMode {
    /// The L1 Pallas grouped kernel (the TPU-shaped hot path; slower
    /// under CPU interpret because VMEM streaming degrades to memcpy).
    GroupedPallas,
    /// One dense-XLA `expert_ffn` call per active expert (the CPU fast
    /// path; identical numerics).
    PerExpert,
}

impl RealModel {
    /// Load a tiny variant's weights + artifacts and spin up its PJRT
    /// engine (`artifacts_dir` is what `make artifacts` wrote).
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>, variant: &str)
                -> anyhow::Result<RealModel> {
        let manifest = Manifest::load(artifacts_dir)?;
        let ws = WeightStore::load(&manifest, variant)?;
        let cfg = ws.config().clone();
        let eng = Arc::new(PjrtEngine::new(manifest)?);

        let (emb, eshape) = ws.tensor("emb")?;
        let emb = lit_f32(emb, eshape)?;
        let mut layers = Vec::with_capacity(cfg.layers);
        for l in 0..cfg.layers {
            let lit = |name: &str| -> anyhow::Result<xla::Literal> {
                let (v, s) = ws.layer_tensor(name, l)?;
                lit_f32(v, &s)
            };
            layers.push(LayerLits {
                wqkv: lit("wqkv")?,
                wo: lit("wo")?,
                wg: lit("wg")?,
                w1: lit("w1")?,
                w3: lit("w3")?,
                w2: lit("w2")?,
            });
        }
        Ok(RealModel {
            eng,
            variant: variant.to_string(),
            cfg,
            emb,
            layers,
            ws,
            expert_cache: Mutex::new(WeightTier {
                entries: HashMap::new(),
                budget: None,
                clock: 0,
                stats: CacheStats::default(),
            }),
        })
    }

    fn run(&self, name: &str, inputs: &[xla::Literal])
           -> anyhow::Result<Vec<xla::Literal>> {
        self.eng.run(&self.variant, name, inputs)
    }

    /// Embed a (ctx-padded) id sequence → `[ctx, hidden]` activations.
    pub fn embed(&self, ids: &[i32]) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(ids.len() == self.cfg.ctx, "ids must be ctx-padded");
        let out = self.run(
            "embed",
            &[lit_i32(ids, &[self.cfg.ctx])?, self.emb.clone()],
        )?;
        to_f32(&out[0])
    }

    /// Causal attention block over one sequence: `[ctx, hidden]` →
    /// `[ctx, hidden]`, rows ≥ `valid_len` pass through.
    pub fn attention(&self, x: &[f32], layer: usize, valid_len: usize)
                     -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let out = self.run(
            "attention",
            &[
                lit_f32(x, &[c.ctx, c.hidden])?,
                self.layers[layer].wqkv.clone(),
                self.layers[layer].wo.clone(),
                lit_scalar_i32(valid_len as i32),
            ],
        )?;
        to_f32(&out[0])
    }

    /// Gate one token tile: returns (xn, topw, topi).
    pub fn gate(&self, x_tile: &[f32], layer: usize)
                -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<i32>)> {
        let c = &self.cfg;
        let out = self.run(
            "gate",
            &[
                lit_f32(x_tile, &[c.tile_t, c.hidden])?,
                self.layers[layer].wg.clone(),
            ],
        )?;
        Ok((to_f32(&out[0])?, to_f32(&out[1])?, to_i32(&out[2])?))
    }

    /// Single-device whole-MoE-layer oracle (includes LN + residual).
    pub fn moe_layer_oracle(&self, x_tile: &[f32], layer: usize)
                            -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let l = &self.layers[layer];
        let out = self.run(
            "moe_layer_full",
            &[
                lit_f32(x_tile, &[c.tile_t, c.hidden])?,
                l.wg.clone(),
                l.w1.clone(),
                l.w3.clone(),
                l.w2.clone(),
            ],
        )?;
        to_f32(&out[0])
    }

    /// One logical rank's grouped FFN over an expert-aligned buffer.
    pub fn grouped_ffn(&self, layer: usize, xa: &[f32],
                       tile_expert: &[i32]) -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let l = &self.layers[layer];
        let out = self.run(
            "grouped_ffn",
            &[
                lit_f32(xa, &[c.cap_rows(), c.hidden])?,
                lit_i32(tile_expert, &[c.cap_tiles])?,
                l.w1.clone(),
                l.w3.clone(),
                l.w2.clone(),
            ],
        )?;
        to_f32(&out[0])
    }

    /// Single-expert FFN over one fixed-size token tile (plain-XLA dense
    /// path; exactly one expert's slice of the Pallas kernel's math).
    ///
    /// This is the CPU fast path of the §Perf pass: under interpret-mode
    /// the Pallas grouped kernel pays a 96-step weight-streaming loop per
    /// call (its VMEM pipeline becomes memcpys), while this dense XLA
    /// executable runs the same GEMMs directly. Numerical equivalence of
    /// the two paths is asserted by `ffn_modes_agree` below and the
    /// losslessness tests.
    pub fn expert_ffn(&self, layer: usize, expert: usize, x_tile: &[f32])
                      -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let lits = self.expert_weight_lits(layer, expert)?;
        let out = self.run(
            "expert_ffn",
            &[
                lit_f32(x_tile, &[c.tile_t, c.hidden])?,
                lits.0.clone(),
                lits.1.clone(),
                lits.2.clone(),
            ],
        )?;
        to_f32(&out[0])
    }

    /// One expert's (w1, w3, w2) weight literals, built on first use and
    /// held in the hot tier — residency stands in for "expert weights on
    /// this rank" in the logical-rank execution model. The tier lock is
    /// held across the cold fetch so racing ranks never build the same
    /// literals twice.
    fn expert_weight_lits(&self, layer: usize, expert: usize)
                          -> anyhow::Result<
        Arc<(xla::Literal, xla::Literal, xla::Literal)>,
    > {
        let key = (layer, expert);
        let mut tier = self.expert_cache.lock().unwrap();
        tier.clock += 1;
        let now = tier.clock;
        if let Some(entry) = tier.entries.get_mut(&key) {
            entry.last_use = now;
            tier.stats.hits += 1;
            return Ok(entry.lits.clone());
        }
        tier.stats.cold_loads += 1;
        let (w1, s1) = self.ws.expert_tensor("w1", layer, expert)?;
        let (w3, s3) = self.ws.expert_tensor("w3", layer, expert)?;
        let (w2, s2) = self.ws.expert_tensor("w2", layer, expert)?;
        let lits = Arc::new((
            lit_f32(w1, &s1)?,
            lit_f32(w3, &s3)?,
            lit_f32(w2, &s2)?,
        ));
        tier.entries
            .insert(key, TierEntry { lits: lits.clone(), last_use: now });
        Self::evict_to_budget(&mut tier);
        Ok(lits)
    }

    /// Evict least-recently-used entries until the tier fits its
    /// budget. Ties break to the smaller `(layer, expert)` key, so
    /// eviction order is deterministic. An executing rank holding the
    /// `Arc` keeps its literals alive; eviction only drops residency.
    fn evict_to_budget(tier: &mut WeightTier) {
        let Some(b) = tier.budget else { return };
        while tier.entries.len() > b {
            let victim = tier
                .entries
                .iter()
                .min_by_key(|(k, e)| (e.last_use, **k))
                .map(|(k, _)| *k)
                .expect("tier over budget implies non-empty");
            tier.entries.remove(&victim);
            tier.stats.evictions += 1;
        }
    }

    /// Cap the hot tier at `budget` resident experts (process-wide
    /// across the logical ranks), evicting down immediately if already
    /// over; `None` restores the historical keep-everything cache.
    ///
    /// # Panics
    /// On `Some(0)` — a zero weight budget cannot hold any working set
    /// (the CLI rejects `--weight-budget 0` before it gets here).
    pub fn set_weight_budget(&self, budget: Option<usize>) {
        if let Some(b) = budget {
            assert!(b >= 1, "--weight-budget 0 cannot hold a working \
                             set; use at least 1");
        }
        let mut tier = self.expert_cache.lock().unwrap();
        tier.budget = budget;
        Self::evict_to_budget(&mut tier);
    }

    /// Snapshot of the weight-tier counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.expert_cache.lock().unwrap().stats
    }

    /// Number of experts currently resident in the hot tier.
    pub fn resident_experts(&self) -> usize {
        self.expert_cache.lock().unwrap().entries.len()
    }

    /// Whether `(layer, expert)` is resident right now. A pure probe:
    /// it bumps neither recency nor the hit counter, so prefetch
    /// planning can ask without perturbing LRU order.
    pub fn is_resident(&self, layer: usize, expert: usize) -> bool {
        self.expert_cache
            .lock()
            .unwrap()
            .entries
            .contains_key(&(layer, expert))
    }

    /// Stage one expert's weights ahead of use: what an online replica
    /// migration copies before the new host can serve the expert. The
    /// executor calls this for every replica a
    /// [`crate::replan::ReplanDelta`] adds, so the weight-copy cost is
    /// paid at swap time, not silently on the first routed token.
    ///
    /// Idempotent: staging an already-resident expert is a no-op hit —
    /// no duplicate literal build, no second cold load — so replan
    /// executors and the prefetcher can re-stage defensively for free.
    pub fn stage_expert(&self, layer: usize, expert: usize)
                        -> anyhow::Result<()> {
        self.expert_weight_lits(layer, expert).map(|_| ())
    }

    /// Tied-embedding logits over one (ctx-padded) sequence.
    pub fn lmhead(&self, x: &[f32]) -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let out = self.run(
            "lmhead",
            &[lit_f32(x, &[c.ctx, c.hidden])?, self.emb.clone()],
        )?;
        to_f32(&out[0])
    }

    /// Tied-embedding logits for a single row (`[1, hidden]` →
    /// `[1, vocab]`) — the cached decode path only materialises one new
    /// activation row per live sequence, so it never pays the full
    /// `[ctx, vocab]` logits matmul.
    pub fn lmhead_row(&self, x_row: &[f32]) -> anyhow::Result<Vec<f32>> {
        let c = &self.cfg;
        let out = self.run(
            "lmhead_row",
            &[lit_f32(x_row, &[1, c.hidden])?, self.emb.clone()],
        )?;
        to_f32(&out[0])
    }

    /// Full-prefix attention that also emits the K/V rows to seed a
    /// sequence's [`KvCache`]: `[ctx, hidden]` → `(out, k, v)` each
    /// `[ctx, hidden]`, where `out` is identical to [`Self::attention`]
    /// and rows ≥ `valid_len` of the caches are zero.
    pub fn attention_prefill(&self, x: &[f32], layer: usize,
                             valid_len: usize)
                             -> anyhow::Result<(Vec<f32>, Vec<f32>,
                                                Vec<f32>)> {
        let c = &self.cfg;
        let out = self.run(
            "attention_prefill",
            &[
                lit_f32(x, &[c.ctx, c.hidden])?,
                self.layers[layer].wqkv.clone(),
                self.layers[layer].wo.clone(),
                lit_scalar_i32(valid_len as i32),
            ],
        )?;
        Ok((to_f32(&out[0])?, to_f32(&out[1])?, to_f32(&out[2])?))
    }

    /// Incremental attention: one new-token row against a layer's cached
    /// K/V. `x_row` is `[1, hidden]`, `k`/`v` are `[ctx, hidden]` with
    /// rows `< pos` populated; returns the attended residual row plus the
    /// caches with row `pos` appended. Because the causal window
    /// `0..=pos` sees exactly the keys the full-prefix program sees for
    /// row `pos`, greedy decode through this path is token-for-token
    /// identical to full recompute (pinned by
    /// `cached_decode_matches_recompute_token_for_token`).
    pub fn attention_step(&self, x_row: &[f32], k: &[f32], v: &[f32],
                          layer: usize, pos: usize)
                          -> anyhow::Result<(Vec<f32>, Vec<f32>,
                                             Vec<f32>)> {
        let c = &self.cfg;
        let out = self.run(
            "attention_step",
            &[
                lit_f32(x_row, &[1, c.hidden])?,
                lit_f32(k, &[c.ctx, c.hidden])?,
                lit_f32(v, &[c.ctx, c.hidden])?,
                self.layers[layer].wqkv.clone(),
                self.layers[layer].wo.clone(),
                lit_scalar_i32(pos as i32),
            ],
        )?;
        Ok((to_f32(&out[0])?, to_f32(&out[1])?, to_f32(&out[2])?))
    }
}

/// Per-sequence attention K/V cache: one `[ctx, hidden]` K and V buffer
/// per layer, with the first [`KvCache::len`] rows populated. Owned by
/// the serving front per *live* sequence — allocated at admission,
/// dropped at retirement — so a decode step only has to feed each
/// sequence's **new** token through attention instead of recomputing the
/// whole prefix.
pub struct KvCache {
    /// Per-layer `(k, v)` buffers, each `[ctx, hidden]` row-major; rows
    /// ≥ `len` are zero.
    layers: Vec<(Vec<f32>, Vec<f32>)>,
    /// Number of populated rows == tokens already attended and cached.
    len: usize,
}

impl KvCache {
    /// Empty cache sized for one sequence of `cfg`'s model.
    pub fn new(cfg: &TinyConfig) -> KvCache {
        let zeros = || vec![0.0f32; cfg.ctx * cfg.hidden];
        KvCache {
            layers: (0..cfg.layers).map(|_| (zeros(), zeros())).collect(),
            len: 0,
        }
    }

    /// Number of cached positions (tokens whose K/V rows are populated).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True before prefill has populated anything.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

/// One live sequence in a cached decode step: the full token ids plus the
/// sequence's K/V cache, of which the first `cache.len()` positions are
/// already populated (so `ids.len() - cache.len()` tokens are *new* this
/// step — the whole prompt at prefill, exactly one during decode).
pub struct CachedSeq<'a> {
    /// Full token ids so far (prompt + generated), `1..=ctx` long.
    pub ids: &'a [i32],
    /// The sequence's cache; mutated in place by the step.
    pub cache: &'a mut KvCache,
}

/// Profile the *real* gate: embed random tokens, run the reference layer
/// stack, and record each layer's top-k selections as a [`GateTrace`].
pub fn profile_real(model: &RealModel, n_tiles: usize, seed: u64)
                    -> anyhow::Result<GateTrace> {
    let c = &model.cfg;
    let mut rng = Rng::new(seed);
    let mut layers: Vec<LayerTrace> = (0..c.layers)
        .map(|_| LayerTrace {
            experts: c.experts,
            top_k: c.top_k,
            tokens: Vec::new(),
        })
        .collect();

    for _ in 0..n_tiles {
        // Random ids → one ctx sequence; profile the first tile_t tokens.
        let ids: Vec<i32> = (0..c.ctx)
            .map(|_| rng.index(c.vocab) as i32)
            .collect();
        let mut x = model.embed(&ids)?;
        for l in 0..c.layers {
            x = model.attention(&x, l, c.ctx)?;
            let tile = &x[..c.tile_t * c.hidden];
            let (_, _, topi) = model.gate(tile, l)?;
            for t in 0..c.tile_t {
                layers[l].tokens.push(
                    topi[t * c.top_k..(t + 1) * c.top_k]
                        .iter()
                        .map(|&e| e as u16)
                        .collect(),
                );
            }
            // advance through the full (oracle) MoE layer tile by tile
            let mut next = vec![0.0f32; x.len()];
            for tile_start in (0..c.ctx).step_by(c.tile_t) {
                let s = tile_start * c.hidden;
                let e = (tile_start + c.tile_t) * c.hidden;
                let y = model.moe_layer_oracle(&x[s..e], l)?;
                next[s..e].copy_from_slice(&y);
            }
            x = next;
        }
    }
    Ok(GateTrace { layers })
}

/// Distributed executor for one placement, routed through the online
/// half of the L3 coordinator (which owns the topology and the routing
/// policy). Construct via [`DistributedMoE::new`]: the executor owns the
/// run's [`Dispatcher`], so a stateful policy's online load estimates
/// persist across layers and tiles of one serving run.
///
/// The placement is held behind an [`Arc`] so the server can hot-swap it
/// at an epoch boundary ([`DistributedMoE::apply_replan`]) without
/// rebuilding the executor — the dispatcher (and any online policy
/// state) survives the swap, exactly like a real deployment that keeps
/// serving while replica weights are staged.
///
/// The model is shared via [`Arc`] too: each logical rank's FFN shard
/// executes as its own job on the executor's [`ThreadPool`]
/// ([`DistributedMoE::moe_layer`]), so ranks run concurrently the way a
/// real cluster's GPUs do instead of being serialised on one thread.
pub struct DistributedMoE {
    /// The loaded tiny model executing every compute step.
    pub model: Arc<RealModel>,
    /// FFN executable choice (see [`FfnMode`]); `GroupedPallas` is the
    /// default and the variant all losslessness tests pin down.
    pub ffn_mode: FfnMode,
    placement: Arc<Placement>,
    topo: Topology,
    dispatcher: Dispatcher,
    /// Worker pool the per-rank FFN shards fan out over (one logical
    /// rank per job, capped by host parallelism).
    pool: ThreadPool,
    /// Async weight staging (`None` until
    /// [`DistributedMoE::enable_prefetch`]): every weight stays
    /// resident and no background copies run, exactly the historical
    /// behaviour.
    prefetch: Option<RealPrefetch>,
}

/// Async weight-staging state of the execute-mode engine: the
/// cross-layer predictor, the in-flight staging registry, and the
/// dedicated background pool its copy jobs run on — separate from the
/// FFN worker pool so weight copies overlap compute instead of
/// stealing its workers.
struct RealPrefetch {
    cfg: PrefetchConfig,
    predictor: CrossLayerPredictor,
    /// Staging jobs in flight, keyed by `(layer, expert)`. A finished
    /// job's handle stays registered until first use consumes it.
    inflight: HashMap<(usize, usize), JobHandle>,
    stager: ThreadPool,
    stats: PrefetchStats,
    /// Per-expert weight payload (w1 + w3 + w2, f32) for the byte
    /// accounting.
    expert_bytes: f64,
}

/// Result of one distributed MoE layer execution.
pub struct LayerRun {
    /// Output activations `[tile_t, hidden]` (residual included).
    pub y: Vec<f32>,
    /// The batched routing decision taken: per-`(src,dst)` transfer lists
    /// with byte accounting, plus per-rank copy counts
    /// ([`DispatchPlan::copies_per_gpu`]) — comm and compute accounting
    /// read straight off it.
    pub plan: DispatchPlan,
}

impl DistributedMoE {
    /// Executor over `placement` routing through `coord`'s policy on its
    /// topology (the coordinator is only read at construction — the
    /// caller keeps it, and with it the re-planner, mutable).
    pub fn new(model: Arc<RealModel>, placement: Arc<Placement>,
               coord: &OnlineCoordinator, ffn_mode: FfnMode)
               -> DistributedMoE {
        // Per-copy payload: one f32 hidden activation vector.
        let token_bytes =
            (model.cfg.hidden * std::mem::size_of::<f32>()) as f64;
        let workers = coord
            .topo()
            .num_gpus()
            .min(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            )
            .max(1);
        DistributedMoE {
            model,
            placement,
            topo: coord.topo().clone(),
            ffn_mode,
            dispatcher: coord.dispatcher(token_bytes),
            pool: ThreadPool::new(workers),
            prefetch: None,
        }
    }

    /// The placement currently being served.
    pub fn placement(&self) -> &Placement {
        &self.placement
    }

    /// Turn on the weight tier and the async staging pipeline: caps the
    /// model's hot tier at `weight_budget × num_ranks` resident experts
    /// (the execute-mode cache is host-wide, one logical budget share
    /// per rank), builds the cross-layer predictor, and spins up the
    /// staging pool. With `cfg.predictive` false only the tier and the
    /// demand hit/stall accounting are active — no background copies.
    pub fn enable_prefetch(&mut self, cfg: PrefetchConfig)
                           -> anyhow::Result<()> {
        let c = &self.model.cfg;
        cfg.validate(c.experts)?;
        self.model
            .set_weight_budget(Some(cfg.weight_budget
                                    * self.topo.num_gpus()));
        let expert_bytes =
            (3 * c.hidden * c.ffn * std::mem::size_of::<f32>()) as f64;
        self.prefetch = Some(RealPrefetch {
            cfg,
            predictor: CrossLayerPredictor::new(c.layers, c.experts,
                                                cfg.alpha),
            inflight: HashMap::new(),
            stager: ThreadPool::new(2),
            stats: PrefetchStats::default(),
            expert_bytes,
        });
        Ok(())
    }

    /// Prefetch counters so far (`None` until
    /// [`Self::enable_prefetch`]); evictions are folded in from the
    /// shared model tier so the snapshot is self-contained.
    pub fn prefetch_stats(&self) -> Option<PrefetchStats> {
        self.prefetch.as_ref().map(|pf| {
            let mut s = pf.stats.clone();
            s.evictions = self.model.cache_stats().evictions;
            s
        })
    }

    /// Hot-swap the active placement at an epoch boundary: stage the
    /// expert weights every added replica needs (through the executor's
    /// weight tier — the real cost a migration pays), then switch the
    /// placement. The dispatcher and its policy state survive; call only
    /// between dispatch rounds, never mid-round.
    ///
    /// With prefetching enabled the copies fan out over the same
    /// background staging pool predictive prefetch uses (reusing any
    /// already-in-flight job), with a barrier before the swap — an
    /// epoch boundary publishes a fully staged placement. Staging is
    /// idempotent, so replicas that are already resident (or were just
    /// prefetched) cost nothing and are never double-counted.
    pub fn apply_replan(&mut self, new_placement: Arc<Placement>,
                        delta: &ReplanDelta) -> anyhow::Result<()> {
        let mut keys: Vec<(usize, usize)> = Vec::new();
        for ld in &delta.layers {
            for &(expert, _gpu) in &ld.added {
                if !keys.contains(&(ld.layer, expert)) {
                    keys.push((ld.layer, expert));
                }
            }
        }
        let model = self.model.clone();
        if let Some(pf) = &mut self.prefetch {
            let handles: Vec<JobHandle> = keys
                .iter()
                .map(|&(l, e)| match pf.inflight.get(&(l, e)) {
                    Some(h) => h.clone(),
                    None => {
                        let m = model.clone();
                        pf.stager.submit_tracked(move || {
                            // Failures surface in the sync pass below.
                            let _ = m.stage_expert(l, e);
                        })
                    }
                })
                .collect();
            for h in &handles {
                h.wait();
            }
        }
        // Idempotent confirmation pass: resident entries are no-op
        // hits; a failed background copy re-runs here and surfaces its
        // error on the caller's thread.
        for &(l, e) in &keys {
            model.stage_expert(l, e)?;
        }
        self.placement = new_placement;
        Ok(())
    }

    /// Execute one MoE layer over a token tile distributed across ranks.
    ///
    /// `src_gpu_of` assigns each of the tile's tokens to its resident
    /// rank (data parallelism); one batched dispatch round then decides
    /// which rank executes each expert assignment. Every rank's FFN
    /// shard (its slice of the plan's transfer lists) runs as one job on
    /// the executor's [`ThreadPool`]; the weighted combine stays
    /// sequential in rank order, so the floating-point accumulation is
    /// bit-identical to the serial execution it replaces.
    pub fn moe_layer(&mut self, x_tile: &[f32], layer: usize,
                     src_gpu_of: &dyn Fn(usize) -> GpuId,
                     rng: &mut Rng) -> anyhow::Result<LayerRun> {
        let c = &self.model.cfg;
        let n_gpus = self.topo.num_gpus();
        let lp = &self.placement.layers[layer];

        let (xn, topw, topi) = self.model.gate(x_tile, layer)?;

        // The tile's assignment batch (token-major: batch index t*K+k).
        let mut batch = Vec::with_capacity(c.tile_t * c.top_k);
        for t in 0..c.tile_t {
            let src = src_gpu_of(t);
            for k in 0..c.top_k {
                let e = topi[t * c.top_k + k] as usize;
                batch.push(Assignment { token: t, expert: e, src });
            }
        }
        let plan = self.dispatcher.dispatch(lp, layer, &batch, rng);

        // Weight residency: consume any staging issued for this layer
        // (hit when the background copy already landed, stall when we
        // must block or demand-load), then kick off staging for the
        // predicted next-layer experts — those jobs run on the staging
        // pool while this layer's FFN shards execute below.
        if let Some(pf) = &mut self.prefetch {
            demand_ready(&self.model, pf, layer, &plan)?;
            issue_prefetch(&self.model, pf, layer, &plan);
        }

        // Per-rank buckets of (expert, token, gate weight) — the batch
        // index recovers each assignment's gate weight. Empty ranks are
        // dropped before the fan-out.
        let jobs: Vec<(GpuId, Vec<(usize, usize, f32)>)> = (0..n_gpus)
            .map(|gpu| {
                (
                    gpu,
                    plan.for_rank(gpu)
                        .map(|r| (r.expert, r.token, topw[r.index]))
                        .collect::<Vec<_>>(),
                )
            })
            .filter(|(_, bucket)| !bucket.is_empty())
            .collect();

        // Fan the rank shards out over the pool. `map` preserves input
        // order, so the combine below walks ranks ascending exactly like
        // the old serial loop.
        let hidden = c.hidden;
        let xn = Arc::new(xn);
        let model = self.model.clone();
        let mode = self.ffn_mode;
        let outs = self.pool.map(jobs, move |(gpu, bucket)| {
            rank_ffn(&model, layer, mode, &xn, gpu, bucket)
        });

        let mut y = x_tile.to_vec(); // residual
        for out in outs {
            for (t, w, row) in out? {
                for h in 0..hidden {
                    y[t * hidden + h] += w * row[h];
                }
            }
        }

        Ok(LayerRun { y, plan })
    }

    /// One iteration-level step over a whole live batch of sequences:
    /// the batched multi-sequence forward behind the serving front's
    /// continuous-batching scheduler.
    ///
    /// Embedding, attention, and the LM head execute per sequence (the
    /// AOT artifacts are single-sequence `[ctx, hidden]` programs), but
    /// the MoE layers run over *shared* tiles packed across the batch:
    /// every `tile_t` live tokens — regardless of which sequence they
    /// belong to — form one dispatch round, so a step over N short
    /// sequences issues `⌈Σ len / tile_t⌉` rounds per layer instead of
    /// the per-sequence path's `Σ ⌈len / tile_t⌉`. Fewer, denser plans:
    /// exactly what the locality-aware routing machinery and the comm
    /// models want to see.
    ///
    /// Per-token numerics are independent of tile packing (gate LN,
    /// expert FFN, and the weighted combine are all row-wise), so greedy
    /// decode produces token-for-token the same outputs as stepping each
    /// sequence alone — pinned by `batched_decode_is_batch_invariant`.
    ///
    /// `observe` sees every dispatched `(layer, plan)` in issue order;
    /// returns the next greedy token per sequence.
    pub fn decode_step(&mut self, seqs: &[&[i32]], rng: &mut Rng,
                       observe: &mut dyn FnMut(usize, &DispatchPlan))
                       -> anyhow::Result<Vec<i32>> {
        let c = self.model.cfg.clone();
        anyhow::ensure!(!seqs.is_empty(), "decode_step: empty batch");
        for ids in seqs {
            anyhow::ensure!(
                !ids.is_empty() && ids.len() <= c.ctx,
                "decode_step: sequence length {} outside 1..={}",
                ids.len(),
                c.ctx
            );
        }
        let n_gpus = self.topo.num_gpus();

        // Embed every sequence (ctx-padded, as the artifacts expect).
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(seqs.len());
        for ids in seqs {
            let mut padded = ids.to_vec();
            padded.resize(c.ctx, 0);
            xs.push(self.model.embed(&padded)?);
        }

        // Flat (sequence, position) map over the live tokens,
        // sequence-major — the shared-tile packing order.
        let flat: Vec<(usize, usize)> = seqs
            .iter()
            .enumerate()
            .flat_map(|(s, ids)| (0..ids.len()).map(move |p| (s, p)))
            .collect();
        let total = flat.len();

        for l in 0..c.layers {
            for (s, ids) in seqs.iter().enumerate() {
                let att = self.model.attention(&xs[s], l, ids.len())?;
                xs[s] = att;
            }
            for (tile_idx, tile_toks) in flat.chunks(c.tile_t).enumerate()
            {
                // Gather the tile across sequences (zero-padded tail).
                let mut x_tile = vec![0.0f32; c.tile_t * c.hidden];
                for (row, &(s, p)) in tile_toks.iter().enumerate() {
                    x_tile[row * c.hidden..(row + 1) * c.hidden]
                        .copy_from_slice(
                            &xs[s][p * c.hidden..(p + 1) * c.hidden],
                        );
                }
                let base = tile_idx * c.tile_t;
                let run = self.moe_layer(
                    &x_tile,
                    l,
                    &|t| even_src(base + t, total, n_gpus),
                    rng,
                )?;
                for (row, &(s, p)) in tile_toks.iter().enumerate() {
                    xs[s][p * c.hidden..(p + 1) * c.hidden]
                        .copy_from_slice(
                            &run.y[row * c.hidden..(row + 1) * c.hidden],
                        );
                }
                observe(l, &run.plan);
            }
        }

        // Greedy next token per sequence off the last valid row.
        let mut next = Vec::with_capacity(seqs.len());
        for (s, ids) in seqs.iter().enumerate() {
            let logits = self.model.lmhead(&xs[s])?;
            let last = ids.len() - 1;
            let row = &logits[last * c.vocab..(last + 1) * c.vocab];
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            next.push(best as i32);
        }
        Ok(next)
    }

    /// KV-cached iteration step: one **new** token per live sequence
    /// through attention and the MoE layers, instead of the full prefix.
    ///
    /// Each [`CachedSeq`] brings `ids.len() - cache.len()` new positions:
    /// a freshly admitted sequence (empty cache) takes the prefill path —
    /// one `attention_prefill` call per layer covers its whole prompt and
    /// seeds the cache — while a decoding sequence takes one
    /// `attention_step` call per layer against its cached K/V. Only the
    /// new rows are packed into shared MoE tiles, so a steady-state
    /// decode step over N live sequences issues `⌈N / tile_t⌉` dispatch
    /// rounds per layer instead of [`Self::decode_step`]'s
    /// `⌈Σ len / tile_t⌉`, and prices exactly one token per sequence
    /// against the scheduler's budget.
    ///
    /// Output parity: greedy tokens are identical to [`Self::decode_step`]
    /// on the same sequences (attention rows agree up to float
    /// reassociation, the MoE layer is row-wise with no cross-token
    /// state) — the recompute path survives as the parity oracle behind
    /// `--kv-cache off`. The two paths consume routing randomness
    /// differently (fewer tiles → fewer dispatch rounds), which is
    /// allowed: replica choice is lossless by construction, so it can
    /// never change tokens.
    ///
    /// On success every sequence's cache covers all of `ids`; on error
    /// caches may be partially updated mid-step — callers must drop them
    /// (the serving front retires the request on step failure).
    pub fn decode_step_cached(&mut self, seqs: &mut [CachedSeq<'_>],
                              rng: &mut Rng,
                              observe: &mut dyn FnMut(usize,
                                                      &DispatchPlan))
                              -> anyhow::Result<Vec<i32>> {
        let c = self.model.cfg.clone();
        anyhow::ensure!(!seqs.is_empty(),
                        "decode_step_cached: empty batch");
        for s in seqs.iter() {
            anyhow::ensure!(
                !s.ids.is_empty() && s.ids.len() <= c.ctx,
                "decode_step_cached: sequence length {} outside 1..={}",
                s.ids.len(),
                c.ctx
            );
            anyhow::ensure!(
                s.cache.len < s.ids.len(),
                "decode_step_cached: cache ({} rows) has no new tokens \
                 for a {}-token sequence",
                s.cache.len,
                s.ids.len()
            );
            anyhow::ensure!(
                s.cache.layers.len() == c.layers,
                "decode_step_cached: cache built for {} layers, model \
                 has {}",
                s.cache.layers.len(),
                c.layers
            );
        }
        let n_gpus = self.topo.num_gpus();
        let starts: Vec<usize> =
            seqs.iter().map(|s| s.cache.len).collect();

        // Embed (ctx-padded — the embed artifact's shape); only the new
        // rows are read below.
        let mut xs: Vec<Vec<f32>> = Vec::with_capacity(seqs.len());
        for s in seqs.iter() {
            let mut padded = s.ids.to_vec();
            padded.resize(c.ctx, 0);
            xs.push(self.model.embed(&padded)?);
        }

        // Flat (sequence, position) map over the NEW tokens only — the
        // shared-tile packing order of the cached step.
        let flat: Vec<(usize, usize)> = seqs
            .iter()
            .enumerate()
            .flat_map(|(s, cs)| {
                (starts[s]..cs.ids.len()).map(move |p| (s, p))
            })
            .collect();
        let total_new = flat.len();

        for l in 0..c.layers {
            for (s, cs) in seqs.iter_mut().enumerate() {
                if starts[s] == 0 {
                    // Prefill: whole prompt in one call, cache seeded.
                    let (att, k, v) = self.model.attention_prefill(
                        &xs[s], l, cs.ids.len())?;
                    xs[s] = att;
                    cs.cache.layers[l] = (k, v);
                } else {
                    // Incremental: one step per new position (exactly
                    // one in steady-state decode).
                    for p in starts[s]..cs.ids.len() {
                        let row = xs[s]
                            [p * c.hidden..(p + 1) * c.hidden]
                            .to_vec();
                        let (kc, vc) = &cs.cache.layers[l];
                        let (out, k, v) = self.model.attention_step(
                            &row, kc, vc, l, p)?;
                        xs[s][p * c.hidden..(p + 1) * c.hidden]
                            .copy_from_slice(&out);
                        cs.cache.layers[l] = (k, v);
                    }
                }
            }
            for (tile_idx, tile_toks) in flat.chunks(c.tile_t).enumerate()
            {
                let mut x_tile = vec![0.0f32; c.tile_t * c.hidden];
                for (row, &(s, p)) in tile_toks.iter().enumerate() {
                    x_tile[row * c.hidden..(row + 1) * c.hidden]
                        .copy_from_slice(
                            &xs[s][p * c.hidden..(p + 1) * c.hidden],
                        );
                }
                let base = tile_idx * c.tile_t;
                let run = self.moe_layer(
                    &x_tile,
                    l,
                    &|t| even_src(base + t, total_new, n_gpus),
                    rng,
                )?;
                for (row, &(s, p)) in tile_toks.iter().enumerate() {
                    xs[s][p * c.hidden..(p + 1) * c.hidden]
                        .copy_from_slice(
                            &run.y[row * c.hidden..(row + 1) * c.hidden],
                        );
                }
                observe(l, &run.plan);
            }
        }

        // Commit: every cache now covers its full sequence.
        for cs in seqs.iter_mut() {
            cs.cache.len = cs.ids.len();
        }

        // Greedy next token off each sequence's last (new) row — a
        // single-row LM head, not the full [ctx, vocab] matmul.
        let mut next = Vec::with_capacity(seqs.len());
        for (s, cs) in seqs.iter().enumerate() {
            let last = cs.ids.len() - 1;
            let row = &xs[s][last * c.hidden..(last + 1) * c.hidden];
            let logits = self.model.lmhead_row(row)?;
            let mut best = 0usize;
            for (i, &v) in logits.iter().enumerate() {
                if v > logits[best] {
                    best = i;
                }
            }
            next.push(best as i32);
        }
        Ok(next)
    }
}

/// Demand pass of one dispatch round: make every expert the plan
/// routes resident before the FFN fan-out. An expert whose background
/// staging already landed (or that never left the tier) is a *hit*;
/// one still in flight or entirely cold is a *stall* — the round
/// blocks on it, and any round with at least one stall counts a
/// stall-step against the overlap.
fn demand_ready(model: &RealModel, pf: &mut RealPrefetch, layer: usize,
                plan: &DispatchPlan) -> anyhow::Result<()> {
    let mut experts: Vec<usize> = Vec::new();
    for r in plan.assignments() {
        if !experts.contains(&r.expert) {
            experts.push(r.expert);
        }
    }
    let mut stalled = false;
    for e in experts {
        if let Some(h) = pf.inflight.remove(&(layer, e)) {
            if h.is_done() {
                pf.stats.hits += 1;
            } else {
                pf.stats.stalls += 1;
                pf.stats.demand_bytes += pf.expert_bytes;
                stalled = true;
                h.wait();
            }
            // The background job swallows errors (fire-and-forget);
            // the idempotent re-stage surfaces them on this thread.
            model.stage_expert(layer, e)?;
        } else if model.is_resident(layer, e) {
            pf.stats.hits += 1;
        } else {
            pf.stats.stalls += 1;
            pf.stats.demand_bytes += pf.expert_bytes;
            stalled = true;
            model.stage_expert(layer, e)?;
        }
    }
    if stalled {
        pf.stats.stall_steps += 1;
    }
    Ok(())
}

/// Prediction pass of one dispatch round: feed the finished plan to
/// the cross-layer predictor, then stage the top-k predicted
/// next-layer experts in the background. Already-resident and
/// already-in-flight experts are skipped, so a stable hot set costs
/// nothing once it is staged.
fn issue_prefetch(model: &Arc<RealModel>, pf: &mut RealPrefetch,
                  layer: usize, plan: &DispatchPlan) {
    pf.predictor.observe_plan(layer, plan);
    if !pf.cfg.predictive {
        return;
    }
    let next = pf.predictor.next_layer(layer);
    for e in pf.predictor.predict(layer, pf.cfg.k) {
        if model.is_resident(next, e)
            || pf.inflight.contains_key(&(next, e))
        {
            continue;
        }
        pf.stats.prefetches += 1;
        pf.stats.prefetch_bytes += pf.expert_bytes;
        let m = model.clone();
        let h = pf.stager.submit_tracked(move || {
            // Failure is re-checked (and surfaced) at first use.
            let _ = m.stage_expert(next, e);
        });
        pf.inflight.insert((next, e), h);
    }
}

/// One rank's FFN shard: execute every routed copy in `bucket` and
/// return the weighted-combine inputs `(token, gate weight, FFN output
/// row)` in exactly the order the serial path accumulated them — the
/// caller applies them sequentially so parallel rank execution cannot
/// perturb the floating-point result.
fn rank_ffn(model: &RealModel, layer: usize, mode: FfnMode, xn: &[f32],
            gpu: GpuId, bucket: Vec<(usize, usize, f32)>)
            -> anyhow::Result<Vec<(usize, f32, Vec<f32>)>> {
    let c = &model.cfg;
    // Expert-aligned layout: sort by expert, pad per expert to tile_m
    // (the contract of the L1 tiled Pallas kernel).
    let mut sorted = bucket;
    sorted.sort_by_key(|&(e, t, _)| (e, t));
    let mut out = Vec::with_capacity(sorted.len());

    if mode == FfnMode::PerExpert {
        // CPU fast path: one dense expert_ffn call per (expert,
        // tile_t-chunk) of this rank's bucket.
        let mut i = 0usize;
        while i < sorted.len() {
            let e = sorted[i].0;
            let mut j = i;
            while j < sorted.len() && sorted[j].0 == e {
                j += 1;
            }
            for chunk in sorted[i..j].chunks(c.tile_t) {
                let mut xt = vec![0.0f32; c.tile_t * c.hidden];
                for (row, &(_, t, _)) in chunk.iter().enumerate() {
                    xt[row * c.hidden..(row + 1) * c.hidden]
                        .copy_from_slice(
                            &xn[t * c.hidden..(t + 1) * c.hidden],
                        );
                }
                let yt = model.expert_ffn(layer, e, &xt)?;
                for (row, &(_, t, w)) in chunk.iter().enumerate() {
                    out.push((
                        t,
                        w,
                        yt[row * c.hidden..(row + 1) * c.hidden].to_vec(),
                    ));
                }
            }
            i = j;
        }
        return Ok(out);
    }

    let mut xa = vec![0.0f32; c.cap_rows() * c.hidden];
    let mut tile_expert = vec![-1i32; c.cap_tiles];
    let mut slot_meta: Vec<Option<(usize, f32)>> = vec![None; c.cap_rows()];
    let mut slot = 0usize;
    let mut i = 0usize;
    while i < sorted.len() {
        let e = sorted[i].0;
        let start_tile = slot / c.tile_m;
        while i < sorted.len() && sorted[i].0 == e {
            let (_, t, w) = sorted[i];
            anyhow::ensure!(slot < c.cap_rows(),
                            "dispatch capacity exceeded on rank {gpu} \
                             (cap_rows {})", c.cap_rows());
            xa[slot * c.hidden..(slot + 1) * c.hidden].copy_from_slice(
                &xn[t * c.hidden..(t + 1) * c.hidden],
            );
            slot_meta[slot] = Some((t, w));
            slot += 1;
            i += 1;
        }
        // pad to tile boundary
        slot = (slot + c.tile_m - 1) / c.tile_m * c.tile_m;
        let end_tile = slot / c.tile_m;
        for tile in start_tile..end_tile.min(c.cap_tiles) {
            tile_expert[tile] = e as i32;
        }
    }
    let ya = model.grouped_ffn(layer, &xa, &tile_expert)?;
    for (s, meta) in slot_meta.iter().enumerate() {
        if let Some((t, w)) = *meta {
            out.push((
                t,
                w,
                ya[s * c.hidden..(s + 1) * c.hidden].to_vec(),
            ));
        }
    }
    Ok(out)
}

/// Build a placement for the tiny model from a *real* gate profile —
/// convenience wrapper over the L3 [`Coordinator`] (hierarchical grouping
/// at ratio `r`, the given replication mode).
///
/// Note: the grouping RNG now derives from the coordinator's unified
/// stream (`seed ^ GROUPING_SEED_TAG`), not the bare `Rng::new(seed)` of
/// the pre-coordinator wiring, so placements for a given seed differ from
/// pre-workspace builds; losslessness holds under any placement.
pub fn place_real(_model: &RealModel, topo: &Topology, trace: &GateTrace,
                  mode: crate::placement::ReplicationMode, r: f64,
                  seed: u64) -> Placement {
    Coordinator::new(
        GroupingStrategy::Hierarchical { r },
        mode,
        RoutingPolicy::Tar,
        topo.clone(),
        seed,
    )
    .place(trace)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::ReplicationMode;
    use std::path::PathBuf;

    fn model() -> Option<Arc<RealModel>> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        if !crate::runtime::pjrt::runtime_available() {
            eprintln!("SKIP: PJRT runtime unavailable (std-only xla \
                       stub) — execute-mode tests need real bindings");
            return None;
        }
        Some(Arc::new(RealModel::load(&d, "olmoe_tiny").unwrap()))
    }

    #[test]
    fn distributed_layer_matches_oracle_for_all_policies() {
        // THE losslessness check: distributed dataflow ≡ single device.
        let Some(m) = model() else { return };
        let c = m.cfg.clone();
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 7).unwrap();
        let mut rng = Rng::new(3);
        let x: Vec<f32> = (0..c.tile_t * c.hidden)
            .map(|_| rng.gaussian() as f32 * 0.5)
            .collect();
        let want = m.moe_layer_oracle(&x, 0).unwrap();
        for policy in [RoutingPolicy::Primary, RoutingPolicy::Wrr,
                       RoutingPolicy::Tar, RoutingPolicy::LoadAware] {
            let placement = Arc::new(place_real(
                &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 11,
            ));
            let coord = OnlineCoordinator::new(topo.clone(), policy);
            let mut dist = DistributedMoE::new(
                m.clone(), placement.clone(), &coord,
                FfnMode::GroupedPallas,
            );
            let run = dist
                .moe_layer(&x, 0, &(|t| t % 4), &mut Rng::new(5))
                .unwrap();
            let max_err = run
                .y
                .iter()
                .zip(&want)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err < 5e-4,
                "{policy:?}: max |distributed - oracle| = {max_err}"
            );
            assert_eq!(run.plan.num_tokens(), c.tile_t);
            assert_eq!(run.plan.num_assignments(), c.tile_t * c.top_k);
            let total: usize = run.plan.copies_per_gpu().iter().sum();
            assert_eq!(total, c.tile_t * c.top_k);
        }
    }

    #[test]
    fn ffn_modes_agree() {
        // The §Perf CPU fast path must be numerically interchangeable
        // with the Pallas kernel path.
        let Some(m) = model() else { return };
        let c = m.cfg.clone();
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 21).unwrap();
        let placement = Arc::new(place_real(
            &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 21,
        ));
        let mut rng = Rng::new(4);
        let x: Vec<f32> = (0..c.tile_t * c.hidden)
            .map(|_| rng.gaussian() as f32 * 0.4)
            .collect();
        let mut outs = Vec::new();
        let coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        for mode in [FfnMode::GroupedPallas, FfnMode::PerExpert] {
            let mut dist = DistributedMoE::new(
                m.clone(), placement.clone(), &coord, mode,
            );
            // identical routing randomness per mode
            let run =
                dist.moe_layer(&x, 0, &(|t| t % 4), &mut Rng::new(6))
                    .unwrap();
            outs.push(run.y);
        }
        let max_err = outs[0]
            .iter()
            .zip(&outs[1])
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-4, "modes diverge: {max_err}");
    }

    #[test]
    fn batched_decode_is_batch_invariant() {
        // Token outputs of the batched multi-sequence forward must not
        // depend on batch composition: stepping [a, b] together equals
        // stepping each alone (per-token numerics are row-wise).
        let Some(m) = model() else { return };
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 17).unwrap();
        let placement = Arc::new(place_real(
            &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 17,
        ));
        let coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        let a: Vec<i32> = (0..9).map(|i| (i * 13 % 512) as i32).collect();
        let b: Vec<i32> = (0..5).map(|i| (i * 29 % 512) as i32).collect();
        let run = |seqs: &[&[i32]]| {
            let mut dist = DistributedMoE::new(
                m.clone(), placement.clone(), &coord, FfnMode::PerExpert,
            );
            dist.decode_step(seqs, &mut Rng::new(3), &mut |_, _| {})
                .unwrap()
        };
        let together = run(&[&a, &b]);
        let alone_a = run(&[&a]);
        let alone_b = run(&[&b]);
        assert_eq!(together[0], alone_a[0], "a's token changed in batch");
        assert_eq!(together[1], alone_b[0], "b's token changed in batch");
    }

    #[test]
    fn batched_decode_issues_fewer_dispatch_rounds() {
        // Shared-tile packing: N short sequences stepped together issue
        // ⌈Σ len / tile_t⌉ rounds per layer, strictly fewer than the
        // per-sequence Σ ⌈len / tile_t⌉ whenever fragments combine.
        let Some(m) = model() else { return };
        let c = m.cfg.clone();
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 23).unwrap();
        let placement = Arc::new(place_real(
            &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 23,
        ));
        let coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        let len = (c.tile_t / 2).max(1);
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|s| {
                (0..len).map(|i| ((s * 31 + i * 7) % 512) as i32).collect()
            })
            .collect();
        let refs: Vec<&[i32]> =
            seqs.iter().map(|v| v.as_slice()).collect();
        let mut dist = DistributedMoE::new(
            m.clone(), placement.clone(), &coord, FfnMode::PerExpert,
        );
        let mut batched_rounds = 0usize;
        dist.decode_step(&refs, &mut Rng::new(5), &mut |_, _| {
            batched_rounds += 1;
        })
        .unwrap();
        let per_seq_rounds: usize = seqs
            .iter()
            .map(|s| c.layers * s.len().div_ceil(c.tile_t))
            .sum();
        let want = c.layers * (3 * len).div_ceil(c.tile_t);
        assert_eq!(batched_rounds, want);
        assert!(
            batched_rounds < per_seq_rounds,
            "batched {batched_rounds} !< per-seq {per_seq_rounds}"
        );
    }

    #[test]
    fn cached_decode_matches_recompute_token_for_token() {
        // The headline KV-cache invariant on real numerics: greedy
        // decode through decode_step_cached (prefill + one token per
        // step) produces exactly the tokens of the full-recompute
        // decode_step chain.
        let Some(m) = model() else { return };
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 31).unwrap();
        let placement = Arc::new(place_real(
            &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 31,
        ));
        let coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        let prompt: Vec<i32> =
            (0..7).map(|i| (i * 41 % 512) as i32).collect();
        let n_new = 4;

        // Recompute oracle.
        let mut dist = DistributedMoE::new(
            m.clone(), placement.clone(), &coord, FfnMode::PerExpert,
        );
        let mut ids_r = prompt.clone();
        for _ in 0..n_new {
            let next = dist
                .decode_step(&[&ids_r], &mut Rng::new(3), &mut |_, _| {})
                .unwrap();
            ids_r.push(next[0]);
        }

        // Cached path: prefill populates the cache, then one new token
        // per step.
        let mut dist = DistributedMoE::new(
            m.clone(), placement.clone(), &coord, FfnMode::PerExpert,
        );
        let mut cache = KvCache::new(&m.cfg);
        let mut ids_c = prompt.clone();
        for step in 0..n_new {
            let next = {
                let mut seqs =
                    [CachedSeq { ids: &ids_c, cache: &mut cache }];
                dist.decode_step_cached(&mut seqs, &mut Rng::new(3),
                                        &mut |_, _| {})
                    .unwrap()
            };
            assert_eq!(cache.len(), ids_c.len(),
                       "step {step}: cache must cover the sequence");
            ids_c.push(next[0]);
        }
        assert_eq!(ids_r, ids_c,
                   "cached decode diverged from full recompute");
    }

    #[test]
    fn cached_decode_issues_fewer_rounds_per_token() {
        // Steady-state decode over a batch: the cached step packs one
        // row per live sequence into shared tiles (⌈live/tile_t⌉ rounds
        // per layer), strictly fewer than recompute's ⌈Σ len/tile_t⌉
        // once prefixes outgrow the batch.
        let Some(m) = model() else { return };
        let c = m.cfg.clone();
        let topo = Topology::two_by_two();
        let trace = profile_real(&m, 1, 37).unwrap();
        let placement = Arc::new(place_real(
            &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 37,
        ));
        let coord =
            OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
        let len = c.tile_t; // long enough that Σ len spans many tiles
        let seqs: Vec<Vec<i32>> = (0..3)
            .map(|s| {
                (0..len).map(|i| ((s * 19 + i * 5) % 512) as i32).collect()
            })
            .collect();

        let mut dist = DistributedMoE::new(
            m.clone(), placement.clone(), &coord, FfnMode::PerExpert,
        );
        let mut caches: Vec<KvCache> =
            (0..3).map(|_| KvCache::new(&m.cfg)).collect();
        // Prefill step (caches empty) then one pure-decode step.
        let mut rounds = [0usize; 2];
        let mut ids = seqs.clone();
        for (step, slot) in rounds.iter_mut().enumerate() {
            let next = {
                let mut batch: Vec<CachedSeq> = ids
                    .iter()
                    .zip(caches.iter_mut())
                    .map(|(ids, cache)| CachedSeq { ids, cache })
                    .collect();
                dist.decode_step_cached(&mut batch, &mut Rng::new(7),
                                        &mut |_, _| *slot += 1)
                    .unwrap()
            };
            for (s, t) in next.into_iter().enumerate() {
                ids[s].push(t);
            }
            let _ = step;
        }
        // Prefill packs Σ prompt len; the decode step packs 3 rows.
        assert_eq!(rounds[0],
                   c.layers * (3 * len).div_ceil(c.tile_t));
        assert_eq!(rounds[1], c.layers, // ⌈3 / tile_t⌉ == 1 tile
                   "a cached decode step must cost one tile of rounds");
        let recompute_rounds =
            c.layers * (3 * (len + 1)).div_ceil(c.tile_t);
        assert!(rounds[1] < recompute_rounds,
                "cached {} !< recompute {recompute_rounds}", rounds[1]);
    }

    #[test]
    fn real_profile_has_structure() {
        let Some(m) = model() else { return };
        let trace = profile_real(&m, 2, 9).unwrap();
        assert_eq!(trace.layers.len(), m.cfg.layers);
        assert_eq!(trace.num_tokens(), 2 * m.cfg.tile_t);
        for l in &trace.layers {
            for tok in &l.tokens {
                assert_eq!(tok.len(), m.cfg.top_k);
            }
        }
    }

    #[test]
    fn staging_is_idempotent_one_cold_load() {
        // Satellite regression: re-staging a resident expert must not
        // rebuild literals or recount the copy — a replan that re-adds
        // an existing replica pays zero weight traffic.
        let Some(m) = model() else { return };
        assert_eq!(m.cache_stats(), CacheStats::default());
        m.stage_expert(0, 1).unwrap();
        let first = m.cache_stats();
        assert_eq!(first.cold_loads, 1);
        assert_eq!(m.resident_experts(), 1);
        m.stage_expert(0, 1).unwrap();
        let second = m.cache_stats();
        assert_eq!(second.cold_loads, 1,
                   "re-stage must not fetch the weights again");
        assert_eq!(second.hits, first.hits + 1);
        assert_eq!(m.resident_experts(), 1);
    }

    #[test]
    fn weight_budget_bounds_residency_with_lru_eviction() {
        let Some(m) = model() else { return };
        m.set_weight_budget(Some(2));
        m.stage_expert(0, 0).unwrap();
        m.stage_expert(0, 1).unwrap();
        assert_eq!(m.resident_experts(), 2);
        m.stage_expert(0, 0).unwrap(); // bump (0,0)'s recency
        m.stage_expert(0, 2).unwrap(); // must evict (0,1), the LRU
        assert_eq!(m.resident_experts(), 2, "budget is a hard cap");
        assert!(m.is_resident(0, 0));
        assert!(!m.is_resident(0, 1), "LRU entry must be the victim");
        assert!(m.is_resident(0, 2));
        assert_eq!(m.cache_stats().evictions, 1);
        // Evicted experts reload transparently (a fresh cold load).
        m.stage_expert(0, 1).unwrap();
        assert_eq!(m.cache_stats().cold_loads, 4);
        assert_eq!(m.resident_experts(), 2);
    }

    #[test]
    fn prefetched_decode_matches_unprefetched_token_for_token() {
        // The parity invariant on real numerics: the tier + async
        // staging change when weights move, never which tokens come
        // out. Each arm loads its own model so residency cannot leak
        // between them.
        let topo = Topology::two_by_two();
        let prompt: Vec<i32> =
            (0..6).map(|i| (i * 37 % 512) as i32).collect();
        let run = |prefetch: bool| -> Option<(Vec<i32>,
                                              Option<PrefetchStats>)> {
            let m = model()?;
            let trace = profile_real(&m, 1, 43).unwrap();
            let placement = Arc::new(place_real(
                &m, &topo, &trace, ReplicationMode::Dynamic, 0.15, 43,
            ));
            let coord =
                OnlineCoordinator::new(topo.clone(), RoutingPolicy::Tar);
            let mut dist = DistributedMoE::new(
                m.clone(), placement, &coord, FfnMode::PerExpert,
            );
            if prefetch {
                dist.enable_prefetch(PrefetchConfig {
                    predictive: true,
                    k: 2,
                    weight_budget: 2,
                    alpha: 0.5,
                })
                .unwrap();
            }
            let mut ids = prompt.clone();
            for _ in 0..3 {
                let next = dist
                    .decode_step(&[&ids], &mut Rng::new(3),
                                 &mut |_, _| {})
                    .unwrap();
                ids.push(next[0]);
            }
            Some((ids, dist.prefetch_stats()))
        };
        let Some((off_ids, off_stats)) = run(false) else { return };
        let (on_ids, on_stats) = run(true).unwrap();
        assert_eq!(on_ids, off_ids, "prefetch changed decoded tokens");
        assert!(off_stats.is_none(), "stats only when enabled");
        let s = on_stats.unwrap();
        assert!(s.stalls > 0, "a cold start must demand-stage");
        assert!(s.stall_steps <= s.stalls);
        assert!(s.hits + s.stalls > 0);
    }
}
