//! The MoE inference engine.
//!
//! Two execution modes share the same placement/routing/communication
//! decisions:
//!
//! * [`sim`] — the *timing* engine: drives the full GRACE-MoE pipeline
//!   (profile → group → replicate → route → communicate → compute) over
//!   paper-scale models and the [`crate::cluster::Topology`] cost model.
//!   All evaluation tables/figures are generated from this mode.
//! * [`fleet`] — the *open-loop replay* driver layered on [`sim`]'s
//!   cost model: whole Poisson request traces through the continuous
//!   scheduler, the online re-planner, and the [`crate::comm::sim`]
//!   contended network on a virtual clock.
//! * [`real`] — the *numerics* engine: executes the tiny AOT-compiled
//!   model variants through PJRT ([`crate::runtime`]), performing actual
//!   dispatch/combine in rust, and validates losslessness against the
//!   single-device oracle artifact. Its serving surface is the batched
//!   multi-sequence step ([`real::DistributedMoE::decode_step`]): the
//!   whole live batch shares MoE dispatch tiles, and each logical
//!   rank's FFN shard executes concurrently on a worker pool.
//!
//! All three share the [`prefetch`] weight-staging layer: a per-GPU
//! capacity-bounded hot tier of expert weights plus the cross-layer
//! activation predictor that stages the next layer's forecast experts
//! while the current layer computes.

pub mod fleet;
pub mod prefetch;
pub mod real;
pub mod sim;

pub use fleet::{replay_fleet, FleetConfig, FleetReport};
pub use prefetch::{HotTier, PrefetchEngine};
pub use real::{CacheStats, DistributedMoE, FfnMode, RealModel};
pub use sim::{simulate, simulate_rounds, simulate_with_contention,
              simulate_with_placement, ReplanReport, SimConfig};
