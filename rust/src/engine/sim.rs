//! Simulate-mode engine: paper-scale timing over the cluster cost model.
//!
//! One run = offline phase (profiling trace → grouping → replication →
//! Eq.-4 polling weights) followed by the online phase (serving trace →
//! batched dispatch → two A2A rounds per MoE layer → expert compute),
//! producing the paper's five system metrics plus MoE-layer time and
//! end-to-end latency.
//!
//! Routing is batched: each layer's token chunk becomes one
//! [`Dispatcher::dispatch`] round whose [`DispatchPlan`] feeds the
//! communication models (as per-`(src,dst)` batched transfers) and the
//! per-GPU compute-load accounting. One dispatcher is built per run, so
//! stateful policies ([`crate::routing::LoadAware`]) carry their online
//! load estimates across layers and phases.
//!
//! Scale handling: prefill processes `batch × prefill` tokens and decode
//! `batch` tokens × `decode` steps. The simulator executes a
//! representative chunk of at most `max_chunk` tokens per phase and scales
//! the extensive metrics linearly — routing decisions and load statistics
//! are computed on the real per-token trace of that chunk.
//!
//! Online re-planning: a [`crate::replan::Replanner`] can ride along
//! with any run ([`SimConfig::replan`] + a system with
//! [`SystemSpec::online_replan`], i.e. `grace-dyn`). Every dispatched
//! layer round is observed, epoch boundaries recompute replication from
//! the measured loads, and accepted deltas hot-swap the active placement
//! *between* rounds — with the expert-weight migration priced through
//! [`crate::comm::model`] so it shows up in the simulated latency
//! ([`RunMetrics::migration_bytes`]). [`simulate_rounds`] is the
//! round-by-round driver the drifting-workload scenarios (the `replan`
//! bench and CLI subcommand) replay.

use crate::baselines::SystemSpec;
use crate::cluster::Topology;
use crate::comm::model::{self, CommModel};
use crate::comm::sim::{CommBackend, CommBackendKind};
use crate::config::{GpuModel, ModelSpec, PrefetchConfig, Workload};
use crate::coordinator::Coordinator;
use crate::engine::prefetch::PrefetchEngine;
use crate::metrics::{ContentionReport, RunMetrics};
use crate::placement::Placement;
use crate::replan::{self, CostParams, ReplanConfig, Replanner};
use crate::routing::{Assignment, DispatchPlan, Dispatcher};
use crate::server::even_src;
use crate::stats::{Rng, Summary};
use crate::trace::{GateTrace, LayerTrace, Profile, TraceGen};

/// Per-token routing-decision cost (seconds) — the intra-node computation
/// HSC overlaps with its cross-node stage (§5 "fine-grained pipelining").
pub const ROUTE_DECISION_COST: f64 = 30e-9;

/// Full configuration of one simulated run.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Paper-scale model architecture under simulation.
    pub model: ModelSpec,
    /// Cluster topology and link parameters.
    pub topo: Topology,
    /// GPU compute-cost model.
    pub gpu: GpuModel,
    /// Inference workload (batch / prefill / decode).
    pub workload: Workload,
    /// Dataset profile the *serving* traffic is drawn from.
    pub serve_profile: Profile,
    /// Dataset profile the *offline profiling* used (≠ serve_profile in
    /// the Fig. 6 cross-dataset transfer experiments).
    pub placement_profile: Profile,
    /// Run seed (trace generation, routing RNG, jitter).
    pub seed: u64,
    /// Offline profiling trace length (tokens).
    pub profile_tokens: usize,
    /// Maximum tokens simulated per phase (larger workloads are scaled).
    pub max_chunk: usize,
    /// Epoch re-planning cadence/gates; only consulted by systems with
    /// [`SystemSpec::online_replan`] set (the `grace-dyn` spec).
    pub replan: Option<ReplanConfig>,
    /// Communication backend: closed-form analytic models (the default,
    /// bit-identical to the pre-seam engine) or discrete-event replay
    /// through the contended network ([`crate::comm::sim`]).
    pub comm_backend: CommBackendKind,
    /// Weight-tier / predictive-prefetch knobs ([`PrefetchEngine`]
    /// rides along when set). `None` (the default) keeps every expert
    /// weight permanently resident — bit-identical to older runs.
    pub prefetch: Option<PrefetchConfig>,
}

impl SimConfig {
    /// Defaults: A100 cost model, Text profiles, seed 42, re-planning
    /// off.
    pub fn new(model: ModelSpec, topo: Topology, workload: Workload)
               -> SimConfig {
        SimConfig {
            model,
            topo,
            gpu: GpuModel::a100(),
            workload,
            serve_profile: Profile::Text,
            placement_profile: Profile::Text,
            seed: 42,
            profile_tokens: 2048,
            max_chunk: 4096,
            replan: None,
            comm_backend: CommBackendKind::Analytic,
            prefetch: None,
        }
    }
}

/// Build the optional prefetch engine for a run (off unless the config
/// opts in). Shared with the fleet driver, which builds one per shard.
pub(crate) fn prefetch_engine(cfg: &SimConfig) -> Option<PrefetchEngine> {
    cfg.prefetch.map(|pc| {
        PrefetchEngine::new(
            pc,
            cfg.model.moe_layers,
            cfg.model.experts,
            cfg.topo.num_gpus(),
            cfg.model.expert_bytes(),
        )
    })
}

/// The L3 coordinator implementing `sys`'s placement/routing strategy for
/// one simulated run.
pub fn coordinator(sys: &SystemSpec, cfg: &SimConfig) -> Coordinator {
    Coordinator::for_system(sys, &cfg.topo, cfg.seed)
}

/// Offline phase: profiling trace → placement (grouping + replication +
/// predicted-load polling weights) under `sys`'s strategy. Thin wrapper
/// over [`Coordinator::offline_synthetic`].
pub fn build_placement(sys: &SystemSpec, cfg: &SimConfig) -> Placement {
    coordinator(sys, cfg).offline_synthetic(
        &cfg.model,
        cfg.placement_profile,
        cfg.profile_tokens,
    )
}

/// Offline + online phases.
pub fn simulate(sys: &SystemSpec, cfg: &SimConfig) -> RunMetrics {
    let placement = build_placement(sys, cfg);
    simulate_with_placement(sys, cfg, &placement)
}

/// Online phase against a prebuilt placement (placements are expensive —
/// spectral clustering per layer — and shared across workloads in the
/// benches; Fig. 6 also transplants placements across dataset profiles).
///
/// When the system re-plans online ([`SystemSpec::online_replan`] with
/// [`SimConfig::replan`] set), each phase is one measurement round and
/// epoch boundaries may hot-swap the active placement between phases.
pub fn simulate_with_placement(sys: &SystemSpec, cfg: &SimConfig,
                               placement: &Placement) -> RunMetrics {
    simulate_with_contention(sys, cfg, placement).0
}

/// [`simulate_with_placement`] plus the communication backend's
/// contention diagnostics (`None` for the analytic backend; with
/// [`CommBackendKind::Des`] the rounds replay back-to-back on the
/// virtual clock, so utilization/queue stats quantify how close the
/// serialized engine runs to saturation).
pub fn simulate_with_contention(sys: &SystemSpec, cfg: &SimConfig,
                                placement: &Placement)
                                -> (RunMetrics, Option<ContentionReport>) {
    assert_eq!(placement.experts, cfg.model.experts);
    assert_eq!(placement.num_gpus, cfg.topo.num_gpus());
    let coord = coordinator(sys, cfg);
    let mut dispatcher = coord.dispatcher(cfg.model.token_bytes());
    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let mut backend = CommBackend::new(cfg.comm_backend, &cfg.topo);
    let mut metrics = RunMetrics::default();
    let mut epoch = epoch_state(sys, cfg, placement);
    let mut prefetch = prefetch_engine(cfg);

    // Prefill: batch × prefill tokens through every layer.
    let prefill_tokens = cfg.workload.batch * cfg.workload.prefill;
    let chunk = prefill_tokens.min(cfg.max_chunk);
    if chunk > 0 {
        let scale = prefill_tokens as f64 / chunk as f64;
        let trace = serve_trace(cfg, chunk, 1);
        sim_phase(sys, cfg, &mut dispatcher, &mut backend, placement,
                  &trace, scale, &mut rng, &mut metrics, &mut epoch,
                  &mut prefetch);
        if let Some(s) = &mut epoch {
            s.tick(cfg, &mut metrics, &mut prefetch);
        }
    }

    // Decode: `decode` steps of `batch` tokens each.
    let decode_tokens = cfg.workload.batch;
    let dchunk = decode_tokens.min(cfg.max_chunk);
    if dchunk > 0 && cfg.workload.decode > 0 {
        let scale = cfg.workload.decode as f64 * decode_tokens as f64
            / dchunk as f64;
        let trace = serve_trace(cfg, dchunk, 2);
        sim_phase(sys, cfg, &mut dispatcher, &mut backend, placement,
                  &trace, scale, &mut rng, &mut metrics, &mut epoch,
                  &mut prefetch);
        if let Some(s) = &mut epoch {
            s.tick(cfg, &mut metrics, &mut prefetch);
        }
    }

    metrics.tokens = cfg.workload.total_tokens();
    if let Some(pf) = &mut prefetch {
        pf.finish();
        metrics.prefetch = pf.stats().clone();
    }
    let contention = backend.contention();
    (metrics, contention)
}

/// Outcome summary of a round-by-round (re-planned) run.
#[derive(Clone, Debug, Default)]
pub struct ReplanReport {
    /// Rounds replayed.
    pub rounds: usize,
    /// Epoch deltas actually applied.
    pub applied: usize,
    /// Expert-weight bytes migrated across all applied deltas.
    pub migration_bytes: f64,
    /// Per-round routed copies per GPU (summed over layers) — the
    /// load-share evidence the drifting-workload comparisons read.
    pub copies_rounds: Vec<Vec<f64>>,
}

impl ReplanReport {
    /// Max per-GPU share of routed copies over rounds `from..` (1/n_gpus
    /// is perfectly balanced). Returns 0 when the range is empty.
    pub fn max_load_share(&self, from: usize) -> f64 {
        let mut per_gpu: Vec<f64> = Vec::new();
        for round in self.copies_rounds.iter().skip(from) {
            if per_gpu.len() < round.len() {
                per_gpu.resize(round.len(), 0.0);
            }
            for (acc, &c) in per_gpu.iter_mut().zip(round) {
                *acc += c;
            }
        }
        let total: f64 = per_gpu.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        per_gpu.iter().cloned().fold(0.0, f64::max) / total
    }
}

/// Round-by-round online driver: replay `rounds` serving traces (each
/// one dispatch round per layer) against `placement`, optionally with
/// epoch re-planning between rounds. This is the drifting-workload
/// harness: the same call with `replan: None` is the static baseline,
/// bit-identical whenever the re-planner would have applied nothing.
pub fn simulate_rounds(sys: &SystemSpec, cfg: &SimConfig,
                       placement: &Placement, rounds: &[GateTrace],
                       replan_cfg: Option<ReplanConfig>)
                       -> (RunMetrics, ReplanReport) {
    assert_eq!(placement.experts, cfg.model.experts);
    assert_eq!(placement.num_gpus, cfg.topo.num_gpus());
    let coord = coordinator(sys, cfg);
    let mut dispatcher = coord.dispatcher(cfg.model.token_bytes());
    let mut rng = Rng::new(cfg.seed ^ 0x5E21);
    let mut backend = CommBackend::new(cfg.comm_backend, &cfg.topo);
    let mut metrics = RunMetrics::default();
    let mut report = ReplanReport::default();
    let mut epoch = replan_cfg
        .map(|rc| EpochState::new(placement.clone(), rc, sys, cfg));
    let mut prefetch = prefetch_engine(cfg);

    for trace in rounds {
        report.rounds += 1;
        let copies = sim_phase(sys, cfg, &mut dispatcher, &mut backend,
                               placement, trace, 1.0, &mut rng,
                               &mut metrics, &mut epoch, &mut prefetch);
        report.copies_rounds.push(copies);
        if let Some(s) = &mut epoch {
            if s.tick(cfg, &mut metrics, &mut prefetch) {
                report.applied += 1;
            }
        }
    }
    if let Some(s) = &epoch {
        report.migration_bytes = s.migration_bytes;
    }
    metrics.tokens = rounds.iter().map(GateTrace::num_tokens).sum();
    if let Some(pf) = &mut prefetch {
        pf.finish();
        metrics.prefetch = pf.stats().clone();
    }
    (metrics, report)
}

/// A drifting serving workload: `rounds` independently-sampled traces of
/// `tokens` each; from round `drift_at` on, expert identities are
/// rotated by `shift` ([`GateTrace::shift_experts`]) so the hot-expert
/// set the offline phase placed for moves elsewhere mid-run.
pub fn drifting_rounds(cfg: &SimConfig, rounds: usize, drift_at: usize,
                       shift: usize, tokens: usize) -> Vec<GateTrace> {
    (0..rounds)
        .map(|i| {
            let t = TraceGen {
                experts: cfg.model.experts,
                top_k: cfg.model.top_k,
                layers: cfg.model.moe_layers,
                profile: cfg.serve_profile,
                seed: cfg
                    .seed
                    .wrapping_mul(0x1009)
                    .wrapping_add(0xD81F + i as u64),
            }
            .generate(tokens);
            if i >= drift_at {
                t.shift_experts(shift)
            } else {
                t
            }
        })
        .collect()
}

/// Mutable re-planning state riding along one simulated run: the active
/// placement (diverges from the offline one once a delta lands), the
/// re-planner, and the migration accounting.
struct EpochState {
    active: Placement,
    replanner: Replanner,
    /// Straggler jitter for migration transfers — a stream separate
    /// from the dispatch RNG, drawn only when a delta is applied, so a
    /// run whose every epoch is empty stays bit-identical to the static
    /// path.
    mig_rng: Rng,
    migration_bytes: f64,
}

impl EpochState {
    fn new(active: Placement, rc: ReplanConfig, sys: &SystemSpec,
           cfg: &SimConfig) -> EpochState {
        let cost =
            CostParams::paper(&cfg.model, &cfg.gpu, sys.compute_eff);
        EpochState {
            active,
            replanner: Replanner::new(cfg.topo.clone(), rc, cost),
            mig_rng: Rng::new(cfg.seed ^ 0x4D16),
            migration_bytes: 0.0,
        }
    }

    /// Observe one dispatched layer round (post-dispatch, passive).
    fn observe(&mut self, layer: usize, plan: &DispatchPlan) {
        self.replanner
            .observe(layer, &self.active.layers[layer], plan);
    }

    /// Epoch boundary: evaluate, apply an accepted delta to the active
    /// placement, and price the expert-weight migration through the
    /// flat collective model (weights move point-to-point exactly like
    /// any other payload). With a weight tier riding along, replan
    /// swaps stage through it: replicas already resident (prefetched
    /// or left by an earlier epoch) copy nothing, and freshly staged
    /// ones are admitted so the next demand pass hits. Returns whether
    /// a delta was applied.
    fn tick(&mut self, cfg: &SimConfig, metrics: &mut RunMetrics,
            prefetch: &mut Option<PrefetchEngine>) -> bool {
        let delta = self.replanner.epoch_tick(&self.active);
        if delta.is_empty() {
            return false;
        }
        let expert_bytes = self.replanner.cost().expert_bytes;
        let traffic = match prefetch {
            Some(pf) => replan::migration_traffic_resident(
                &delta,
                &self.active,
                expert_bytes,
                &|l, e, g| pf.is_resident(g, l, e),
            ),
            None => replan::migration_traffic(&delta, &self.active,
                                              expert_bytes),
        };
        let moved = traffic.total_bytes();
        let rep =
            model::flat_all_to_all(&traffic, &cfg.topo, &mut self.mig_rng);
        metrics.e2e_time += rep.time;
        metrics.cross_bytes += rep.cross_bytes;
        metrics.intra_bytes += rep.intra_bytes;
        metrics.launches += rep.launches;
        metrics.migration_bytes += moved;
        metrics.replans += 1;
        self.migration_bytes += moved;
        if let Some(pf) = prefetch {
            for ld in &delta.layers {
                for &(e, g) in &ld.added {
                    pf.admit_migration(g, ld.layer, e);
                }
            }
        }
        self.active = replan::apply_delta(&self.active, &delta);
        true
    }
}

/// Build the optional epoch state for a run (re-planning rides along
/// only when both the system opts in and the config provides a cadence).
fn epoch_state(sys: &SystemSpec, cfg: &SimConfig, placement: &Placement)
               -> Option<EpochState> {
    match (sys.online_replan, cfg.replan) {
        (true, Some(rc)) => {
            Some(EpochState::new(placement.clone(), rc, sys, cfg))
        }
        _ => None,
    }
}

/// Serving trace: same distribution as the profile of `serve_profile` but
/// a different sample (decorrelated seed).
fn serve_trace(cfg: &SimConfig, tokens: usize, phase_tag: u64) -> GateTrace {
    TraceGen {
        experts: cfg.model.experts,
        top_k: cfg.model.top_k,
        layers: cfg.model.moe_layers,
        profile: cfg.serve_profile,
        seed: cfg.seed.wrapping_mul(0x1009).wrapping_add(phase_tag),
    }
    .generate(tokens)
}

/// Simulate one phase (all MoE layers over one token chunk), accumulating
/// scaled metrics; returns the phase's routed copies per GPU (summed over
/// layers). Each layer's chunk is one batched dispatch round through the
/// run's dispatcher, so the online phase uses exactly the policy the
/// offline phase placed for. With an [`EpochState`] riding along, each
/// layer round routes against the *active* (possibly re-planned)
/// placement and is observed by the re-planner after dispatch. With a
/// [`PrefetchEngine`] riding along, each finished plan additionally
/// feeds the cross-layer predictor and stages the next layer's
/// forecast experts, overlapped with the layer's compute.
#[allow(clippy::too_many_arguments)]
fn sim_phase(sys: &SystemSpec, cfg: &SimConfig,
             dispatcher: &mut Dispatcher, backend: &mut CommBackend,
             placement: &Placement, trace: &GateTrace, scale: f64,
             rng: &mut Rng, metrics: &mut RunMetrics,
             epoch: &mut Option<EpochState>,
             prefetch: &mut Option<PrefetchEngine>) -> Vec<f64> {
    let chunk = trace.num_tokens();
    let mut phase_copies = vec![0.0f64; cfg.topo.num_gpus()];

    for (layer_idx, layer) in trace.layers.iter().enumerate() {
        let plan = {
            let lp = match epoch {
                Some(s) => &s.active.layers[layer_idx],
                None => &placement.layers[layer_idx],
            };
            layer_round(sys, cfg, dispatcher, backend, lp, layer_idx,
                        layer, chunk, scale, rng, metrics, prefetch)
        };
        for (acc, &c) in phase_copies.iter_mut()
            .zip(plan.copies_per_gpu())
        {
            *acc += c as f64;
        }
        if let Some(s) = epoch {
            s.observe(layer_idx, &plan);
        }
        if let Some(pf) = prefetch {
            let next = pf.predictor().next_layer(layer_idx);
            let np = match epoch {
                Some(s) => &s.active.layers[next],
                None => &placement.layers[next],
            };
            let at = backend.cursor();
            pf.prefetch_pass(layer_idx, &plan, np, backend, &cfg.topo,
                             at);
        }
    }
    phase_copies
}

/// One layer's dispatch round: assemble the token-major assignment batch
/// (with C2R-style pruning when configured), route it, price the two A2A
/// rounds, and accumulate the scaled metrics. Returns the plan so the
/// caller can observe it.
#[allow(clippy::too_many_arguments)]
fn layer_round(sys: &SystemSpec, cfg: &SimConfig,
               dispatcher: &mut Dispatcher, backend: &mut CommBackend,
               lp: &crate::placement::LayerPlacement, layer_idx: usize,
               layer: &LayerTrace, chunk: usize, scale: f64,
               rng: &mut Rng, metrics: &mut RunMetrics,
               prefetch: &mut Option<PrefetchEngine>) -> DispatchPlan {
    let topo = &cfg.topo;
    let n_gpus = topo.num_gpus();
    let spec = &cfg.model;

    // --- Assemble the layer's assignment batch (token-major). ---
    let mut batch: Vec<Assignment> =
        Vec::with_capacity(chunk * spec.top_k);
    for (t, experts) in layer.tokens.iter().enumerate() {
        // Data parallelism: the batch is split evenly across GPUs.
        let src = even_src(t, chunk, n_gpus);
        for &e in experts {
            let e = e as usize;
            // C2R-style lossy pruning: a remote assignment is dropped
            // (confined to the collaboration group) with prob p.
            if sys.prune_remote > 0.0 {
                let primary = lp.primary[e];
                if !topo.same_node(src, primary)
                    && rng.chance(sys.prune_remote)
                {
                    continue;
                }
            }
            batch.push(Assignment { token: t, expert: e, src });
        }
    }

    // --- Route the whole batch in one dispatch round. ---
    let plan = dispatcher.dispatch(lp, layer_idx, &batch, rng);
    let copies: Vec<f64> = plan
        .copies_per_gpu()
        .iter()
        .map(|&c| c as f64)
        .collect();

    // --- Weight residency: block on cold-tier demand loads. ---
    let stall = match prefetch {
        Some(pf) => {
            let at = backend.cursor();
            pf.demand_pass(layer_idx, &plan, backend, topo, at)
        }
        None => 0.0,
    };

    // --- Communication: two A2A rounds (dispatch + combine). ---
    let overlap = if sys.comm == CommModel::Hsc {
        chunk as f64 * ROUTE_DECISION_COST / n_gpus as f64
    } else {
        0.0
    };
    let mut comm = backend.round(sys.comm, sys.dedup_flat, topo, &plan,
                                 overlap, rng);
    let combine = backend.round(sys.comm, sys.dedup_flat, topo, &plan,
                                0.0, rng);
    comm.accumulate(&combine);

    // --- Expert compute + synchronization idle. ---
    let mut t_max = 0.0f64;
    let mut t_sum = 0.0f64;
    for &c in &copies {
        let t = cfg.gpu.moe_time(spec, c) / sys.compute_eff
            + cfg.gpu.layer_overhead;
        t_max = t_max.max(t);
        t_sum += t;
    }
    let idle = n_gpus as f64 * t_max - t_sum;

    // --- Accumulate (extensive metrics scale with phase size). ---
    metrics.a2a_time += comm.time * sys.comm_eff * scale;
    metrics.cross_bytes += comm.cross_bytes * scale;
    metrics.intra_bytes += comm.intra_bytes * scale;
    metrics.launches += comm.launches;
    metrics.idle_time += idle * scale;
    metrics
        .layer_load_std
        .push(Summary::of(&copies).std() * scale);
    let layer_time = comm.time * sys.comm_eff + t_max;
    // Demand stalls are one-off staging events tied to the replayed
    // chunk, not extensive with the workload — accumulate unscaled.
    metrics.moe_layer_time += layer_time * scale + stall;
    // Dense (attention) part — identical across systems.
    let dense = cfg.gpu.dense_time(spec, chunk as f64 / n_gpus as f64)
        + cfg.gpu.layer_overhead;
    metrics.e2e_time += (layer_time + dense) * scale + stall;
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Small config for tests: OLMoE-shaped but few layers via a custom
    /// spec to keep debug-mode spectral clustering cheap.
    fn small_cfg(topo: Topology) -> SimConfig {
        let model = ModelSpec {
            moe_layers: 2,
            ..ModelSpec::olmoe()
        };
        let mut cfg = SimConfig::new(
            model,
            topo,
            Workload { batch: 32, prefill: 16, decode: 4 },
        );
        cfg.profile_tokens = 512;
        cfg.max_chunk = 512;
        cfg
    }

    #[test]
    fn metrics_are_positive_and_consistent() {
        let cfg = small_cfg(Topology::two_by_two());
        let m = simulate(&SystemSpec::vanilla(), &cfg);
        assert!(m.a2a_time > 0.0);
        assert!(m.cross_bytes > 0.0);
        assert!(m.moe_layer_time > m.a2a_time * 0.5);
        assert!(m.e2e_time >= m.moe_layer_time);
        assert_eq!(m.layer_load_std.len(), 2 * 2, "layers × phases");
        assert_eq!(m.tokens, 32 * 20);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = small_cfg(Topology::two_by_two());
        let a = simulate(&SystemSpec::grace(0.15), &cfg);
        let b = simulate(&SystemSpec::grace(0.15), &cfg);
        assert_eq!(a.e2e_time, b.e2e_time);
        assert_eq!(a.cross_bytes, b.cross_bytes);
    }

    #[test]
    fn load_aware_system_runs_and_is_deterministic() {
        let cfg = small_cfg(Topology::two_by_two());
        let sys = SystemSpec::grace_load_aware(0.15);
        let a = simulate(&sys, &cfg);
        let b = simulate(&sys, &cfg);
        assert!(a.e2e_time > 0.0 && a.e2e_time.is_finite());
        assert_eq!(a.e2e_time, b.e2e_time);
        assert_eq!(a.cross_bytes, b.cross_bytes);
    }

    #[test]
    fn grace_dyn_without_cadence_is_bit_identical_to_grace() {
        // The grace-dyn spec only *enables* re-planning; with no
        // ReplanConfig in the SimConfig the pipeline must be exactly
        // static GRACE.
        let cfg = small_cfg(Topology::two_by_two());
        let g = simulate(&SystemSpec::grace(0.15), &cfg);
        let d = simulate(&SystemSpec::grace_dyn(0.15), &cfg);
        assert_eq!(g.e2e_time, d.e2e_time);
        assert_eq!(g.cross_bytes, d.cross_bytes);
        assert_eq!(g.layer_load_std, d.layer_load_std);
        assert_eq!(d.migration_bytes, 0.0);
        assert_eq!(d.replans, 0);
    }

    #[test]
    fn grace_dyn_with_cadence_is_deterministic() {
        let mut cfg = small_cfg(Topology::two_by_two());
        cfg.replan = Some(ReplanConfig {
            epoch_rounds: 1,
            ..ReplanConfig::default()
        });
        let sys = SystemSpec::grace_dyn(0.15);
        let a = simulate(&sys, &cfg);
        let b = simulate(&sys, &cfg);
        assert!(a.e2e_time > 0.0 && a.e2e_time.is_finite());
        assert_eq!(a.e2e_time, b.e2e_time);
        assert_eq!(a.migration_bytes, b.migration_bytes);
        assert_eq!(a.replans, b.replans);
    }

    #[test]
    fn simulate_rounds_static_arm_reports_load_evidence() {
        let cfg = small_cfg(Topology::two_by_two());
        let sys = SystemSpec::grace(0.15);
        let placement = build_placement(&sys, &cfg);
        let rounds = drifting_rounds(&cfg, 4, 2, 7, 128);
        let (m, report) =
            simulate_rounds(&sys, &cfg, &placement, &rounds, None);
        assert_eq!(report.rounds, 4);
        assert_eq!(report.applied, 0);
        assert_eq!(report.copies_rounds.len(), 4);
        assert_eq!(m.tokens, 4 * 128);
        let share = report.max_load_share(0);
        assert!(share >= 0.25 && share <= 1.0, "share {share}");
        assert_eq!(report.max_load_share(99), 0.0, "empty range");
    }

    #[test]
    fn prefetch_rides_along_and_preserves_routing() {
        // Parity invariant: the tier/prefetch machinery may change
        // *when* weights move, never *what* is computed.
        let off_cfg = small_cfg(Topology::two_by_two());
        let mut on_cfg = off_cfg.clone();
        on_cfg.prefetch = Some(PrefetchConfig::default());
        let sys = SystemSpec::grace(0.15);
        let off = simulate(&sys, &off_cfg);
        let on = simulate(&sys, &on_cfg);
        assert_eq!(on.tokens, off.tokens);
        assert_eq!(on.cross_bytes, off.cross_bytes);
        assert_eq!(on.intra_bytes, off.intra_bytes);
        assert_eq!(on.layer_load_std, off.layer_load_std);
        // The tier is tight (8 of 64 experts): residency must cost.
        assert!(on.prefetch.stalls > 0, "cold start must stall");
        assert!(on.e2e_time >= off.e2e_time);
        assert_eq!(off.prefetch,
                   crate::metrics::PrefetchStats::default());
        // Determinism of the prefetch arm itself.
        let again = simulate(&sys, &on_cfg);
        assert_eq!(on.e2e_time, again.e2e_time);
        assert_eq!(on.prefetch, again.prefetch);
    }

    #[test]
    fn grace_beats_occult_end_to_end() {
        // The headline claim at small scale: GRACE < Occult on e2e and A2A.
        let cfg = small_cfg(Topology::two_by_two());
        let occ = simulate(&SystemSpec::occult(), &cfg);
        let gr = simulate(&SystemSpec::grace(0.15), &cfg);
        assert!(
            gr.a2a_time < occ.a2a_time,
            "grace a2a {} !< occult {}",
            gr.a2a_time,
            occ.a2a_time
        );
        assert!(
            gr.e2e_time < occ.e2e_time,
            "grace e2e {} !< occult {}",
            gr.e2e_time,
            occ.e2e_time
        );
    }

    #[test]
    fn hsc_reduces_cross_node_traffic_vs_flat() {
        let cfg = small_cfg(Topology::two_by_two());
        let occ = simulate(&SystemSpec::occult(), &cfg);
        let mut occ_hsc = SystemSpec::occult();
        occ_hsc.comm = CommModel::Hsc;
        occ_hsc.name = "occult+hsc";
        let h = simulate(&occ_hsc, &cfg);
        assert!(h.cross_bytes < occ.cross_bytes,
                "hsc {} !< flat {}", h.cross_bytes, occ.cross_bytes);
        // dedup shifts traffic intra-node (Table 1 signature)
        assert!(h.intra_bytes > occ.intra_bytes);
    }

    #[test]
    fn hg_increases_load_imbalance_dr_recovers_it() {
        // Table 1 RQ2 shape: HG worsens idle/load-std vs uniform; DR+WRR
        // pulls it back down.
        let mut cfg = small_cfg(Topology::two_by_two());
        cfg.serve_profile = Profile::Math; // strongest skew
        cfg.placement_profile = Profile::Math;
        let ladder = SystemSpec::table1_ladder(0.15);
        let occult_hsc = simulate(&ladder[1], &cfg);
        let hg_hsc = simulate(&ladder[2], &cfg);
        let dr_wrr = simulate(&ladder[4], &cfg);
        assert!(
            hg_hsc.mean_load_std() > occult_hsc.mean_load_std(),
            "HG should worsen load balance: {} !> {}",
            hg_hsc.mean_load_std(),
            occult_hsc.mean_load_std()
        );
        assert!(
            dr_wrr.mean_load_std() < hg_hsc.mean_load_std(),
            "DR+WRR should recover balance: {} !< {}",
            dr_wrr.mean_load_std(),
            hg_hsc.mean_load_std()
        );
    }

    #[test]
    fn tar_reduces_traffic_vs_wrr() {
        let cfg = small_cfg(Topology::two_by_two());
        let ladder = SystemSpec::table1_ladder(0.15);
        let wrr = simulate(&ladder[4], &cfg);
        let tar = simulate(&ladder[5], &cfg);
        assert!(
            tar.cross_bytes <= wrr.cross_bytes,
            "tar {} !<= wrr {}",
            tar.cross_bytes,
            wrr.cross_bytes
        );
    }

    #[test]
    fn c2r_prunes_compute_and_traffic() {
        let cfg = small_cfg(Topology::two_by_two());
        let occ = simulate(&SystemSpec::occult(), &cfg);
        let c2r = simulate(&SystemSpec::c2r(), &cfg);
        assert!(c2r.cross_bytes < occ.cross_bytes,
                "pruning must cut cross traffic");
    }

    #[test]
    fn scaling_chunks_preserves_extensive_metrics() {
        // doubling the workload should roughly double extensive metrics
        let cfg1 = small_cfg(Topology::two_by_two());
        let mut cfg2 = cfg1.clone();
        cfg2.workload.batch *= 2;
        let a = simulate(&SystemSpec::vanilla(), &cfg1);
        let b = simulate(&SystemSpec::vanilla(), &cfg2);
        let ratio = b.cross_bytes / a.cross_bytes;
        assert!((1.6..=2.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn cross_dataset_placement_transfer_runs() {
        // Fig. 6 machinery: place on Math, serve Text.
        let mut cfg = small_cfg(Topology::two_by_two());
        cfg.placement_profile = Profile::Math;
        cfg.serve_profile = Profile::Text;
        let sys = SystemSpec::grace(0.15);
        let placement = build_placement(&sys, &cfg);
        let m = simulate_with_placement(&sys, &cfg, &placement);
        assert!(m.e2e_time > 0.0);
    }

    #[test]
    fn larger_cluster_amplifies_grace_advantage() {
        // Fig. 4's scalability claim: speedup(2×4) ≥ speedup(2×2) − slack.
        let cfg22 = small_cfg(Topology::two_by_two());
        let cfg24 = small_cfg(Topology::two_by_four());
        let s22 = simulate(&SystemSpec::occult(), &cfg22).e2e_time
            / simulate(&SystemSpec::grace(0.15), &cfg22).e2e_time;
        let s24 = simulate(&SystemSpec::occult(), &cfg24).e2e_time
            / simulate(&SystemSpec::grace(0.15), &cfg24).e2e_time;
        assert!(s24 > s22 * 0.8, "2x4 speedup {s24} vs 2x2 {s22}");
    }
}
