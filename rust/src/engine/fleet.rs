//! Open-loop fleet replay: large request traces through the continuous
//! scheduler, the online re-planner, and the contended network.
//!
//! The timing engine ([`super::sim`]) prices one representative chunk per
//! phase and scales; the serving harness
//! ([`crate::server::sched::simulate_serve_with`]) drives real steps but
//! prices them with a caller-supplied flat cost. This driver closes the
//! gap: it replays a whole [`ServeLoad`] (up to 10⁵–10⁶ Poisson arrivals
//! on the *virtual* clock) where every scheduler step is priced by
//! routing its actual token batch through the dispatcher and the
//! [`CommBackend`] seam. With [`CommBackendKind::Des`] the dispatch and
//! combine collectives of concurrent steps queue on the simulated links,
//! and each request's prompt payload is DMA-ed through its host GPU's
//! ingress path at the arrival instant — so admission bursts contend
//! with decode traffic for the NIC, which is exactly the regime the
//! analytic α–β models cannot see.
//!
//! Re-planning rides along as in the timing engine (systems with
//! [`SystemSpec::online_replan`] plus a [`SimConfig::replan`] cadence):
//! every layer round is observed, epoch boundaries fall between steps,
//! and accepted migrations are priced through the same backend — on the
//! DES arm the weight copies queue behind serving traffic. The migration
//! cost model is refreshed from *measured* step time via
//! [`CostParams::from_observed`], so the payback gate uses the replay's
//! own tokens-per-second rather than the a-priori GPU model.

use crate::baselines::SystemSpec;
use crate::comm::model::{CommModel, CommReport};
use crate::comm::sim::{CommBackend, CommBackendKind};
use crate::config::ServeLoad;
use crate::configio::Value;
use crate::metrics::{ContentionReport, ServeMetrics};
use crate::placement::Placement;
use crate::replan::{self, CostParams, Replanner};
use crate::routing::{Assignment, DispatchPlan, Dispatcher};
use crate::server::sched::{SchedConfig, SchedMode, Scheduler};
use crate::server::{even_src, Request};
use crate::stats::Rng;
use crate::testutil::fake_decode_token;
use crate::trace::TraceGen;

use super::sim::{build_placement, coordinator, SimConfig,
                 ROUTE_DECISION_COST};

/// Configuration of one fleet replay: the system under test, the
/// simulated model/cluster, the request workload, and the scheduler's
/// admission limits. The communication backend is taken from
/// [`SimConfig::comm_backend`].
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// System under test (placement/routing/communication strategy).
    pub sys: SystemSpec,
    /// Model, cluster, seed, and backend of the simulated deployment.
    pub sim: SimConfig,
    /// Request workload (count, shape, arrival process).
    pub load: ServeLoad,
    /// Maximum concurrently-live sequences.
    pub max_batch: usize,
    /// Token budget one batched step may compute.
    pub max_batch_tokens: usize,
    /// Priority classes to spread the trace over: request `i` gets
    /// class `i % priority_classes` (1, the default, keeps the whole
    /// trace in class 0 — the pre-priority replay, bit-for-bit).
    pub priority_classes: usize,
    /// Evict lower-priority decodes for higher-priority arrivals.
    pub preempt: bool,
    /// Per-class TTFT deadlines, seconds (empty: no SLO shedding).
    pub ttft_slo: Vec<f64>,
}

impl FleetConfig {
    /// Fleet over `sys`/`sim`/`load` with default admission limits
    /// (32 live sequences, 2048 computed tokens per step), one
    /// priority class, and no preemption or SLO shedding.
    pub fn new(sys: SystemSpec, sim: SimConfig, load: ServeLoad)
               -> FleetConfig {
        FleetConfig { sys, sim, load, max_batch: 32,
                      max_batch_tokens: 2048, priority_classes: 1,
                      preempt: false, ttft_slo: Vec::new() }
    }

    /// Loud input validation: a zero-length trace, an empty prompt, a
    /// non-positive arrival rate, or zero admission limits would
    /// otherwise surface as a silent empty report or a scheduler stall
    /// deep into the replay.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.load.validate()?;
        anyhow::ensure!(self.max_batch > 0,
                        "max_batch must be at least 1");
        anyhow::ensure!(self.max_batch_tokens > 0,
                        "max_batch_tokens must be at least 1");
        anyhow::ensure!(self.priority_classes > 0,
                        "priority_classes must be at least 1");
        for (class, &slo) in self.ttft_slo.iter().enumerate() {
            anyhow::ensure!(slo.is_finite() && slo > 0.0,
                            "ttft_slo[{class}] = {slo} (want a \
                             positive finite deadline)");
        }
        if let Some(rc) = self.sim.replan {
            rc.validate()?;
        }
        Ok(())
    }
}

/// Outcome of one fleet replay.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Which communication backend priced the replay.
    pub backend: CommBackendKind,
    /// Serving-side metrics (latency/TTFT/TPOT distributions, steps,
    /// throughput) on the virtual clock.
    pub serve: ServeMetrics,
    /// Communication totals accumulated over every dispatch, combine,
    /// and migration collective.
    pub comm: CommReport,
    /// Network contention diagnostics (`None` on the analytic backend).
    pub contention: Option<ContentionReport>,
    /// Re-planning deltas applied during the replay.
    pub replans: usize,
    /// Expert-weight bytes migrated by applied deltas.
    pub migration_bytes: f64,
}

impl FleetReport {
    /// Deterministic JSON-style rendering — two replays with the same
    /// config must serialise identically (the `des-smoke` CI gate diffs
    /// this, including the DES event digest).
    pub fn to_value(&self) -> Value {
        let mean = |s: Option<crate::stats::Summary>| {
            Value::num(s.as_ref().map_or(0.0, |s| s.mean()))
        };
        let mut fields = vec![
            ("backend", Value::str(self.backend.name())),
            ("requests", Value::from(self.serve.latencies.len())),
            ("steps", Value::from(self.serve.steps)),
            ("dispatch_rounds", Value::from(self.serve.dispatch_rounds)),
            ("generated_tokens", Value::from(self.serve.generated_tokens)),
            ("computed_tokens", Value::from(self.serve.computed_tokens)),
            ("wall_time_s", Value::num(self.serve.wall_time)),
            ("throughput_tps", Value::num(self.serve.throughput_tps())),
            ("latency_mean_s", mean(self.serve.latency_summary())),
            ("latency_p99_s",
             Value::num(self.serve.latency_summary()
                 .map_or(0.0, |s| s.p99()))),
            ("ttft_mean_s", mean(self.serve.ttft_summary())),
            ("tpot_mean_s", mean(self.serve.tpot_summary())),
            ("queue_wait_mean_s", mean(self.serve.queue_wait_summary())),
            ("a2a_time_s", Value::num(self.comm.time)),
            ("a2a_sync_s", Value::num(self.comm.sync_time)),
            ("cross_bytes", Value::num(self.comm.cross_bytes)),
            ("intra_bytes", Value::num(self.comm.intra_bytes)),
            ("launches", Value::from(self.comm.launches)),
            ("replans", Value::from(self.replans)),
            ("migration_bytes", Value::num(self.migration_bytes)),
            ("preemptions", Value::from(self.serve.preemptions)),
            ("resumes", Value::from(self.serve.resumes)),
            ("rejected", Value::from(self.serve.rejected.len())),
        ];
        // Per-priority-class tails: the quantities the preemption bench
        // compares (urgent traffic's TTFT must not sit behind
        // background decodes).
        let classes = self.serve.priority_classes();
        let class_fields: Vec<(String, Value)> = classes
            .iter()
            .flat_map(|&c| {
                let ttft = self.serve.ttft_summary_class(c);
                let tpot = self.serve.tpot_summary_class(c);
                vec![
                    (format!("ttft_p95_class{c}_s"),
                     Value::num(ttft.as_ref()
                         .map_or(0.0, |s| s.p95()))),
                    (format!("tpot_mean_class{c}_s"),
                     Value::num(tpot.as_ref()
                         .map_or(0.0, |s| s.mean()))),
                ]
            })
            .collect();
        for (k, v) in &class_fields {
            fields.push((k.as_str(), v.clone()));
        }
        if let Some(c) = &self.contention {
            fields.push(("contention", Value::object(vec![
                ("max_utilization", Value::num(c.max_utilization)),
                ("queue_depth_p50", Value::num(c.queue_depth_p50)),
                ("queue_depth_p95", Value::num(c.queue_depth_p95)),
                ("queue_depth_p99", Value::num(c.queue_depth_p99)),
                ("queue_depth_max", Value::from(c.queue_depth_max)),
                ("queued_wait_s", Value::num(c.queued_wait_s)),
                ("straggler_stall_s", Value::num(c.straggler_stall_s)),
                ("transfers", Value::from(c.transfers as usize)),
                ("events", Value::from(c.events as usize)),
                ("event_digest",
                 Value::str(format!("{:016x}", c.event_digest))),
            ])));
        }
        Value::object(fields)
    }
}

/// Re-planning state riding along a fleet replay (mirrors the timing
/// engine's `EpochState`, but prices migrations through the replay's
/// [`CommBackend`] at the current virtual time).
struct FleetEpoch {
    active: Placement,
    replanner: Replanner,
    /// Jitter stream for migration transfers, separate from the dispatch
    /// RNG so empty epochs leave the dispatch stream untouched.
    mig_rng: Rng,
    migration_bytes: f64,
    replans: usize,
}

impl FleetEpoch {
    fn new(active: Placement, sys: &SystemSpec, cfg: &SimConfig)
           -> Option<FleetEpoch> {
        let rc = match (sys.online_replan, cfg.replan) {
            (true, Some(rc)) => rc,
            _ => return None,
        };
        let cost = CostParams::paper(&cfg.model, &cfg.gpu,
                                     sys.compute_eff);
        Some(FleetEpoch {
            active,
            replanner: Replanner::new(cfg.topo.clone(), rc, cost),
            mig_rng: Rng::new(cfg.seed ^ 0x4D16),
            migration_bytes: 0.0,
            replans: 0,
        })
    }

    fn observe(&mut self, layer: usize, plan: &DispatchPlan) {
        self.replanner
            .observe(layer, &self.active.layers[layer], plan);
    }

    /// Epoch boundary between steps: evaluate, apply, and price the
    /// weight migration through the backend at virtual time `at`.
    /// Returns the seconds the migration blocks the serving pipeline.
    fn tick(&mut self, cfg: &SimConfig, backend: &mut CommBackend,
            at: f64, comm_total: &mut CommReport) -> f64 {
        let delta = self.replanner.epoch_tick(&self.active);
        if delta.is_empty() {
            return 0.0;
        }
        let traffic = replan::migration_traffic(
            &delta,
            &self.active,
            self.replanner.cost().expert_bytes,
        );
        let rep = backend.flat_round_at(&traffic, &cfg.topo, at,
                                        &mut self.mig_rng);
        self.migration_bytes += delta.migration_bytes;
        self.replans += 1;
        self.active = replan::apply_delta(&self.active, &delta);
        let secs = rep.time;
        fold_comm(comm_total, &rep);
        secs
    }
}

/// Accumulate a collective's scalar costs without retaining its
/// per-stage diagnostics (a million-step replay would otherwise grow
/// `stage_times` unboundedly).
fn fold_comm(total: &mut CommReport, rep: &CommReport) {
    total.time += rep.time;
    total.cross_bytes += rep.cross_bytes;
    total.intra_bytes += rep.intra_bytes;
    total.launches += rep.launches;
    total.sync_time += rep.sync_time;
}

/// Deterministic synthetic prompt for request `id`; priority class
/// round-robins over `classes` so a mixed-priority trace interleaves
/// urgent and background traffic uniformly.
fn synth_request(id: u64, prompt: usize, new_tokens: usize,
                 classes: usize) -> Request {
    let prompt = (0..prompt)
        .map(|p| ((id as usize * 1009 + p * 31) % 997) as i32)
        .collect();
    Request { id, prompt, max_new_tokens: new_tokens,
              priority: id as usize % classes.max(1) }
}

/// Replay the whole [`ServeLoad`] through scheduler + re-planner +
/// network on the virtual clock.
///
/// Each scheduler step routes its actual computed-token batch through
/// every MoE layer (one dispatch round per layer, dispatch + combine
/// collectives priced at the step's virtual time) and advances the
/// clock by the resulting step seconds; arrivals land their prompt
/// payloads on the network at their arrival instants. The whole replay
/// is deterministic per [`SimConfig::seed`].
pub fn replay_fleet(cfg: &FleetConfig) -> anyhow::Result<FleetReport> {
    cfg.validate()?;
    let sim = &cfg.sim;
    let topo = &sim.topo;
    let n_gpus = topo.num_gpus();
    let token_bytes = sim.model.token_bytes();

    let placement = build_placement(&cfg.sys, sim);
    let mut dispatcher =
        coordinator(&cfg.sys, sim).dispatcher(token_bytes);
    let mut rng = Rng::new(sim.seed ^ 0x5E21);
    let mut backend = CommBackend::new(sim.comm_backend, topo);
    let mut epoch = FleetEpoch::new(placement.clone(), &cfg.sys, sim);

    // Arrival schedule (ascending) and synthetic requests, from an RNG
    // stream decoupled from dispatch so both backends replay the same
    // trace.
    let mut arr_rng = Rng::new(sim.seed ^ 0xA441);
    let arrivals: Vec<(Request, f64)> = cfg
        .load
        .arrival_times(&mut arr_rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            (synth_request(i as u64, cfg.load.prompt,
                           cfg.load.new_tokens, cfg.priority_classes),
             t)
        })
        .collect();

    let mut sched = Scheduler::new(SchedConfig {
        mode: SchedMode::Continuous,
        max_batch: cfg.max_batch,
        max_batch_tokens: cfg.max_batch_tokens,
        ctx: cfg.load.prompt + cfg.load.new_tokens,
        kv_cache: true,
        preempt: cfg.preempt,
        retain_cache_tokens: usize::MAX,
        ttft_slo: cfg.ttft_slo.clone(),
    })?;

    let mut comm_total = CommReport::default();
    let mut now = 0.0f64;
    let mut next_arrival = 0usize;
    let mut next_ingest = 0usize;
    let mut measured_secs = 0.0f64;
    let mut measured_tokens = 0usize;

    loop {
        // Prompt payload DMA: every request that has arrived by `now`
        // occupies its host GPU's NIC-in/ingress path at the arrival
        // instant (analytic backend: free, as in the α–β models).
        while next_ingest < arrivals.len()
            && arrivals[next_ingest].1 <= now
        {
            let (req, t) = &arrivals[next_ingest];
            let dst = (req.id as usize) % n_gpus;
            backend.ingest(dst, req.prompt.len() as f64 * token_bytes,
                           *t);
            next_ingest += 1;
        }

        // Offer arrived requests / admit from the pending queue.
        loop {
            if sched.wants_offer() && next_arrival < arrivals.len()
                && arrivals[next_arrival].1 <= now
            {
                let (req, t) = arrivals[next_arrival].clone();
                next_arrival += 1;
                sched.offer(req, t);
                continue;
            }
            let progressed = sched.admit_pending(now)?;
            // No engine-side caches to keep in lockstep here — cached
            // pricing self-accounts through `cached_len` (a dropped
            // cache re-prices resume as a full prefill) — but the
            // event buffer must not grow unboundedly over a 10⁵-request
            // replay.
            sched.take_events();
            if !progressed {
                break;
            }
        }
        if sched.is_idle() {
            if next_arrival >= arrivals.len() {
                break;
            }
            now = now.max(arrivals[next_arrival].1);
            continue;
        }
        anyhow::ensure!(!sched.live().is_empty(),
                        "fleet scheduler stalled with a pending request");

        // One batched step, priced through the network at `now`.
        let batch = sched.microbatch();
        let tokens = sched.step_tokens(&batch);
        let step = sched.steps();
        let (dt, rounds) = network_step(
            &cfg.sys, sim, &mut dispatcher, &mut backend, &placement,
            &mut epoch, tokens, step, now, &mut rng, &mut comm_total,
        );
        let next: Vec<i32> = batch
            .iter()
            .map(|&i| fake_decode_token(&sched.live()[i].ids))
            .collect();
        now += dt;
        measured_secs += dt;
        measured_tokens += tokens;
        sched.complete_step(&batch, &next, now, rounds)?;

        // Epoch boundary between steps: refresh the payback gate's cost
        // model from measured step time, then evaluate.
        if let Some(s) = &mut epoch {
            if let Some(cost) = CostParams::from_observed(
                &sim.model, measured_secs, measured_tokens)
            {
                s.replanner.update_cost(cost);
            }
            now += s.tick(sim, &mut backend, now, &mut comm_total);
        }
    }

    let (_responses, serve) = sched.into_results(now);
    let contention = backend.contention();
    Ok(FleetReport {
        backend: sim.comm_backend,
        serve,
        comm: comm_total,
        contention,
        replans: epoch.as_ref().map_or(0, |s| s.replans),
        migration_bytes: epoch.as_ref()
            .map_or(0.0, |s| s.migration_bytes),
    })
}

/// Price one scheduler step: route `tokens` computed tokens through
/// every MoE layer (dispatch + combine per layer through `backend` at
/// the accumulating virtual time), mirroring the timing engine's
/// per-layer cost model. Returns the step's seconds and its dispatch
/// round count.
#[allow(clippy::too_many_arguments)]
fn network_step(sys: &SystemSpec, cfg: &SimConfig,
                dispatcher: &mut Dispatcher, backend: &mut CommBackend,
                placement: &Placement, epoch: &mut Option<FleetEpoch>,
                tokens: usize, step: usize, at: f64, rng: &mut Rng,
                comm_total: &mut CommReport) -> (f64, usize) {
    let topo = &cfg.topo;
    let n_gpus = topo.num_gpus();
    let spec = &cfg.model;
    let trace = TraceGen {
        experts: spec.experts,
        top_k: spec.top_k,
        layers: spec.moe_layers,
        profile: cfg.serve_profile,
        seed: cfg
            .seed
            .wrapping_mul(0x1009)
            .wrapping_add(0xF1EE + step as u64),
    }
    .generate(tokens);

    let mut t = at;
    for (layer_idx, layer) in trace.layers.iter().enumerate() {
        let plan = {
            let lp = match epoch {
                Some(s) => &s.active.layers[layer_idx],
                None => &placement.layers[layer_idx],
            };
            let mut batch: Vec<Assignment> =
                Vec::with_capacity(tokens * spec.top_k);
            for (tok, experts) in layer.tokens.iter().enumerate() {
                let src = even_src(tok, tokens, n_gpus);
                for &e in experts {
                    let e = e as usize;
                    if sys.prune_remote > 0.0 {
                        let primary = lp.primary[e];
                        if !topo.same_node(src, primary)
                            && rng.chance(sys.prune_remote)
                        {
                            continue;
                        }
                    }
                    batch.push(Assignment { token: tok, expert: e, src });
                }
            }
            dispatcher.dispatch(lp, layer_idx, &batch, rng)
        };

        let overlap = if sys.comm == CommModel::Hsc {
            tokens as f64 * ROUTE_DECISION_COST / n_gpus as f64
        } else {
            0.0
        };
        let mut comm = backend.round_at(sys.comm, sys.dedup_flat, topo,
                                        &plan, overlap, t, rng);
        let combine = backend.round_at(sys.comm, sys.dedup_flat, topo,
                                       &plan, 0.0, t + comm.time, rng);
        comm.accumulate(&combine);

        let mut t_max = 0.0f64;
        for &c in plan.copies_per_gpu() {
            let tc = cfg.gpu.moe_time(spec, c as f64) / sys.compute_eff
                + cfg.gpu.layer_overhead;
            t_max = t_max.max(tc);
        }
        let dense = cfg.gpu
            .dense_time(spec, tokens as f64 / n_gpus as f64)
            + cfg.gpu.layer_overhead;
        t += comm.time * sys.comm_eff + t_max + dense;
        fold_comm(comm_total, &comm);
        if let Some(s) = epoch {
            s.observe(layer_idx, &plan);
        }
    }
    (t - at, 2 * spec.moe_layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::{ArrivalProcess, ModelSpec, Workload};
    use crate::replan::ReplanConfig;

    fn small_sim(backend: CommBackendKind) -> SimConfig {
        let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
        let mut sim = SimConfig::new(
            model,
            Topology::two_by_two(),
            Workload { batch: 8, prefill: 8, decode: 2 },
        );
        sim.profile_tokens = 256;
        sim.max_chunk = 256;
        sim.comm_backend = backend;
        sim
    }

    fn small_load(rate: f64) -> ServeLoad {
        ServeLoad {
            requests: 12,
            prompt: 8,
            new_tokens: 3,
            arrival: ArrivalProcess::Poisson { rate },
        }
    }

    fn small_fleet(backend: CommBackendKind, rate: f64) -> FleetConfig {
        let mut cfg = FleetConfig::new(SystemSpec::grace(0.15),
                                       small_sim(backend),
                                       small_load(rate));
        cfg.max_batch = 4;
        cfg.max_batch_tokens = 64;
        cfg
    }

    #[test]
    fn fleet_serves_every_request_and_is_deterministic() {
        let cfg = small_fleet(CommBackendKind::Analytic, 200.0);
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.serve.generated_tokens, 12 * 3);
        assert!(a.serve.wall_time > 0.0);
        assert!(a.comm.time > 0.0);
        assert!(a.contention.is_none(), "analytic has no contention");
        assert_eq!(a.serve.wall_time, b.serve.wall_time);
        assert_eq!(a.comm.time, b.comm.time);
    }

    #[test]
    fn des_fleet_reports_contention_and_matches_request_count() {
        let cfg = small_fleet(CommBackendKind::Des, 200.0);
        let r = replay_fleet(&cfg).unwrap();
        assert_eq!(r.serve.latencies.len(), 12);
        let c = r.contention.expect("DES must report contention");
        assert!(c.transfers > 0);
        assert!(c.events >= 4 * c.transfers,
                "each transfer arrives and departs on every leg");
        assert!(c.max_utilization > 0.0 && c.max_utilization <= 1.0);
    }

    #[test]
    fn des_replay_is_bit_deterministic() {
        let cfg = small_fleet(CommBackendKind::Des, 500.0);
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        let (ca, cb) = (a.contention.unwrap(), b.contention.unwrap());
        assert_eq!(ca.event_digest, cb.event_digest);
        assert_eq!(ca.events, cb.events);
        assert_eq!(a.serve.wall_time, b.serve.wall_time);
        assert_eq!(a.to_value(), b.to_value());
    }

    #[test]
    fn saturating_arrivals_inflate_des_latency_over_analytic() {
        // Same workload, both backends: at a crush arrival rate the DES
        // queues prompt DMA + dispatch traffic on finite links, so its
        // mean latency must exceed the uncontended analytic pricing.
        let slow = replay_fleet(&small_fleet(CommBackendKind::Des, 1e5))
            .unwrap();
        let fast =
            replay_fleet(&small_fleet(CommBackendKind::Analytic, 1e5))
                .unwrap();
        let l_des = slow.serve.latency_summary().unwrap().mean();
        let l_ana = fast.serve.latency_summary().unwrap().mean();
        assert!(l_des >= l_ana,
                "contended {l_des} must not beat uncontended {l_ana}");
    }

    #[test]
    fn replanning_fleet_runs_and_stays_deterministic() {
        let mut cfg = small_fleet(CommBackendKind::Des, 300.0);
        cfg.sys = SystemSpec::grace_dyn(0.15);
        cfg.sim.replan =
            Some(ReplanConfig { epoch_rounds: 2,
                                ..ReplanConfig::default() });
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.replans, b.replans);
        assert_eq!(a.migration_bytes, b.migration_bytes);
        assert_eq!(a.contention.unwrap().event_digest,
                   b.contention.unwrap().event_digest);
    }

    #[test]
    fn validation_rejects_degenerate_inputs() {
        let good = small_fleet(CommBackendKind::Analytic, 10.0);
        assert!(good.validate().is_ok());

        let mut zero_req = good.clone();
        zero_req.load.requests = 0;
        assert!(replay_fleet(&zero_req).is_err());

        let mut zero_prompt = good.clone();
        zero_prompt.load.prompt = 0;
        assert!(replay_fleet(&zero_prompt).is_err());

        let mut bad_rate = good.clone();
        bad_rate.load.arrival = ArrivalProcess::Poisson { rate: 0.0 };
        assert!(replay_fleet(&bad_rate).is_err());

        let mut no_batch = good.clone();
        no_batch.max_batch = 0;
        assert!(replay_fleet(&no_batch).is_err());

        let mut no_class = good.clone();
        no_class.priority_classes = 0;
        assert!(replay_fleet(&no_class).is_err());

        let mut bad_slo = good.clone();
        bad_slo.ttft_slo = vec![0.0];
        assert!(replay_fleet(&bad_slo).is_err());

        let mut bad_epoch = good;
        bad_epoch.sim.replan =
            Some(ReplanConfig { epoch_rounds: 0,
                                ..ReplanConfig::default() });
        assert!(replay_fleet(&bad_epoch).is_err());
    }

    #[test]
    fn report_serialises_key_fields() {
        let cfg = small_fleet(CommBackendKind::Des, 100.0);
        let v = replay_fleet(&cfg).unwrap().to_value();
        assert_eq!(v.str_or("backend", ""), "des");
        assert_eq!(v.req_usize("requests").unwrap(), 12);
        assert!(v.req_f64("wall_time_s").unwrap() > 0.0);
        assert_eq!(v.req_usize("preemptions").unwrap(), 0);
        assert_eq!(v.req_usize("rejected").unwrap(), 0);
        assert!(v.req_f64("ttft_p95_class0_s").unwrap() > 0.0);
        let c = v.req("contention").unwrap();
        assert_eq!(c.req_str("event_digest").unwrap().len(), 16);
    }

    #[test]
    fn priority_fleet_replays_per_class_and_stays_deterministic() {
        // Two classes, preemption on, a crush arrival rate: every
        // request still completes (no SLO set), both classes report
        // tails, and the replay stays bit-deterministic.
        let mut cfg = small_fleet(CommBackendKind::Analytic, 1e4);
        cfg.priority_classes = 2;
        cfg.preempt = true;
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.serve.rejected.len(), 0);
        assert_eq!(a.serve.priority_classes(), vec![0, 1]);
        assert_eq!(a.to_value(), b.to_value());
        let v = a.to_value();
        assert!(v.req_f64("ttft_p95_class0_s").unwrap() > 0.0);
        assert!(v.req_f64("ttft_p95_class1_s").unwrap() > 0.0);
        // SLO shedding surfaces loudly in the report.
        let mut shed = small_fleet(CommBackendKind::Analytic, 1e4);
        shed.ttft_slo = vec![1e-9, 1e9];
        shed.priority_classes = 2;
        let r = replay_fleet(&shed).unwrap();
        assert!(!r.serve.rejected.is_empty(),
                "a 1 ns class-0 deadline must shed");
        assert_eq!(
            r.serve.latencies.len() + r.serve.rejected.len(),
            12,
            "every request either completes or is shed loudly"
        );
    }
}
