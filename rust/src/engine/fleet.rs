//! Open-loop fleet replay: large request traces through the continuous
//! scheduler, the online re-planner, and the contended network — now
//! over a *replica-sharded fleet* of N independent serving shards.
//!
//! The timing engine ([`super::sim`]) prices one representative chunk per
//! phase and scales; the serving harness
//! ([`crate::server::sched::simulate_serve_with`]) drives real steps but
//! prices them with a caller-supplied flat cost. This driver closes the
//! gap: it replays a whole [`ServeLoad`] (up to 10⁵–10⁶ Poisson arrivals
//! on the *virtual* clock) where every scheduler step is priced by
//! routing its actual token batch through the dispatcher and the
//! [`CommBackend`] seam. With [`CommBackendKind::Des`] the dispatch and
//! combine collectives of concurrent steps queue on the simulated links,
//! and each request's prompt payload is DMA-ed through its host GPU's
//! ingress path at the arrival instant — so admission bursts contend
//! with decode traffic for the NIC, which is exactly the regime the
//! analytic α–β models cannot see.
//!
//! **Fleet sharding** ([`ShardConfig::replicas`] > 1): the replay
//! becomes the virtual-clock twin of the threaded
//! [`crate::server::shard::FleetFrontend`]. One admission front-end
//! routes each arrival to exactly one shard through the shared
//! [`FleetRouter`] (jsq / wrr / placement-affinity over the per-class
//! gate profiles of [`ClassProfiles`]); each shard owns its own
//! scheduler, dispatcher, placement copy, and network backend, and the
//! shards are interleaved deterministically by a min-virtual-clock loop
//! (always step the shard whose next work item is earliest, ties to the
//! lowest index). A single-replica fleet reduces *bit-for-bit* to the
//! pre-sharding replay — `tests::reference` keeps the old loop alive as
//! the parity oracle.
//!
//! Re-planning rides along as in the timing engine (systems with
//! [`SystemSpec::online_replan`] plus a [`SimConfig::replan`] cadence):
//! every layer round from every shard feeds one fleet-wide
//! [`Replanner`], epoch boundaries fall between steps, and accepted
//! deltas roll out replica-by-replica through
//! [`crate::replan::RollingReplan`] — at most one shard swaps per
//! epoch, its migration priced through its own backend at its own
//! virtual time, while the other N−1 shards keep serving (no global
//! barrier). The migration cost model is refreshed from *measured*
//! fleet step time via [`CostParams::from_observed`], so the payback
//! gate uses the replay's own tokens-per-second rather than the
//! a-priori GPU model.

use crate::baselines::SystemSpec;
use crate::comm::model::{CommModel, CommReport};
use crate::comm::sim::{CommBackend, CommBackendKind};
use crate::config::ServeLoad;
use crate::configio::Value;
use crate::engine::prefetch::PrefetchEngine;
use crate::metrics::{ContentionReport, PrefetchStats, ServeMetrics};
use crate::placement::Placement;
use crate::replan::{self, CostParams, PreparedDelta, Replanner,
                    RollingReplan};
use crate::routing::{Assignment, Dispatcher};
use crate::server::sched::{SchedConfig, SchedEvent, SchedMode, Scheduler};
use crate::server::shard::{ClassProfiles, FleetRoutePolicy, FleetRouter,
                           ShardConfig};
use crate::server::{even_src, Request};
use crate::stats::Rng;
use crate::testutil::fake_decode_token;
use crate::trace::TraceGen;
use std::collections::VecDeque;

use super::sim::{build_placement, coordinator, prefetch_engine,
                 SimConfig, ROUTE_DECISION_COST};

/// Per-shard seed decorrelation stride (splitmix64's golden-gamma);
/// shard 0 keeps the base seed so a single-replica fleet replays the
/// pre-sharding RNG streams bit-for-bit.
const SHARD_SEED_STRIDE: u64 = 0x9E3779B97F4A7C15;

/// Configuration of one fleet replay: the system under test, the
/// simulated model/cluster, the request workload, the scheduler's
/// admission limits, and the fleet shape ([`ShardConfig`]). The
/// communication backend is taken from [`SimConfig::comm_backend`].
#[derive(Clone, Debug)]
pub struct FleetConfig {
    /// System under test (placement/routing/communication strategy).
    pub sys: SystemSpec,
    /// Model, cluster, seed, and backend of the simulated deployment.
    pub sim: SimConfig,
    /// Request workload (count, shape, arrival process).
    pub load: ServeLoad,
    /// Maximum concurrently-live sequences (per shard).
    pub max_batch: usize,
    /// Token budget one batched step may compute (per shard).
    pub max_batch_tokens: usize,
    /// Priority classes to spread the trace over: request `i` gets
    /// class `i % priority_classes` (1, the default, keeps the whole
    /// trace in class 0 — the pre-priority replay, bit-for-bit).
    pub priority_classes: usize,
    /// Evict lower-priority decodes for higher-priority arrivals.
    pub preempt: bool,
    /// Per-class TTFT deadlines, seconds (empty: no SLO shedding).
    pub ttft_slo: Vec<f64>,
    /// Fleet shape: replica count, route policy, and fleet-wide
    /// admission queue capacity. The replay default keeps the queue
    /// unbounded (`usize::MAX`) so a single-replica fleet reproduces
    /// the pre-sharding closed-loop behaviour exactly; a finite cap
    /// sheds overflow arrivals loudly into the rejected list.
    pub shard: ShardConfig,
    /// Condition the synthetic gate trace on priority class: each
    /// token's expert picks rotate by `class · experts / classes`, so
    /// different classes exercise different hot experts (the regime
    /// where placement-affinity routing has something to win). Off by
    /// default — the unconditioned trace is the bit-compatible one.
    pub class_shift: bool,
    /// Give replica `r` a placement built from the profiling trace
    /// shifted by class `r % priority_classes` (instead of a clone of
    /// the shared offline placement), specialising each replica to one
    /// class's hot experts. Off by default.
    pub replica_profiles: bool,
}

impl FleetConfig {
    /// Fleet over `sys`/`sim`/`load` with default admission limits
    /// (32 live sequences, 2048 computed tokens per step), one
    /// priority class, no preemption or SLO shedding, and a
    /// single-replica jsq fleet with an unbounded admission queue.
    pub fn new(sys: SystemSpec, sim: SimConfig, load: ServeLoad)
               -> FleetConfig {
        FleetConfig {
            sys,
            sim,
            load,
            max_batch: 32,
            max_batch_tokens: 2048,
            priority_classes: 1,
            preempt: false,
            ttft_slo: Vec::new(),
            shard: ShardConfig {
                queue_cap: usize::MAX,
                ..ShardConfig::default()
            },
            class_shift: false,
            replica_profiles: false,
        }
    }

    /// Loud input validation: a zero-length trace, an empty prompt, a
    /// non-positive arrival rate, zero admission limits, or a
    /// degenerate fleet shape (`--replicas 0`, queue smaller than the
    /// fleet) would otherwise surface as a silent empty report or a
    /// scheduler stall deep into the replay.
    pub fn validate(&self) -> anyhow::Result<()> {
        self.load.validate()?;
        self.shard.validate()?;
        anyhow::ensure!(self.max_batch > 0,
                        "max_batch must be at least 1");
        anyhow::ensure!(self.max_batch_tokens > 0,
                        "max_batch_tokens must be at least 1");
        anyhow::ensure!(self.priority_classes > 0,
                        "priority_classes must be at least 1");
        for (class, &slo) in self.ttft_slo.iter().enumerate() {
            anyhow::ensure!(slo.is_finite() && slo > 0.0,
                            "ttft_slo[{class}] = {slo} (want a \
                             positive finite deadline)");
        }
        if let Some(rc) = self.sim.replan {
            rc.validate()?;
        }
        Ok(())
    }
}

/// Outcome of one fleet replay.
#[derive(Clone, Debug)]
pub struct FleetReport {
    /// Which communication backend priced the replay.
    pub backend: CommBackendKind,
    /// Replica shards the fleet ran.
    pub replicas: usize,
    /// Fleet-wide serving metrics on the virtual clock: per-replica
    /// distributions merged, counters summed, wall-clock the slowest
    /// shard's (shards serve concurrently).
    pub serve: ServeMetrics,
    /// Per-replica serving metrics, indexed by shard.
    pub per_replica: Vec<ServeMetrics>,
    /// Communication totals accumulated over every dispatch, combine,
    /// and migration collective on every shard.
    pub comm: CommReport,
    /// Network contention diagnostics folded across shards (`None` on
    /// the analytic backend).
    pub contention: Option<ContentionReport>,
    /// Completed re-plan rollouts (every shard swapped to the delta).
    pub replans: usize,
    /// Individual replica placement swaps (one per shard per rollout;
    /// `replans × replicas` once every rollout has completed).
    pub swaps: usize,
    /// The rolling-replan swap log: `(epoch, shard)` per swap, in
    /// commit order — the "at most one shard swaps per epoch"
    /// invariant is assertable directly on it.
    pub swap_log: Vec<(u64, usize)>,
    /// Expert-weight bytes migrated by applied deltas.
    pub migration_bytes: f64,
    /// Weight-staging counters summed over shards (`None` when the
    /// replay ran without a weight tier — the bit-compatible default).
    pub prefetch: Option<PrefetchStats>,
}

impl FleetReport {
    /// Fleet load imbalance: the busiest shard's generated tokens over
    /// the per-shard mean (1.0 = perfectly balanced; 0.0 when nothing
    /// was generated).
    pub fn fleet_imbalance(&self) -> f64 {
        let total: usize = self
            .per_replica
            .iter()
            .map(|m| m.generated_tokens)
            .sum();
        if total == 0 || self.per_replica.is_empty() {
            return 0.0;
        }
        let mean = total as f64 / self.per_replica.len() as f64;
        let max = self
            .per_replica
            .iter()
            .map(|m| m.generated_tokens)
            .max()
            .unwrap_or(0) as f64;
        max / mean
    }

    /// Deterministic JSON-style rendering — two replays with the same
    /// config must serialise identically (the `des-smoke` and
    /// `fleet-smoke` CI gates diff this, including the DES event
    /// digest).
    pub fn to_value(&self) -> Value {
        let mean = |s: Option<crate::stats::Summary>| {
            Value::num(s.as_ref().map_or(0.0, |s| s.mean()))
        };
        let mut fields = vec![
            ("backend", Value::str(self.backend.name())),
            ("replicas", Value::from(self.replicas)),
            ("requests", Value::from(self.serve.latencies.len())),
            ("steps", Value::from(self.serve.steps)),
            ("dispatch_rounds", Value::from(self.serve.dispatch_rounds)),
            ("generated_tokens", Value::from(self.serve.generated_tokens)),
            ("computed_tokens", Value::from(self.serve.computed_tokens)),
            ("wall_time_s", Value::num(self.serve.wall_time)),
            ("throughput_tps", Value::num(self.serve.throughput_tps())),
            ("latency_mean_s", mean(self.serve.latency_summary())),
            ("latency_p99_s",
             Value::num(self.serve.latency_summary()
                 .map_or(0.0, |s| s.p99()))),
            ("ttft_mean_s", mean(self.serve.ttft_summary())),
            ("tpot_mean_s", mean(self.serve.tpot_summary())),
            ("queue_wait_mean_s", mean(self.serve.queue_wait_summary())),
            ("a2a_time_s", Value::num(self.comm.time)),
            ("a2a_sync_s", Value::num(self.comm.sync_time)),
            ("cross_bytes", Value::num(self.comm.cross_bytes)),
            ("intra_bytes", Value::num(self.comm.intra_bytes)),
            ("launches", Value::from(self.comm.launches)),
            ("replans", Value::from(self.replans)),
            ("swaps", Value::from(self.swaps)),
            ("migration_bytes", Value::num(self.migration_bytes)),
            ("fleet_imbalance", Value::num(self.fleet_imbalance())),
            ("preemptions", Value::from(self.serve.preemptions)),
            ("resumes", Value::from(self.serve.resumes)),
            ("rejected", Value::from(self.serve.rejected.len())),
        ];
        // Per-priority-class tails: the quantities the preemption bench
        // compares (urgent traffic's TTFT must not sit behind
        // background decodes).
        let classes = self.serve.priority_classes();
        let class_fields: Vec<(String, Value)> = classes
            .iter()
            .flat_map(|&c| {
                let ttft = self.serve.ttft_summary_class(c);
                let tpot = self.serve.tpot_summary_class(c);
                vec![
                    (format!("ttft_p95_class{c}_s"),
                     Value::num(ttft.as_ref()
                         .map_or(0.0, |s| s.p95()))),
                    (format!("tpot_mean_class{c}_s"),
                     Value::num(tpot.as_ref()
                         .map_or(0.0, |s| s.mean()))),
                ]
            })
            .collect();
        for (k, v) in &class_fields {
            fields.push((k.as_str(), v.clone()));
        }
        // Per-replica breakdown: enough to read shard balance and
        // per-shard latency off the report without a second run.
        let replica_fields: Vec<(String, Value)> = self
            .per_replica
            .iter()
            .enumerate()
            .map(|(r, m)| {
                (format!("replica{r}"),
                 Value::object(vec![
                     ("requests", Value::from(m.latencies.len())),
                     ("generated_tokens",
                      Value::from(m.generated_tokens)),
                     ("steps", Value::from(m.steps)),
                     ("wall_time_s", Value::num(m.wall_time)),
                     ("ttft_mean_s",
                      Value::num(m.ttft_summary()
                          .map_or(0.0, |s| s.mean()))),
                 ]))
            })
            .collect();
        for (k, v) in &replica_fields {
            fields.push((k.as_str(), v.clone()));
        }
        if let Some(p) = &self.prefetch {
            fields.push(("prefetch", Value::object(vec![
                ("prefetches", Value::from(p.prefetches)),
                ("hits", Value::from(p.hits)),
                ("stalls", Value::from(p.stalls)),
                ("stall_steps", Value::from(p.stall_steps)),
                ("evictions", Value::from(p.evictions)),
                ("hit_rate", Value::num(p.hit_rate())),
                ("prefetch_bytes", Value::num(p.prefetch_bytes)),
                ("demand_bytes", Value::num(p.demand_bytes)),
                ("wasted_bytes", Value::num(p.wasted_bytes)),
            ])));
        }
        if let Some(c) = &self.contention {
            fields.push(("contention", Value::object(vec![
                ("max_utilization", Value::num(c.max_utilization)),
                ("queue_depth_p50", Value::num(c.queue_depth_p50)),
                ("queue_depth_p95", Value::num(c.queue_depth_p95)),
                ("queue_depth_p99", Value::num(c.queue_depth_p99)),
                ("queue_depth_max", Value::from(c.queue_depth_max)),
                ("queued_wait_s", Value::num(c.queued_wait_s)),
                ("straggler_stall_s", Value::num(c.straggler_stall_s)),
                ("transfers", Value::from(c.transfers as usize)),
                ("events", Value::from(c.events as usize)),
                ("event_digest",
                 Value::str(format!("{:016x}", c.event_digest))),
            ])));
        }
        Value::object(fields)
    }
}

/// One serving shard of the fleet: its own scheduler, dispatcher,
/// network backend, RNG stream, active placement copy, admission
/// queue, and virtual clock. Shard 0's streams equal the pre-sharding
/// replay's.
struct Shard {
    sched: Scheduler,
    dispatcher: Dispatcher,
    backend: CommBackend,
    rng: Rng,
    active: Placement,
    queue: VecDeque<(Request, f64)>,
    now: f64,
    /// Base of this shard's per-step trace seeds.
    seed: u64,
    /// Weight tier + predictor (None: every weight stays resident).
    prefetch: Option<PrefetchEngine>,
}

impl Shard {
    /// The earliest virtual time at which this shard can run one
    /// serving iteration: now if sequences are in flight, the head
    /// arrival's instant if only queued work exists, `None` when the
    /// shard has nothing to do.
    fn ready_time(&self) -> Option<f64> {
        if !self.sched.is_idle() {
            Some(self.now)
        } else {
            self.queue.front().map(|&(_, ta)| self.now.max(ta))
        }
    }
}

/// Fleet-wide re-planning state: one shared [`Replanner`] aggregating
/// every shard's observed dispatch plans, rolled out shard-by-shard
/// through [`RollingReplan`] (at most one shard drains/swaps per epoch;
/// the other N−1 keep serving). Mirrors the timing engine's
/// `EpochState`, but prices each shard's migration through that shard's
/// [`CommBackend`] at its own virtual time.
struct FleetEpochs {
    replanner: Replanner,
    rolling: RollingReplan,
    /// Jitter stream for migration transfers, separate from the dispatch
    /// RNGs so empty epochs leave the dispatch streams untouched.
    mig_rng: Rng,
    migration_bytes: f64,
    /// Completed rollouts (every shard swapped).
    replans: usize,
}

impl FleetEpochs {
    fn new(sys: &SystemSpec, cfg: &SimConfig, replicas: usize)
           -> Option<FleetEpochs> {
        let rc = match (sys.online_replan, cfg.replan) {
            (true, Some(rc)) => rc,
            _ => return None,
        };
        let cost = CostParams::paper(&cfg.model, &cfg.gpu,
                                     sys.compute_eff);
        Some(FleetEpochs {
            replanner: Replanner::new(cfg.topo.clone(), rc, cost),
            rolling: RollingReplan::new(replicas),
            mig_rng: Rng::new(cfg.seed ^ 0x4D16),
            migration_bytes: 0.0,
            replans: 0,
        })
    }

    /// Epoch boundary at shard `r`'s step edge. With no rollout in
    /// flight, evaluate the fleet-wide epoch against this shard's
    /// active placement and prepare any accepted delta (the instance
    /// tables are built *once* here — [`PreparedDelta`] — not once per
    /// shard). Then, if the rolling cursor points at this shard in a
    /// fresh epoch, price its migration through its own backend and
    /// swap its placement. Returns the seconds the swap blocks this
    /// shard's pipeline (the other shards never stall).
    fn tick(&mut self, cfg: &SimConfig, r: usize, shard: &mut Shard,
            comm_total: &mut CommReport) -> f64 {
        if !self.rolling.in_flight() {
            let delta = self.replanner.epoch_tick(&shard.active);
            if !delta.is_empty() {
                self.rolling
                    .begin(PreparedDelta::new(&shard.active, delta));
            }
        }
        let epoch = self.replanner.estimator().max_rounds()
            / self.replanner.config().epoch_rounds;
        if !self.rolling.due(r, epoch) {
            return 0.0;
        }
        let secs;
        {
            let prep = self
                .rolling
                .prepared()
                .expect("due implies a prepared delta");
            let traffic = match &shard.prefetch {
                Some(pf) => replan::migration_traffic_resident(
                    prep.delta(),
                    &shard.active,
                    self.replanner.cost().expert_bytes,
                    &|l, e, g| pf.is_resident(g, l, e),
                ),
                None => replan::migration_traffic(
                    prep.delta(),
                    &shard.active,
                    self.replanner.cost().expert_bytes,
                ),
            };
            let rep = shard.backend.flat_round_at(&traffic, &cfg.topo,
                                                  shard.now,
                                                  &mut self.mig_rng);
            self.migration_bytes += traffic.total_bytes();
            if let Some(pf) = &mut shard.prefetch {
                for ld in &prep.delta().layers {
                    for &(e, g) in &ld.added {
                        pf.admit_migration(g, ld.layer, e);
                    }
                }
            }
            shard.active = prep.apply(&shard.active);
            fold_comm(comm_total, &rep);
            secs = rep.time;
        }
        self.rolling.commit(r, epoch);
        if !self.rolling.in_flight() {
            self.replans += 1;
        }
        secs
    }
}

/// Accumulate a collective's scalar costs without retaining its
/// per-stage diagnostics (a million-step replay would otherwise grow
/// `stage_times` unboundedly).
fn fold_comm(total: &mut CommReport, rep: &CommReport) {
    total.time += rep.time;
    total.cross_bytes += rep.cross_bytes;
    total.intra_bytes += rep.intra_bytes;
    total.launches += rep.launches;
    total.sync_time += rep.sync_time;
}

/// Fold shard `b`'s contention diagnostics into `a`: transfer/event
/// counts and waits sum, utilizations and depths take the fleet max,
/// and the event digests chain through an FNV-style mix so any shard's
/// event-stream change perturbs the fleet digest. Folding a fleet of
/// one is the identity.
fn fold_contention(a: &mut ContentionReport, b: &ContentionReport) {
    for (u, &v) in a
        .per_link_utilization
        .iter_mut()
        .zip(&b.per_link_utilization)
    {
        *u = u.max(v);
    }
    a.max_utilization = a.max_utilization.max(b.max_utilization);
    a.queue_depth_p50 = a.queue_depth_p50.max(b.queue_depth_p50);
    a.queue_depth_p95 = a.queue_depth_p95.max(b.queue_depth_p95);
    a.queue_depth_p99 = a.queue_depth_p99.max(b.queue_depth_p99);
    a.queue_depth_max = a.queue_depth_max.max(b.queue_depth_max);
    a.queued_wait_s += b.queued_wait_s;
    a.straggler_stall_s += b.straggler_stall_s;
    a.transfers += b.transfers;
    a.events += b.events;
    a.event_digest = a
        .event_digest
        .wrapping_mul(0x100000001b3)
        .wrapping_add(b.event_digest);
}

/// Deterministic synthetic prompt for request `id`; priority class
/// round-robins over `classes` so a mixed-priority trace interleaves
/// urgent and background traffic uniformly.
fn synth_request(id: u64, prompt: usize, new_tokens: usize,
                 classes: usize) -> Request {
    let prompt = (0..prompt)
        .map(|p| ((id as usize * 1009 + p * 31) % 997) as i32)
        .collect();
    Request { id, prompt, max_new_tokens: new_tokens,
              priority: id as usize % classes.max(1) }
}

/// Route one arrival: shed it if the fleet admission queue is full,
/// otherwise pick a shard (affinity scores computed against each
/// shard's *current* placement when profiles are warm), account its
/// outstanding tokens, land its prompt DMA on the chosen shard's
/// ingress at the arrival instant, and enqueue it there.
#[allow(clippy::too_many_arguments)]
fn route_arrival(req: Request, ta: f64, shards: &mut [Shard],
                 router: &mut FleetRouter,
                 profiles: Option<&ClassProfiles>,
                 outstanding: &mut [f64], shed: &mut Vec<u64>,
                 queue_cap: usize, req_tokens: f64, n_gpus: usize,
                 token_bytes: f64) {
    let waiting: usize = shards.iter().map(|s| s.queue.len()).sum();
    if waiting >= queue_cap {
        shed.push(req.id);
        return;
    }
    let scores: Option<Vec<f64>> = profiles.map(|p| {
        shards
            .iter()
            .map(|s| p.score(&s.active, req.priority))
            .collect()
    });
    let r = router.choose(outstanding, scores.as_deref());
    outstanding[r] += req_tokens;
    let dst = (req.id as usize) % n_gpus;
    shards[r]
        .backend
        .ingest(dst, req.prompt.len() as f64 * token_bytes, ta);
    shards[r].queue.push_back((req, ta));
}

/// Replay the whole [`ServeLoad`] through the sharded fleet — routing
/// front-end, per-shard scheduler + network, fleet-wide re-planner —
/// on the virtual clock.
///
/// Each scheduler step routes its shard's actual computed-token batch
/// through every MoE layer (one dispatch round per layer, dispatch +
/// combine collectives priced at the shard's virtual time) and
/// advances that shard's clock by the resulting step seconds; arrivals
/// land their prompt payloads on their shard's network at their
/// arrival instants. Shards interleave by minimum virtual clock with
/// lowest-index tie-breaks, so the whole replay is deterministic per
/// [`SimConfig::seed`], and a single-replica fleet is bit-identical to
/// the pre-sharding replay.
pub fn replay_fleet(cfg: &FleetConfig) -> anyhow::Result<FleetReport> {
    cfg.validate()?;
    let sim = &cfg.sim;
    let topo = &sim.topo;
    let n_gpus = topo.num_gpus();
    let token_bytes = sim.model.token_bytes();
    let n = cfg.shard.replicas;

    // Per-replica placements: clones of the shared offline placement,
    // or (with `replica_profiles`) per-class specialisations built
    // from the class-shifted profiling trace. Shift 0 rebuilds the
    // shared placement exactly, so replica 0 is always the baseline.
    let base = build_placement(&cfg.sys, sim);
    let placements: Vec<Placement> = (0..n)
        .map(|r| {
            let classes = cfg.priority_classes.max(1);
            let shift = (r % classes) * sim.model.experts / classes;
            if cfg.replica_profiles && shift > 0 {
                let coord = coordinator(&cfg.sys, sim);
                let trace = coord.profile_synthetic(
                    &sim.model,
                    sim.placement_profile,
                    sim.profile_tokens,
                );
                coord.place(&trace.shift_experts(shift))
            } else {
                base.clone()
            }
        })
        .collect();

    let mut shards: Vec<Shard> = placements
        .into_iter()
        .enumerate()
        .map(|(r, active)| -> anyhow::Result<Shard> {
            let stride = (r as u64).wrapping_mul(SHARD_SEED_STRIDE);
            Ok(Shard {
                sched: Scheduler::new(SchedConfig {
                    mode: SchedMode::Continuous,
                    max_batch: cfg.max_batch,
                    max_batch_tokens: cfg.max_batch_tokens,
                    ctx: cfg.load.prompt + cfg.load.new_tokens,
                    kv_cache: true,
                    preempt: cfg.preempt,
                    retain_cache_tokens: usize::MAX,
                    ttft_slo: cfg.ttft_slo.clone(),
                })?,
                dispatcher: coordinator(&cfg.sys, sim)
                    .dispatcher(token_bytes),
                backend: CommBackend::new(sim.comm_backend, topo),
                rng: Rng::new(sim.seed ^ 0x5E21 ^ stride),
                active,
                queue: VecDeque::new(),
                now: 0.0,
                seed: sim.seed ^ stride,
                prefetch: prefetch_engine(sim),
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    // Arrival schedule (ascending) and synthetic requests, from an RNG
    // stream decoupled from dispatch so both backends replay the same
    // trace.
    let mut arr_rng = Rng::new(sim.seed ^ 0xA441);
    let arrivals: Vec<(Request, f64)> = cfg
        .load
        .arrival_times(&mut arr_rng)
        .into_iter()
        .enumerate()
        .map(|(i, t)| {
            (synth_request(i as u64, cfg.load.prompt,
                           cfg.load.new_tokens, cfg.priority_classes),
             t)
        })
        .collect();

    let mut epochs = FleetEpochs::new(&cfg.sys, sim, n);
    let mut router = FleetRouter::new(cfg.shard.route);
    let mut profiles = (cfg.shard.route == FleetRoutePolicy::Affinity)
        .then(|| ClassProfiles::new(cfg.priority_classes));
    let mut outstanding = vec![0.0f64; n];
    let req_tokens = (cfg.load.prompt + cfg.load.new_tokens) as f64;
    let mut shed: Vec<u64> = Vec::new();
    let mut comm_total = CommReport::default();
    let mut next_arrival = 0usize;
    let mut measured_secs = 0.0f64;
    let mut measured_tokens = 0usize;

    loop {
        // The routing horizon: the earliest instant any shard can act.
        // Arrivals at or before it must be routed *now* so the acting
        // shard sees every request it could admit (for one shard this
        // is exactly the pre-sharding "ingest while ta ≤ now" loop).
        let min_ready = shards
            .iter()
            .filter_map(Shard::ready_time)
            .fold(None, |m: Option<f64>, t| {
                Some(m.map_or(t, |m| m.min(t)))
            });
        match min_ready {
            None => {
                // Whole fleet idle and empty: done, or route the next
                // arrival instant's batch (ties route together so jsq
                // spreads a burst instead of stacking one shard).
                if next_arrival >= arrivals.len() {
                    break;
                }
                let t0 = arrivals[next_arrival].1;
                while next_arrival < arrivals.len()
                    && arrivals[next_arrival].1 == t0
                {
                    let (req, ta) = arrivals[next_arrival].clone();
                    next_arrival += 1;
                    route_arrival(req, ta, &mut shards, &mut router,
                                  profiles.as_ref(), &mut outstanding,
                                  &mut shed, cfg.shard.queue_cap,
                                  req_tokens, n_gpus, token_bytes);
                }
                continue;
            }
            Some(horizon) => {
                while next_arrival < arrivals.len()
                    && arrivals[next_arrival].1 <= horizon
                {
                    let (req, ta) = arrivals[next_arrival].clone();
                    next_arrival += 1;
                    route_arrival(req, ta, &mut shards, &mut router,
                                  profiles.as_ref(), &mut outstanding,
                                  &mut shed, cfg.shard.queue_cap,
                                  req_tokens, n_gpus, token_bytes);
                }
            }
        }

        // Min-virtual-clock interleave: always run the shard whose next
        // work item is earliest; ties break to the lowest index so the
        // interleave (and with it the whole replay) is deterministic.
        let mut pick: Option<(usize, f64)> = None;
        for (r, s) in shards.iter().enumerate() {
            if let Some(t) = s.ready_time() {
                if pick.map_or(true, |(_, bt)| t < bt) {
                    pick = Some((r, t));
                }
            }
        }
        let Some((r, _)) = pick else { continue };
        let shard = &mut shards[r];

        // Idle shard with queued work: jump its clock to the head
        // arrival (virtual time passes instantly when nothing is in
        // flight).
        if shard.sched.is_idle() {
            if let Some(&(_, ta)) = shard.queue.front() {
                shard.now = shard.now.max(ta);
            } else {
                continue;
            }
        }

        // Offer arrived requests from this shard's queue / admit from
        // its pending set.
        loop {
            if shard.sched.wants_offer() {
                if let Some(&(_, ta)) = shard.queue.front() {
                    if ta <= shard.now {
                        let (req, t) =
                            shard.queue.pop_front().expect("front");
                        shard.sched.offer(req, t);
                        continue;
                    }
                }
            }
            let progressed = shard.sched.admit_pending(shard.now)?;
            // SLO-shed candidates leave this replica's outstanding-
            // token account (they will never produce step work); the
            // event buffer must not grow unboundedly over a
            // 10⁵-request replay either way.
            for e in shard.sched.take_events() {
                if let SchedEvent::Rejected { .. } = e {
                    outstanding[r] -= req_tokens;
                }
            }
            if !progressed {
                break;
            }
        }
        if shard.sched.is_idle() {
            // Everything offerable was shed or is still in the future;
            // the next pass re-picks with updated ready times.
            continue;
        }
        anyhow::ensure!(!shard.sched.live().is_empty(),
                        "fleet scheduler stalled with a pending request");

        // One batched step, priced through this shard's network slice.
        let batch = shard.sched.microbatch();
        let tokens = shard.sched.step_tokens(&batch);
        let step = shard.sched.steps();
        // Per-token priority classes of the step's computed tokens, in
        // tile order — the class-conditioned trace shift and the
        // affinity gate profiles both key on it.
        let token_classes: Option<Vec<usize>> =
            (cfg.class_shift || profiles.is_some()).then(|| {
                let mut cls = Vec::with_capacity(tokens);
                for &i in &batch {
                    let s = &shard.sched.live()[i];
                    let fresh = s.ids.len() - s.cached_len;
                    cls.extend(
                        std::iter::repeat(s.req.priority).take(fresh),
                    );
                }
                debug_assert_eq!(cls.len(), tokens);
                cls
            });
        let (dt, rounds) = network_step(
            &cfg.sys, sim, shard, tokens, step,
            token_classes.as_deref(), cfg.class_shift,
            cfg.priority_classes, &mut profiles, &mut epochs,
            &mut comm_total,
        );
        let next: Vec<i32> = batch
            .iter()
            .map(|&i| fake_decode_token(&shard.sched.live()[i].ids))
            .collect();
        shard.now += dt;
        measured_secs += dt;
        measured_tokens += tokens;
        for _id in
            shard.sched.complete_step(&batch, &next, shard.now, rounds)?
        {
            outstanding[r] -= req_tokens;
        }

        // Epoch boundary at this shard's step edge: refresh the
        // payback gate's cost model from the fleet's measured
        // throughput, then evaluate/roll (only this shard can swap
        // here; the other N−1 keep serving).
        if let Some(ep) = &mut epochs {
            if let Some(cost) = CostParams::from_observed(
                &sim.model, measured_secs, measured_tokens)
            {
                ep.replanner.update_cost(cost);
            }
            let swap_secs = ep.tick(sim, r, shard, &mut comm_total);
            shard.now += swap_secs;
        }
    }

    // Fold the fleet: per-replica metrics kept and merged, contention
    // diagnostics folded, overflow-shed ids appended to the rejected
    // list.
    let mut per_replica = Vec::with_capacity(n);
    let mut contention: Option<ContentionReport> = None;
    let mut prefetch: Option<PrefetchStats> = None;
    for shard in shards {
        let mut backend = shard.backend;
        if let Some(c) = backend.contention() {
            match &mut contention {
                None => contention = Some(c),
                Some(t) => fold_contention(t, &c),
            }
        }
        if let Some(mut pf) = shard.prefetch {
            pf.finish();
            match &mut prefetch {
                None => prefetch = Some(pf.stats().clone()),
                Some(t) => t.accumulate(pf.stats()),
            }
        }
        let (_responses, m) = shard.sched.into_results(shard.now);
        per_replica.push(m);
    }
    let mut serve = ServeMetrics::default();
    for m in &per_replica {
        serve.merge(m);
    }
    serve.rejected.extend(shed);
    serve.rejected.sort_unstable();
    serve.per_request.sort_by_key(|t| t.id);

    Ok(FleetReport {
        backend: sim.comm_backend,
        replicas: n,
        serve,
        per_replica,
        comm: comm_total,
        contention,
        replans: epochs.as_ref().map_or(0, |e| e.replans),
        swaps: epochs
            .as_ref()
            .map_or(0, |e| e.rolling.swaps() as usize),
        swap_log: epochs
            .as_ref()
            .map_or_else(Vec::new, |e| e.rolling.log().to_vec()),
        migration_bytes: epochs
            .as_ref()
            .map_or(0.0, |e| e.migration_bytes),
        prefetch,
    })
}

/// Price one scheduler step of one shard: route `tokens` computed
/// tokens through every MoE layer (dispatch + combine per layer
/// through the shard's backend at its accumulating virtual time),
/// mirroring the timing engine's per-layer cost model. Feeds the
/// fleet-wide re-planner and (for affinity routing) the per-class gate
/// profiles along the way. Returns the step's seconds and its dispatch
/// round count.
#[allow(clippy::too_many_arguments)]
fn network_step(sys: &SystemSpec, cfg: &SimConfig, shard: &mut Shard,
                tokens: usize, step: usize,
                token_classes: Option<&[usize]>, class_shift: bool,
                classes: usize, profiles: &mut Option<ClassProfiles>,
                epochs: &mut Option<FleetEpochs>,
                comm_total: &mut CommReport) -> (f64, usize) {
    let topo = &cfg.topo;
    let n_gpus = topo.num_gpus();
    let spec = &cfg.model;
    let trace = TraceGen {
        experts: spec.experts,
        top_k: spec.top_k,
        layers: spec.moe_layers,
        profile: cfg.serve_profile,
        seed: shard
            .seed
            .wrapping_mul(0x1009)
            .wrapping_add(0xF1EE + step as u64),
    }
    .generate(tokens);
    let class_stride = spec.experts / classes.max(1);

    let mut t = shard.now;
    for (layer_idx, layer) in trace.layers.iter().enumerate() {
        let plan = {
            let lp = &shard.active.layers[layer_idx];
            let mut batch: Vec<Assignment> =
                Vec::with_capacity(tokens * spec.top_k);
            for (tok, experts) in layer.tokens.iter().enumerate() {
                let src = even_src(tok, tokens, n_gpus);
                let class = token_classes.map_or(0, |c| c[tok]);
                for &e in experts {
                    let mut e = e as usize;
                    if class_shift {
                        e = (e + class * class_stride) % spec.experts;
                    }
                    if sys.prune_remote > 0.0 {
                        let primary = lp.primary[e];
                        if !topo.same_node(src, primary)
                            && shard.rng.chance(sys.prune_remote)
                        {
                            continue;
                        }
                    }
                    if let Some(p) = profiles {
                        p.observe(class, layer_idx, lp, e);
                    }
                    batch.push(Assignment { token: tok, expert: e, src });
                }
            }
            shard
                .dispatcher
                .dispatch(lp, layer_idx, &batch, &mut shard.rng)
        };
        if let Some(p) = profiles {
            p.end_round(layer_idx, n_gpus, spec.experts);
        }

        // Weight residency: cold-tier demand loads block this round,
        // priced on the shard's contended ingress at its virtual time.
        let stall = match &mut shard.prefetch {
            Some(pf) => pf.demand_pass(layer_idx, &plan,
                                       &mut shard.backend, topo, t),
            None => 0.0,
        };

        let overlap = if sys.comm == CommModel::Hsc {
            tokens as f64 * ROUTE_DECISION_COST / n_gpus as f64
        } else {
            0.0
        };
        let mut comm = shard.backend.round_at(sys.comm, sys.dedup_flat,
                                              topo, &plan, overlap, t,
                                              &mut shard.rng);
        let combine = shard.backend.round_at(sys.comm, sys.dedup_flat,
                                             topo, &plan, 0.0,
                                             t + comm.time,
                                             &mut shard.rng);
        comm.accumulate(&combine);

        let mut t_max = 0.0f64;
        for &c in plan.copies_per_gpu() {
            let tc = cfg.gpu.moe_time(spec, c as f64) / sys.compute_eff
                + cfg.gpu.layer_overhead;
            t_max = t_max.max(tc);
        }
        let dense = cfg.gpu
            .dense_time(spec, tokens as f64 / n_gpus as f64)
            + cfg.gpu.layer_overhead;
        t += comm.time * sys.comm_eff + t_max + dense + stall;
        fold_comm(comm_total, &comm);
        if let Some(ep) = epochs {
            ep.replanner.observe(layer_idx,
                                 &shard.active.layers[layer_idx],
                                 &plan);
        }
        // Overlapped with the layer's FFN compute: stage the next
        // layer's predicted experts on the links, off the critical path.
        if let Some(pf) = &mut shard.prefetch {
            let next = pf.predictor().next_layer(layer_idx);
            pf.prefetch_pass(layer_idx, &plan,
                             &shard.active.layers[next],
                             &mut shard.backend, topo, t);
        }
    }
    (t - shard.now, 2 * spec.moe_layers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::config::{ArrivalProcess, ModelSpec, Workload};
    use crate::replan::ReplanConfig;
    use crate::trace::Profile;

    fn small_sim(backend: CommBackendKind) -> SimConfig {
        let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
        let mut sim = SimConfig::new(
            model,
            Topology::two_by_two(),
            Workload { batch: 8, prefill: 8, decode: 2 },
        );
        sim.profile_tokens = 256;
        sim.max_chunk = 256;
        sim.comm_backend = backend;
        sim
    }

    fn small_load(rate: f64) -> ServeLoad {
        ServeLoad {
            requests: 12,
            prompt: 8,
            new_tokens: 3,
            arrival: ArrivalProcess::Poisson { rate },
        }
    }

    fn small_fleet(backend: CommBackendKind, rate: f64) -> FleetConfig {
        let mut cfg = FleetConfig::new(SystemSpec::grace(0.15),
                                       small_sim(backend),
                                       small_load(rate));
        cfg.max_batch = 4;
        cfg.max_batch_tokens = 64;
        cfg
    }

    #[test]
    fn fleet_serves_every_request_and_is_deterministic() {
        let cfg = small_fleet(CommBackendKind::Analytic, 200.0);
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.serve.generated_tokens, 12 * 3);
        assert!(a.serve.wall_time > 0.0);
        assert!(a.comm.time > 0.0);
        assert!(a.contention.is_none(), "analytic has no contention");
        assert_eq!(a.serve.wall_time, b.serve.wall_time);
        assert_eq!(a.comm.time, b.comm.time);
    }

    #[test]
    fn des_fleet_reports_contention_and_matches_request_count() {
        let cfg = small_fleet(CommBackendKind::Des, 200.0);
        let r = replay_fleet(&cfg).unwrap();
        assert_eq!(r.serve.latencies.len(), 12);
        let c = r.contention.expect("DES must report contention");
        assert!(c.transfers > 0);
        assert!(c.events >= 4 * c.transfers,
                "each transfer arrives and departs on every leg");
        assert!(c.max_utilization > 0.0 && c.max_utilization <= 1.0);
    }

    #[test]
    fn fleet_prefetch_rides_along_and_preserves_serving() {
        let off_cfg = small_fleet(CommBackendKind::Analytic, 200.0);
        let mut on_cfg = off_cfg.clone();
        on_cfg.sim.prefetch =
            Some(crate::config::PrefetchConfig::default());
        let off = replay_fleet(&off_cfg).unwrap();
        let on = replay_fleet(&on_cfg).unwrap();
        // Token-for-token parity: the tier changes when weights move,
        // never what is served.
        assert_eq!(on.serve.generated_tokens,
                   off.serve.generated_tokens);
        assert_eq!(on.serve.latencies.len(),
                   off.serve.latencies.len());
        assert_eq!(on.comm.cross_bytes, off.comm.cross_bytes);
        assert!(off.prefetch.is_none(), "off arm reports no tier");
        let p = on.prefetch.clone().expect("tier configured");
        assert!(p.stalls > 0, "cold start must stall");
        assert!(on.serve.wall_time >= off.serve.wall_time);
        // Deterministic replay, counters included.
        let again = replay_fleet(&on_cfg).unwrap();
        assert_eq!(again.prefetch.unwrap(), p);
        assert_eq!(again.serve.wall_time, on.serve.wall_time);
        // The JSON rendering carries the counters (the CI smoke greps
        // them) — and only when the tier is configured.
        let json = crate::configio::to_string_pretty(&on.to_value());
        assert!(json.contains("\"stalls\""));
        assert!(json.contains("\"hit_rate\""));
        let off_json =
            crate::configio::to_string_pretty(&off.to_value());
        assert!(!off_json.contains("\"prefetch\""));
    }

    #[test]
    fn des_replay_is_bit_deterministic() {
        let cfg = small_fleet(CommBackendKind::Des, 500.0);
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        let (ca, cb) = (a.contention.unwrap(), b.contention.unwrap());
        assert_eq!(ca.event_digest, cb.event_digest);
        assert_eq!(ca.events, cb.events);
        assert_eq!(a.serve.wall_time, b.serve.wall_time);
        assert_eq!(a.to_value(), b.to_value());
    }

    #[test]
    fn saturating_arrivals_inflate_des_latency_over_analytic() {
        // Same workload, both backends: at a crush arrival rate the DES
        // queues prompt DMA + dispatch traffic on finite links, so its
        // mean latency must exceed the uncontended analytic pricing.
        let slow = replay_fleet(&small_fleet(CommBackendKind::Des, 1e5))
            .unwrap();
        let fast =
            replay_fleet(&small_fleet(CommBackendKind::Analytic, 1e5))
                .unwrap();
        let l_des = slow.serve.latency_summary().unwrap().mean();
        let l_ana = fast.serve.latency_summary().unwrap().mean();
        assert!(l_des >= l_ana,
                "contended {l_des} must not beat uncontended {l_ana}");
    }

    #[test]
    fn replanning_fleet_runs_and_stays_deterministic() {
        let mut cfg = small_fleet(CommBackendKind::Des, 300.0);
        cfg.sys = SystemSpec::grace_dyn(0.15);
        cfg.sim.replan =
            Some(ReplanConfig { epoch_rounds: 2,
                                ..ReplanConfig::default() });
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.replans, b.replans);
        assert_eq!(a.migration_bytes, b.migration_bytes);
        assert_eq!(a.contention.unwrap().event_digest,
                   b.contention.unwrap().event_digest);
    }

    #[test]
    fn validation_rejects_degenerate_inputs() {
        let good = small_fleet(CommBackendKind::Analytic, 10.0);
        assert!(good.validate().is_ok());

        let mut zero_req = good.clone();
        zero_req.load.requests = 0;
        assert!(replay_fleet(&zero_req).is_err());

        let mut zero_prompt = good.clone();
        zero_prompt.load.prompt = 0;
        assert!(replay_fleet(&zero_prompt).is_err());

        let mut bad_rate = good.clone();
        bad_rate.load.arrival = ArrivalProcess::Poisson { rate: 0.0 };
        assert!(replay_fleet(&bad_rate).is_err());

        let mut no_batch = good.clone();
        no_batch.max_batch = 0;
        assert!(replay_fleet(&no_batch).is_err());

        let mut no_class = good.clone();
        no_class.priority_classes = 0;
        assert!(replay_fleet(&no_class).is_err());

        let mut bad_slo = good.clone();
        bad_slo.ttft_slo = vec![0.0];
        assert!(replay_fleet(&bad_slo).is_err());

        let mut bad_epoch = good;
        bad_epoch.sim.replan =
            Some(ReplanConfig { epoch_rounds: 0,
                                ..ReplanConfig::default() });
        assert!(replay_fleet(&bad_epoch).is_err());
    }

    #[test]
    fn fleet_shape_validation_is_loud() {
        // Regression: --replicas 0 and a queue smaller than the fleet
        // must refuse at config time, before any request is consumed.
        let mut no_replicas =
            small_fleet(CommBackendKind::Analytic, 10.0);
        no_replicas.shard.replicas = 0;
        let err = replay_fleet(&no_replicas).unwrap_err();
        assert!(err.to_string().contains("--replicas 0"), "{err}");

        let mut tiny_queue = small_fleet(CommBackendKind::Analytic, 10.0);
        tiny_queue.shard.replicas = 4;
        tiny_queue.shard.queue_cap = 2;
        let err = replay_fleet(&tiny_queue).unwrap_err();
        assert!(err.to_string().contains("queue capacity"), "{err}");
    }

    #[test]
    fn report_serialises_key_fields() {
        let cfg = small_fleet(CommBackendKind::Des, 100.0);
        let v = replay_fleet(&cfg).unwrap().to_value();
        assert_eq!(v.str_or("backend", ""), "des");
        assert_eq!(v.req_usize("requests").unwrap(), 12);
        assert_eq!(v.req_usize("replicas").unwrap(), 1);
        assert_eq!(v.req_usize("swaps").unwrap(), 0);
        assert_eq!(v.req_f64("fleet_imbalance").unwrap(), 1.0);
        assert!(v.req_f64("wall_time_s").unwrap() > 0.0);
        assert_eq!(v.req_usize("preemptions").unwrap(), 0);
        assert_eq!(v.req_usize("rejected").unwrap(), 0);
        assert!(v.req_f64("ttft_p95_class0_s").unwrap() > 0.0);
        let r0 = v.req("replica0").unwrap();
        assert_eq!(r0.req_usize("requests").unwrap(), 12);
        let c = v.req("contention").unwrap();
        assert_eq!(c.req_str("event_digest").unwrap().len(), 16);
    }

    #[test]
    fn priority_fleet_replays_per_class_and_stays_deterministic() {
        // Two classes, preemption on, a crush arrival rate: every
        // request still completes (no SLO set), both classes report
        // tails, and the replay stays bit-deterministic.
        let mut cfg = small_fleet(CommBackendKind::Analytic, 1e4);
        cfg.priority_classes = 2;
        cfg.preempt = true;
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.serve.latencies.len(), 12);
        assert_eq!(a.serve.rejected.len(), 0);
        assert_eq!(a.serve.priority_classes(), vec![0, 1]);
        assert_eq!(a.to_value(), b.to_value());
        let v = a.to_value();
        assert!(v.req_f64("ttft_p95_class0_s").unwrap() > 0.0);
        assert!(v.req_f64("ttft_p95_class1_s").unwrap() > 0.0);
        // SLO shedding surfaces loudly in the report.
        let mut shed = small_fleet(CommBackendKind::Analytic, 1e4);
        shed.ttft_slo = vec![1e-9, 1e9];
        shed.priority_classes = 2;
        let r = replay_fleet(&shed).unwrap();
        assert!(!r.serve.rejected.is_empty(),
                "a 1 ns class-0 deadline must shed");
        assert_eq!(
            r.serve.latencies.len() + r.serve.rejected.len(),
            12,
            "every request either completes or is shed loudly"
        );
    }

    #[test]
    fn single_replica_fleet_matches_the_unsharded_reference() {
        // The parity oracle: a 1-replica fleet must reproduce the
        // pre-sharding replay loop bit-for-bit, across backends,
        // re-planning, and priority classes.
        let mut configs = vec![
            small_fleet(CommBackendKind::Analytic, 200.0),
            small_fleet(CommBackendKind::Des, 300.0),
        ];
        let mut replan = small_fleet(CommBackendKind::Des, 300.0);
        replan.sys = SystemSpec::grace_dyn(0.15);
        replan.sim.replan =
            Some(ReplanConfig { epoch_rounds: 2,
                                ..ReplanConfig::default() });
        configs.push(replan);
        let mut classes = small_fleet(CommBackendKind::Analytic, 1e4);
        classes.priority_classes = 2;
        classes.preempt = true;
        configs.push(classes);

        for cfg in configs {
            let sharded = replay_fleet(&cfg).unwrap();
            let oracle = reference::replay_fleet_reference(&cfg).unwrap();
            assert_eq!(sharded.to_value(), oracle.to_value(),
                       "N=1 fleet diverged from the pre-sharding loop \
                        ({:?} backend)", cfg.sim.comm_backend);
        }
    }

    #[test]
    fn four_replica_fleet_is_deterministic_and_spreads_load() {
        let mut cfg = small_fleet(CommBackendKind::Des, 2000.0);
        cfg.load.requests = 16;
        cfg.shard.replicas = 4;
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.to_value(), b.to_value(),
                   "N=4 virtual-clock fleet must be bit-identical \
                    across reruns");
        assert_eq!(a.replicas, 4);
        assert_eq!(a.serve.latencies.len(), 16);
        assert_eq!(a.serve.generated_tokens, 16 * 3);
        // jsq starts round-robin from empty, so every shard serves.
        for (r, m) in a.per_replica.iter().enumerate() {
            assert!(m.steps > 0, "replica {r} never stepped");
            assert!(!m.latencies.is_empty(),
                    "replica {r} served nothing");
        }
        let requests: usize =
            a.per_replica.iter().map(|m| m.latencies.len()).sum();
        assert_eq!(requests, 16);
        assert!(a.fleet_imbalance() >= 1.0);
    }

    #[test]
    fn wrr_fleet_round_robins_requests() {
        let mut cfg = small_fleet(CommBackendKind::Analytic, 400.0);
        cfg.load.requests = 12;
        cfg.shard.replicas = 3;
        cfg.shard.route = FleetRoutePolicy::Wrr;
        let r = replay_fleet(&cfg).unwrap();
        for m in &r.per_replica {
            assert_eq!(m.latencies.len(), 4,
                       "wrr must deal 12 requests 4-4-4");
        }
    }

    #[test]
    fn rolling_replan_keeps_the_fleet_serving() {
        // Permissive gates + a serve profile that drifts from the
        // placement profile, so deltas actually fire; then the rolling
        // invariants: at most one swap per epoch, shards swap in cursor
        // order, and every shard keeps stepping throughout.
        let mut cfg = small_fleet(CommBackendKind::Analytic, 2000.0);
        cfg.load.requests = 32;
        cfg.shard.replicas = 4;
        cfg.sys = SystemSpec::grace_dyn(0.15);
        cfg.sim.serve_profile = Profile::Math;
        cfg.sim.replan = Some(ReplanConfig {
            epoch_rounds: 1,
            min_drift: 0.0,
            payback: 0.0,
            ..ReplanConfig::default()
        });
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.to_value(), b.to_value());
        assert_eq!(a.swaps, a.swap_log.len());
        assert_eq!(a.replans, a.swaps / 4,
                   "a rollout completes after all 4 shards swapped");
        // ≤ 1 swap per epoch: epochs in the log strictly increase.
        assert!(a.swap_log.windows(2).all(|w| w[0].0 < w[1].0),
                "two swaps shared an epoch: {:?}", a.swap_log);
        // Rollouts visit shards in cursor order 0,1,2,3,0,1,2,…
        for (i, &(_, shard)) in a.swap_log.iter().enumerate() {
            assert_eq!(shard, i % 4, "swap order broke: {:?}",
                       a.swap_log);
        }
        // No global barrier: every shard kept serving to completion.
        assert_eq!(a.serve.latencies.len(), 32);
        for (r, m) in a.per_replica.iter().enumerate() {
            assert!(m.steps > 0, "replica {r} stalled");
        }
    }

    #[test]
    fn class_conditioned_fleet_is_deterministic_and_complete() {
        // The affinity-routing regime the bench compares: per-class
        // expert shift, per-class replica placements, warm gate
        // profiles. Every request completes and the replay stays
        // bit-deterministic.
        let mut cfg = small_fleet(CommBackendKind::Analytic, 2000.0);
        cfg.load.requests = 16;
        cfg.shard.replicas = 2;
        cfg.shard.route = FleetRoutePolicy::Affinity;
        cfg.priority_classes = 2;
        cfg.class_shift = true;
        cfg.replica_profiles = true;
        let a = replay_fleet(&cfg).unwrap();
        let b = replay_fleet(&cfg).unwrap();
        assert_eq!(a.to_value(), b.to_value());
        assert_eq!(a.serve.latencies.len(), 16);
        assert_eq!(a.serve.generated_tokens, 16 * 3);
    }

    #[test]
    fn finite_queue_cap_sheds_overflow_loudly() {
        let mut cfg = small_fleet(CommBackendKind::Analytic, 1e6);
        cfg.load.requests = 12;
        cfg.shard.queue_cap = 2;
        cfg.max_batch = 1;
        cfg.max_batch_tokens = 16;
        let r = replay_fleet(&cfg).unwrap();
        assert!(!r.serve.rejected.is_empty(),
                "a 2-deep queue under a 10⁶ req/s burst must shed");
        assert_eq!(r.serve.latencies.len() + r.serve.rejected.len(), 12,
                   "every request completes or sheds loudly");
    }

    /// The pre-sharding replay loop, kept verbatim as the parity
    /// oracle for `single_replica_fleet_matches_the_unsharded_
    /// reference`: if the generalized min-clock loop ever drifts from
    /// this code path at N=1, that test fails.
    mod reference {
        use super::super::*;
        use crate::routing::DispatchPlan;

        struct FleetEpoch {
            active: Placement,
            replanner: Replanner,
            mig_rng: Rng,
            migration_bytes: f64,
            replans: usize,
        }

        impl FleetEpoch {
            fn new(active: Placement, sys: &SystemSpec,
                   cfg: &SimConfig) -> Option<FleetEpoch> {
                let rc = match (sys.online_replan, cfg.replan) {
                    (true, Some(rc)) => rc,
                    _ => return None,
                };
                let cost = CostParams::paper(&cfg.model, &cfg.gpu,
                                             sys.compute_eff);
                Some(FleetEpoch {
                    active,
                    replanner: Replanner::new(cfg.topo.clone(), rc,
                                              cost),
                    mig_rng: Rng::new(cfg.seed ^ 0x4D16),
                    migration_bytes: 0.0,
                    replans: 0,
                })
            }

            fn observe(&mut self, layer: usize, plan: &DispatchPlan) {
                self.replanner
                    .observe(layer, &self.active.layers[layer], plan);
            }

            fn tick(&mut self, cfg: &SimConfig,
                    backend: &mut CommBackend, at: f64,
                    comm_total: &mut CommReport) -> f64 {
                let delta = self.replanner.epoch_tick(&self.active);
                if delta.is_empty() {
                    return 0.0;
                }
                let traffic = replan::migration_traffic(
                    &delta,
                    &self.active,
                    self.replanner.cost().expert_bytes,
                );
                let rep = backend.flat_round_at(&traffic, &cfg.topo, at,
                                                &mut self.mig_rng);
                self.migration_bytes += delta.migration_bytes;
                self.replans += 1;
                self.active = replan::apply_delta(&self.active, &delta);
                let secs = rep.time;
                fold_comm(comm_total, &rep);
                secs
            }
        }

        pub fn replay_fleet_reference(cfg: &FleetConfig)
                                      -> anyhow::Result<FleetReport> {
            cfg.validate()?;
            let sim = &cfg.sim;
            let topo = &sim.topo;
            let n_gpus = topo.num_gpus();
            let token_bytes = sim.model.token_bytes();

            let placement = build_placement(&cfg.sys, sim);
            let mut dispatcher =
                coordinator(&cfg.sys, sim).dispatcher(token_bytes);
            let mut rng = Rng::new(sim.seed ^ 0x5E21);
            let mut backend = CommBackend::new(sim.comm_backend, topo);
            let mut epoch =
                FleetEpoch::new(placement.clone(), &cfg.sys, sim);

            let mut arr_rng = Rng::new(sim.seed ^ 0xA441);
            let arrivals: Vec<(Request, f64)> = cfg
                .load
                .arrival_times(&mut arr_rng)
                .into_iter()
                .enumerate()
                .map(|(i, t)| {
                    (synth_request(i as u64, cfg.load.prompt,
                                   cfg.load.new_tokens,
                                   cfg.priority_classes),
                     t)
                })
                .collect();

            let mut sched = Scheduler::new(SchedConfig {
                mode: SchedMode::Continuous,
                max_batch: cfg.max_batch,
                max_batch_tokens: cfg.max_batch_tokens,
                ctx: cfg.load.prompt + cfg.load.new_tokens,
                kv_cache: true,
                preempt: cfg.preempt,
                retain_cache_tokens: usize::MAX,
                ttft_slo: cfg.ttft_slo.clone(),
            })?;

            let mut comm_total = CommReport::default();
            let mut now = 0.0f64;
            let mut next_arrival = 0usize;
            let mut next_ingest = 0usize;
            let mut measured_secs = 0.0f64;
            let mut measured_tokens = 0usize;

            loop {
                while next_ingest < arrivals.len()
                    && arrivals[next_ingest].1 <= now
                {
                    let (req, t) = &arrivals[next_ingest];
                    let dst = (req.id as usize) % n_gpus;
                    backend.ingest(dst,
                                   req.prompt.len() as f64
                                       * token_bytes,
                                   *t);
                    next_ingest += 1;
                }

                loop {
                    if sched.wants_offer()
                        && next_arrival < arrivals.len()
                        && arrivals[next_arrival].1 <= now
                    {
                        let (req, t) = arrivals[next_arrival].clone();
                        next_arrival += 1;
                        sched.offer(req, t);
                        continue;
                    }
                    let progressed = sched.admit_pending(now)?;
                    sched.take_events();
                    if !progressed {
                        break;
                    }
                }
                if sched.is_idle() {
                    if next_arrival >= arrivals.len() {
                        break;
                    }
                    now = now.max(arrivals[next_arrival].1);
                    continue;
                }
                anyhow::ensure!(
                    !sched.live().is_empty(),
                    "fleet scheduler stalled with a pending request"
                );

                let batch = sched.microbatch();
                let tokens = sched.step_tokens(&batch);
                let step = sched.steps();
                let (dt, rounds) = network_step_reference(
                    &cfg.sys, sim, &mut dispatcher, &mut backend,
                    &placement, &mut epoch, tokens, step, now,
                    &mut rng, &mut comm_total,
                );
                let next: Vec<i32> = batch
                    .iter()
                    .map(|&i| fake_decode_token(&sched.live()[i].ids))
                    .collect();
                now += dt;
                measured_secs += dt;
                measured_tokens += tokens;
                sched.complete_step(&batch, &next, now, rounds)?;

                if let Some(s) = &mut epoch {
                    if let Some(cost) = CostParams::from_observed(
                        &sim.model, measured_secs, measured_tokens)
                    {
                        s.replanner.update_cost(cost);
                    }
                    now += s.tick(sim, &mut backend, now,
                                  &mut comm_total);
                }
            }

            let (_responses, serve) = sched.into_results(now);
            let contention = backend.contention();
            Ok(FleetReport {
                backend: sim.comm_backend,
                replicas: 1,
                per_replica: vec![serve.clone()],
                serve,
                comm: comm_total,
                contention,
                replans: epoch.as_ref().map_or(0, |s| s.replans),
                swaps: epoch.as_ref().map_or(0, |s| s.replans),
                swap_log: Vec::new(),
                migration_bytes: epoch.as_ref()
                    .map_or(0.0, |s| s.migration_bytes),
            })
        }

        #[allow(clippy::too_many_arguments)]
        fn network_step_reference(
            sys: &SystemSpec, cfg: &SimConfig,
            dispatcher: &mut Dispatcher, backend: &mut CommBackend,
            placement: &Placement, epoch: &mut Option<FleetEpoch>,
            tokens: usize, step: usize, at: f64, rng: &mut Rng,
            comm_total: &mut CommReport) -> (f64, usize) {
            let topo = &cfg.topo;
            let n_gpus = topo.num_gpus();
            let spec = &cfg.model;
            let trace = TraceGen {
                experts: spec.experts,
                top_k: spec.top_k,
                layers: spec.moe_layers,
                profile: cfg.serve_profile,
                seed: cfg
                    .seed
                    .wrapping_mul(0x1009)
                    .wrapping_add(0xF1EE + step as u64),
            }
            .generate(tokens);

            let mut t = at;
            for (layer_idx, layer) in trace.layers.iter().enumerate() {
                let plan = {
                    let lp = match epoch {
                        Some(s) => &s.active.layers[layer_idx],
                        None => &placement.layers[layer_idx],
                    };
                    let mut batch: Vec<Assignment> =
                        Vec::with_capacity(tokens * spec.top_k);
                    for (tok, experts) in
                        layer.tokens.iter().enumerate()
                    {
                        let src = even_src(tok, tokens, n_gpus);
                        for &e in experts {
                            let e = e as usize;
                            if sys.prune_remote > 0.0 {
                                let primary = lp.primary[e];
                                if !topo.same_node(src, primary)
                                    && rng.chance(sys.prune_remote)
                                {
                                    continue;
                                }
                            }
                            batch.push(Assignment {
                                token: tok,
                                expert: e,
                                src,
                            });
                        }
                    }
                    dispatcher.dispatch(lp, layer_idx, &batch, rng)
                };

                let overlap = if sys.comm == CommModel::Hsc {
                    tokens as f64 * ROUTE_DECISION_COST
                        / n_gpus as f64
                } else {
                    0.0
                };
                let mut comm = backend.round_at(sys.comm,
                                                sys.dedup_flat, topo,
                                                &plan, overlap, t, rng);
                let combine = backend.round_at(sys.comm,
                                               sys.dedup_flat, topo,
                                               &plan, 0.0,
                                               t + comm.time, rng);
                comm.accumulate(&combine);

                let mut t_max = 0.0f64;
                for &c in plan.copies_per_gpu() {
                    let tc = cfg.gpu.moe_time(spec, c as f64)
                        / sys.compute_eff
                        + cfg.gpu.layer_overhead;
                    t_max = t_max.max(tc);
                }
                let dense = cfg.gpu
                    .dense_time(spec, tokens as f64 / n_gpus as f64)
                    + cfg.gpu.layer_overhead;
                t += comm.time * sys.comm_eff + t_max + dense;
                fold_comm(comm_total, &comm);
                if let Some(s) = epoch {
                    s.observe(layer_idx, &plan);
                }
            }
            (t - at, 2 * spec.moe_layers)
        }
    }
}
