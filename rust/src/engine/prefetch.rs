//! Predictive expert-weight prefetching and the two-tier weight cache.
//!
//! GRACE-MoE's placement machinery decides which experts get replicas;
//! this module manages whether a replica's *weights* are actually
//! resident when a routed token arrives. Each GPU owns a
//! capacity-bounded [`HotTier`] (`--weight-budget` experts, LRU
//! eviction into an unbounded cold tier — host memory in the real
//! engine); every layer round runs two passes over it:
//!
//! * **demand pass** — for each distinct `(expert, dst)` pair of the
//!   finished [`DispatchPlan`], a resident weight is a *hit* (recency
//!   bump), a missing one is a *stall*: the round blocks on a
//!   cold-tier load priced on the destination's real ingress links
//!   ([`CommBackend::ingest`] — the DES queues it behind whatever else
//!   the NIC is carrying), and the total per-GPU serial stall time is
//!   returned for the caller's critical path.
//! * **prefetch pass** — the plan also feeds the
//!   [`CrossLayerPredictor`]; if prediction is enabled, the top-k
//!   experts forecast for layer `l+1` are staged to their replica
//!   hosts *now*, overlapped with layer-`l` FFN compute: the transfer
//!   is committed on the contended links (prefetch traffic can itself
//!   cause queueing) but never on the critical path. If the forecast
//!   was right, the next demand pass hits; if not, the entry ages out
//!   of the LRU unused and its bytes are counted as *wasted*.
//!
//! The engine never touches routing: plans are observed after the
//! fact, so a run with prefetching enabled computes token-for-token
//! the same thing as one without — prefetch may change *when* weights
//! move, never *what* is computed (the tier-1 parity property test
//! pins this).
//!
//! Consumed by the timing engine ([`crate::engine::sim`]), the fleet
//! driver ([`crate::engine::fleet`]), and — through
//! [`crate::exec::JobHandle`]-tracked staging jobs — the real engine
//! ([`crate::engine::real`]).

use crate::cluster::{GpuId, Topology};
use crate::comm::sim::CommBackend;
use crate::config::PrefetchConfig;
use crate::metrics::PrefetchStats;
use crate::placement::LayerPlacement;
use crate::routing::{CrossLayerPredictor, DispatchPlan};
use std::collections::HashMap;

/// Identity of one expert weight tensor: `(layer, expert)`.
pub type WeightKey = (usize, usize);

#[derive(Clone, Debug)]
struct Entry {
    last_use: u64,
    /// Whether any demand lookup ever touched the entry. Demand-staged
    /// entries are born used; prefetched ones stay unused until a hit
    /// confirms the prediction — evicting (or retiring) an unused
    /// entry is the overprediction cost the stats expose.
    used: bool,
}

/// One GPU's resident expert-weight set: at most `budget` entries,
/// least-recently-used eviction, deterministic victim selection
/// (recency first, then the lower `(layer, expert)` key).
#[derive(Clone, Debug)]
pub struct HotTier {
    budget: usize,
    clock: u64,
    entries: HashMap<WeightKey, Entry>,
}

impl HotTier {
    /// A tier holding at most `budget >= 1` expert weights.
    pub fn new(budget: usize) -> HotTier {
        assert!(budget >= 1, "a zero-budget tier can hold nothing");
        HotTier { budget, clock: 0, entries: HashMap::new() }
    }

    /// Capacity in experts.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Resident entries (never exceeds [`Self::budget`]).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether nothing is resident yet.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Whether `key` is resident (no recency side effect).
    pub fn contains(&self, key: WeightKey) -> bool {
        self.entries.contains_key(&key)
    }

    /// Demand lookup: if `key` is resident, bump its recency, mark it
    /// used, and return `true`; a miss returns `false` untouched.
    pub fn touch(&mut self, key: WeightKey) -> bool {
        self.clock += 1;
        match self.entries.get_mut(&key) {
            Some(e) => {
                e.last_use = self.clock;
                e.used = true;
                true
            }
            None => false,
        }
    }

    /// Stage `key` into the tier (`used` tells demand staging apart
    /// from speculative prefetch). Staging a resident key is a no-op
    /// recency bump — never a duplicate copy. Returns the evicted
    /// `(key, was_used)` when the insert pushed the tier past budget.
    pub fn insert(&mut self, key: WeightKey, used: bool)
                  -> Option<(WeightKey, bool)> {
        self.clock += 1;
        if let Some(e) = self.entries.get_mut(&key) {
            e.last_use = self.clock;
            e.used |= used;
            return None;
        }
        self.entries.insert(key, Entry { last_use: self.clock, used });
        if self.entries.len() <= self.budget {
            return None;
        }
        let victim = self
            .entries
            .iter()
            .min_by(|(ka, ea), (kb, eb)| {
                ea.last_use.cmp(&eb.last_use).then(ka.cmp(kb))
            })
            .map(|(k, _)| *k)
            .expect("tier past budget is non-empty");
        let e = self.entries.remove(&victim).expect("victim resident");
        Some((victim, e.used))
    }

    /// Count the still-resident never-used entries and mark them used
    /// (so an end-of-run sweep is idempotent).
    fn take_unused(&mut self) -> usize {
        let mut n = 0;
        for e in self.entries.values_mut() {
            if !e.used {
                e.used = true;
                n += 1;
            }
        }
        n
    }
}

/// The per-run prefetch engine: one [`HotTier`] per GPU, one shared
/// [`CrossLayerPredictor`], and the [`PrefetchStats`] ledger. Drivers
/// call [`PrefetchEngine::demand_pass`] before a layer's FFN compute
/// (its return value is critical-path stall time) and
/// [`PrefetchEngine::prefetch_pass`] after dispatch, overlapped with
/// compute.
#[derive(Debug)]
pub struct PrefetchEngine {
    cfg: PrefetchConfig,
    expert_bytes: f64,
    predictor: CrossLayerPredictor,
    tiers: Vec<HotTier>,
    stats: PrefetchStats,
}

impl PrefetchEngine {
    /// Engine for a model of `layers × experts` weights of
    /// `expert_bytes` each, serving `num_gpus` tiers. Panics on a
    /// config [`PrefetchConfig::validate`] would reject — drivers
    /// validate at the CLI boundary first.
    pub fn new(cfg: PrefetchConfig, layers: usize, experts: usize,
               num_gpus: usize, expert_bytes: f64) -> PrefetchEngine {
        cfg.validate(experts).expect("prefetch config rejected");
        assert!(expert_bytes > 0.0 && num_gpus > 0,
                "non-degenerate staging geometry");
        PrefetchEngine {
            cfg,
            expert_bytes,
            predictor: CrossLayerPredictor::new(layers, experts,
                                                cfg.alpha),
            tiers: (0..num_gpus)
                .map(|_| HotTier::new(cfg.weight_budget))
                .collect(),
            stats: PrefetchStats::default(),
        }
    }

    /// The knobs this engine runs under.
    pub fn config(&self) -> &PrefetchConfig {
        &self.cfg
    }

    /// Bytes one expert weight stage moves.
    pub fn expert_bytes(&self) -> f64 {
        self.expert_bytes
    }

    /// The staging counters accumulated so far.
    pub fn stats(&self) -> &PrefetchStats {
        &self.stats
    }

    /// The cross-layer predictor (read access for diagnostics/tests).
    pub fn predictor(&self) -> &CrossLayerPredictor {
        &self.predictor
    }

    /// Resident experts on `gpu`'s hot tier.
    pub fn occupancy(&self, gpu: GpuId) -> usize {
        self.tiers[gpu].len()
    }

    /// Whether `gpu`'s tier holds `(layer, expert)` right now — the
    /// residency probe behind
    /// [`crate::replan::migration_traffic_resident`]: a migrated
    /// replica whose weights were already staged copies nothing.
    pub fn is_resident(&self, gpu: GpuId, layer: usize, expert: usize)
                       -> bool {
        self.tiers[gpu].contains((layer, expert))
    }

    /// Admit a replica the re-planner migrated onto `gpu`: replan
    /// swaps stage weights through the same tier the demand/prefetch
    /// passes use, so the next routed token hits instead of paying the
    /// copy a second time. Counted as demand-staged (`used`) — the
    /// migration was asked for, not speculated.
    pub fn admit_migration(&mut self, gpu: GpuId, layer: usize,
                           expert: usize) {
        self.admit(gpu, (layer, expert), true);
    }

    /// Tiers managed (one per GPU).
    pub fn num_tiers(&self) -> usize {
        self.tiers.len()
    }

    /// Price one cold-tier load into `dst` submitted at `at`: the DES
    /// queues it on the destination's real ingress links; the analytic
    /// backend charges the uncontended host-link latency + serialization.
    fn stage_cost(&self, backend: &mut CommBackend, topo: &Topology,
                  dst: GpuId, at: f64) -> f64 {
        let done = backend.ingest(dst, self.expert_bytes, at);
        if done > at {
            done - at
        } else {
            topo.inter_lat + self.expert_bytes / topo.inter_bw
        }
    }

    /// The demand pass over a routed round of `layer`: every distinct
    /// `(expert, dst)` pair must be resident before `dst` can run its
    /// FFN shard. Returns the round's blocking stall time (max over
    /// GPUs of their serial cold-load chain; 0 when everything hit).
    pub fn demand_pass(&mut self, layer: usize, plan: &DispatchPlan,
                       backend: &mut CommBackend, topo: &Topology,
                       at: f64) -> f64 {
        let mut seen: Vec<(usize, GpuId)> = Vec::new();
        for r in plan.assignments() {
            if !seen.contains(&(r.expert, r.dst)) {
                seen.push((r.expert, r.dst));
            }
        }
        let mut serial: HashMap<GpuId, f64> = HashMap::new();
        let mut stalled = false;
        for (expert, dst) in seen {
            let key = (layer, expert);
            if self.tiers[dst].touch(key) {
                self.stats.hits += 1;
                continue;
            }
            stalled = true;
            self.stats.stalls += 1;
            self.stats.demand_bytes += self.expert_bytes;
            let lag = serial.entry(dst).or_insert(0.0);
            let dt = self.stage_cost(backend, topo, dst, at + *lag);
            *lag += dt;
            self.admit(dst, key, true);
        }
        if stalled {
            self.stats.stall_steps += 1;
        }
        serial.values().copied().fold(0.0, f64::max)
    }

    /// The overlapped pass: feed the finished plan to the predictor
    /// and — when prediction is on — stage the top-k layer-`l+1`
    /// forecasts to their replica hosts. Transfers are committed on
    /// the links at `at` (contending with everything else in flight)
    /// but cost the caller nothing: they hide under layer-`l` compute.
    pub fn prefetch_pass(&mut self, layer: usize, plan: &DispatchPlan,
                         next_placement: &LayerPlacement,
                         backend: &mut CommBackend, topo: &Topology,
                         at: f64) {
        self.predictor.observe_plan(layer, plan);
        if !self.cfg.predictive {
            return;
        }
        let next = self.predictor.next_layer(layer);
        for expert in self.predictor.predict(layer, self.cfg.k) {
            for &gpu in &next_placement.instances[expert] {
                let key = (next, expert);
                if self.tiers[gpu].contains(key) {
                    continue;
                }
                let _ = self.stage_cost(backend, topo, gpu, at);
                self.stats.prefetches += 1;
                self.stats.prefetch_bytes += self.expert_bytes;
                self.admit(gpu, key, false);
            }
        }
    }

    fn admit(&mut self, gpu: GpuId, key: WeightKey, used: bool) {
        if let Some((_victim, was_used)) =
            self.tiers[gpu].insert(key, used)
        {
            self.stats.evictions += 1;
            if !was_used {
                self.stats.wasted_bytes += self.expert_bytes;
            }
        }
        debug_assert!(self.tiers[gpu].len() <= self.tiers[gpu].budget());
    }

    /// End-of-run sweep: prefetched entries still resident but never
    /// demanded are overpredictions too — fold them into
    /// `wasted_bytes`. Idempotent.
    pub fn finish(&mut self) {
        for tier in &mut self.tiers {
            self.stats.wasted_bytes +=
                tier.take_unused() as f64 * self.expert_bytes;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::sim::CommBackendKind;
    use crate::linalg::Matrix;
    use crate::placement::ReplicationMode;
    use crate::profile::LayerProfile;
    use crate::routing::{Assignment, Dispatcher, RoutingPolicy};
    use crate::stats::Rng;

    /// 4 experts, one per GPU, no replication: Primary routing sends
    /// expert `e` to GPU `e` deterministically.
    fn fixture() -> LayerPlacement {
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![4.0, 3.0, 2.0, 1.0],
            tokens: 10,
        };
        LayerPlacement::build(
            &profile,
            vec![vec![0], vec![1], vec![2], vec![3]],
            ReplicationMode::None,
        )
    }

    fn plan_for(lp: &LayerPlacement, layer: usize, sets: &[Vec<u16>])
                -> DispatchPlan {
        let topo = Topology::paper_testbed(1, 4);
        let mut d = Dispatcher::new(topo, RoutingPolicy::Primary.build(),
                                    1.0);
        let batch: Vec<Assignment> = sets
            .iter()
            .enumerate()
            .flat_map(|(t, es)| {
                es.iter().map(move |&e| Assignment {
                    token: t,
                    expert: e as usize,
                    src: t % 4,
                })
            })
            .collect();
        d.dispatch(lp, layer, &batch, &mut Rng::new(5))
    }

    fn engine(predictive: bool, budget: usize) -> PrefetchEngine {
        let cfg = PrefetchConfig {
            predictive,
            k: 2,
            weight_budget: budget,
            alpha: 0.5,
        };
        PrefetchEngine::new(cfg, 2, 4, 4, 1e6)
    }

    #[test]
    fn hot_tier_lru_eviction_is_deterministic() {
        let mut t = HotTier::new(2);
        assert!(t.is_empty());
        assert!(t.insert((0, 0), true).is_none());
        assert!(t.insert((0, 1), true).is_none());
        assert_eq!(t.len(), 2);
        // (0, 0) is now the more recently used entry.
        assert!(t.touch((0, 0)));
        let evicted = t.insert((0, 2), true);
        assert_eq!(evicted, Some(((0, 1), true)), "LRU victim");
        assert_eq!(t.len(), 2);
        assert!(t.contains((0, 0)) && t.contains((0, 2)));
        assert!(!t.contains((0, 1)));
        // Never past budget, whatever the insert pattern.
        for e in 0..16 {
            t.insert((1, e), false);
            assert!(t.len() <= t.budget());
        }
    }

    #[test]
    fn hot_tier_reinsert_is_a_noop_touch() {
        let mut t = HotTier::new(2);
        t.insert((0, 7), false);
        assert!(t.insert((0, 7), false).is_none(), "no duplicate copy");
        assert_eq!(t.len(), 1);
        // Re-staging an unused prefetched entry never clears its used
        // bit once set, and a used re-insert upgrades it.
        t.insert((0, 7), true);
        assert_eq!(t.take_unused(), 0, "used flag upgraded in place");
    }

    #[test]
    fn demand_pass_stalls_cold_then_hits_warm() {
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let mut backend = CommBackend::new(CommBackendKind::Analytic,
                                           &topo);
        let mut eng = engine(false, 8);
        let plan = plan_for(&lp, 0, &[vec![0, 1]]);

        let dt = eng.demand_pass(0, &plan, &mut backend, &topo, 0.0);
        // Experts 0 and 1 stall on different GPUs: they load in
        // parallel, so the round blocks for exactly one stage.
        let one_stage = topo.inter_lat + 1e6 / topo.inter_bw;
        assert_eq!(eng.stats().stalls, 2);
        assert_eq!(eng.stats().stall_steps, 1);
        assert_eq!(eng.stats().demand_bytes, 2e6);
        assert!((dt - one_stage).abs() < 1e-12, "dt {dt}");

        // Same round again: everything is resident now.
        let dt = eng.demand_pass(0, &plan, &mut backend, &topo, dt);
        assert_eq!(dt, 0.0);
        assert_eq!(eng.stats().hits, 2);
        assert_eq!(eng.stats().stalls, 2, "no new stalls");
        assert_eq!(eng.stats().stall_steps, 1);
        assert!((eng.stats().hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn prefetch_wins_the_race_for_the_next_layer() {
        // Both layers demand expert 0, whose weights live on GPU 0:
        // with a budget of one expert the two layers' weights fight
        // over the same tier slot, so prefetch-off stalls every round
        // while prefetch-on rotates the slot ahead of each demand.
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let run = |predictive: bool| -> PrefetchStats {
            let mut backend =
                CommBackend::new(CommBackendKind::Analytic, &topo);
            let mut eng = engine(predictive, 1);
            let p0 = plan_for(&lp, 0, &[vec![0]]);
            let p1 = plan_for(&lp, 1, &[vec![0]]);
            for round in 0..6 {
                let at = round as f64;
                eng.demand_pass(0, &p0, &mut backend, &topo, at);
                eng.prefetch_pass(0, &p0, &lp, &mut backend, &topo, at);
                eng.demand_pass(1, &p1, &mut backend, &topo, at);
                eng.prefetch_pass(1, &p1, &lp, &mut backend, &topo, at);
            }
            eng.finish();
            eng.stats().clone()
        };
        let on = run(true);
        let off = run(false);
        assert!(on.prefetches > 0, "prediction never fired");
        assert!(on.hits > off.hits, "prefetch must win the race");
        assert!(on.stalls < off.stalls, "prefetch must remove stalls");
        assert!(on.stall_steps < off.stall_steps);
        assert_eq!(off.prefetches, 0);
        assert_eq!(off.prefetch_bytes, 0.0);
        assert_eq!(off.hits, 0, "off arm thrashes the one-expert tier");
        // At most the final in-flight prefetch retires unused.
        assert!(on.wasted_bytes <= 1e6 + 1e-9,
                "wasted {} of {} prefetched",
                on.wasted_bytes, on.prefetch_bytes);
        assert!(on.wasted_bytes < on.prefetch_bytes);
    }

    #[test]
    fn wasted_prefetch_is_counted_on_retire_and_eviction() {
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let mut backend = CommBackend::new(CommBackendKind::Analytic,
                                           &topo);
        let mut eng = engine(true, 1);
        // Warm the 0 → 0 correlation, then switch the layer-1 demand
        // to expert 3: the prefetch the stale correlation issues is
        // never demanded and retires unused in the finish() sweep.
        let p0 = plan_for(&lp, 0, &[vec![0]]);
        let p1 = plan_for(&lp, 1, &[vec![0]]);
        let q1 = plan_for(&lp, 1, &[vec![3]]);
        eng.demand_pass(0, &p0, &mut backend, &topo, 0.0);
        eng.prefetch_pass(0, &p0, &lp, &mut backend, &topo, 0.0);
        eng.demand_pass(1, &p1, &mut backend, &topo, 0.0);
        eng.prefetch_pass(1, &p1, &lp, &mut backend, &topo, 0.0);
        eng.demand_pass(0, &p0, &mut backend, &topo, 1.0);
        eng.prefetch_pass(0, &p0, &lp, &mut backend, &topo, 1.0);
        eng.demand_pass(1, &q1, &mut backend, &topo, 1.0);
        eng.prefetch_pass(1, &q1, &lp, &mut backend, &topo, 1.0);
        assert!(eng.stats().prefetches > 0);
        eng.finish();
        assert_eq!(eng.stats().wasted_bytes,
                   eng.stats().prefetch_bytes,
                   "nothing prefetched was ever used");
        // finish() is idempotent.
        let before = eng.stats().clone();
        eng.finish();
        assert_eq!(*eng.stats(), before);
    }

    #[test]
    fn des_backend_prices_demand_on_contended_links() {
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let run = || -> (f64, PrefetchStats) {
            let mut backend =
                CommBackend::new(CommBackendKind::Des, &topo);
            let mut eng = engine(true, 8);
            let plan = plan_for(&lp, 0, &[vec![0, 1], vec![2]]);
            let dt = eng.demand_pass(0, &plan, &mut backend, &topo, 0.0);
            (dt, eng.stats().clone())
        };
        let (dt, stats) = run();
        assert!(dt > 0.0, "DES stage must take real time");
        assert_eq!(stats.stalls, 3);
        // Deterministic replay: identical stats and timing.
        let (dt2, stats2) = run();
        assert_eq!(dt, dt2);
        assert_eq!(stats, stats2);
    }

    #[test]
    fn occupancy_never_exceeds_budget() {
        let lp = fixture();
        let topo = Topology::paper_testbed(1, 4);
        let mut backend = CommBackend::new(CommBackendKind::Analytic,
                                           &topo);
        let mut eng = engine(true, 1);
        for round in 0..6u16 {
            for layer in 0..2usize {
                let sets: Vec<Vec<u16>> =
                    vec![vec![round % 4, (round + 1) % 4]];
                let plan = plan_for(&lp, layer, &sets);
                let at = round as f64;
                eng.demand_pass(layer, &plan, &mut backend, &topo, at);
                eng.prefetch_pass(layer, &plan, &lp, &mut backend,
                                  &topo, at);
                for gpu in 0..eng.num_tiers() {
                    assert!(eng.occupancy(gpu) <= 1,
                            "tier {gpu} past budget");
                }
            }
        }
        assert!(eng.stats().evictions > 0, "budget 1 must evict");
    }

    #[test]
    #[should_panic(expected = "--weight-budget 0")]
    fn zero_budget_engine_is_rejected() {
        let cfg = PrefetchConfig { weight_budget: 0,
                                   ..PrefetchConfig::default() };
        let _ = PrefetchEngine::new(cfg, 2, 4, 4, 1e6);
    }
}
