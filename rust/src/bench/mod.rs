//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations + robust summary, plus a tiny table printer shared by the
//! paper-figure benches under `benches/` and an opt-in JSON recorder
//! ([`JsonRecorder`]) for machine-readable bench archives
//! (`make bench-record`).

use crate::configio::{self, Value};
use crate::stats::Summary;
use std::path::PathBuf;
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub secs: Summary,
}

impl BenchResult {
    /// Mean per-iteration time, milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.secs.mean() * 1e3
    }

    /// Median per-iteration time, milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.secs.p50() * 1e3
    }

    /// p99 per-iteration time, milliseconds.
    pub fn p99_ms(&self) -> f64 {
        self.secs.p99() * 1e3
    }

    /// One formatted result line for bench output.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms/iter  (p50 {:>9.4}, p99 {:>9.4}, n={})",
            self.name,
            self.mean_ms(),
            self.p50_ms(),
            self.p99_ms(),
            self.iters
        )
    }
}

/// Run `f` with `warmup` untimed then `iters` timed iterations.
pub fn bench<T>(name: &str, warmup: usize, iters: usize,
                mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        secs: Summary::of(&samples),
    }
}

/// Auto-scale iteration count so one case takes roughly `target_secs`.
pub fn bench_auto<T>(name: &str, target_secs: f64,
                     mut f: impl FnMut() -> T) -> BenchResult {
    // Calibrate with a single run.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once) as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Fixed-width text table used by the paper-figure benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Render the aligned fixed-width table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a fraction as the paper's "+x.xx% / -x.xx%" convention.
pub fn pct(frac: f64) -> String {
    format!("{}{:.2}%", if frac >= 0.0 { "+" } else { "" }, frac * 100.0)
}

/// Opt-in JSON emitter for bench results: enabled when the bench binary
/// is invoked with `--json`, or when the `BENCH_JSON` environment
/// variable names an output directory (the `make bench-record` path).
/// Disabled, every call is a no-op, so bench output stays plain text by
/// default. The document is a sorted-key JSON object, deterministic up
/// to the timings themselves.
#[derive(Debug)]
pub struct JsonRecorder {
    out: Option<PathBuf>,
    fields: Vec<(String, Value)>,
}

impl JsonRecorder {
    /// Recorder for bench `name`, gated on the process argv/environment.
    /// Writes to `$BENCH_JSON/BENCH_<name>.json` (with `--json` alone,
    /// `BENCH_<name>.json` in the current directory).
    pub fn from_env(name: &str) -> JsonRecorder {
        let flag = std::env::args().any(|a| a == "--json");
        let dir = std::env::var("BENCH_JSON").ok()
            .filter(|d| !d.is_empty());
        Self::new(name, flag, dir)
    }

    /// Explicit-gate constructor (what [`JsonRecorder::from_env`]
    /// resolves to; tests drive this directly).
    pub fn new(name: &str, flag: bool, dir: Option<String>)
               -> JsonRecorder {
        let out = match (dir, flag) {
            (Some(d), _) => Some(PathBuf::from(d)),
            (None, true) => Some(PathBuf::from(".")),
            (None, false) => None,
        }
        .map(|d| d.join(format!("BENCH_{name}.json")));
        JsonRecorder { out, fields: Vec::new() }
    }

    /// `true` when [`JsonRecorder::finish`] will write a file.
    pub fn enabled(&self) -> bool {
        self.out.is_some()
    }

    /// Record one timed case under its bench name.
    pub fn record(&mut self, r: &BenchResult) {
        self.record_value(&r.name, Value::object(vec![
            ("iters", Value::from(r.iters)),
            ("mean_ms", Value::num(r.mean_ms())),
            ("p50_ms", Value::num(r.p50_ms())),
            ("p99_ms", Value::num(r.p99_ms())),
        ]));
    }

    /// Record an arbitrary value under `key` (self-check evidence,
    /// derived metrics, config echoes).
    pub fn record_value(&mut self, key: &str, v: Value) {
        if self.enabled() {
            self.fields.push((key.to_string(), v));
        }
    }

    /// Write the recorded document. Returns the path written, or `None`
    /// when the recorder is disabled.
    pub fn finish(&self) -> std::io::Result<Option<PathBuf>> {
        let Some(path) = &self.out else {
            return Ok(None);
        };
        let pairs: Vec<(&str, Value)> = self
            .fields
            .iter()
            .map(|(k, v)| (k.as_str(), v.clone()))
            .collect();
        let mut doc = configio::to_string_pretty(&Value::object(pairs));
        doc.push('\n');
        std::fs::write(path, doc)?;
        Ok(Some(path.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_something() {
        let r = bench("spin", 2, 10, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 10);
        assert!(r.secs.mean() > 0.0);
        assert!(r.p99_ms() >= r.p50_ms());
    }

    #[test]
    fn auto_scales_iters() {
        let r = bench_auto("fast", 0.01, || 1 + 1);
        assert!(r.iters >= 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["a2a".into(), "-35.19%".into()]);
        t.row(vec!["idle".into(), "+0.02%".into()]);
        let s = t.render();
        assert!(s.contains("metric"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(pct(-0.3519), "-35.19%");
        assert_eq!(pct(1.0013), "+100.13%");
    }

    #[test]
    fn disabled_recorder_is_a_no_op() {
        let mut rec = JsonRecorder::new("off", false, None);
        assert!(!rec.enabled());
        rec.record_value("k", Value::num(1.0));
        assert_eq!(rec.finish().unwrap(), None);
    }

    #[test]
    fn enabled_recorder_writes_bench_json() {
        let dir = std::env::temp_dir()
            .join(format!("grace_bench_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rec = JsonRecorder::new(
            "smoke", false, Some(dir.to_string_lossy().into_owned()));
        assert!(rec.enabled());
        let r = bench("case_a", 0, 3, || 1 + 1);
        rec.record(&r);
        rec.record_value("self_check", Value::from(true));
        let path = rec.finish().unwrap().expect("path written");
        assert_eq!(path.file_name().unwrap(), "BENCH_smoke.json");
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = configio::parse(&text).unwrap();
        assert_eq!(doc.req("case_a").unwrap()
                       .req_usize("iters").unwrap(), 3);
        assert!(doc.req("self_check").is_ok());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_flag_defaults_to_current_dir() {
        let rec = JsonRecorder::new("flagged", true, None);
        assert!(rec.enabled());
    }
}
