//! Bench harness (criterion is unavailable offline): warmup + timed
//! iterations + robust summary, plus a tiny table printer shared by the
//! paper-figure benches under `benches/`.

use crate::stats::Summary;
use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Per-iteration wall time in seconds.
    pub secs: Summary,
}

impl BenchResult {
    /// Mean per-iteration time, milliseconds.
    pub fn mean_ms(&self) -> f64 {
        self.secs.mean() * 1e3
    }

    /// Median per-iteration time, milliseconds.
    pub fn p50_ms(&self) -> f64 {
        self.secs.p50() * 1e3
    }

    /// p99 per-iteration time, milliseconds.
    pub fn p99_ms(&self) -> f64 {
        self.secs.p99() * 1e3
    }

    /// One formatted result line for bench output.
    pub fn report_line(&self) -> String {
        format!(
            "{:<44} {:>10.4} ms/iter  (p50 {:>9.4}, p99 {:>9.4}, n={})",
            self.name,
            self.mean_ms(),
            self.p50_ms(),
            self.p99_ms(),
            self.iters
        )
    }
}

/// Run `f` with `warmup` untimed then `iters` timed iterations.
pub fn bench<T>(name: &str, warmup: usize, iters: usize,
                mut f: impl FnMut() -> T) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        iters,
        secs: Summary::of(&samples),
    }
}

/// Auto-scale iteration count so one case takes roughly `target_secs`.
pub fn bench_auto<T>(name: &str, target_secs: f64,
                     mut f: impl FnMut() -> T) -> BenchResult {
    // Calibrate with a single run.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_secs / once) as usize).clamp(3, 10_000);
    bench(name, (iters / 10).max(1), iters, f)
}

/// Fixed-width text table used by the paper-figure benches.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers.
    pub fn new(header: &[&str]) -> Self {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header width).
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "ragged table row");
        self.rows.push(cells);
    }

    /// Render the aligned fixed-width table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let line = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(c, s)| format!("{:>w$}", s, w = widths[c]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = String::new();
        out.push_str(&line(&self.header));
        out.push('\n');
        out.push_str(
            &widths
                .iter()
                .map(|w| "-".repeat(*w))
                .collect::<Vec<_>>()
                .join("  "),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row));
            out.push('\n');
        }
        out
    }
}

/// Format a fraction as the paper's "+x.xx% / -x.xx%" convention.
pub fn pct(frac: f64) -> String {
    format!("{}{:.2}%", if frac >= 0.0 { "+" } else { "" }, frac * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_times_something() {
        let r = bench("spin", 2, 10, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert_eq!(r.iters, 10);
        assert!(r.secs.mean() > 0.0);
        assert!(r.p99_ms() >= r.p50_ms());
    }

    #[test]
    fn auto_scales_iters() {
        let r = bench_auto("fast", 0.01, || 1 + 1);
        assert!(r.iters >= 3);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["metric", "value"]);
        t.row(vec!["a2a".into(), "-35.19%".into()]);
        t.row(vec!["idle".into(), "+0.02%".into()]);
        let s = t.render();
        assert!(s.contains("metric"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_formatting() {
        assert_eq!(pct(-0.3519), "-35.19%");
        assert_eq!(pct(1.0013), "+100.13%");
    }
}
