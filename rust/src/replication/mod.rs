//! Expert replication — the paper's computational-load-balance-centric
//! optimization (§4.2).
//!
//! * [`dynamic_replication`] — the DR strategy: the number of replicas is
//!   driven by the load-skew factor `ρ = W_max / W̄` (Eq. 3,
//!   `n_replica = min(max(1, ⌊ρ⌋), n_gpu − 1)`); hot experts are the
//!   top-loaded experts of the *heaviest group* whose cumulative load
//!   exceeds `W_max · n_replica / (1 + n_replica)`; replicas land on the
//!   `n_replica` most underutilized GPUs.
//! * [`fixed_replication`] — the FR baseline of §6.3 RQ2: one replica of
//!   the overloaded experts of the heaviest group on the least-loaded GPU.
//! * [`predict_loads`] — Eq. 4 load prediction, which feeds the WRR
//!   polling weights of [`crate::routing`].

use crate::cluster::GpuId;
use crate::grouping::Grouping;
use crate::profile::LayerProfile;

/// Replication decision for one layer.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Replication {
    /// Experts replicated (primary copies stay in their group).
    pub hot_experts: Vec<usize>,
    /// GPUs receiving one secondary copy of *each* hot expert.
    pub replica_gpus: Vec<GpuId>,
    /// `n_replica` of Eq. 3 (`replica_gpus.len()`).
    pub n_replica: usize,
    /// Pre-replication load of the heaviest group (`W_max`).
    pub w_max: f64,
    /// Total pre-replication load of the replicated experts (`W_r`).
    pub w_r: f64,
    /// Whether a replication pass actually ran. `false` only for
    /// [`Replication::none`] (replication not configured); a computed
    /// decision that found nothing worth replicating sets it `true`
    /// ([`Replication::empty`]) — the two used to be conflated through
    /// [`Replication::is_none`] alone.
    pub computed: bool,
}

impl Replication {
    /// No replication *configured* (HG-only configurations); see
    /// [`Replication::empty`] for the computed-but-empty outcome.
    pub fn none() -> Replication {
        Replication::default()
    }

    /// A replication pass that ran and selected no hot experts (e.g. a
    /// zero-load layer at the threshold boundary). Distinguishable from
    /// [`Replication::none`] via [`Replication::was_computed`].
    pub fn empty() -> Replication {
        Replication { computed: true, ..Replication::default() }
    }

    /// Nothing is replicated — regardless of whether that is because no
    /// pass ran ([`Replication::none`]) or because a pass found no hot
    /// experts ([`Replication::empty`]); use
    /// [`Replication::was_computed`] to tell them apart.
    pub fn is_none(&self) -> bool {
        self.hot_experts.is_empty()
    }

    /// `true` when a replication pass produced this value (even if it
    /// selected nothing); `false` for the not-configured sentinel.
    pub fn was_computed(&self) -> bool {
        self.computed
    }
}

/// Eq. 3: `n_replica = min(max(1, ⌊ρ⌋), n_gpu − 1)`.
pub fn replica_count(rho: f64, n_gpu: usize) -> usize {
    assert!(n_gpu >= 2, "replication needs ≥ 2 GPUs");
    (rho.floor() as usize).max(1).min(n_gpu - 1)
}

/// The paper's hot-expert rule: rank the heaviest group's experts by
/// individual load (descending) and take the minimal prefix whose
/// cumulative load exceeds `W_max · n_replica / (1 + n_replica)`.
fn hot_experts_of_group(profile: &LayerProfile, group: &[usize],
                        w_max: f64, n_replica: usize) -> Vec<usize> {
    let threshold = w_max * n_replica as f64 / (1.0 + n_replica as f64);
    let mut ranked: Vec<usize> = group.to_vec();
    ranked.sort_by(|&a, &b| {
        profile.load[b].partial_cmp(&profile.load[a]).unwrap()
    });
    let mut hot = Vec::new();
    let mut cum = 0.0;
    for e in ranked {
        if cum > threshold {
            break;
        }
        cum += profile.load[e];
        hot.push(e);
    }
    hot
}

/// Dynamic replication driven by load skew (paper §4.2).
///
/// `groups[g]` is the expert set of GPU `g` (one group per GPU after
/// hierarchical grouping).
pub fn dynamic_replication(profile: &LayerProfile, groups: &Grouping)
                           -> Replication {
    let n_gpu = groups.len();
    assert!(n_gpu >= 2);
    let loads: Vec<f64> =
        groups.iter().map(|g| profile.group_load(g)).collect();
    let mean = loads.iter().sum::<f64>() / n_gpu as f64;
    if mean == 0.0 {
        return Replication::empty();
    }
    let heavy = profile.heaviest_group(groups);
    let w_max = loads[heavy];
    let rho = w_max / mean;
    let n_replica = replica_count(rho, n_gpu);

    let hot = hot_experts_of_group(profile, &groups[heavy], w_max,
                                   n_replica);
    let w_r: f64 = hot.iter().map(|&e| profile.load[e]).sum();

    // The n_replica most underutilized GPUs (excluding the hot group's
    // own GPU — its primaries already live there).
    let mut order: Vec<GpuId> =
        (0..n_gpu).filter(|&g| g != heavy).collect();
    order.sort_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap());
    let replica_gpus: Vec<GpuId> =
        order.into_iter().take(n_replica).collect();

    Replication {
        hot_experts: hot,
        n_replica: replica_gpus.len(),
        replica_gpus,
        w_max,
        w_r,
        computed: true,
    }
}

/// Fixed-replica baseline (FR, §6.3 RQ2): one replica of the heaviest
/// group's overloaded experts onto the single least-loaded GPU.
pub fn fixed_replication(profile: &LayerProfile, groups: &Grouping)
                         -> Replication {
    let n_gpu = groups.len();
    assert!(n_gpu >= 2);
    let loads: Vec<f64> =
        groups.iter().map(|g| profile.group_load(g)).collect();
    let mean = loads.iter().sum::<f64>() / n_gpu as f64;
    if mean == 0.0 {
        return Replication::empty();
    }
    let heavy = profile.heaviest_group(groups);
    let w_max = loads[heavy];
    // "overloaded experts": those above the group's per-expert mean load
    let group = &groups[heavy];
    let gmean = w_max / group.len() as f64;
    let mut hot: Vec<usize> = group
        .iter()
        .copied()
        .filter(|&e| profile.load[e] > gmean)
        .collect();
    if hot.is_empty() {
        // degenerate flat group: take the single heaviest expert
        hot = vec![*group
            .iter()
            .max_by(|&&a, &&b| {
                profile.load[a].partial_cmp(&profile.load[b]).unwrap()
            })
            .unwrap()];
    }
    let w_r: f64 = hot.iter().map(|&e| profile.load[e]).sum();
    let dst = (0..n_gpu)
        .filter(|&g| g != heavy)
        .min_by(|&a, &b| loads[a].partial_cmp(&loads[b]).unwrap())
        .unwrap();
    Replication {
        hot_experts: hot,
        replica_gpus: vec![dst],
        n_replica: 1,
        w_max,
        w_r,
        computed: true,
    }
}

/// Eq. 4 load prediction: post-replication per-GPU loads.
///
/// With per-instance load `W_p = W_max / (n_replica + 1)` (as printed in
/// the paper — note it divides the *group* max, not `W_r`):
/// the heaviest GPU drops to `W'_max = W_max − W_r + W_p`, every
/// replica-hosting GPU rises to `W'_i = W_i + W_p`.
pub fn predict_loads(pre_loads: &[f64], heavy: usize, rep: &Replication)
                     -> Vec<f64> {
    let mut post = pre_loads.to_vec();
    if rep.is_none() {
        return post;
    }
    let w_p = rep.w_max / (rep.n_replica as f64 + 1.0);
    post[heavy] = rep.w_max - rep.w_r + w_p;
    for &g in &rep.replica_gpus {
        post[g] += w_p;
    }
    post
}

/// Polling weights for WRR (paper §4.3): inversely proportional to the
/// predicted loads, normalized to sum to 1.
pub fn polling_weights(predicted: &[f64]) -> Vec<f64> {
    let eps = 1e-9;
    let inv: Vec<f64> =
        predicted.iter().map(|&w| 1.0 / (w + eps)).collect();
    let total: f64 = inv.iter().sum();
    inv.into_iter().map(|w| w / total).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Matrix;
    use crate::testutil::{check, prop_assert};

    /// Profile with explicit per-expert loads (affinity unused here).
    fn profile_with_loads(loads: Vec<f64>) -> LayerProfile {
        let n = loads.len();
        LayerProfile {
            affinity: Matrix::zeros(n, n),
            load: loads,
            tokens: 100,
        }
    }

    #[test]
    fn eq3_replica_count() {
        assert_eq!(replica_count(0.4, 4), 1, "max(1, ⌊ρ⌋) floor");
        assert_eq!(replica_count(1.0, 4), 1);
        assert_eq!(replica_count(2.9, 4), 2);
        assert_eq!(replica_count(9.0, 4), 3, "capped at n_gpu − 1");
        assert_eq!(replica_count(9.0, 2), 1);
    }

    #[test]
    fn dynamic_selects_hot_prefix_of_heaviest_group() {
        // gpu0 hosts experts {0,1,2}: loads 50, 30, 4 → heaviest (84)
        // gpu1 {3}: 10, gpu2 {4}: 2, gpu3 {5}: 0
        let p = profile_with_loads(vec![50.0, 30.0, 4.0, 10.0, 2.0, 0.0]);
        let groups =
            vec![vec![0, 1, 2], vec![3], vec![4], vec![5]];
        let rep = dynamic_replication(&p, &groups);
        // ρ = 84 / 24 = 3.5 → n = min(3, 3) = 3
        assert_eq!(rep.n_replica, 3);
        // threshold = 84·3/4 = 63: 50 < 63 (take), 50+30=80 > 63 stop after
        assert_eq!(rep.hot_experts, vec![0, 1]);
        assert_eq!(rep.w_r, 80.0);
        // replicas on most underutilized gpus: 3 (0), 2 (2), 1 (10)
        assert_eq!(rep.replica_gpus, vec![3, 2, 1]);
    }

    #[test]
    fn dynamic_never_targets_heavy_gpu() {
        check(50, |rng| {
            let n_exp = 8 + rng.index(24);
            let loads: Vec<f64> =
                (0..n_exp).map(|_| rng.index(100) as f64).collect();
            let p = profile_with_loads(loads);
            let n_gpu = 2 + rng.index(6);
            let groups = random_groups(rng, n_exp, n_gpu);
            let rep = dynamic_replication(&p, &groups);
            if rep.is_none() {
                return Ok(());
            }
            let heavy = p.heaviest_group(&groups);
            prop_assert(!rep.replica_gpus.contains(&heavy),
                        "replica on the heavy gpu")?;
            prop_assert(rep.n_replica <= n_gpu - 1, "Eq.3 cap")?;
            prop_assert(
                rep.hot_experts.iter().all(|e| groups[heavy].contains(e)),
                "hot experts from heaviest group only",
            )?;
            // replica gpus distinct
            let mut rg = rep.replica_gpus.clone();
            rg.sort_unstable();
            rg.dedup();
            prop_assert(rg.len() == rep.replica_gpus.len(), "dup gpus")
        });
    }

    fn random_groups(rng: &mut crate::stats::Rng, n_exp: usize,
                     n_gpu: usize) -> Grouping {
        let mut groups: Grouping = vec![Vec::new(); n_gpu];
        for e in 0..n_exp {
            groups[rng.index(n_gpu)].push(e);
        }
        // guarantee non-empty
        for g in 0..n_gpu {
            if groups[g].is_empty() {
                let donor =
                    (0..n_gpu).max_by_key(|&d| groups[d].len()).unwrap();
                let e = groups[donor].pop().unwrap();
                groups[g].push(e);
            }
        }
        groups
    }

    #[test]
    fn fixed_uses_single_least_loaded_gpu() {
        let p = profile_with_loads(vec![50.0, 30.0, 4.0, 10.0, 2.0, 0.0]);
        let groups = vec![vec![0, 1, 2], vec![3], vec![4], vec![5]];
        let rep = fixed_replication(&p, &groups);
        assert_eq!(rep.n_replica, 1);
        assert_eq!(rep.replica_gpus, vec![3]);
        // overloaded = above group mean 28: experts 0 (50) and 1 (30)
        assert_eq!(rep.hot_experts, vec![0, 1]);
    }

    #[test]
    fn zero_load_yields_no_replication() {
        let p = profile_with_loads(vec![0.0; 8]);
        let groups = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        assert!(dynamic_replication(&p, &groups).is_none());
        assert!(fixed_replication(&p, &groups).is_none());
    }

    #[test]
    fn computed_empty_is_distinguishable_from_not_configured() {
        // Regression for the is_none conflation: a replication pass that
        // ran and survived zero hot experts (threshold boundary — here
        // the degenerate all-zero-load layer) must be tellable apart
        // from "replication was never configured".
        let p = profile_with_loads(vec![0.0; 8]);
        let groups = vec![vec![0, 1], vec![2, 3], vec![4, 5], vec![6, 7]];
        let dr = dynamic_replication(&p, &groups);
        let fr = fixed_replication(&p, &groups);
        assert!(dr.is_none() && dr.was_computed(),
                "DR ran but found nothing");
        assert!(fr.is_none() && fr.was_computed());
        let off = Replication::none();
        assert!(off.is_none() && !off.was_computed(),
                "none() means not configured");
        assert_ne!(off, Replication::empty());
        // A non-degenerate pass is computed and non-empty.
        let hot = profile_with_loads(vec![50.0, 1.0, 1.0, 1.0,
                                          1.0, 1.0, 1.0, 1.0]);
        let rep = dynamic_replication(&hot, &groups);
        assert!(rep.was_computed() && !rep.is_none());
    }

    #[test]
    fn eq4_prediction() {
        let pre = vec![84.0, 10.0, 2.0, 0.0];
        let rep = Replication {
            hot_experts: vec![0, 1],
            replica_gpus: vec![3, 2, 1],
            n_replica: 3,
            w_max: 84.0,
            w_r: 80.0,
            computed: true,
        };
        let post = predict_loads(&pre, 0, &rep);
        let w_p = 84.0 / 4.0;
        assert_eq!(post[0], 84.0 - 80.0 + w_p);
        assert_eq!(post[1], 10.0 + w_p);
        assert_eq!(post[2], 2.0 + w_p);
        assert_eq!(post[3], 0.0 + w_p);
    }

    #[test]
    fn prediction_reduces_imbalance() {
        check(40, |rng| {
            let n_gpu = 3 + rng.index(5);
            let n_exp = n_gpu * 4;
            // skewed loads: one very hot expert
            let mut loads = vec![1.0; n_exp];
            loads[0] = 50.0 + rng.index(100) as f64;
            let p = profile_with_loads(loads.clone());
            let groups: Grouping = (0..n_gpu)
                .map(|g| (g * 4..(g + 1) * 4).collect())
                .collect();
            let rep = dynamic_replication(&p, &groups);
            let pre: Vec<f64> =
                groups.iter().map(|g| p.group_load(g)).collect();
            let heavy = p.heaviest_group(&groups);
            let post = predict_loads(&pre, heavy, &rep);
            let max_pre = pre.iter().cloned().fold(0.0, f64::max);
            let max_post = post.iter().cloned().fold(0.0, f64::max);
            prop_assert(max_post <= max_pre + 1e-9,
                        format!("peak rose: {max_pre} → {max_post}"))
        });
    }

    #[test]
    fn polling_weights_inverse_and_normalized() {
        let w = polling_weights(&[10.0, 20.0, 40.0]);
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] > w[1] && w[1] > w[2]);
        assert!((w[0] / w[1] - 2.0).abs() < 1e-6, "inverse proportional");
    }

    #[test]
    fn polling_weights_handle_zero_load() {
        let w = polling_weights(&[0.0, 1.0]);
        assert!(w[0] > 0.99, "idle gpu takes almost all weight");
    }
}
