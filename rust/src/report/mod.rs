//! Experiment reporting: the paper-shaped tables (relative-to-baseline
//! component analysis, per-system end-to-end comparisons) and JSON export
//! for downstream plotting.

use crate::bench::{pct, Table};
use crate::configio::Value;
use crate::metrics::RunMetrics;
use crate::stats::summary::rel_change;

/// Table 1: relative change of each metric vs the baseline system (first
/// column), in the paper's row order.
pub fn table1(names: &[&str], runs: &[RunMetrics]) -> Table {
    assert_eq!(names.len(), runs.len());
    assert!(!runs.is_empty());
    let base = &runs[0];
    let mut header = vec!["METRIC"];
    header.extend_from_slice(names);
    let mut t = Table::new(&header);
    let rows: [(&str, fn(&RunMetrics) -> f64); 5] = [
        ("ALL-TO-ALL TIME", |m| m.a2a_time),
        ("CROSS-NODE TRAFFIC", |m| m.cross_bytes),
        ("INTRA-NODE TRAFFIC", |m| m.intra_bytes),
        ("GPU IDLE TIME", |m| m.idle_time),
        ("AVG. GPU LOAD STD.", |m| m.mean_load_std()),
    ];
    for (label, get) in rows {
        let mut cells = vec![label.to_string()];
        for m in runs {
            let rc = rel_change(get(base), get(m));
            cells.push(if std::ptr::eq(m, base) {
                "0.00".to_string()
            } else {
                pct(rc)
            });
        }
        t.row(cells);
    }
    t
}

/// End-to-end comparison row set (Fig. 4 / Fig. 7 style): absolute
/// latencies (ms) plus speedup vs the first system.
pub fn e2e_table(names: &[&str], runs: &[RunMetrics]) -> Table {
    assert_eq!(names.len(), runs.len());
    let mut t = Table::new(&[
        "SYSTEM",
        "E2E (ms)",
        "MOE LAYER (ms)",
        "A2A (ms)",
        "SPEEDUP",
    ]);
    let base = runs[0].e2e_time;
    for (n, m) in names.iter().zip(runs) {
        t.row(vec![
            n.to_string(),
            format!("{:.2}", m.e2e_time * 1e3),
            format!("{:.2}", m.moe_layer_time * 1e3),
            format!("{:.2}", m.a2a_time * 1e3),
            format!("{:.2}x", base / m.e2e_time),
        ]);
    }
    t
}

/// JSON export of one run's metrics (machine-readable bench output).
pub fn metrics_json(name: &str, m: &RunMetrics) -> Value {
    Value::object(vec![
        ("system", Value::str(name)),
        ("e2e_ms", Value::num(m.e2e_time * 1e3)),
        ("moe_layer_ms", Value::num(m.moe_layer_time * 1e3)),
        ("a2a_ms", Value::num(m.a2a_time * 1e3)),
        ("cross_gb", Value::num(m.cross_bytes / 1e9)),
        ("intra_gb", Value::num(m.intra_bytes / 1e9)),
        ("idle_ms", Value::num(m.idle_time * 1e3)),
        ("avg_load_std", Value::num(m.mean_load_std())),
        ("launches", Value::from(m.launches)),
        ("tokens", Value::from(m.tokens)),
        ("migration_mb", Value::num(m.migration_bytes / 1e6)),
        ("replans", Value::from(m.replans)),
    ])
}

/// Aggregate several named runs into a JSON array.
pub fn runs_json(named: &[(&str, &RunMetrics)]) -> Value {
    Value::array(named.iter().map(|(n, m)| metrics_json(n, m)))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(a2a: f64, e2e: f64) -> RunMetrics {
        RunMetrics {
            a2a_time: a2a,
            e2e_time: e2e,
            moe_layer_time: e2e * 0.6,
            cross_bytes: a2a * 1e9,
            intra_bytes: a2a * 2e9,
            idle_time: 0.01,
            layer_load_std: vec![1.0],
            launches: 2,
            tokens: 100,
            migration_bytes: 0.0,
            replans: 0,
        }
    }

    #[test]
    fn table1_relative_format() {
        let runs = vec![m(1.0, 2.0), m(0.6481, 2.0)];
        let t = table1(&["occult", "occult+hsc"], &runs);
        let s = t.render();
        assert!(s.contains("-35.19%"), "{s}");
        assert!(s.contains("ALL-TO-ALL TIME"));
    }

    #[test]
    fn e2e_table_speedups() {
        let runs = vec![m(1.0, 2.0), m(0.5, 1.0)];
        let t = e2e_table(&["occult", "grace"], &runs);
        let s = t.render();
        assert!(s.contains("2.00x"), "{s}");
        assert!(s.contains("1.00x"));
    }

    #[test]
    fn json_roundtrips() {
        let v = metrics_json("grace", &m(0.1, 0.5));
        let text = crate::configio::to_string(&v);
        let back = crate::configio::parse(&text).unwrap();
        assert_eq!(back.req_str("system").unwrap(), "grace");
        assert!((back.req_f64("e2e_ms").unwrap() - 500.0).abs() < 1e-9);
    }
}
