//! Dynamically-typed config value tree with typed accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON-style value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

/// Typed-access errors with a path-ish message for debuggability.
#[derive(Debug)]
pub enum ValueError {
    Missing(String),
    Type { key: String, want: &'static str, got: &'static str },
}

impl fmt::Display for ValueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ValueError::Missing(key) => write!(f, "missing key '{key}'"),
            ValueError::Type { key, want, got } => {
                write!(f, "'{key}': expected {want}, got {got}")
            }
        }
    }
}

impl std::error::Error for ValueError {}

impl Value {
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Required typed getters (errors carry the key for diagnostics).
    pub fn req(&self, key: &str) -> Result<&Value, ValueError> {
        self.get(key).ok_or_else(|| ValueError::Missing(key.into()))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, ValueError> {
        let v = self.req(key)?;
        v.as_usize().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "non-negative integer",
            got: v.kind(),
        })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, ValueError> {
        let v = self.req(key)?;
        v.as_f64().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "number",
            got: v.kind(),
        })
    }

    pub fn req_str(&self, key: &str) -> Result<&str, ValueError> {
        let v = self.req(key)?;
        v.as_str().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "string",
            got: v.kind(),
        })
    }

    pub fn req_array(&self, key: &str) -> Result<&[Value], ValueError> {
        let v = self.req(key)?;
        v.as_array().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "array",
            got: v.kind(),
        })
    }

    /// Optional getter with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Builder helpers.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn array<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Array(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Value {
        Value::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::json::to_string(self))
    }
}

impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_getters() {
        let v = Value::object(vec![
            ("n", Value::from(5usize)),
            ("x", Value::from(1.5)),
            ("s", Value::from("hi")),
        ]);
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_f64("x").unwrap(), 1.5);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(matches!(v.req_usize("x"), Err(ValueError::Type { .. })));
        assert!(matches!(v.req_str("zzz"), Err(ValueError::Missing(_))));
    }

    #[test]
    fn defaults() {
        let v = Value::object(vec![("a", Value::from(2usize))]);
        assert_eq!(v.usize_or("a", 9), 2);
        assert_eq!(v.usize_or("b", 9), 9);
        assert_eq!(v.str_or("c", "d"), "d");
    }

    #[test]
    fn negative_is_not_usize() {
        let v = Value::Num(-3.0);
        assert_eq!(v.as_usize(), None);
        assert_eq!(v.as_f64(), Some(-3.0));
    }
}
