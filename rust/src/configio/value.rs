//! Dynamically-typed config value tree with typed accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON-style value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (always f64).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Array(Vec<Value>),
    /// JSON object (sorted keys).
    Object(BTreeMap<String, Value>),
}

/// Typed-access errors with a path-ish message for debuggability.
#[derive(Debug)]
pub enum ValueError {
    /// Required key absent.
    Missing(String),
    /// Key present with the wrong type.
    Type {
        /// The key looked up.
        key: String,
        /// Expected type name.
        want: &'static str,
        /// Actual type name found.
        got: &'static str,
    },
}

impl fmt::Display for ValueError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ValueError::Missing(key) => write!(f, "missing key '{key}'"),
            ValueError::Type { key, want, got } => {
                write!(f, "'{key}': expected {want}, got {got}")
            }
        }
    }
}

impl std::error::Error for ValueError {}

impl Value {
    /// Type name of this value (diagnostics).
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Null => "null",
            Value::Bool(_) => "bool",
            Value::Num(_) => "number",
            Value::Str(_) => "string",
            Value::Array(_) => "array",
            Value::Object(_) => "object",
        }
    }

    /// The object map, if this is an object.
    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    /// The number, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a usize, if it is a non-negative integer.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Num(x) if *x >= 0.0 && x.fract() == 0.0 => {
                Some(*x as usize)
            }
            _ => None,
        }
    }

    /// The string, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The boolean, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Object member lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|m| m.get(key))
    }

    /// Required member (errors carry the key for diagnostics).
    pub fn req(&self, key: &str) -> Result<&Value, ValueError> {
        self.get(key).ok_or_else(|| ValueError::Missing(key.into()))
    }

    /// Required non-negative-integer member.
    pub fn req_usize(&self, key: &str) -> Result<usize, ValueError> {
        let v = self.req(key)?;
        v.as_usize().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "non-negative integer",
            got: v.kind(),
        })
    }

    /// Required number member.
    pub fn req_f64(&self, key: &str) -> Result<f64, ValueError> {
        let v = self.req(key)?;
        v.as_f64().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "number",
            got: v.kind(),
        })
    }

    /// Required string member.
    pub fn req_str(&self, key: &str) -> Result<&str, ValueError> {
        let v = self.req(key)?;
        v.as_str().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "string",
            got: v.kind(),
        })
    }

    /// Required array member.
    pub fn req_array(&self, key: &str) -> Result<&[Value], ValueError> {
        let v = self.req(key)?;
        v.as_array().ok_or_else(|| ValueError::Type {
            key: key.into(),
            want: "array",
            got: v.kind(),
        })
    }

    /// Optional non-negative-integer member with default.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.as_usize()).unwrap_or(default)
    }

    /// Optional number member with default.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    /// Optional string member with default.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|v| v.as_str()).unwrap_or(default)
    }

    /// Optional boolean member with default.
    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    /// Build an object from `(key, value)` pairs.
    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    /// Build an array from an iterator of values.
    pub fn array<I: IntoIterator<Item = Value>>(items: I) -> Value {
        Value::Array(items.into_iter().collect())
    }

    /// Build a number value.
    pub fn num<T: Into<f64>>(x: T) -> Value {
        Value::Num(x.into())
    }

    /// Build a string value.
    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::json::to_string(self))
    }
}

impl From<usize> for Value {
    fn from(x: usize) -> Value {
        Value::Num(x as f64)
    }
}

impl From<f64> for Value {
    fn from(x: f64) -> Value {
        Value::Num(x)
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Value {
        Value::Str(s.to_string())
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Value {
        Value::Bool(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn typed_getters() {
        let v = Value::object(vec![
            ("n", Value::from(5usize)),
            ("x", Value::from(1.5)),
            ("s", Value::from("hi")),
        ]);
        assert_eq!(v.req_usize("n").unwrap(), 5);
        assert_eq!(v.req_f64("x").unwrap(), 1.5);
        assert_eq!(v.req_str("s").unwrap(), "hi");
        assert!(matches!(v.req_usize("x"), Err(ValueError::Type { .. })));
        assert!(matches!(v.req_str("zzz"), Err(ValueError::Missing(_))));
    }

    #[test]
    fn defaults() {
        let v = Value::object(vec![("a", Value::from(2usize))]);
        assert_eq!(v.usize_or("a", 9), 2);
        assert_eq!(v.usize_or("b", 9), 9);
        assert_eq!(v.str_or("c", "d"), "d");
    }

    #[test]
    fn negative_is_not_usize() {
        let v = Value::Num(-3.0);
        assert_eq!(v.as_usize(), None);
        assert_eq!(v.as_f64(), Some(-3.0));
    }
}
