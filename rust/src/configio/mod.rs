//! Config/serialization substrate: a self-contained JSON parser and
//! writer (no serde available offline).
//!
//! Used for the AOT `artifacts/manifest.json` handshake with the python
//! compile path, for experiment/cluster/workload config files, and for
//! machine-readable bench output.

pub mod json;
pub mod value;

pub use json::{parse, to_string, to_string_pretty};
pub use value::{Value, ValueError};
