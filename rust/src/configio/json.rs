//! Recursive-descent JSON parser + writer (RFC 8259 subset: no surrogate
//! pairs beyond \uXXXX handling, numbers as f64).

use super::value::Value;
use std::collections::BTreeMap;

/// Parse error with byte offset and a short context excerpt.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset of the failure.
    pub offset: usize,
    /// What the parser expected.
    pub msg: String,
    /// A short excerpt of the input at the failure point.
    pub near: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "json parse error at byte {}: {} (near '{}')",
            self.offset, self.msg, self.near
        )
    }
}

impl std::error::Error for ParseError {}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, ParseError> {
        let end = (self.pos + 16).min(self.b.len());
        Err(ParseError {
            offset: self.pos,
            msg: msg.into(),
            near: String::from_utf8_lossy(&self.b[self.pos..end]).into(),
        })
    }

    fn skip_ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            self.err(format!("expected '{}'", c as char))
        }
    }

    fn eat_lit(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            self.err(format!("expected '{lit}'"))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.eat_lit("true", Value::Bool(true)),
            Some(b'f') => self.eat_lit("false", Value::Bool(false)),
            Some(b'n') => self.eat_lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => self.err("unexpected character"),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Object(m));
                }
                _ => return self.err("expected ',' or '}'"),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Array(a));
                }
                _ => return self.err("expected ',' or ']'"),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return self.err("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return self.err("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| ParseError {
                                offset: self.pos,
                                msg: "bad \\u escape".into(),
                                near: String::new(),
                            })?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| ParseError {
                                    offset: self.pos,
                                    msg: "bad \\u escape".into(),
                                    near: hex.into(),
                                })?;
                            s.push(
                                char::from_u32(cp).unwrap_or('\u{fffd}'),
                            );
                            self.pos += 4;
                        }
                        _ => return self.err("bad escape"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let start = self.pos;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.b[start..start + len]) {
                        Ok(chunk) => s.push_str(chunk),
                        Err(_) => return self.err("invalid utf-8"),
                    }
                    self.pos += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError {
                offset: start,
                msg: "bad number".into(),
                near: text.into(),
            })
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.b.len() {
        return p.err("trailing data");
    }
    Ok(v)
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_num(x: f64, out: &mut String) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>,
               level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(x) => write_num(*x, out),
        Value::Str(s) => write_escaped(s, out),
        Value::Array(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_value(item, out, indent, level + 1);
            }
            if let Some(w) = indent {
                if !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * level));
                }
            }
            out.push(']');
        }
        Value::Object(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if let Some(w) = indent {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * (level + 1)));
                }
                write_escaped(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            if let Some(w) = indent {
                if !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(w * level));
                }
            }
            out.push('}');
        }
    }
}

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s, None, 0);
    s
}

/// 2-space-indented serialization.
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, &mut s, Some(2), 0);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("-2.5e2").unwrap(), Value::Num(-250.0));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": "x"}, null], "c": {}}"#).unwrap();
        assert_eq!(v.req_array("a").unwrap().len(), 3);
        assert_eq!(
            v.req_array("a").unwrap()[1].req_str("b").unwrap(),
            "x"
        );
        assert!(v.get("c").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            parse(r#""Aé""#).unwrap(),
            Value::Str("Aé".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"z":[1,2.5,true,null,"s\"q"],"a":{"k":-3}}"#;
        let v = parse(src).unwrap();
        for text in [to_string(&v), to_string_pretty(&v)] {
            assert_eq!(parse(&text).unwrap(), v, "text={text}");
        }
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(to_string(&Value::Num(5.0)), "5");
        assert_eq!(to_string(&Value::Num(5.5)), "5.5");
        assert_eq!(to_string(&Value::Num(-0.0)), "0");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
 "fingerprint": "abc",
 "variants": {
  "olmoe_tiny": {
   "config": {"experts": 64, "top_k": 8},
   "artifacts": {"gate": {"file": "olmoe_tiny_gate.hlo.txt",
                          "inputs": [{"shape": [64, 64],
                                      "dtype": "float32"}]}}
  }
 }
}"#;
        let v = parse(src).unwrap();
        let variant = v.get("variants").unwrap().get("olmoe_tiny").unwrap();
        assert_eq!(variant.get("config").unwrap()
                   .req_usize("experts").unwrap(), 64);
    }
}
