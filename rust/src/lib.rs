//! # GRACE-MoE
//!
//! Reproduction of *"GRACE-MoE: Grouping and Replication with
//! Locality-Aware Routing for Efficient Distributed MoE Inference"*
//! (Han et al., 2025).
//!
//! GRACE-MoE jointly optimizes the two conflicting bottlenecks of
//! distributed Sparse-MoE inference — All-to-All communication overhead and
//! computational load imbalance — through:
//!
//! * **offline non-uniform hierarchical expert grouping** on an expert
//!   co-activation affinity matrix ([`grouping`]),
//! * **dynamic expert replication** driven by the load-skew factor
//!   `ρ = W_max / W̄` ([`replication`]), kept live under workload drift
//!   by the epoch-based online re-planner ([`replan`]),
//! * **online locality-aware routing**: an object-safe [`routing::RoutePolicy`]
//!   trait (primary / WRR / TAR / online load-aware) executed in batched
//!   dispatch rounds that emit per-`(src, dst)` transfer plans
//!   ([`routing`]),
//! * a **hierarchical sparse communication** substrate replacing flat
//!   global All-to-All ([`comm`]).
//!
//! This crate is the L3 coordinator of a three-layer rust + JAX + Pallas
//! stack: the JAX/Pallas compute graph is AOT-lowered to HLO text at build
//! time (`make artifacts`) and executed from rust through the PJRT C API
//! ([`runtime`]); python never runs on the request path.
//!
//! Architecture tour (bottom-up):
//!
//! | layer | modules |
//! |---|---|
//! | substrates | [`stats`], [`linalg`], [`configio`], [`cli`], [`testutil`], [`bench`], [`exec`] |
//! | cluster model | [`cluster`], [`comm`] |
//! | profiling | [`trace`], [`profile`] |
//! | GRACE algorithms | [`grouping`], [`replication`], [`placement`], [`routing`] — `RoutePolicy` trait + `Dispatcher`/`DispatchPlan` batched dispatch |
//! | online feedback | [`replan`] — epoch-based re-planning: measured loads → Eq. 3/4 recomputed → gated placement hot-swap |
//! | coordination | [`coordinator`] — the L3 offline→online pipeline (`Coordinator` offline, `OnlineCoordinator` serving + epoch ticks) |
//! | engine | [`engine`], [`runtime`], [`server`] — continuous-batching serving core: [`server::sched`] iteration-level scheduler over the batched multi-sequence decode step |
//! | evaluation | [`baselines`], [`metrics`], [`report`] |
//!
//! The paper-to-code map — every section, equation, and figure of the
//! paper against the module, type, and test implementing it — lives in
//! `docs/ARCHITECTURE.md`; `docs/BENCHMARKS.md` maps the bench targets
//! to the figures/tables they reproduce.

#![warn(missing_docs)]
#![deny(rustdoc::broken_intra_doc_links)]

pub mod bench;
pub mod cli;
pub mod configio;
pub mod linalg;
pub mod stats;
pub mod testutil;

pub mod cluster;
pub mod comm;

pub mod profile;
pub mod trace;

pub mod grouping;
pub mod placement;
pub mod replan;
pub mod replication;
pub mod routing;

pub mod coordinator;

pub mod config;
pub mod engine;
pub mod exec;
pub mod runtime;
pub mod server;

pub mod baselines;
pub mod metrics;
pub mod report;
