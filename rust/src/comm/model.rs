//! Timing models for the three All-to-All implementations.
//!
//! All models share the same physical primitives (α–β cost with per-node
//! NIC sharing and per-rank straggler jitter) and differ exactly where the
//! paper says they differ:
//!
//! | effect | flat | staged hierarchical | HSC |
//! |---|---|---|---|
//! | cross-node dedup | no | node-level | node-level |
//! | kernel launches | 1 | 1 per rail group + 1 per node | 2 |
//! | synchronization | global hard sync | per-group (decoupled) | implicit barrier (soft) |
//! | progress decoupling penalty | — | yes | no |
//! | overlap with routing compute | no | no | stage 1 overlapped |
//! | zero-padding overhead | — | — | pad to tile quantum |

use super::traffic::{TrafficMatrix, TwoStageTraffic};
use crate::cluster::Topology;
use crate::stats::Rng;

/// Which collective implementation a system variant uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommModel {
    /// Flat global All-to-All (Tutel / MegaBlocks / vanilla EP).
    Flat,
    /// Conventional multi-stage hierarchical All-to-All.
    StagedHierarchical,
    /// GRACE-MoE's hierarchical sparse communication (§5).
    Hsc,
}

/// Cost breakdown of one collective invocation (one direction — the engine
/// invokes it twice per MoE layer: dispatch and combine).
#[derive(Clone, Debug, Default)]
pub struct CommReport {
    /// End-to-end wall time of the collective, seconds.
    pub time: f64,
    /// Bytes over cross-node links.
    pub cross_bytes: f64,
    /// Bytes over intra-node (NVLink) links.
    pub intra_bytes: f64,
    /// Kernel launches issued.
    pub launches: usize,
    /// Per-stage wall times (diagnostics).
    pub stage_times: Vec<f64>,
    /// Time lost to synchronization (straggler max + decoupling stall).
    pub sync_time: f64,
}

impl CommReport {
    /// Sum another invocation's costs into this report.
    pub fn accumulate(&mut self, other: &CommReport) {
        self.time += other.time;
        self.cross_bytes += other.cross_bytes;
        self.intra_bytes += other.intra_bytes;
        self.launches += other.launches;
        self.sync_time += other.sync_time;
        self.stage_times.extend(other.stage_times.iter().copied());
    }
}

/// Per-rank straggler slowdown factors for one synchronization scope.
/// Returns the max over `ranks` of `1 + |N(0,1)| * jitter`.
/// `pub(crate)` so the DES backend ([`crate::comm::sim`]) draws the
/// *same* jitter stream in the same order as the analytic models.
pub(crate) fn straggler_max(rng: &mut Rng, ranks: usize, jitter: f64) -> f64 {
    let mut worst = 1.0_f64;
    for _ in 0..ranks {
        worst = worst.max(1.0 + rng.gaussian().abs() * jitter);
    }
    worst
}

/// α–β time for one synchronous stage over a traffic matrix: every GPU's
/// egress and ingress serialize on its links, cross-node flows share the
/// node NIC, and the stage completes at the slowest participant.
///
/// Latency (α) is charged once per *active pair* — the collective
/// aggregates all of a pair's tokens into one buffer exchange; per-token
/// message floors would be off by the token count.
pub(crate) fn stage_time(m: &TrafficMatrix, topo: &Topology) -> f64 {
    let n = m.num_gpus();
    let mut worst = 0.0_f64;
    // Per-GPU link serialization + one latency floor per active pair.
    for g in 0..n {
        let mut t_out = 0.0;
        let mut t_in = 0.0;
        for peer in 0..n {
            if peer == g {
                continue;
            }
            if m.get(g, peer) > 0.0 || m.msg_count(g, peer) > 0 {
                t_out += m.get(g, peer) / topo.bw(g, peer)
                    + topo.lat(g, peer);
            }
            if m.get(peer, g) > 0.0 || m.msg_count(peer, g) > 0 {
                t_in += m.get(peer, g) / topo.bw(peer, g)
                    + topo.lat(peer, g);
            }
        }
        worst = worst.max(t_out.max(t_in));
    }
    // Per-node NIC sharing: all cross-node egress (and ingress) of a node
    // squeezes through one NIC.
    for node in 0..topo.nodes {
        let mut nic_out = 0.0;
        let mut nic_in = 0.0;
        for g in topo.gpus_of(node) {
            for peer in 0..n {
                if topo.tier(g, peer) == 2 {
                    nic_out += m.get(g, peer);
                }
                if topo.tier(peer, g) == 2 {
                    nic_in += m.get(peer, g);
                }
            }
        }
        worst = worst.max(nic_out.max(nic_in) / topo.inter_bw);
    }
    worst
}

/// Restrict a matrix to the (src, dst) pairs for which `keep` holds.
pub(crate) fn filter_matrix(m: &TrafficMatrix,
                            keep: impl Fn(usize, usize) -> bool)
                            -> TrafficMatrix {
    let n = m.num_gpus();
    let mut out = TrafficMatrix::zeros(n);
    for s in 0..n {
        for d in 0..n {
            if keep(s, d) {
                for _ in 0..m.msg_count(s, d).saturating_sub(1) {
                    out.add(s, d, 0.0);
                }
                if m.msg_count(s, d) > 0 {
                    out.add(s, d, m.get(s, d));
                }
            }
        }
    }
    out
}

/// Flat global All-to-All: single stage, hard global synchronization.
pub fn flat_all_to_all(m: &TrafficMatrix, topo: &Topology,
                       rng: &mut Rng) -> CommReport {
    let t = stage_time(m, topo);
    let strag = straggler_max(rng, topo.num_gpus(), topo.jitter);
    let sync = t * (strag - 1.0);
    CommReport {
        time: topo.launch_overhead + t + sync,
        cross_bytes: m.cross_node_bytes(topo),
        intra_bytes: m.intra_node_bytes(topo),
        launches: 1,
        stage_times: vec![t],
        sync_time: sync,
    }
}

/// Progress-decoupling stall factor for independently progressing groups:
/// faster groups contend for the shared NIC and force slower ones to
/// spin-wait; the paper observes this amplifies tail latency. We model the
/// completion as `max_g t_g + κ·(max_g t_g − min_g t_g)` with κ = 0.5.
pub(crate) const DECOUPLE_KAPPA: f64 = 0.5;

/// Conventional staged hierarchical A2A: per-rail cross-node groups
/// (physically partitioned, no global coordination), then per-node
/// intra-node redistribution.
pub fn staged_hierarchical(ts: &TwoStageTraffic, topo: &Topology,
                           rng: &mut Rng) -> CommReport {
    let rails = topo.gpus_per_node;
    // Stage 1: one independent communication group per rail.
    let mut rail_times = Vec::with_capacity(rails);
    for r in 0..rails {
        let sub = filter_matrix(&ts.cross, |s, d| {
            s % topo.gpus_per_node == r && d % topo.gpus_per_node == r
        });
        let t = stage_time(&sub, topo);
        // Each group synchronizes only its own ranks (one per node).
        let strag = straggler_max(rng, topo.nodes, topo.jitter);
        rail_times.push(t * strag);
    }
    let t_max = rail_times.iter().cloned().fold(0.0, f64::max);
    let t_min = rail_times.iter().cloned().fold(f64::INFINITY, f64::min);
    let stall = if t_max > 0.0 {
        DECOUPLE_KAPPA * (t_max - t_min.min(t_max))
    } else {
        0.0
    };
    // All rail groups still squeeze through the same per-node NICs even
    // though they progress independently — the shared-bandwidth
    // contention that drives the paper's progress-decoupling observation.
    let nic_floor = stage_time(&ts.cross, topo);
    let t1 = t_max.max(nic_floor) + stall;

    // Stage 2: per-node redistribution; a node starts only after all its
    // landings arrive (strict barrier), so the stage is the max over nodes.
    let mut t2 = 0.0_f64;
    for node in 0..topo.nodes {
        let sub = filter_matrix(&ts.intra, |s, d| {
            topo.node_of(s) == node && topo.node_of(d) == node
        });
        t2 = t2.max(stage_time(&sub, topo));
    }
    let strag2 = straggler_max(rng, topo.gpus_per_node, topo.jitter);
    let sync2 = t2 * (strag2 - 1.0);

    let launches = rails + topo.nodes;
    CommReport {
        time: topo.launch_overhead * launches as f64 + t1 + t2 + sync2,
        cross_bytes: ts.cross.cross_node_bytes(topo),
        intra_bytes: ts.intra.intra_node_bytes(topo)
            + ts.cross.intra_node_bytes(topo),
        launches,
        stage_times: vec![t1, t2 + sync2],
        sync_time: stall + sync2,
    }
}

/// Zero-padding quantum for HSC's logically-sparse slots (bytes); slots
/// are padded up to a multiple of this (one token tile of the tiny model ≈
/// 8 tokens × 64 hidden × 4 B).
pub const HSC_PAD_QUANTUM: f64 = 2048.0;

/// GRACE-MoE hierarchical sparse communication (§5).
///
/// `overlap_budget` is the intra-node routing-decision compute time the
/// engine can overlap with the cross-node stage (fine-grained pipelining):
/// stage 1 costs `max(t1, overlap)` instead of `t1 + overlap`.
pub fn hsc(ts: &TwoStageTraffic, topo: &Topology, overlap_budget: f64,
           rng: &mut Rng) -> CommReport {
    // Stage 1: single global collective with zero-padded sparse slots.
    let padded = pad_matrix(&ts.cross, HSC_PAD_QUANTUM);
    let t1_raw = stage_time(&padded, topo);
    // Implicit barrier of the single global collective: jitter is paid
    // once across all ranks (soft synchronization), with no decoupling.
    let strag = straggler_max(rng, topo.num_gpus(), topo.jitter);
    let sync1 = t1_raw * (strag - 1.0);
    let t1 = (t1_raw + sync1).max(overlap_budget);

    // Stage 2: isolated per-node redistribution on NVLink.
    let mut t2 = 0.0_f64;
    for node in 0..topo.nodes {
        let sub = filter_matrix(&ts.intra, |s, d| {
            topo.node_of(s) == node && topo.node_of(d) == node
        });
        t2 = t2.max(stage_time(&sub, topo));
    }

    CommReport {
        time: topo.launch_overhead * 2.0 + t1 + t2,
        cross_bytes: padded.cross_node_bytes(topo),
        intra_bytes: ts.intra.intra_node_bytes(topo)
            + ts.cross.intra_node_bytes(topo),
        launches: 2,
        stage_times: vec![t1, t2],
        sync_time: sync1,
    }
}

/// Pad every non-empty slot up to a multiple of `quantum` bytes.
pub(crate) fn pad_matrix(m: &TrafficMatrix, quantum: f64) -> TrafficMatrix {
    let n = m.num_gpus();
    let mut out = TrafficMatrix::zeros(n);
    for s in 0..n {
        for d in 0..n {
            let b = m.get(s, d);
            if b > 0.0 {
                let padded = (b / quantum).ceil() * quantum;
                for _ in 0..m.msg_count(s, d).saturating_sub(1) {
                    out.add(s, d, 0.0);
                }
                out.add(s, d, padded);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::traffic::{per_copy, two_stage, Dispatch};

    fn topo() -> Topology {
        Topology::two_by_two()
    }

    fn no_jitter(mut t: Topology) -> Topology {
        t.jitter = 0.0;
        t
    }

    /// A skewed dispatch set: node-0 tokens hitting both GPUs of node 1.
    fn cross_heavy(n_tokens: usize) -> Vec<Dispatch> {
        (0..n_tokens)
            .map(|i| Dispatch { src: i % 2, dsts: vec![2, 3] })
            .collect()
    }

    #[test]
    fn flat_time_scales_with_bytes() {
        let t = no_jitter(topo());
        let mut rng = Rng::new(1);
        let small = per_copy(&cross_heavy(10), 4, 1024.0);
        let large = per_copy(&cross_heavy(1000), 4, 1024.0);
        let r_small = flat_all_to_all(&small, &t, &mut rng);
        let r_large = flat_all_to_all(&large, &t, &mut rng);
        // 100× the bytes: must grow several-fold even over the fixed
        // launch/latency floors.
        assert!(r_large.time > r_small.time * 5.0,
                "{} vs {}", r_large.time, r_small.time);
        assert_eq!(r_small.launches, 1);
    }

    #[test]
    fn hsc_beats_flat_on_cross_heavy_traffic() {
        let t = topo();
        let disp = cross_heavy(2000);
        let flat_m = per_copy(&disp, 4, 1024.0);
        let ts = two_stage(&disp, &t, 1024.0);
        let rf = flat_all_to_all(&flat_m, &t, &mut Rng::new(2));
        let rh = hsc(&ts, &t, 0.0, &mut Rng::new(2));
        assert!(
            rh.time < rf.time,
            "hsc {} !< flat {}",
            rh.time,
            rf.time
        );
        assert!(rh.cross_bytes < rf.cross_bytes, "node dedup halves bytes");
        // dedup shifts traffic intra-node — the paper's Table 1 signature
        assert!(rh.intra_bytes >= rf.intra_bytes);
    }

    #[test]
    fn hsc_beats_staged_hierarchical_on_sync() {
        // Skewed rails — the regime the paper's §3 decoupling argument is
        // about: one cross-node group carries most of the traffic, so
        // independently-progressing groups stall on the shared NIC.
        let t = topo();
        // Rails are source-aligned, so skew the *sources*: 3/4 of the
        // tokens live on gpu 0 (rail 0), 1/4 on gpu 1 (rail 1).
        let disp: Vec<Dispatch> = (0..2000)
            .map(|i| Dispatch {
                src: usize::from(i % 4 == 0),
                dsts: vec![2, 3],
            })
            .collect();
        let ts = two_stage(&disp, &t, 1024.0);
        let mut acc_staged = 0.0;
        let mut acc_hsc = 0.0;
        for seed in 0..20 {
            acc_staged +=
                staged_hierarchical(&ts, &t, &mut Rng::new(seed)).time;
            acc_hsc += hsc(&ts, &t, 0.0, &mut Rng::new(seed)).time;
        }
        assert!(
            acc_hsc < acc_staged,
            "hsc {acc_hsc} !< staged {acc_staged} (avg over seeds)"
        );
    }

    #[test]
    fn overlap_hides_stage1_under_budget() {
        let t = no_jitter(topo());
        let disp = cross_heavy(100);
        let ts = two_stage(&disp, &t, 1024.0);
        let r0 = hsc(&ts, &t, 0.0, &mut Rng::new(3));
        let big_budget = r0.time * 10.0;
        let r1 = hsc(&ts, &t, big_budget, &mut Rng::new(3));
        // with a huge overlap budget, stage 1 is exactly the budget
        assert!((r1.stage_times[0] - big_budget).abs() < 1e-12);
        // with zero budget, stage 1 is the raw comm time
        assert!(r0.stage_times[0] < big_budget);
    }

    #[test]
    fn padding_rounds_up_to_quantum() {
        let mut m = TrafficMatrix::zeros(2);
        m.add(0, 1, 1.0);
        let p = pad_matrix(&m, 2048.0);
        assert_eq!(p.get(0, 1), 2048.0);
        let p2 = pad_matrix(&p, 2048.0);
        assert_eq!(p2.get(0, 1), 2048.0, "idempotent at multiples");
    }

    #[test]
    fn empty_traffic_costs_only_launch() {
        let t = no_jitter(topo());
        let m = TrafficMatrix::zeros(4);
        let r = flat_all_to_all(&m, &t, &mut Rng::new(4));
        assert!((r.time - t.launch_overhead).abs() < 1e-12);
        assert_eq!(r.cross_bytes, 0.0);
    }

    #[test]
    fn staged_decoupling_penalizes_rail_imbalance() {
        let mut t = no_jitter(topo());
        t.launch_overhead = 0.0;
        // all cross traffic on rail 0 (gpu0 → gpu2): max spread
        let disp: Vec<Dispatch> = (0..100)
            .map(|_| Dispatch { src: 0, dsts: vec![2] })
            .collect();
        let ts = two_stage(&disp, &t, 1024.0);
        let r = staged_hierarchical(&ts, &t, &mut Rng::new(5));
        // stall = κ * (t_max - 0) > 0 since rail 1 is empty
        assert!(r.sync_time > 0.0);
        let rh = hsc(&ts, &t, 0.0, &mut Rng::new(5));
        assert!(rh.time < r.time);
    }

    #[test]
    fn report_accumulation() {
        let mut a = CommReport::default();
        let b = CommReport {
            time: 1.0,
            cross_bytes: 2.0,
            intra_bytes: 3.0,
            launches: 4,
            stage_times: vec![0.5],
            sync_time: 0.1,
        };
        a.accumulate(&b);
        a.accumulate(&b);
        assert_eq!(a.time, 2.0);
        assert_eq!(a.launches, 8);
        assert_eq!(a.stage_times.len(), 2);
    }
}
