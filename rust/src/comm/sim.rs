//! Contention-aware discrete-event network simulator (DES).
//!
//! The analytic models of [`crate::comm::model`] price each collective in
//! closed form and therefore cannot see *contention*: link queueing when
//! rounds overlap in virtual time, NIC sharing across concurrent flows, or
//! request ingest DMA colliding with dispatch traffic. This module replays
//! the same [`TrafficMatrix`] transfers through an event-driven simulation
//! of the cluster network:
//!
//! * The network is derived from [`Topology`]: one **egress port** and one
//!   **ingress port** per GPU, plus one **NIC-out** and one **NIC-in**
//!   resource per node that every cross-node flow of the node additionally
//!   occupies (the shared-NIC squeeze of the analytic model, made
//!   queue-accurate).
//! * Each point-to-point transfer occupies *all* of its resources in
//!   parallel and completes when the slowest leg finishes; every resource
//!   is a FIFO queue with α latency + β service time per message
//!   (`bytes/bw + lat`), advanced by the Lindley recursion
//!   `begin = max(submit, busy_until)`.
//! * Every leg emits typed [`EventKind::Arrive`]/[`EventKind::Depart`]
//!   events onto a binary-heap event queue, drained in `(time, seq)`
//!   order by [`NetworkSim::advance`] to maintain queue depths and a
//!   deterministic FNV-1a event digest (the `des-smoke` CI gate).
//!
//! **Validation invariant** (pinned by `tests/cluster_sim.rs`): a single
//! uncontended stage submitted to an idle network completes in exactly the
//! analytic [`stage-time`](crate::comm::model) — each resource's queue
//! serializes the same byte/latency terms the closed form sums — so the
//! DES wrappers [`flat_all_to_all`]/[`staged_hierarchical`]/[`hsc`]
//! reproduce the analytic `CommReport` times on uncontended traffic up to
//! floating-point association. They draw straggler jitter from the shared
//! [`Rng`] in *exactly* the analytic draw order, so the two backends stay
//! comparable seed-for-seed.
//!
//! [`CommBackend`] is the seam the engine and the open-loop fleet driver
//! ([`crate::engine::fleet`]) route rounds through: `Analytic` preserves
//! the closed-form path bit-for-bit, `Des` replays every round (and
//! request ingest) on the contended network at explicit virtual times.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;

use super::model::{self, CommModel, CommReport};
use super::traffic::{self, TrafficMatrix, TwoStageTraffic};
use crate::cluster::{GpuId, Topology};
use crate::metrics::ContentionReport;
use crate::routing::DispatchPlan;
use crate::stats::Rng;

/// Queue-depth histogram resolution: depths ≥ this land in the overflow
/// bucket, keeping memory flat over ~10⁶-request replays.
const DEPTH_BUCKETS: usize = 64;

/// Event type of one event-log entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    /// A transfer joined a link's FIFO queue.
    Arrive,
    /// A transfer's service on a link completed.
    Depart,
}

/// One processed event, as retained by the optional event log
/// ([`NetworkSim::enable_log`]) — the determinism tests compare two runs'
/// logs entry-for-entry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct EventRecord {
    /// Bit pattern of the event's virtual time (exact comparison).
    pub time_bits: u64,
    /// Global push sequence number (total order tiebreak).
    pub seq: u64,
    /// Arrive or depart.
    pub kind: EventKind,
    /// Link the event happened on (see [`NetworkSim`] link order).
    pub link: u32,
    /// Transfer the event belongs to.
    pub transfer: u64,
}

/// Typed event on the simulator's binary-heap queue, min-ordered by
/// `(time, seq)` via [`Reverse`].
#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
    link: u32,
    transfer: u64,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Event {}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        self.time
            .total_cmp(&other.time)
            .then(self.seq.cmp(&other.seq))
    }
}

/// Per-link occupancy accounting.
#[derive(Clone, Debug, Default)]
struct LinkStats {
    /// Seconds the link spent serving transfers.
    busy_s: f64,
    /// Seconds transfers spent queued behind earlier transfers.
    wait_s: f64,
    /// Bytes served.
    bytes: f64,
}

/// Event-driven model of the cluster network.
///
/// Link index space (`2·num_gpus + 2·nodes` FIFO resources):
///
/// | index | resource |
/// |---|---|
/// | `g` | egress port of GPU `g` |
/// | `num_gpus + g` | ingress port of GPU `g` |
/// | `2·num_gpus + m` | NIC-out of node `m` (cross-node flows only) |
/// | `2·num_gpus + nodes + m` | NIC-in of node `m` (cross-node flows only) |
#[derive(Clone, Debug)]
pub struct NetworkSim {
    nodes: usize,
    gpus_per_node: usize,
    num_gpus: usize,
    intra_bw: f64,
    inter_bw: f64,
    intra_lat: f64,
    inter_lat: f64,
    /// Lindley state: when each link's queue drains.
    busy_until: Vec<f64>,
    stats: Vec<LinkStats>,
    heap: BinaryHeap<Reverse<Event>>,
    seq: u64,
    next_transfer: u64,
    /// Earliest submit time seen (utilization horizon start).
    t0: f64,
    /// Latest leg completion seen (utilization horizon end).
    makespan: f64,
    /// Current queue depth per link (in service + waiting).
    depth: Vec<usize>,
    depth_max: usize,
    /// Arrival-sampled depth histogram; last bucket is overflow.
    depth_hist: Vec<u64>,
    digest: u64,
    log: Option<Vec<EventRecord>>,
    straggler_stall_s: f64,
    events_processed: u64,
}

impl NetworkSim {
    /// An idle network over `topo`'s ports and NICs.
    pub fn new(topo: &Topology) -> NetworkSim {
        let links = 2 * topo.num_gpus() + 2 * topo.nodes;
        NetworkSim {
            nodes: topo.nodes,
            gpus_per_node: topo.gpus_per_node,
            num_gpus: topo.num_gpus(),
            intra_bw: topo.intra_bw,
            inter_bw: topo.inter_bw,
            intra_lat: topo.intra_lat,
            inter_lat: topo.inter_lat,
            busy_until: vec![0.0; links],
            stats: vec![LinkStats::default(); links],
            heap: BinaryHeap::new(),
            seq: 0,
            next_transfer: 0,
            t0: f64::INFINITY,
            makespan: f64::NEG_INFINITY,
            depth: vec![0; links],
            depth_max: 0,
            depth_hist: vec![0; DEPTH_BUCKETS + 1],
            digest: 0xcbf2_9ce4_8422_2325,
            log: None,
            straggler_stall_s: 0.0,
            events_processed: 0,
        }
    }

    /// Simulated FIFO resources.
    pub fn num_links(&self) -> usize {
        self.busy_until.len()
    }

    fn egress_link(&self, g: GpuId) -> usize {
        g
    }

    fn ingress_link(&self, g: GpuId) -> usize {
        self.num_gpus + g
    }

    fn nic_out_link(&self, node: usize) -> usize {
        2 * self.num_gpus + node
    }

    fn nic_in_link(&self, node: usize) -> usize {
        2 * self.num_gpus + self.nodes + node
    }

    fn node_of(&self, g: GpuId) -> usize {
        g / self.gpus_per_node
    }

    /// Resource legs of one `(src, dst)` transfer: `(link, service_s)`.
    /// Same α–β terms as the analytic `stage_time` — ports pay
    /// `bytes/bw + lat` per message, NICs pay pure `bytes/bw`.
    fn legs(&self, s: GpuId, d: GpuId, bytes: f64,
            out: &mut [(usize, f64); 4]) -> usize {
        if self.node_of(s) == self.node_of(d) {
            let service = bytes / self.intra_bw + self.intra_lat;
            out[0] = (self.egress_link(s), service);
            out[1] = (self.ingress_link(d), service);
            2
        } else {
            let service = bytes / self.inter_bw + self.inter_lat;
            let nic = bytes / self.inter_bw;
            out[0] = (self.egress_link(s), service);
            out[1] = (self.ingress_link(d), service);
            out[2] = (self.nic_out_link(self.node_of(s)), nic);
            out[3] = (self.nic_in_link(self.node_of(d)), nic);
            4
        }
    }

    /// Occupy `legs` from `submit`, emit events, and return the
    /// transfer's completion (max over legs).
    fn commit_legs(&mut self, legs: &[(usize, f64)], bytes: f64,
                   submit: f64) -> f64 {
        let id = self.next_transfer;
        self.next_transfer += 1;
        let mut fin = submit;
        for &(link, service) in legs {
            let begin = self.busy_until[link].max(submit);
            self.stats[link].wait_s += begin - submit;
            self.stats[link].busy_s += service;
            self.stats[link].bytes += bytes;
            let end = begin + service;
            self.busy_until[link] = end;
            self.push_event(submit, EventKind::Arrive, link, id);
            self.push_event(end, EventKind::Depart, link, id);
            fin = fin.max(end);
        }
        self.t0 = self.t0.min(submit);
        self.makespan = self.makespan.max(fin);
        fin
    }

    /// Submit every active pair of `m` at `start` (all at once — the
    /// collective hands the whole stage to the network) and return the
    /// stage finish time. Committing: link occupancy, stats, and events
    /// persist, so later stages queue behind this one.
    ///
    /// On an idle network this is exactly the analytic stage time: each
    /// port's FIFO serializes the same `bytes/bw + lat` terms the closed
    /// form sums, and the stage ends at the slowest resource.
    pub fn replay_stage(&mut self, m: &TrafficMatrix, start: f64) -> f64 {
        debug_assert_eq!(m.num_gpus(), self.num_gpus);
        let n = m.num_gpus();
        let mut legs = [(0usize, 0.0f64); 4];
        let mut fin = start;
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue; // same-GPU moves are free (no network leg)
                }
                if m.get(s, d) <= 0.0 && m.msg_count(s, d) == 0 {
                    continue;
                }
                let bytes = m.get(s, d);
                let k = self.legs(s, d, bytes, &mut legs);
                let done = self.commit_legs(&legs[..k], bytes, start);
                fin = fin.max(done);
            }
        }
        fin
    }

    /// Hypothetical finish time of `m` submitted at `start` against the
    /// *current* occupancy, without committing anything — how the staged
    /// collective times each rail group in isolation while the combined
    /// NIC occupancy is what actually lands on the network.
    pub fn probe_stage(&self, m: &TrafficMatrix, start: f64) -> f64 {
        debug_assert_eq!(m.num_gpus(), self.num_gpus);
        let n = m.num_gpus();
        let mut busy = self.busy_until.clone();
        let mut legs = [(0usize, 0.0f64); 4];
        let mut fin = start;
        for s in 0..n {
            for d in 0..n {
                if s == d {
                    continue;
                }
                if m.get(s, d) <= 0.0 && m.msg_count(s, d) == 0 {
                    continue;
                }
                let k = self.legs(s, d, m.get(s, d), &mut legs);
                for &(link, service) in &legs[..k] {
                    let end = busy[link].max(start) + service;
                    busy[link] = end;
                    fin = fin.max(end);
                }
            }
        }
        fin
    }

    /// One request payload arriving from *outside* the cluster at `at`:
    /// it DMAs through the destination node's NIC-in and the destination
    /// GPU's ingress port, contending with whatever dispatch traffic is
    /// in flight. Returns the delivery completion time.
    pub fn ingest(&mut self, dst: GpuId, bytes: f64, at: f64) -> f64 {
        let legs = [
            (self.nic_in_link(self.node_of(dst)), bytes / self.inter_bw),
            (self.ingress_link(dst), bytes / self.inter_bw + self.inter_lat),
        ];
        self.commit_legs(&legs, bytes, at)
    }

    /// Record straggler-synchronization seconds charged by a collective
    /// wrapper (stalls happen on the compute side, not on a link).
    fn note_stall(&mut self, seconds: f64) {
        self.straggler_stall_s += seconds;
    }

    fn push_event(&mut self, time: f64, kind: EventKind, link: usize,
                  transfer: u64) {
        let ev = Event { time, seq: self.seq, kind, link: link as u32,
                         transfer };
        self.seq += 1;
        self.heap.push(Reverse(ev));
    }

    /// Drain and process every queued event with `time ≤ upto` in
    /// `(time, seq)` order: maintain per-link queue depths, sample the
    /// depth histogram at arrivals, and fold each event into the FNV-1a
    /// digest (and the retained log when enabled).
    pub fn advance(&mut self, upto: f64) {
        while let Some(&Reverse(ev)) = self.heap.peek() {
            if ev.time > upto {
                break;
            }
            self.heap.pop();
            self.process(ev);
        }
    }

    fn process(&mut self, ev: Event) {
        self.events_processed += 1;
        let l = ev.link as usize;
        match ev.kind {
            EventKind::Arrive => {
                // A depart at the same instant has a larger seq (pushed
                // after its own arrive), so depths never go negative.
                self.depth[l] += 1;
                let d = self.depth[l];
                self.depth_max = self.depth_max.max(d);
                self.depth_hist[d.min(DEPTH_BUCKETS)] += 1;
            }
            EventKind::Depart => {
                self.depth[l] -= 1;
            }
        }
        let kind_word = match ev.kind {
            EventKind::Arrive => 0u64,
            EventKind::Depart => 1u64,
        };
        self.fold(ev.time.to_bits());
        self.fold(ev.seq);
        self.fold(kind_word);
        self.fold(u64::from(ev.link));
        self.fold(ev.transfer);
        if let Some(log) = &mut self.log {
            log.push(EventRecord {
                time_bits: ev.time.to_bits(),
                seq: ev.seq,
                kind: ev.kind,
                link: ev.link,
                transfer: ev.transfer,
            });
        }
    }

    /// FNV-1a fold of one 64-bit word.
    fn fold(&mut self, x: u64) {
        self.digest = (self.digest ^ x).wrapping_mul(0x100_0000_01b3);
    }

    /// Retain processed events for inspection (determinism tests).
    pub fn enable_log(&mut self) {
        self.log = Some(Vec::new());
    }

    /// Events processed so far, when logging is enabled.
    pub fn log(&self) -> Option<&[EventRecord]> {
        self.log.as_deref()
    }

    /// FNV-1a digest over all *processed* events — drain first
    /// ([`NetworkSim::advance`] or [`NetworkSim::contention`]).
    pub fn digest(&self) -> u64 {
        self.digest
    }

    /// Bytes served by GPU `g`'s egress port.
    pub fn egress_bytes(&self, g: GpuId) -> f64 {
        self.stats[self.egress_link(g)].bytes
    }

    /// Bytes served by GPU `g`'s ingress port.
    pub fn ingress_bytes(&self, g: GpuId) -> f64 {
        self.stats[self.ingress_link(g)].bytes
    }

    /// Bytes served by node `node`'s NIC-out.
    pub fn nic_out_bytes(&self, node: usize) -> f64 {
        self.stats[self.nic_out_link(node)].bytes
    }

    /// Bytes served by node `node`'s NIC-in.
    pub fn nic_in_bytes(&self, node: usize) -> f64 {
        self.stats[self.nic_in_link(node)].bytes
    }

    fn depth_percentile(&self, q: f64) -> f64 {
        let total: u64 = self.depth_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (depth, &c) in self.depth_hist.iter().enumerate() {
            acc += c;
            if acc >= target {
                return depth as f64;
            }
        }
        DEPTH_BUCKETS as f64
    }

    /// Drain all remaining events and summarize contention over the
    /// whole replay (first submit → last departure).
    pub fn contention(&mut self) -> ContentionReport {
        self.advance(f64::INFINITY);
        let horizon = if self.next_transfer == 0 {
            0.0
        } else {
            (self.makespan - self.t0).max(0.0)
        };
        let per_link: Vec<f64> = self
            .stats
            .iter()
            .map(|s| if horizon > 0.0 { s.busy_s / horizon } else { 0.0 })
            .collect();
        let max_utilization =
            per_link.iter().cloned().fold(0.0, f64::max);
        ContentionReport {
            per_link_utilization: per_link,
            max_utilization,
            queue_depth_p50: self.depth_percentile(0.50),
            queue_depth_p95: self.depth_percentile(0.95),
            queue_depth_p99: self.depth_percentile(0.99),
            queue_depth_max: self.depth_max,
            queued_wait_s: self.stats.iter().map(|s| s.wait_s).sum(),
            straggler_stall_s: self.straggler_stall_s,
            transfers: self.next_transfer,
            events: self.events_processed,
            event_digest: self.digest,
        }
    }
}

// --- DES collective wrappers ------------------------------------------------
//
// Same structure, same report fields, and — critically — the same Rng
// draw order as the analytic models, so the two backends see identical
// jitter streams and differ only by queueing (zero when uncontended).

/// DES flat All-to-All submitted at virtual time `at`.
pub fn flat_all_to_all(net: &mut NetworkSim, m: &TrafficMatrix,
                       topo: &Topology, at: f64, rng: &mut Rng)
                       -> CommReport {
    let start = at + topo.launch_overhead;
    let t = net.replay_stage(m, start) - start;
    let strag = model::straggler_max(rng, topo.num_gpus(), topo.jitter);
    let sync = t * (strag - 1.0);
    net.note_stall(sync);
    CommReport {
        time: topo.launch_overhead + t + sync,
        cross_bytes: m.cross_node_bytes(topo),
        intra_bytes: m.intra_node_bytes(topo),
        launches: 1,
        stage_times: vec![t],
        sync_time: sync,
    }
}

/// DES staged hierarchical A2A submitted at virtual time `at`.
///
/// Rail groups are timed in isolation via [`NetworkSim::probe_stage`]
/// (independent progress), while the full cross matrix is what actually
/// occupies the network — the committed replay *is* the analytic NIC
/// floor, now queue-accurate under contention.
pub fn staged_hierarchical(net: &mut NetworkSim, ts: &TwoStageTraffic,
                           topo: &Topology, at: f64, rng: &mut Rng)
                           -> CommReport {
    let rails = topo.gpus_per_node;
    let s1 = at + topo.launch_overhead * rails as f64;
    let mut rail_times = Vec::with_capacity(rails);
    for r in 0..rails {
        let sub = model::filter_matrix(&ts.cross, |s, d| {
            s % topo.gpus_per_node == r && d % topo.gpus_per_node == r
        });
        let t = net.probe_stage(&sub, s1) - s1;
        let strag = model::straggler_max(rng, topo.nodes, topo.jitter);
        rail_times.push(t * strag);
    }
    let t_max = rail_times.iter().cloned().fold(0.0, f64::max);
    let t_min = rail_times.iter().cloned().fold(f64::INFINITY, f64::min);
    let stall = if t_max > 0.0 {
        model::DECOUPLE_KAPPA * (t_max - t_min.min(t_max))
    } else {
        0.0
    };
    let t_full = net.replay_stage(&ts.cross, s1) - s1;
    let t1 = t_max.max(t_full) + stall;

    let launches = rails + topo.nodes;
    let s2 = at + topo.launch_overhead * launches as f64 + t1;
    let t2 = net.replay_stage(&ts.intra, s2) - s2;
    let strag2 = model::straggler_max(rng, topo.gpus_per_node, topo.jitter);
    let sync2 = t2 * (strag2 - 1.0);
    net.note_stall(stall + sync2);
    CommReport {
        time: topo.launch_overhead * launches as f64 + t1 + t2 + sync2,
        cross_bytes: ts.cross.cross_node_bytes(topo),
        intra_bytes: ts.intra.intra_node_bytes(topo)
            + ts.cross.intra_node_bytes(topo),
        launches,
        stage_times: vec![t1, t2 + sync2],
        sync_time: stall + sync2,
    }
}

/// DES hierarchical sparse communication submitted at virtual time `at`.
pub fn hsc(net: &mut NetworkSim, ts: &TwoStageTraffic, topo: &Topology,
           overlap_budget: f64, at: f64, rng: &mut Rng) -> CommReport {
    let padded = model::pad_matrix(&ts.cross, model::HSC_PAD_QUANTUM);
    let s1 = at + topo.launch_overhead;
    let t1_raw = net.replay_stage(&padded, s1) - s1;
    let strag = model::straggler_max(rng, topo.num_gpus(), topo.jitter);
    let sync1 = t1_raw * (strag - 1.0);
    let t1 = (t1_raw + sync1).max(overlap_budget);

    let s2 = s1 + t1 + topo.launch_overhead;
    let t2 = net.replay_stage(&ts.intra, s2) - s2;
    net.note_stall(sync1);
    CommReport {
        time: topo.launch_overhead * 2.0 + t1 + t2,
        cross_bytes: padded.cross_node_bytes(topo),
        intra_bytes: ts.intra.intra_node_bytes(topo)
            + ts.cross.intra_node_bytes(topo),
        launches: 2,
        stage_times: vec![t1, t2],
        sync_time: sync1,
    }
}

// --- backend seam -----------------------------------------------------------

/// Which communication backend prices a run's A2A rounds.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CommBackendKind {
    /// Closed-form α–β models ([`crate::comm::model`]) — contention-blind,
    /// bit-identical to the pre-seam engine.
    #[default]
    Analytic,
    /// Discrete-event replay through the contended network.
    Des,
}

impl CommBackendKind {
    /// Parse a `--comm` CLI value.
    pub fn from_name(name: &str) -> Option<CommBackendKind> {
        match name {
            "analytic" => Some(CommBackendKind::Analytic),
            "des" => Some(CommBackendKind::Des),
            _ => None,
        }
    }

    /// CLI name of the backend.
    pub fn name(self) -> &'static str {
        match self {
            CommBackendKind::Analytic => "analytic",
            CommBackendKind::Des => "des",
        }
    }
}

enum Inner {
    Analytic,
    Des { net: NetworkSim, cursor: f64 },
}

/// The seam between the engines and the two communication backends.
///
/// `Analytic` delegates to [`crate::comm::model`] verbatim. `Des` replays
/// rounds on a persistent [`NetworkSim`]: [`CommBackend::round`] submits
/// at the internal cursor (back-to-back rounds — the serialized-engine
/// case, uncontended by construction), [`CommBackend::round_at`] at an
/// explicit virtual time (the fleet driver's clock, where ingest DMA and
/// dispatch rounds genuinely overlap).
pub struct CommBackend {
    inner: Inner,
}

impl CommBackend {
    /// Build a backend of `kind` over `topo`'s network.
    pub fn new(kind: CommBackendKind, topo: &Topology) -> CommBackend {
        let inner = match kind {
            CommBackendKind::Analytic => Inner::Analytic,
            CommBackendKind::Des => {
                Inner::Des { net: NetworkSim::new(topo), cursor: 0.0 }
            }
        };
        CommBackend { inner }
    }

    /// The backend's kind.
    pub fn kind(&self) -> CommBackendKind {
        match self.inner {
            Inner::Analytic => CommBackendKind::Analytic,
            Inner::Des { .. } => CommBackendKind::Des,
        }
    }

    /// Current virtual-time cursor (0 for the analytic backend).
    pub fn cursor(&self) -> f64 {
        match &self.inner {
            Inner::Analytic => 0.0,
            Inner::Des { cursor, .. } => *cursor,
        }
    }

    /// The underlying network, for DES backends (log control, byte
    /// conservation accessors).
    pub fn net_mut(&mut self) -> Option<&mut NetworkSim> {
        match &mut self.inner {
            Inner::Analytic => None,
            Inner::Des { net, .. } => Some(net),
        }
    }

    /// One A2A round under `comm`, consuming the routed batch's
    /// [`DispatchPlan`], submitted at the internal cursor (which then
    /// advances past the round).
    pub fn round(&mut self, comm: CommModel, dedup_flat: bool,
                 topo: &Topology, plan: &DispatchPlan, overlap: f64,
                 rng: &mut Rng) -> CommReport {
        let at = self.cursor();
        self.round_at(comm, dedup_flat, topo, plan, overlap, at, rng)
    }

    /// One A2A round submitted at explicit virtual time `at`; the cursor
    /// advances to at least `at + time`.
    #[allow(clippy::too_many_arguments)]
    pub fn round_at(&mut self, comm: CommModel, dedup_flat: bool,
                    topo: &Topology, plan: &DispatchPlan, overlap: f64,
                    at: f64, rng: &mut Rng) -> CommReport {
        match &mut self.inner {
            Inner::Analytic => match comm {
                CommModel::Flat => {
                    let m = if dedup_flat {
                        traffic::per_gpu_dedup_plan(plan)
                    } else {
                        traffic::per_copy_plan(plan)
                    };
                    model::flat_all_to_all(&m, topo, rng)
                }
                CommModel::StagedHierarchical => {
                    let ts = traffic::two_stage_plan(plan, topo);
                    model::staged_hierarchical(&ts, topo, rng)
                }
                CommModel::Hsc => {
                    let ts = traffic::two_stage_plan(plan, topo);
                    model::hsc(&ts, topo, overlap, rng)
                }
            },
            Inner::Des { net, cursor } => {
                let rep = match comm {
                    CommModel::Flat => {
                        let m = if dedup_flat {
                            traffic::per_gpu_dedup_plan(plan)
                        } else {
                            traffic::per_copy_plan(plan)
                        };
                        flat_all_to_all(net, &m, topo, at, rng)
                    }
                    CommModel::StagedHierarchical => {
                        let ts = traffic::two_stage_plan(plan, topo);
                        staged_hierarchical(net, &ts, topo, at, rng)
                    }
                    CommModel::Hsc => {
                        let ts = traffic::two_stage_plan(plan, topo);
                        hsc(net, &ts, topo, overlap, at, rng)
                    }
                };
                *cursor = cursor.max(at + rep.time);
                rep
            }
        }
    }

    /// Price a raw traffic matrix through the flat collective at `at`
    /// (expert-weight migration transfers).
    pub fn flat_round_at(&mut self, m: &TrafficMatrix, topo: &Topology,
                         at: f64, rng: &mut Rng) -> CommReport {
        match &mut self.inner {
            Inner::Analytic => model::flat_all_to_all(m, topo, rng),
            Inner::Des { net, cursor } => {
                let rep = flat_all_to_all(net, m, topo, at, rng);
                *cursor = cursor.max(at + rep.time);
                rep
            }
        }
    }

    /// Submit one external request payload arriving at `at` (DES: DMA
    /// through NIC-in + ingress port; analytic: free). Returns delivery
    /// completion.
    pub fn ingest(&mut self, dst: GpuId, bytes: f64, at: f64) -> f64 {
        match &mut self.inner {
            Inner::Analytic => at,
            Inner::Des { net, .. } => net.ingest(dst, bytes, at),
        }
    }

    /// Drain the event queue and summarize contention (`None` for the
    /// analytic backend, which has nothing to contend).
    pub fn contention(&mut self) -> Option<ContentionReport> {
        match &mut self.inner {
            Inner::Analytic => None,
            Inner::Des { net, .. } => Some(net.contention()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::traffic::{per_copy, two_stage, Dispatch};

    fn topo() -> Topology {
        Topology::two_by_two()
    }

    fn no_jitter(mut t: Topology) -> Topology {
        t.jitter = 0.0;
        t
    }

    fn cross_heavy(n_tokens: usize) -> Vec<Dispatch> {
        (0..n_tokens)
            .map(|i| Dispatch { src: i % 2, dsts: vec![2, 3] })
            .collect()
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() <= 1e-9 * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn idle_stage_replay_matches_analytic_stage_time() {
        let t = topo();
        let m = per_copy(&cross_heavy(200), 4, 1024.0);
        let mut net = NetworkSim::new(&t);
        let fin = net.replay_stage(&m, 0.0);
        let want = model::stage_time(&m, &t);
        assert!(close(fin, want), "des {fin} vs analytic {want}");
    }

    #[test]
    fn second_round_queues_behind_first() {
        let t = topo();
        let m = per_copy(&cross_heavy(200), 4, 1024.0);
        let mut net = NetworkSim::new(&t);
        let fin1 = net.replay_stage(&m, 0.0);
        // Same traffic submitted again at time 0: it must wait for the
        // first round's queues to drain.
        let fin2 = net.replay_stage(&m, 0.0);
        assert!(close(fin2, 2.0 * fin1), "fin2 {fin2} vs 2×{fin1}");
        // A third probe sees the same occupancy without committing.
        let probe = net.probe_stage(&m, 0.0);
        assert!(close(probe, 3.0 * fin1));
        let probe_again = net.probe_stage(&m, 0.0);
        assert!(close(probe_again, probe), "probe must not commit");
    }

    #[test]
    fn ingest_contends_with_dispatch_on_nic_in() {
        let t = topo();
        let mut net = NetworkSim::new(&t);
        // Saturate node 1's NIC-in with dispatch traffic…
        let m = per_copy(&cross_heavy(500), 4, 1024.0);
        net.replay_stage(&m, 0.0);
        // …then an external arrival at t=0 must queue behind it.
        let idle_delivery = {
            let mut fresh = NetworkSim::new(&t);
            fresh.ingest(2, 4096.0, 0.0)
        };
        let contended = net.ingest(2, 4096.0, 0.0);
        assert!(contended > idle_delivery * 2.0,
                "contended {contended} vs idle {idle_delivery}");
    }

    #[test]
    fn event_queue_orders_by_time_then_seq_and_depth_stays_sane() {
        let t = topo();
        let m = per_copy(&cross_heavy(50), 4, 1024.0);
        let mut net = NetworkSim::new(&t);
        net.enable_log();
        net.replay_stage(&m, 0.0);
        let rep = net.contention();
        let log = net.log().unwrap();
        assert_eq!(rep.events as usize, log.len());
        // Processed order is non-decreasing in (time, seq).
        for w in log.windows(2) {
            let (a, b) = (w[0], w[1]);
            let ta = f64::from_bits(a.time_bits);
            let tb = f64::from_bits(b.time_bits);
            assert!(ta < tb || (ta == tb && a.seq < b.seq));
        }
        assert!(rep.queue_depth_max >= 1);
        assert!(rep.queue_depth_p99 >= rep.queue_depth_p50);
    }

    #[test]
    fn bytes_are_conserved_per_link() {
        let t = topo();
        let disp = cross_heavy(300);
        let m = per_copy(&disp, 4, 1024.0);
        let mut net = NetworkSim::new(&t);
        net.replay_stage(&m, 0.0);
        for g in 0..4 {
            assert_eq!(net.egress_bytes(g), m.egress(g));
            assert_eq!(net.ingress_bytes(g), m.ingress(g));
        }
        // NIC totals: everything entering a node's NIC leaves it on the
        // GPUs' ingress side of that node, and vice versa.
        let out: f64 = (0..2).map(|n| net.nic_out_bytes(n)).sum();
        let inn: f64 = (0..2).map(|n| net.nic_in_bytes(n)).sum();
        assert_eq!(out, inn);
        assert_eq!(out, m.cross_node_bytes(&t));
    }

    #[test]
    fn uncontended_wrappers_match_analytic_reports() {
        let t = topo();
        let disp = cross_heavy(400);
        let flat_m = per_copy(&disp, 4, 1024.0);
        let ts = two_stage(&disp, &t, 1024.0);
        for seed in 0..5 {
            let a = model::flat_all_to_all(&flat_m, &t,
                                           &mut Rng::new(seed));
            let mut net = NetworkSim::new(&t);
            let d = flat_all_to_all(&mut net, &flat_m, &t, 0.0,
                                    &mut Rng::new(seed));
            assert!(close(a.time, d.time), "flat {} vs {}", a.time, d.time);
            assert_eq!(a.cross_bytes, d.cross_bytes);

            let a = model::staged_hierarchical(&ts, &t, &mut Rng::new(seed));
            let mut net = NetworkSim::new(&t);
            let d = staged_hierarchical(&mut net, &ts, &t, 0.0,
                                        &mut Rng::new(seed));
            assert!(close(a.time, d.time),
                    "staged {} vs {}", a.time, d.time);

            let a = model::hsc(&ts, &t, 1e-5, &mut Rng::new(seed));
            let mut net = NetworkSim::new(&t);
            let d = hsc(&mut net, &ts, &t, 1e-5, 0.0, &mut Rng::new(seed));
            assert!(close(a.time, d.time), "hsc {} vs {}", a.time, d.time);
            assert_eq!(a.launches, d.launches);
        }
    }

    #[test]
    fn empty_traffic_costs_only_launch() {
        let t = no_jitter(topo());
        let m = TrafficMatrix::zeros(4);
        let mut net = NetworkSim::new(&t);
        let r = flat_all_to_all(&mut net, &m, &t, 0.0, &mut Rng::new(4));
        assert!((r.time - t.launch_overhead).abs() < 1e-12);
        assert_eq!(net.contention().transfers, 0);
    }

    #[test]
    fn backend_kind_round_trips_names() {
        for kind in [CommBackendKind::Analytic, CommBackendKind::Des] {
            assert_eq!(CommBackendKind::from_name(kind.name()), Some(kind));
        }
        assert_eq!(CommBackendKind::from_name("magic"), None);
        assert_eq!(CommBackendKind::default(), CommBackendKind::Analytic);
    }

    #[test]
    fn backend_cursor_advances_past_each_round() {
        let t = topo();
        let mut b = CommBackend::new(CommBackendKind::Des, &t);
        assert_eq!(b.cursor(), 0.0);
        let m = per_copy(&cross_heavy(100), 4, 1024.0);
        let rep = b.flat_round_at(&m, &t, 1.0, &mut Rng::new(7));
        assert!(close(b.cursor(), 1.0 + rep.time));
        assert!(b.contention().is_some());
        let mut a = CommBackend::new(CommBackendKind::Analytic, &t);
        assert!(a.contention().is_none());
        assert_eq!(a.ingest(0, 4096.0, 2.0), 2.0);
    }
}
