//! Communication substrate: All-to-All models for multi-node MoE dispatch.
//!
//! Three implementations of the MoE dispatch/combine collective, matching
//! the paper's §3/§5 taxonomy:
//!
//! * [`model::flat_all_to_all`] — the baseline **flat global All-to-All**:
//!   one synchronous collective over all ranks; global synchronization is
//!   limited by the slowest link and pays the straggler maximum.
//! * [`model::staged_hierarchical`] — **conventional hierarchical A2A**:
//!   cross-node rail groups then intra-node redistribution. Fewer
//!   cross-node bytes (node-level dedup) but extra kernel launches and
//!   *progress decoupling*: independently-progressing groups contend for
//!   the shared NIC and force spin-waiting, amplifying tail latency.
//! * [`model::hsc`] — the paper's **hierarchical sparse communication**:
//!   physically global but logically sparse. Stage 1 is a single global
//!   zero-padded collective (one launch, an *implicit barrier* that softly
//!   aligns nodes — jitter is paid once, without decoupling), stage 2 is
//!   isolated intra-node redistribution, and stage 1 is overlapped with
//!   intra-node routing-decision compute via fine-grained pipelining.
//!
//! [`traffic`] builds the byte matrices these models consume from
//! per-token dispatch decisions, including the node-level deduplication
//! ("tokens routed to multiple experts on the same destination are
//! transmitted only once").

//! [`sim`] is the contention-aware counterpart: the same traffic replayed
//! through a discrete-event simulation of the cluster network (per-link
//! FIFO queues, shared NICs, typed events on a binary-heap queue), with a
//! [`CommBackend`] seam letting the engines pick either backend per run.

pub mod model;
pub mod sim;
pub mod traffic;

pub use model::{CommModel, CommReport};
pub use sim::{CommBackend, CommBackendKind, NetworkSim};
pub use traffic::{Dispatch, TrafficMatrix, TwoStageTraffic};
