//! Traffic-matrix construction from per-token dispatch decisions.
//!
//! The engine resolves routing into one [`Dispatch`] per token (source GPU
//! plus the destination GPU of each of its top-k expert assignments);
//! this module aggregates those into byte matrices under the different
//! transfer-granularity semantics of each collective:
//!
//! * per-copy: one transfer per expert assignment (flat A2A baseline),
//! * per-GPU dedup: one transfer per distinct destination GPU,
//! * two-stage: node-level dedup for the cross-node stage, GPU-level dedup
//!   for the intra-node stage (hierarchical A2A and HSC).

use crate::cluster::{GpuId, Topology};
use crate::routing::DispatchPlan;

/// Routing outcome for one token at one MoE layer: where it lives and the
/// GPU hosting each of its selected expert instances.
#[derive(Clone, Debug)]
pub struct Dispatch {
    /// GPU the token resides on.
    pub src: GpuId,
    /// Destination GPU of each of the token's expert assignments.
    pub dsts: Vec<GpuId>,
}

/// Dense per-(src,dst) byte counts. The diagonal (same-GPU "transfers") is
/// tracked but free for timing; tier classification splits the rest into
/// intra-node and cross-node bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficMatrix {
    n: usize,
    bytes: Vec<f64>,
    msgs: Vec<u64>,
}

impl TrafficMatrix {
    /// Empty matrix over `num_gpus` GPUs.
    pub fn zeros(num_gpus: usize) -> Self {
        TrafficMatrix {
            n: num_gpus,
            bytes: vec![0.0; num_gpus * num_gpus],
            msgs: vec![0; num_gpus * num_gpus],
        }
    }

    /// GPUs the matrix spans.
    pub fn num_gpus(&self) -> usize {
        self.n
    }

    /// Record one message of `bytes` from `src` to `dst`.
    #[inline]
    pub fn add(&mut self, src: GpuId, dst: GpuId, bytes: f64) {
        self.bytes[src * self.n + dst] += bytes;
        self.msgs[src * self.n + dst] += 1;
    }

    /// Accumulated bytes of the `(src, dst)` slot.
    #[inline]
    pub fn get(&self, src: GpuId, dst: GpuId) -> f64 {
        self.bytes[src * self.n + dst]
    }

    /// Messages recorded into the `(src, dst)` slot.
    #[inline]
    pub fn msg_count(&self, src: GpuId, dst: GpuId) -> u64 {
        self.msgs[src * self.n + dst]
    }

    /// Total bytes over all slots (diagonal included).
    pub fn total_bytes(&self) -> f64 {
        self.bytes.iter().sum()
    }

    /// Bytes crossing node boundaries.
    pub fn cross_node_bytes(&self, topo: &Topology) -> f64 {
        self.fold_tier(topo, 2)
    }

    /// Bytes moving between GPUs within a node (excludes same-GPU).
    pub fn intra_node_bytes(&self, topo: &Topology) -> f64 {
        self.fold_tier(topo, 1)
    }

    fn fold_tier(&self, topo: &Topology, tier: u8) -> f64 {
        let mut total = 0.0;
        for s in 0..self.n {
            for d in 0..self.n {
                if topo.tier(s, d) == tier {
                    total += self.get(s, d);
                }
            }
        }
        total
    }

    /// Egress bytes per GPU (excluding the free diagonal).
    pub fn egress(&self, gpu: GpuId) -> f64 {
        (0..self.n)
            .filter(|&d| d != gpu)
            .map(|d| self.get(gpu, d))
            .sum()
    }

    /// Ingress bytes per GPU (excluding the free diagonal).
    pub fn ingress(&self, gpu: GpuId) -> f64 {
        (0..self.n)
            .filter(|&s| s != gpu)
            .map(|s| self.get(s, gpu))
            .sum()
    }
}

/// The two-stage decomposition used by hierarchical A2A and HSC:
/// `cross` carries node-deduplicated cross-node transfers (landing on the
/// rail-aligned peer GPU), `intra` the per-node redistribution (one matrix
/// over the global GPU id space; entries are always intra-node).
#[derive(Clone, Debug)]
pub struct TwoStageTraffic {
    /// Stage-1 node-deduplicated cross-node transfers.
    pub cross: TrafficMatrix,
    /// Stage-2 per-node redistribution transfers.
    pub intra: TrafficMatrix,
}

/// Flat A2A: one transfer per expert assignment (no dedup) — what Tutel /
/// MegaBlocks / vanilla EP dispatch does.
pub fn per_copy(dispatches: &[Dispatch], num_gpus: usize,
                token_bytes: f64) -> TrafficMatrix {
    let mut m = TrafficMatrix::zeros(num_gpus);
    for d in dispatches {
        for &dst in &d.dsts {
            m.add(d.src, dst, token_bytes);
        }
    }
    m
}

/// GPU-level dedup: one transfer per distinct destination GPU per token.
pub fn per_gpu_dedup(dispatches: &[Dispatch], num_gpus: usize,
                     token_bytes: f64) -> TrafficMatrix {
    let mut m = TrafficMatrix::zeros(num_gpus);
    let mut seen = vec![false; num_gpus];
    for d in dispatches {
        for &dst in &d.dsts {
            if !seen[dst] {
                seen[dst] = true;
                m.add(d.src, dst, token_bytes);
            }
        }
        for &dst in &d.dsts {
            seen[dst] = false;
        }
    }
    m
}

/// Rail-aligned landing GPU: cross-node transfers land on the GPU of the
/// destination node with the same local index as the source GPU (so every
/// NIC flow has a fixed peer — the "physically global" group of §5).
pub fn landing_gpu(topo: &Topology, src: GpuId, dst_node: usize) -> GpuId {
    dst_node * topo.gpus_per_node + (src % topo.gpus_per_node)
}

/// Two-stage traffic with node-level dedup (§5): each token is sent to
/// each remote destination *node* at most once (stage 1, landing on the
/// rail-aligned peer), then redistributed to the destination GPUs within
/// each node (stage 2, GPU-level dedup).
pub fn two_stage(dispatches: &[Dispatch], topo: &Topology,
                 token_bytes: f64) -> TwoStageTraffic {
    let n = topo.num_gpus();
    let mut cross = TrafficMatrix::zeros(n);
    let mut intra = TrafficMatrix::zeros(n);
    let mut node_seen = vec![false; topo.nodes];
    let mut gpu_seen = vec![false; n];
    for d in dispatches {
        let src_node = topo.node_of(d.src);
        // Stage 1: one copy per distinct remote destination node.
        for &dst in &d.dsts {
            let dn = topo.node_of(dst);
            if dn != src_node && !node_seen[dn] {
                node_seen[dn] = true;
                cross.add(d.src, landing_gpu(topo, d.src, dn), token_bytes);
            }
        }
        // Stage 2: within each destination node, move the (single) landed
        // copy to each distinct destination GPU.
        for &dst in &d.dsts {
            if gpu_seen[dst] {
                continue;
            }
            gpu_seen[dst] = true;
            let dn = topo.node_of(dst);
            let local_src = if dn == src_node {
                d.src
            } else {
                landing_gpu(topo, d.src, dn)
            };
            if local_src != dst {
                intra.add(local_src, dst, token_bytes);
            } else {
                // Same-GPU landing: record a free diagonal move so token
                // conservation checks still see the copy.
                intra.add(local_src, dst, 0.0);
            }
        }
        for &dst in &d.dsts {
            gpu_seen[dst] = false;
            node_seen[topo.node_of(dst)] = false;
        }
    }
    TwoStageTraffic { cross, intra }
}

// --- batched-plan entry points ---------------------------------------------
//
// The engines route whole batches through `routing::Dispatcher` and hand
// the resulting `DispatchPlan` to the collectives; these constructors
// consume the plan's token-major view directly (the dedup semantics above
// are per token), with the payload size taken from the plan's own byte
// accounting.

/// [`per_copy`] over a routed batch.
pub fn per_copy_plan(plan: &DispatchPlan) -> TrafficMatrix {
    per_copy(plan.per_token(), plan.num_gpus(), plan.token_bytes())
}

/// [`per_gpu_dedup`] over a routed batch.
pub fn per_gpu_dedup_plan(plan: &DispatchPlan) -> TrafficMatrix {
    per_gpu_dedup(plan.per_token(), plan.num_gpus(), plan.token_bytes())
}

/// [`two_stage`] over a routed batch.
pub fn two_stage_plan(plan: &DispatchPlan, topo: &Topology)
                      -> TwoStageTraffic {
    two_stage(plan.per_token(), topo, plan.token_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topo() -> Topology {
        Topology::two_by_two() // gpus 0,1 on node 0; 2,3 on node 1
    }

    #[test]
    fn per_copy_counts_every_assignment() {
        let d = vec![Dispatch { src: 0, dsts: vec![1, 1, 2] }];
        let m = per_copy(&d, 4, 10.0);
        assert_eq!(m.get(0, 1), 20.0);
        assert_eq!(m.get(0, 2), 10.0);
        assert_eq!(m.total_bytes(), 30.0);
        assert_eq!(m.msg_count(0, 1), 2);
    }

    #[test]
    fn per_gpu_dedup_collapses_same_gpu() {
        let d = vec![Dispatch { src: 0, dsts: vec![1, 1, 2, 2, 2] }];
        let m = per_gpu_dedup(&d, 4, 10.0);
        assert_eq!(m.get(0, 1), 10.0);
        assert_eq!(m.get(0, 2), 10.0);
        assert_eq!(m.total_bytes(), 20.0);
    }

    #[test]
    fn dedup_state_resets_between_tokens() {
        let d = vec![
            Dispatch { src: 0, dsts: vec![1] },
            Dispatch { src: 0, dsts: vec![1] },
        ];
        let m = per_gpu_dedup(&d, 4, 10.0);
        assert_eq!(m.get(0, 1), 20.0, "two tokens = two transfers");
    }

    #[test]
    fn two_stage_dedups_at_node_level() {
        let t = topo();
        // token on gpu 0 → experts on gpus 2 and 3 (both node 1)
        let d = vec![Dispatch { src: 0, dsts: vec![2, 3] }];
        let ts = two_stage(&d, &t, 10.0);
        // one cross-node copy, landing rail-aligned on gpu 2 (0 % 2 == 0)
        assert_eq!(ts.cross.get(0, 2), 10.0);
        assert_eq!(ts.cross.total_bytes(), 10.0);
        // redistribution 2→3 inside node 1, plus free diagonal 2→2
        assert_eq!(ts.intra.get(2, 3), 10.0);
        assert_eq!(ts.intra.get(2, 2), 0.0);
        assert_eq!(ts.intra.msg_count(2, 2), 1);
    }

    #[test]
    fn two_stage_local_tokens_skip_cross() {
        let t = topo();
        let d = vec![Dispatch { src: 1, dsts: vec![0, 1] }];
        let ts = two_stage(&d, &t, 8.0);
        assert_eq!(ts.cross.total_bytes(), 0.0);
        assert_eq!(ts.intra.get(1, 0), 8.0);
    }

    #[test]
    fn two_stage_landing_is_rail_aligned() {
        let t = Topology::two_by_four();
        // src gpu 5 (node 1, local idx 1) → expert on gpu 0 (node 0)
        let d = vec![Dispatch { src: 5, dsts: vec![0] }];
        let ts = two_stage(&d, &t, 4.0);
        assert_eq!(ts.cross.get(5, 1), 4.0, "lands on node0's local idx 1");
        assert_eq!(ts.intra.get(1, 0), 4.0);
    }

    #[test]
    fn tier_classification() {
        let t = topo();
        let mut m = TrafficMatrix::zeros(4);
        m.add(0, 1, 5.0); // intra node 0
        m.add(0, 2, 7.0); // cross
        m.add(3, 3, 9.0); // same gpu
        assert_eq!(m.intra_node_bytes(&t), 5.0);
        assert_eq!(m.cross_node_bytes(&t), 7.0);
        assert_eq!(m.egress(0), 12.0);
        assert_eq!(m.ingress(2), 7.0);
        assert_eq!(m.egress(3), 0.0, "diagonal excluded");
    }

    #[test]
    fn plan_constructors_match_per_token_scalar_walk() {
        use crate::linalg::Matrix;
        use crate::placement::{LayerPlacement, ReplicationMode};
        use crate::profile::LayerProfile;
        use crate::routing::{Assignment, Dispatcher, RoutingPolicy};
        use crate::stats::Rng;

        // 4 experts, one per GPU, primary routing: the plan's per-token
        // view is fully determined, so the plan-based matrices must equal
        // the ones built from a hand-rolled Vec<Dispatch>.
        let t = topo();
        let profile = LayerProfile {
            affinity: Matrix::zeros(4, 4),
            load: vec![4.0, 3.0, 2.0, 1.0],
            tokens: 10,
        };
        let lp = LayerPlacement::build(
            &profile,
            vec![vec![0], vec![1], vec![2], vec![3]],
            ReplicationMode::None,
        );
        let batch = vec![
            Assignment { token: 0, expert: 2, src: 0 },
            Assignment { token: 0, expert: 3, src: 0 },
            Assignment { token: 1, expert: 0, src: 1 },
            Assignment { token: 1, expert: 1, src: 1 },
        ];
        let mut d = Dispatcher::new(t.clone(),
                                    RoutingPolicy::Primary.build(), 10.0);
        let plan = d.dispatch(&lp, 0, &batch, &mut Rng::new(1));

        let hand = vec![
            Dispatch { src: 0, dsts: vec![2, 3] },
            Dispatch { src: 1, dsts: vec![0, 1] },
        ];
        assert_eq!(per_copy_plan(&plan), per_copy(&hand, 4, 10.0));
        assert_eq!(per_gpu_dedup_plan(&plan),
                   per_gpu_dedup(&hand, 4, 10.0));
        let a = two_stage_plan(&plan, &t);
        let b = two_stage(&hand, &t, 10.0);
        assert_eq!(a.cross, b.cross);
        assert_eq!(a.intra, b.intra);
    }

    #[test]
    fn node_dedup_saves_vs_gpu_dedup_exactly_when_multi_gpu_node() {
        let t = topo();
        let d = vec![Dispatch { src: 0, dsts: vec![2, 3] }];
        let flat = per_gpu_dedup(&d, 4, 10.0);
        let ts = two_stage(&d, &t, 10.0);
        assert_eq!(flat.cross_node_bytes(&t), 20.0);
        assert_eq!(ts.cross.cross_node_bytes(&t), 10.0);
    }
}
