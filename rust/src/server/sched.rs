//! Iteration-level scheduling — the continuous-batching core of the
//! serving front.
//!
//! The seed server was "continuous-batching lite": it drained a static
//! batch, ran every sequence's full forward one at a time, admitted
//! nothing mid-flight, and only retired requests at the drain barrier.
//! This module replaces that with a per-request state machine driven at
//! *iteration* (decode-step) granularity, the discipline of vLLM-style
//! serving systems:
//!
//! ```text
//!              offer/admit            first token           retire
//!   Queued ───────────────▶ Prefill ─────────────▶ Decode ────────▶ Done
//!   (admission buffer /      (admitted, producing   (generating)   (out of
//!    bounded queue)           its first token)                      the batch)
//! ```
//!
//! * **Admission** happens between steps, never mid-forward: the driver
//!   offers queued requests one at a time ([`Scheduler::offer`] →
//!   [`Scheduler::admit_pending`]) and the scheduler accepts them FIFO
//!   while the live batch stays under `max_batch` sequences and — in
//!   [`SchedMode::Continuous`] — under the `max_batch_tokens` step
//!   budget. With the KV cache on (`kv_cache`, the default), a step
//!   only computes each sequence's **uncached** tokens, so prefill
//!   costs the prompt length and every later step costs exactly one
//!   token per sequence; with it off, every step recomputes the whole
//!   prefix and a sequence costs its full current length.
//! * **Microbatching**: every step advances a token-budgeted FIFO prefix
//!   of the live batch ([`Scheduler::microbatch`]); sequences over
//!   budget wait a step instead of stalling the batch, and at least one
//!   sequence always runs so an oversized sequence cannot deadlock.
//! * **Retirement** is immediate: a sequence that reaches its token
//!   budget or the model context leaves the batch at the end of the
//!   step that finished it ([`Scheduler::complete_step`]); the freed
//!   budget admits new work at the very next step.
//! * **Replan safety**: the driver owns the step loop, so the epoch
//!   re-planner's `epoch_tick` runs *between* steps — after
//!   `complete_step`, before the next admission — and therefore never
//!   mid-dispatch-round (the invariant `docs/ARCHITECTURE.md` pins).
//!
//! [`SchedMode::StaticDrain`] reproduces the seed server's behaviour on
//! top of the same state machine (admission only into an empty batch, no
//! token budget) so the serving bench can compare the two disciplines on
//! identical workloads; greedy-decode outputs are token-for-token
//! identical across modes because per-token numerics are independent of
//! batch composition.
//!
//! [`simulate_serve`] is the virtual-clock driver used by tier-1 tests
//! and `benches/serving.rs`: same scheduler, same admission rules, with
//! the engine and the clock supplied as closures — so every scheduling
//! property is pinned without PJRT artifacts.

use super::{Request, Response};
use crate::metrics::{RequestTiming, ServeMetrics};

/// Request lifecycle within the serving core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting in the admission queue (or the scheduler's one-deep
    /// admission buffer).
    Queued,
    /// Admitted; its first token has not been produced yet.
    Prefill,
    /// Generating tokens.
    Decode,
    /// Finished; retired from the live batch.
    Done,
}

/// Batching discipline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Seed-server behaviour: admit only into an empty batch (up to
    /// `max_batch` requests), run the drain to completion, repeat. No
    /// token budget; kept as the baseline arm of `benches/serving.rs`.
    StaticDrain,
    /// Iteration-level continuous batching: admission between every
    /// step under the `max_batch_tokens` budget, immediate retirement.
    Continuous,
}

/// Scheduler tunables (the serving front copies these out of
/// [`super::ServerConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct SchedConfig {
    /// Batching discipline.
    pub mode: SchedMode,
    /// Maximum live sequences.
    pub max_batch: usize,
    /// Step token budget (continuous mode): the number of tokens a step
    /// may *compute*. Under KV-cached pricing that is each sequence's
    /// uncached suffix (prompt length at prefill, one token thereafter);
    /// under recompute pricing it is the full current length.
    pub max_batch_tokens: usize,
    /// Model context length (admission bound and finish condition).
    pub ctx: usize,
    /// Price steps for KV-cached decode (1 token per live sequence after
    /// prefill) instead of full-prefix recompute. Must match the engine
    /// path the driver runs, or the budget meters the wrong cost.
    pub kv_cache: bool,
}

/// One live (or finished) sequence and its timing record. Times are
/// driver-clock seconds: wall-clock in the real server, virtual seconds
/// under [`simulate_serve`].
#[derive(Clone, Debug)]
pub struct SeqState {
    /// The originating request.
    pub req: Request,
    /// Prompt plus generated tokens.
    pub ids: Vec<i32>,
    /// Lifecycle phase.
    pub phase: SeqPhase,
    /// When the request entered the admission queue.
    pub enqueue: f64,
    /// When it was admitted into the live batch.
    pub admit: f64,
    /// Step index at admission.
    pub admit_step: usize,
    /// `(time, step)` of the first generated token.
    pub first_token: Option<(f64, usize)>,
    /// Completion time of the most recent token.
    pub last_token: f64,
    /// Completion time of the whole request.
    pub finish: f64,
    /// Tokens of `ids` whose K/V rows the engine has cached (0 until the
    /// sequence's first step; stays 0 under recompute pricing). Mirrors
    /// the engine-side `KvCache::len` — the server debug-asserts the two
    /// agree every step.
    pub cached_len: usize,
}

impl SeqState {
    /// Tokens generated so far (prompt excluded).
    pub fn generated(&self) -> usize {
        self.ids.len() - self.req.prompt.len()
    }

    fn wants_tokens(&self, ctx: usize) -> bool {
        self.generated() < self.req.max_new_tokens && self.ids.len() < ctx
    }
}

/// The iteration-level scheduler: a FIFO live batch, a one-deep
/// admission buffer, and the retired set. Drivers loop over
/// offer/admit → [`Scheduler::microbatch`] → run the step →
/// [`Scheduler::complete_step`]; see the module docs for the protocol.
pub struct Scheduler {
    cfg: SchedConfig,
    /// Popped-but-unadmitted head of the queue (keeps FIFO order while
    /// letting admission inspect the prompt before committing budget).
    pending: Option<(Request, f64)>,
    live: Vec<SeqState>,
    done: Vec<SeqState>,
    steps: usize,
    dispatch_rounds: usize,
    /// Tokens actually computed across all steps (uncached suffixes
    /// under KV pricing; full prefixes under recompute).
    computed_tokens: usize,
    /// Prefix tokens served from the KV cache instead of recomputed
    /// (always 0 under recompute pricing).
    cached_tokens: usize,
    /// Static-drain admission window: open from the first admission
    /// into an empty batch until the next step executes.
    drain_open: bool,
}

impl Scheduler {
    /// Scheduler over validated tunables (zero `max_batch`,
    /// `max_batch_tokens`, or `ctx` would serve nothing — rejected
    /// loudly instead of silently dropping every request).
    pub fn new(cfg: SchedConfig) -> anyhow::Result<Scheduler> {
        anyhow::ensure!(cfg.max_batch > 0,
                        "scheduler: max_batch = 0 admits nothing");
        anyhow::ensure!(cfg.max_batch_tokens > 0,
                        "scheduler: max_batch_tokens = 0 steps nothing");
        anyhow::ensure!(cfg.ctx > 0, "scheduler: ctx = 0");
        Ok(Scheduler {
            cfg,
            pending: None,
            live: Vec::new(),
            done: Vec::new(),
            steps: 0,
            dispatch_rounds: 0,
            computed_tokens: 0,
            cached_tokens: 0,
            drain_open: false,
        })
    }

    /// The scheduler's configuration.
    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Steps executed so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Dispatch rounds recorded across all steps.
    pub fn dispatch_rounds(&self) -> usize {
        self.dispatch_rounds
    }

    /// The live batch, in admission (FIFO) order.
    pub fn live(&self) -> &[SeqState] {
        &self.live
    }

    /// Retired sequences, in retirement order.
    pub fn done(&self) -> &[SeqState] {
        &self.done
    }

    /// Whether a request sits in the admission buffer.
    pub fn has_pending(&self) -> bool {
        self.pending.is_some()
    }

    /// Nothing live and nothing buffered: the driver should block on
    /// the queue (or finish, if the queue is closed and drained).
    pub fn is_idle(&self) -> bool {
        self.live.is_empty() && self.pending.is_none()
    }

    /// What one step of `s` costs against the token budget: the uncached
    /// suffix under KV pricing, the full prefix under recompute.
    fn seq_cost(&self, s: &SeqState) -> usize {
        if self.cfg.kv_cache {
            s.ids.len() - s.cached_len
        } else {
            s.ids.len()
        }
    }

    /// Tokens the next full-batch step would compute (budget-priced per
    /// the `seq_cost` rule above).
    pub fn live_tokens(&self) -> usize {
        self.live.iter().map(|s| self.seq_cost(s)).sum()
    }

    /// Whether the driver should pull another request off the queue:
    /// the admission buffer is free and admission is currently open.
    pub fn wants_offer(&self) -> bool {
        self.pending.is_none() && self.admission_open()
    }

    fn admission_open(&self) -> bool {
        if self.live.len() >= self.cfg.max_batch {
            return false;
        }
        match self.cfg.mode {
            SchedMode::Continuous => true,
            SchedMode::StaticDrain => {
                self.live.is_empty() || self.drain_open
            }
        }
    }

    /// Buffer the next queued request for admission; `false` (refusing
    /// the offer) when the one-deep buffer is occupied.
    pub fn offer(&mut self, req: Request, enqueue: f64) -> bool {
        if self.pending.is_some() {
            return false;
        }
        self.pending = Some((req, enqueue));
        true
    }

    /// Try to admit the buffered request under the mode's rules.
    /// Returns whether a request left the buffer (admitted, or retired
    /// instantly when it wants zero tokens). Errors on malformed
    /// requests (empty prompt, prompt beyond the model context).
    pub fn admit_pending(&mut self, now: f64) -> anyhow::Result<bool> {
        let Some((req, _)) = self.pending.as_ref() else {
            return Ok(false);
        };
        if !self.admission_open() {
            return Ok(false);
        }
        let fits = match self.cfg.mode {
            SchedMode::StaticDrain => true,
            SchedMode::Continuous => {
                self.live.is_empty()
                    || self.live_tokens() + req.prompt.len()
                        <= self.cfg.max_batch_tokens
            }
        };
        if !fits {
            return Ok(false);
        }
        let (req, enqueue) = self.pending.take().unwrap();
        anyhow::ensure!(!req.prompt.is_empty(),
                        "request {}: empty prompt", req.id);
        anyhow::ensure!(req.prompt.len() <= self.cfg.ctx,
                        "request {}: prompt {} exceeds ctx {}",
                        req.id, req.prompt.len(), self.cfg.ctx);
        // A prompt that already fills the context has no room to append
        // even one generated token. Admitting it used to complete the
        // request silently with zero tokens — reject loudly instead so
        // callers learn their generation budget is unservable.
        anyhow::ensure!(
            req.max_new_tokens == 0 || req.prompt.len() < self.cfg.ctx,
            "request {}: prompt fills the whole context ({} == ctx), \
             leaving no room for any of the {} requested tokens — \
             shorten the prompt or raise ctx",
            req.id, req.prompt.len(), req.max_new_tokens
        );
        let ids = req.prompt.clone();
        let mut seq = SeqState {
            req,
            ids,
            phase: SeqPhase::Prefill,
            enqueue,
            admit: now,
            admit_step: self.steps,
            first_token: None,
            last_token: now,
            finish: now,
            cached_len: 0,
        };
        if !seq.wants_tokens(self.cfg.ctx) {
            // Zero-token request (max_new_tokens = 0): completes at
            // admission, generating nothing.
            seq.phase = SeqPhase::Done;
            seq.finish = now;
            self.done.push(seq);
            return Ok(true);
        }
        if self.live.is_empty() && self.cfg.mode == SchedMode::StaticDrain
        {
            self.drain_open = true;
        }
        self.live.push(seq);
        Ok(true)
    }

    /// The FIFO token-budgeted microbatch for this step: indices into
    /// [`Scheduler::live`]. Always non-empty when the batch is —
    /// an over-budget head sequence runs alone rather than stalling.
    pub fn microbatch(&self) -> Vec<usize> {
        let mut batch = Vec::with_capacity(self.live.len());
        let mut tokens = 0usize;
        for (i, s) in self.live.iter().enumerate() {
            let cost = self.seq_cost(s);
            if self.cfg.mode == SchedMode::Continuous
                && !batch.is_empty()
                && tokens + cost > self.cfg.max_batch_tokens
            {
                break;
            }
            batch.push(i);
            tokens += cost;
        }
        batch
    }

    /// Tokens the given microbatch computes (budget-priced per the
    /// `seq_cost` rule above).
    pub fn step_tokens(&self, batch: &[usize]) -> usize {
        batch.iter().map(|&i| self.seq_cost(&self.live[i])).sum()
    }

    /// Record one executed step: `next[j]` is the token generated for
    /// live sequence `batch[j]`. Finished sequences retire immediately
    /// (the remaining live batch keeps FIFO order); the retired request
    /// ids are returned so the driver can evict their KV caches.
    pub fn complete_step(&mut self, batch: &[usize], next: &[i32],
                         now: f64, dispatch_rounds: usize)
                         -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(batch.len() == next.len(),
                        "step produced {} tokens for {} sequences",
                        next.len(), batch.len());
        self.drain_open = false;
        self.steps += 1;
        self.dispatch_rounds += dispatch_rounds;
        for (&i, &tok) in batch.iter().zip(next) {
            let cost = self.seq_cost(&self.live[i]);
            let full = self.live[i].ids.len();
            self.computed_tokens += cost;
            self.cached_tokens += full - cost;
            let s = &mut self.live[i];
            if self.cfg.kv_cache {
                // The engine's cache now covers every token it was fed.
                s.cached_len = full;
            }
            s.ids.push(tok);
            if s.first_token.is_none() {
                s.first_token = Some((now, self.steps - 1));
                s.phase = SeqPhase::Decode;
            }
            s.last_token = now;
        }
        let ctx = self.cfg.ctx;
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].wants_tokens(ctx) {
                i += 1;
            } else {
                let mut s = self.live.remove(i);
                s.phase = SeqPhase::Done;
                s.finish = now;
                retired.push(s.req.id);
                self.done.push(s);
            }
        }
        Ok(retired)
    }

    /// Consume the scheduler into responses (sorted by request id) and
    /// serving metrics. `wall_time` is the driver clock at shutdown.
    pub fn into_results(self, wall_time: f64)
                        -> (Vec<Response>, ServeMetrics) {
        debug_assert!(self.live.is_empty() && self.pending.is_none(),
                      "into_results with work still in flight");
        let mut done = self.done;
        done.sort_by_key(|s| s.req.id);
        let mut responses = Vec::with_capacity(done.len());
        let mut metrics = ServeMetrics {
            wall_time,
            steps: self.steps,
            dispatch_rounds: self.dispatch_rounds,
            computed_tokens: self.computed_tokens,
            cached_tokens: self.cached_tokens,
            ..ServeMetrics::default()
        };
        for s in done {
            let generated = s.generated();
            let latency = s.finish - s.enqueue;
            let queue_wait = s.admit - s.enqueue;
            let mut timing = RequestTiming {
                id: s.req.id,
                queue_wait,
                ttft: latency,
                latency,
                tpot: 0.0,
                admit_step: s.admit_step,
                first_token_step: s.admit_step,
            };
            if let Some((t, step)) = s.first_token {
                timing.ttft = t - s.enqueue;
                timing.first_token_step = step;
                metrics.ttft.push(timing.ttft);
                if generated >= 2 {
                    timing.tpot =
                        (s.last_token - t) / (generated - 1) as f64;
                    metrics.tpot.push(timing.tpot);
                }
            }
            metrics.latencies.push(latency);
            metrics.queue_wait.push(queue_wait);
            metrics.generated_tokens += generated;
            metrics.per_request.push(timing);
            responses.push(Response {
                id: s.req.id,
                tokens: s.ids[s.req.prompt.len()..].to_vec(),
                latency,
            });
        }
        (responses, metrics)
    }
}

/// Virtual-clock serving driver for tests and benches: replays a
/// (time-sorted) arrival schedule through the scheduler with the engine
/// and the clock supplied by the caller. `step_fn` receives the
/// microbatch as `(request id, token prefix, cached prefix length)`
/// triples — the cached length is 0 under recompute pricing, and tells
/// a KV-aware fake engine how many leading tokens it may serve from its
/// cache — and returns the next token per sequence plus the dispatch
/// rounds the step issued; `step_cost` maps `(step tokens, dispatch
/// rounds)` to virtual seconds. The real server
/// ([`super::MoEServer::serve`]) is the same loop on the wall clock and
/// the PJRT engine.
pub fn simulate_serve<F, C>(cfg: SchedConfig,
                            arrivals: Vec<(Request, f64)>,
                            step_fn: F, step_cost: C)
                            -> anyhow::Result<(Vec<Response>, ServeMetrics)>
where
    F: FnMut(&[(u64, &[i32], usize)]) -> anyhow::Result<(Vec<i32>, usize)>,
    C: FnMut(usize, usize) -> f64,
{
    simulate_serve_with(cfg, arrivals, step_fn, step_cost, |_| {})
}

/// [`simulate_serve`] plus a retirement hook: `retire_fn` is called with
/// each request id the moment its sequence leaves the live batch —
/// exactly when the real server drops the sequence's KV cache, so
/// cache-eviction tests can mirror the lifecycle without PJRT.
pub fn simulate_serve_with<F, C, R>(cfg: SchedConfig,
                                    mut arrivals: Vec<(Request, f64)>,
                                    mut step_fn: F, mut step_cost: C,
                                    mut retire_fn: R)
                                    -> anyhow::Result<(Vec<Response>,
                                                       ServeMetrics)>
where
    F: FnMut(&[(u64, &[i32], usize)]) -> anyhow::Result<(Vec<i32>, usize)>,
    C: FnMut(usize, usize) -> f64,
    R: FnMut(u64),
{
    arrivals.sort_by(|a, b| {
        a.1.partial_cmp(&b.1).expect("NaN arrival time")
    });
    let mut sched = Scheduler::new(cfg)?;
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;
    loop {
        // Admission: pull every arrived request the scheduler will take.
        loop {
            if sched.wants_offer()
                && next_arrival < arrivals.len()
                && arrivals[next_arrival].1 <= now
            {
                let (req, t) = arrivals[next_arrival].clone();
                next_arrival += 1;
                sched.offer(req, t);
                continue;
            }
            if !sched.admit_pending(now)? {
                break;
            }
        }
        if sched.is_idle() {
            if next_arrival >= arrivals.len() {
                break;
            }
            // Open-loop idle gap: jump the clock to the next arrival.
            now = now.max(arrivals[next_arrival].1);
            continue;
        }
        if sched.live().is_empty() {
            anyhow::bail!("scheduler stalled with a pending request");
        }
        let batch = sched.microbatch();
        let tokens = sched.step_tokens(&batch);
        let (next, rounds) = {
            let seqs: Vec<(u64, &[i32], usize)> = batch
                .iter()
                .map(|&i| {
                    let s = &sched.live()[i];
                    (s.req.id, s.ids.as_slice(), s.cached_len)
                })
                .collect();
            step_fn(&seqs)?
        };
        now += step_cost(tokens, rounds);
        for id in sched.complete_step(&batch, &next, now, rounds)? {
            retire_fn(id);
        }
    }
    Ok(sched.into_results(now))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, new_tokens: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt).map(|i| (id as i32) * 100 + i as i32)
                .collect(),
            max_new_tokens: new_tokens,
        }
    }

    fn cfg(mode: SchedMode, max_batch: usize, budget: usize)
           -> SchedConfig {
        SchedConfig {
            mode,
            max_batch,
            max_batch_tokens: budget,
            ctx: 64,
            kv_cache: false,
        }
    }

    use crate::testutil::fake_decode_token as fake_next;

    fn fake_step(seqs: &[(u64, &[i32], usize)])
                 -> anyhow::Result<(Vec<i32>, usize)> {
        let tokens: usize = seqs.iter().map(|(_, ids, _)| ids.len()).sum();
        let rounds = 2 * tokens.div_ceil(16); // 2 layers, tile 16
        Ok((seqs.iter().map(|(_, ids, _)| fake_next(ids)).collect(),
            rounds))
    }

    #[test]
    fn config_is_validated() {
        assert!(Scheduler::new(cfg(SchedMode::Continuous, 0, 8)).is_err());
        assert!(Scheduler::new(cfg(SchedMode::Continuous, 8, 0)).is_err());
        let bad = SchedConfig { ctx: 0, ..cfg(SchedMode::Continuous, 8, 8) };
        assert!(Scheduler::new(bad).is_err());
    }

    #[test]
    fn state_machine_walks_queued_prefill_decode_done() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        assert!(s.offer(req(0, 4, 2), 0.0));
        assert!(!s.offer(req(1, 4, 2), 0.0), "buffer is one deep");
        assert!(s.admit_pending(0.5).unwrap());
        assert_eq!(s.live()[0].phase, SeqPhase::Prefill);
        assert_eq!(s.live()[0].admit, 0.5);

        let batch = s.microbatch();
        assert_eq!(batch, vec![0]);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 1.0, 2).unwrap();
        assert_eq!(s.live()[0].phase, SeqPhase::Decode);
        assert_eq!(s.live()[0].first_token, Some((1.0, 0)));

        let batch = s.microbatch();
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 2.0, 2).unwrap();
        assert!(s.live().is_empty(), "finished sequences retire");
        assert_eq!(s.done().len(), 1);
        assert_eq!(s.done()[0].phase, SeqPhase::Done);
        assert_eq!(s.done()[0].generated(), 2);
        assert_eq!(s.steps(), 2);
        assert_eq!(s.dispatch_rounds(), 4);
    }

    #[test]
    fn continuous_admission_respects_the_token_budget() {
        // Budget 10, prompts of 4: two fit, the third waits.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 10)).unwrap();
        for id in 0..3 {
            if s.wants_offer() {
                s.offer(req(id, 4, 4), 0.0);
            }
            let _ = s.admit_pending(0.0).unwrap();
        }
        assert_eq!(s.live().len(), 2);
        assert!(s.has_pending(), "third request buffered, not dropped");
        assert!(!s.admit_pending(0.0).unwrap(), "over budget");
        // An empty batch always admits, even over budget.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 2)).unwrap();
        s.offer(req(9, 8, 1), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert_eq!(s.live().len(), 1);
    }

    #[test]
    fn microbatch_is_a_fifo_budget_prefix() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 100)).unwrap();
        for id in 0..3 {
            s.offer(req(id, 6, 4), 0.0);
            assert!(s.admit_pending(0.0).unwrap());
        }
        // All three fit under 100.
        assert_eq!(s.microbatch(), vec![0, 1, 2]);
        assert_eq!(s.step_tokens(&s.microbatch()), 18);
        // Shrink the budget: only the FIFO prefix runs.
        let mut tight =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 13)).unwrap();
        for id in 0..3 {
            tight.offer(req(id, 6, 4), 0.0);
            if !tight.admit_pending(0.0).unwrap() {
                break;
            }
        }
        assert_eq!(tight.live().len(), 2, "6 + 6 <= 13, third waits");
        assert_eq!(tight.microbatch(), vec![0, 1]);
    }

    #[test]
    fn static_drain_gates_admission_at_the_barrier() {
        let mut s =
            Scheduler::new(cfg(SchedMode::StaticDrain, 2, 1)).unwrap();
        // Drain opens on an empty batch and ignores the token budget.
        s.offer(req(0, 8, 2), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert!(s.wants_offer(), "drain window still open");
        s.offer(req(1, 8, 3), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert!(!s.wants_offer(), "max_batch reached");
        // First step closes the window: no mid-flight admission.
        let batch = s.microbatch();
        assert_eq!(batch.len(), 2, "static drain advances everyone");
        let next: Vec<i32> = batch
            .iter()
            .map(|&i| fake_next(&s.live()[i].ids))
            .collect();
        s.complete_step(&batch, &next, 1.0, 1).unwrap();
        assert!(!s.wants_offer(), "no admission mid-drain");
        s.offer(req(2, 4, 1), 1.0);
        assert!(!s.admit_pending(1.5).unwrap());
        // Drain the batch; the window reopens.
        while !s.live().is_empty() {
            let batch = s.microbatch();
            let next: Vec<i32> = batch
                .iter()
                .map(|&i| fake_next(&s.live()[i].ids))
                .collect();
            s.complete_step(&batch, &next, 2.0, 1).unwrap();
        }
        assert!(s.admit_pending(3.0).unwrap());
        assert_eq!(s.live()[0].req.id, 2);
    }

    #[test]
    fn zero_token_requests_complete_at_admission() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        s.offer(req(0, 4, 0), 0.0);
        assert!(s.admit_pending(0.25).unwrap());
        assert!(s.live().is_empty());
        assert_eq!(s.done().len(), 1);
        let (responses, metrics) = s.into_results(0.25);
        assert!(responses[0].tokens.is_empty());
        assert_eq!(metrics.generated_tokens, 0);
        assert!(metrics.ttft.is_empty(), "no token, no TTFT sample");
        assert_eq!(metrics.latencies.len(), 1);
    }

    #[test]
    fn malformed_requests_error_loudly() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        s.offer(req(0, 0, 4), 0.0);
        assert!(s.admit_pending(0.0).is_err(), "empty prompt");
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(1, 65, 4), 0.0); // ctx is 64
        assert!(s.admit_pending(0.0).is_err(), "prompt beyond ctx");
    }

    #[test]
    fn ctx_filling_prompt_with_generation_budget_is_rejected() {
        // Regression: a prompt at exactly ctx with max_new_tokens > 0
        // used to be admitted and silently completed with zero tokens;
        // it must now error loudly at admission.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(0, 64, 4), 0.0); // ctx is 64
        let err = s.admit_pending(0.0).unwrap_err().to_string();
        assert!(err.contains("no room"),
                "want the no-room-to-generate error, got: {err}");
        // The degenerate-but-honest case stays accepted: a ctx-long
        // prompt that asks for nothing completes at admission.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(1, 64, 0), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert_eq!(s.done().len(), 1);
    }

    #[test]
    fn kv_pricing_charges_prefill_then_one_token_per_step() {
        let mut c = cfg(SchedMode::Continuous, 4, 64);
        c.kv_cache = true;
        let mut s = Scheduler::new(c).unwrap();
        s.offer(req(0, 6, 3), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        // Prefill step: the whole prompt is uncached.
        let batch = s.microbatch();
        assert_eq!(s.step_tokens(&batch), 6);
        assert_eq!(s.live_tokens(), 6);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 1.0, 1).unwrap();
        // Decode steps: exactly one uncached token per live sequence.
        assert_eq!(s.live()[0].cached_len, 6);
        let batch = s.microbatch();
        assert_eq!(s.step_tokens(&batch), 1,
                   "cached decode must cost 1 token");
        assert_eq!(s.live_tokens(), 1);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 2.0, 1).unwrap();
        assert_eq!(s.live()[0].cached_len, 7);
    }

    #[test]
    fn kv_budget_admits_deeper_batches_than_recompute() {
        // Budget 10, prompts of 4 for 3 new tokens each. Recompute
        // pricing fits two live sequences; KV pricing fits the same two
        // at prefill but frees 3 tokens of budget the moment they decode
        // (cost 1 each), so the third request is admitted mid-flight.
        let run = |kv: bool| {
            let mut c = cfg(SchedMode::Continuous, 8, 10);
            c.kv_cache = kv;
            let arrivals: Vec<(Request, f64)> =
                (0..3).map(|id| (req(id, 4, 3), 0.0)).collect();
            simulate_serve(c, arrivals, fake_step, |_, _| 1.0)
                .unwrap()
                .1
        };
        let kv = run(true);
        let re = run(false);
        assert_eq!(kv.generated_tokens, re.generated_tokens);
        let wait = |m: &ServeMetrics| {
            m.per_request.iter().find(|t| t.id == 2).unwrap().queue_wait
        };
        assert!(wait(&kv) < wait(&re),
                "cached pricing must admit request 2 sooner: {} !< {}",
                wait(&kv), wait(&re));
    }

    #[test]
    fn kv_counters_split_computed_from_cached() {
        // One request, prompt P = 5, N = 4 new tokens, loose budget.
        // Computed = P + (N - 1) (prefill plus one per later step);
        // cached = sum of the prefix lengths served from cache.
        let mut c = cfg(SchedMode::Continuous, 4, 999);
        c.kv_cache = true;
        let (_, m) = simulate_serve(
            c,
            vec![(req(0, 5, 4), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(m.computed_tokens, 5 + 3);
        // Steps feed prefixes of length 5, 6, 7, 8; all but the last
        // token of each post-prefill step come from the cache.
        assert_eq!(m.cached_tokens, 5 + 6 + 7);
        assert!(m.cache_hit_rate() > 0.6);

        // Recompute pricing: everything is computed, nothing cached.
        let (_, m) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 999),
            vec![(req(0, 5, 4), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(m.computed_tokens, 5 + 6 + 7 + 8);
        assert_eq!(m.cached_tokens, 0);
        assert_eq!(m.cache_hit_rate(), 0.0);
    }

    #[test]
    fn retired_ids_are_reported_for_cache_eviction() {
        let mut evicted: Vec<u64> = Vec::new();
        let (responses, _) = simulate_serve_with(
            cfg(SchedMode::Continuous, 4, 64),
            (0..3).map(|id| (req(id, 4, 2), 0.0)).collect(),
            fake_step,
            |_, _| 1.0,
            |id| evicted.push(id),
        )
        .unwrap();
        assert_eq!(responses.len(), 3);
        evicted.sort_unstable();
        assert_eq!(evicted, vec![0, 1, 2],
                   "every retired request must be reported exactly once");
    }

    #[test]
    fn sequences_truncate_at_ctx() {
        let mut c = cfg(SchedMode::Continuous, 2, 64);
        c.ctx = 6;
        let (responses, _) = simulate_serve(
            c,
            vec![(req(0, 4, 100), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(responses[0].tokens.len(), 2, "4 + 2 == ctx");
    }

    #[test]
    fn simulate_serve_completes_everything_and_times_the_clock() {
        let arrivals: Vec<(Request, f64)> =
            (0..5).map(|id| (req(id, 5, 3), 0.0)).collect();
        let (responses, metrics) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 40),
            arrivals,
            fake_step,
            |tokens, _| tokens as f64 * 1e-3,
        )
        .unwrap();
        assert_eq!(responses.len(), 5);
        assert!(responses.windows(2).all(|w| w[0].id < w[1].id));
        for r in &responses {
            assert_eq!(r.tokens.len(), 3);
        }
        assert_eq!(metrics.generated_tokens, 15);
        assert_eq!(metrics.per_request.len(), 5);
        assert_eq!(metrics.ttft.len(), 5);
        assert_eq!(metrics.tpot.len(), 5);
        assert!(metrics.wall_time > 0.0);
        assert!(metrics.steps > 0);
        assert!(metrics.dispatch_rounds > 0);
        assert!(metrics.queue_wait.iter().all(|&w| w >= 0.0));
    }

    #[test]
    fn step_budget_is_respected_throughout_the_run() {
        // Every step's token count stays under the budget (prompts are
        // all below it, so the at-least-one escape never triggers).
        let arrivals: Vec<(Request, f64)> =
            (0..8).map(|id| (req(id, 10, 6), 0.0)).collect();
        let mut step_sizes: Vec<usize> = Vec::new();
        let (responses, _) = simulate_serve(
            cfg(SchedMode::Continuous, 8, 25),
            arrivals,
            |seqs| {
                step_sizes
                    .push(seqs.iter().map(|(_, ids, _)| ids.len()).sum());
                fake_step(seqs)
            },
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(responses.len(), 8);
        assert!(!step_sizes.is_empty());
        assert!(step_sizes.iter().all(|&t| t <= 25),
                "budget violated: {step_sizes:?}");
    }
}
