//! Iteration-level scheduling — the continuous-batching core of the
//! serving front.
//!
//! The seed server was "continuous-batching lite": it drained a static
//! batch, ran every sequence's full forward one at a time, admitted
//! nothing mid-flight, and only retired requests at the drain barrier.
//! This module replaces that with a per-request state machine driven at
//! *iteration* (decode-step) granularity, the discipline of vLLM-style
//! serving systems:
//!
//! ```text
//!              offer/admit            first token           retire
//!   Queued ───────────────▶ Prefill ─────────────▶ Decode ────────▶ Done
//!     │                                            ▲    │
//!     │ SLO shed                            resume │    │ evict
//!     ▼                                            │    ▼
//!  Rejected                                      Preempted
//! ```
//!
//! * **Admission** happens between steps, never mid-forward: the driver
//!   offers queued requests into a `max_batch`-deep admission window
//!   ([`Scheduler::offer`] → [`Scheduler::admit_pending`]) and the
//!   scheduler admits the best-priority candidate (FIFO within a class)
//!   while the live batch stays under `max_batch` sequences and — in
//!   [`SchedMode::Continuous`] — under the `max_batch_tokens` step
//!   budget. With the KV cache on (`kv_cache`, the default), a step
//!   only computes each sequence's **uncached** tokens, so prefill
//!   costs the prompt length and every later step costs exactly one
//!   token per sequence; with it off, every step recomputes the whole
//!   prefix and a sequence costs its full current length.
//! * **Priority & preemption** (`preempt`, Continuous only): requests
//!   carry a priority class (`0` = most urgent). When a candidate with
//!   a better class cannot be admitted, the scheduler evicts the
//!   deepest decode among strictly-lower-priority live sequences
//!   (Decode → Preempted) until the candidate fits — and only if
//!   eviction actually makes it fit, so no work is thrown away in
//!   vain. A preempted sequence keeps its KV cache while the retained
//!   total stays under `retain_cache_tokens`; over the cap the cache is
//!   dropped (`cached_len` → 0) and resume re-prefills the whole
//!   prefix. Resumes compete with fresh admissions by class (resumes
//!   win ties) and are themselves non-preempting.
//! * **SLO admission** (`ttft_slo`): per-class TTFT deadlines. A
//!   candidate is rejected loudly — surfaced via
//!   [`SchedEvent::Rejected`] and `ServeMetrics::rejected`, never
//!   silently dropped — when the larger of its wait so far and the p95
//!   of recent same-class admission waits exceeds its class deadline.
//! * **Microbatching**: every step advances a token-budgeted FIFO prefix
//!   of the live batch ([`Scheduler::microbatch`]); sequences over
//!   budget wait a step instead of stalling the batch, and at least one
//!   sequence always runs so an oversized sequence cannot deadlock.
//! * **Retirement** is immediate: a sequence that reaches its token
//!   budget or the model context leaves the batch at the end of the
//!   step that finished it ([`Scheduler::complete_step`]); the freed
//!   budget admits new work at the very next step.
//! * **Replan safety**: the driver owns the step loop, so the epoch
//!   re-planner's `epoch_tick` runs *between* steps — after
//!   `complete_step`, before the next admission — and therefore never
//!   mid-dispatch-round (the invariant `docs/ARCHITECTURE.md` pins).
//!
//! [`SchedMode::StaticDrain`] reproduces the seed server's behaviour on
//! top of the same state machine (admission only into an empty batch, no
//! token budget, preemption inert) so the serving bench can compare the
//! disciplines on identical workloads; greedy-decode outputs are
//! token-for-token identical across modes — and across preempt/resume —
//! because per-token numerics are independent of batch composition.
//!
//! [`simulate_serve`] is the virtual-clock driver used by tier-1 tests
//! and `benches/serving.rs`: same scheduler, same admission rules, with
//! the engine and the clock supplied as closures — so every scheduling
//! property is pinned without PJRT artifacts. [`simulate_serve_events`]
//! additionally surfaces the full [`SchedEvent`] stream (preemptions,
//! resumes, rejections, retirements) so cache-lifecycle tests can
//! mirror the real server's KV bookkeeping.

use std::collections::HashMap;

use super::{Request, Response};
use crate::metrics::{RequestTiming, ServeMetrics};
use crate::stats::Summary;

/// How many recent same-class admission waits feed the SLO predictor.
const SLO_WINDOW: usize = 32;

/// Request lifecycle within the serving core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SeqPhase {
    /// Waiting in the admission queue (or the scheduler's
    /// `max_batch`-deep admission window).
    Queued,
    /// Admitted; its first token has not been produced yet.
    Prefill,
    /// Generating tokens.
    Decode,
    /// Evicted mid-decode by a higher-priority admission; waiting to
    /// resume (Decode → Preempted → Decode).
    Preempted,
    /// Finished; retired from the live batch.
    Done,
}

/// Batching discipline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// Seed-server behaviour: admit only into an empty batch (up to
    /// `max_batch` requests), run the drain to completion, repeat. No
    /// token budget; kept as the baseline arm of `benches/serving.rs`.
    StaticDrain,
    /// Iteration-level continuous batching: admission between every
    /// step under the `max_batch_tokens` budget, immediate retirement.
    Continuous,
}

/// Scheduler tunables (the serving front copies these out of
/// [`super::ServerConfig`]).
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// Batching discipline.
    pub mode: SchedMode,
    /// Maximum live sequences (also the admission-window depth).
    pub max_batch: usize,
    /// Step token budget (continuous mode): the number of tokens a step
    /// may *compute*. Under KV-cached pricing that is each sequence's
    /// uncached suffix (prompt length at prefill, one token thereafter);
    /// under recompute pricing it is the full current length.
    pub max_batch_tokens: usize,
    /// Model context length (admission bound and finish condition).
    pub ctx: usize,
    /// Price steps for KV-cached decode (1 token per live sequence after
    /// prefill) instead of full-prefix recompute. Must match the engine
    /// path the driver runs, or the budget meters the wrong cost.
    pub kv_cache: bool,
    /// Evict lower-priority decodes when a higher-priority candidate
    /// cannot be admitted (Continuous mode only; inert under
    /// StaticDrain).
    pub preempt: bool,
    /// Total KV-cache tokens preempted sequences may keep warm. Evicting
    /// past the cap drops the victim's cache instead (resume then
    /// re-prefills the whole prefix). `usize::MAX` retains everything.
    pub retain_cache_tokens: usize,
    /// Per-class TTFT deadlines, seconds, indexed by priority class.
    /// Classes beyond the vector have no deadline; empty (the default)
    /// disables SLO admission entirely.
    pub ttft_slo: Vec<f64>,
}

impl Default for SchedConfig {
    fn default() -> SchedConfig {
        SchedConfig {
            mode: SchedMode::Continuous,
            max_batch: 8,
            max_batch_tokens: 512,
            ctx: 128,
            kv_cache: true,
            preempt: false,
            retain_cache_tokens: usize::MAX,
            ttft_slo: Vec::new(),
        }
    }
}

/// Scheduler-side lifecycle notifications, drained by the driver via
/// [`Scheduler::take_events`] (or delivered by
/// [`simulate_serve_events`]). The driver owns the engine-side KV
/// caches, so cache drops on preemption and eviction at retirement are
/// *its* job — these events are the contract that keeps the two sides
/// in lockstep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedEvent {
    /// A live sequence was evicted mid-decode. When `cache_dropped`,
    /// the driver must free the engine-side KV cache for `id` (the
    /// scheduler has already zeroed its `cached_len`); otherwise the
    /// cache stays warm for resume.
    Preempted {
        /// Request id of the evicted sequence.
        id: u64,
        /// Whether the KV cache was dropped (over the retain cap or
        /// KV caching disabled) rather than kept warm.
        cache_dropped: bool,
    },
    /// A preempted sequence re-entered the live batch.
    Resumed {
        /// Request id of the resumed sequence.
        id: u64,
    },
    /// A candidate was shed by SLO admission control: it never entered
    /// the live batch and produces no response.
    Rejected {
        /// Request id of the shed candidate.
        id: u64,
    },
    /// A sequence finished and left the live batch; the driver evicts
    /// its KV cache. Fires exactly once per admitted request, no
    /// matter how many times it was preempted and resumed.
    Retired {
        /// Request id of the finished sequence.
        id: u64,
    },
}

/// One live (or finished) sequence and its timing record. Times are
/// driver-clock seconds: wall-clock in the real server, virtual seconds
/// under [`simulate_serve`].
#[derive(Clone, Debug)]
pub struct SeqState {
    /// The originating request.
    pub req: Request,
    /// Prompt plus generated tokens.
    pub ids: Vec<i32>,
    /// Lifecycle phase.
    pub phase: SeqPhase,
    /// When the request entered the admission queue.
    pub enqueue: f64,
    /// When it was admitted into the live batch.
    pub admit: f64,
    /// Step index at admission.
    pub admit_step: usize,
    /// `(time, step)` of the first generated token.
    pub first_token: Option<(f64, usize)>,
    /// Completion time of the most recent token.
    pub last_token: f64,
    /// Completion time of the whole request.
    pub finish: f64,
    /// Tokens of `ids` whose K/V rows the engine has cached (0 until the
    /// sequence's first step; stays 0 under recompute pricing; reset to
    /// 0 when an eviction drops the cache). Mirrors the engine-side
    /// `KvCache::len` — the server debug-asserts the two agree every
    /// step.
    pub cached_len: usize,
    /// How many times this sequence has been evicted mid-decode.
    pub preemptions: usize,
}

impl SeqState {
    /// Tokens generated so far (prompt excluded).
    pub fn generated(&self) -> usize {
        self.ids.len() - self.req.prompt.len()
    }

    fn wants_tokens(&self, ctx: usize) -> bool {
        self.generated() < self.req.max_new_tokens && self.ids.len() < ctx
    }
}

/// The iteration-level scheduler: a FIFO live batch, a
/// `max_batch`-deep priority admission window, the preempted set, and
/// the retired set. Drivers loop over offer/admit →
/// [`Scheduler::microbatch`] → run the step →
/// [`Scheduler::complete_step`]; see the module docs for the protocol.
pub struct Scheduler {
    cfg: SchedConfig,
    /// Offered-but-unadmitted candidates: `(request, enqueue time,
    /// offer sequence number)`. Bounded by `max_batch`; admission picks
    /// by `(priority class, offer order)` so equal-priority traffic is
    /// served strictly FIFO — bit-identical to the pre-priority
    /// scheduler.
    pending: Vec<(Request, f64, u64)>,
    /// Monotone offer counter (the FIFO tie-breaker within a class).
    offer_seq: u64,
    live: Vec<SeqState>,
    /// Evicted-mid-decode sequences awaiting resume, in eviction order.
    preempted: Vec<SeqState>,
    done: Vec<SeqState>,
    /// Ids shed by SLO admission control, in rejection order.
    rejected: Vec<u64>,
    /// Undrained lifecycle events (preemptions/resumes/rejections).
    events: Vec<SchedEvent>,
    /// KV tokens currently held warm by preempted sequences.
    retained_cache: usize,
    /// Recent admission queue-waits per class, feeding the SLO
    /// predictor (last [`SLO_WINDOW`] samples).
    recent_waits: HashMap<usize, Vec<f64>>,
    steps: usize,
    dispatch_rounds: usize,
    preemptions: usize,
    resumes: usize,
    /// Tokens actually computed across all steps (uncached suffixes
    /// under KV pricing; full prefixes under recompute).
    computed_tokens: usize,
    /// Prefix tokens served from the KV cache instead of recomputed
    /// (always 0 under recompute pricing).
    cached_tokens: usize,
    /// Static-drain admission window: open from the first admission
    /// into an empty batch until the next step executes.
    drain_open: bool,
}

impl Scheduler {
    /// Scheduler over validated tunables (zero `max_batch`,
    /// `max_batch_tokens`, or `ctx` would serve nothing — rejected
    /// loudly instead of silently dropping every request; SLO deadlines
    /// must be positive and finite).
    pub fn new(cfg: SchedConfig) -> anyhow::Result<Scheduler> {
        anyhow::ensure!(cfg.max_batch > 0,
                        "scheduler: max_batch = 0 admits nothing");
        anyhow::ensure!(cfg.max_batch_tokens > 0,
                        "scheduler: max_batch_tokens = 0 steps nothing");
        anyhow::ensure!(cfg.ctx > 0, "scheduler: ctx = 0");
        for (class, &slo) in cfg.ttft_slo.iter().enumerate() {
            anyhow::ensure!(slo.is_finite() && slo > 0.0,
                            "scheduler: ttft_slo[{class}] = {slo} \
                             (want a positive finite deadline)");
        }
        Ok(Scheduler {
            cfg,
            pending: Vec::new(),
            offer_seq: 0,
            live: Vec::new(),
            preempted: Vec::new(),
            done: Vec::new(),
            rejected: Vec::new(),
            events: Vec::new(),
            retained_cache: 0,
            recent_waits: HashMap::new(),
            steps: 0,
            dispatch_rounds: 0,
            preemptions: 0,
            resumes: 0,
            computed_tokens: 0,
            cached_tokens: 0,
            drain_open: false,
        })
    }

    /// The scheduler's configuration.
    pub fn config(&self) -> &SchedConfig {
        &self.cfg
    }

    /// Steps executed so far.
    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Dispatch rounds recorded across all steps.
    pub fn dispatch_rounds(&self) -> usize {
        self.dispatch_rounds
    }

    /// The live batch, in admission (FIFO) order.
    pub fn live(&self) -> &[SeqState] {
        &self.live
    }

    /// Sequences evicted mid-decode and awaiting resume, in eviction
    /// order.
    pub fn preempted(&self) -> &[SeqState] {
        &self.preempted
    }

    /// Retired sequences, in retirement order.
    pub fn done(&self) -> &[SeqState] {
        &self.done
    }

    /// Ids shed by SLO admission control so far, in rejection order.
    pub fn rejected_ids(&self) -> &[u64] {
        &self.rejected
    }

    /// Evictions performed so far.
    pub fn preemptions(&self) -> usize {
        self.preemptions
    }

    /// Preempted sequences re-admitted so far.
    pub fn resumes(&self) -> usize {
        self.resumes
    }

    /// Whether any request sits in the admission window.
    pub fn has_pending(&self) -> bool {
        !self.pending.is_empty()
    }

    /// Drain the undrained lifecycle events (preemptions, resumes,
    /// rejections) accumulated since the last call. Drivers that own
    /// engine-side KV caches must act on `Preempted { cache_dropped:
    /// true }` by freeing the cache.
    pub fn take_events(&mut self) -> Vec<SchedEvent> {
        std::mem::take(&mut self.events)
    }

    /// Nothing live, buffered, or preempted: the driver should block on
    /// the queue (or finish, if the queue is closed and drained).
    pub fn is_idle(&self) -> bool {
        self.live.is_empty() && self.pending.is_empty()
            && self.preempted.is_empty()
    }

    /// What one step of `s` costs against the token budget: the uncached
    /// suffix under KV pricing, the full prefix under recompute.
    fn seq_cost(&self, s: &SeqState) -> usize {
        if self.cfg.kv_cache {
            s.ids.len() - s.cached_len
        } else {
            s.ids.len()
        }
    }

    /// Tokens the next full-batch step would compute (budget-priced per
    /// the `seq_cost` rule above).
    pub fn live_tokens(&self) -> usize {
        self.live.iter().map(|s| self.seq_cost(s)).sum()
    }

    /// Whether the driver should pull another request off the queue
    /// into the admission window. With preemption on, the window keeps
    /// filling even when the live batch is full — a higher-priority
    /// arrival must become *visible* to trigger an eviction.
    pub fn wants_offer(&self) -> bool {
        if self.pending.len() >= self.cfg.max_batch {
            return false;
        }
        if self.cfg.mode == SchedMode::Continuous && self.cfg.preempt {
            return true;
        }
        self.admission_open()
    }

    fn admission_open(&self) -> bool {
        if self.live.len() >= self.cfg.max_batch {
            return false;
        }
        match self.cfg.mode {
            SchedMode::Continuous => true,
            SchedMode::StaticDrain => {
                self.live.is_empty() || self.drain_open
            }
        }
    }

    /// Buffer a queued request in the admission window; `false`
    /// (refusing the offer) when the window is `max_batch` deep.
    pub fn offer(&mut self, req: Request, enqueue: f64) -> bool {
        if self.pending.len() >= self.cfg.max_batch {
            return false;
        }
        let seq = self.offer_seq;
        self.offer_seq += 1;
        self.pending.push((req, enqueue, seq));
        true
    }

    /// Admissibility of a new `cost`-token sequence against the current
    /// live batch (or a hypothetical `(slots, tokens)` state during a
    /// preemption dry-run). An empty batch always admits — the
    /// at-least-one escape.
    fn fits(&self, slots: usize, tokens: usize, cost: usize) -> bool {
        if slots >= self.cfg.max_batch {
            return false;
        }
        match self.cfg.mode {
            SchedMode::StaticDrain => {
                slots == 0 || self.drain_open
            }
            SchedMode::Continuous => {
                slots == 0
                    || tokens + cost <= self.cfg.max_batch_tokens
            }
        }
    }

    /// Best resume candidate: `(priority class, eviction order)`.
    fn best_preempted(&self) -> Option<usize> {
        (0..self.preempted.len())
            .min_by_key(|&i| (self.preempted[i].req.priority, i))
    }

    /// Best fresh candidate: `(priority class, offer order)` — strict
    /// FIFO within a class.
    fn best_pending(&self) -> Option<usize> {
        (0..self.pending.len())
            .min_by_key(|&i| (self.pending[i].0.priority,
                              self.pending[i].2))
    }

    /// p95 of recent same-class admission waits; 0 with no history.
    fn predicted_wait(&self, class: usize) -> f64 {
        match self.recent_waits.get(&class) {
            Some(w) if !w.is_empty() => Summary::of(w).p95(),
            _ => 0.0,
        }
    }

    /// Try to admit (or resume, or SLO-shed) the best-priority
    /// candidate under the mode's rules. Returns whether the scheduler
    /// made progress — admitted a request, resumed a preempted
    /// sequence, retired a zero-token request instantly, or rejected a
    /// candidate past its deadline — so drivers loop `while
    /// admit_pending()?`. Strictly head-of-line: if the best candidate
    /// cannot move (even after eviction, with preemption on), worse
    /// candidates are not tried. Errors on malformed requests (empty
    /// prompt, prompt beyond the model context).
    pub fn admit_pending(&mut self, now: f64) -> anyhow::Result<bool> {
        let resume = self.best_preempted();
        let fresh = self.best_pending();
        match (resume, fresh) {
            (None, None) => Ok(false),
            (Some(r), None) => Ok(self.try_resume(r)),
            (Some(r), Some(p))
                if self.preempted[r].req.priority
                    <= self.pending[p].0.priority =>
            {
                // Resumes win ties within a class: finishing evicted
                // work beats starting fresh work of the same urgency.
                Ok(self.try_resume(r))
            }
            (_, Some(p)) => self.try_admit(p, now),
        }
    }

    /// Re-admit preempted sequence `i` if it fits. Resumes are
    /// non-preempting: a resume that does not fit simply waits.
    fn try_resume(&mut self, i: usize) -> bool {
        let cost = self.seq_cost(&self.preempted[i]);
        if !self.fits(self.live.len(), self.live_tokens(), cost) {
            return false;
        }
        let mut s = self.preempted.remove(i);
        self.retained_cache =
            self.retained_cache.saturating_sub(s.cached_len);
        s.phase = SeqPhase::Decode;
        self.resumes += 1;
        self.events.push(SchedEvent::Resumed { id: s.req.id });
        self.live.push(s);
        true
    }

    /// Evict strictly-lower-priority decodes, deepest first, until a
    /// `cost`-token class-`prio` candidate fits — but only if eviction
    /// actually achieves that (dry-run first; no work is thrown away
    /// for an admission that still fails). Continuous mode only.
    fn preempt_to_fit(&mut self, prio: usize, cost: usize) -> bool {
        if !self.cfg.preempt || self.cfg.mode != SchedMode::Continuous {
            return false;
        }
        let mut victims: Vec<usize> = (0..self.live.len())
            .filter(|&i| {
                self.live[i].phase == SeqPhase::Decode
                    && self.live[i].req.priority > prio
            })
            .collect();
        // Deepest decode first (most budget freed per eviction under
        // recompute pricing; least remaining work disturbed is the
        // paper-level trade we accept for the priority inversion fix).
        victims.sort_by_key(|&i| {
            std::cmp::Reverse((self.live[i].ids.len(), i))
        });
        let mut slots = self.live.len();
        let mut tokens = self.live_tokens();
        let mut chosen: Vec<usize> = Vec::new();
        for &v in &victims {
            if self.fits(slots, tokens, cost) {
                break;
            }
            chosen.push(v);
            slots -= 1;
            tokens -= self.seq_cost(&self.live[v]);
        }
        if !self.fits(slots, tokens, cost) {
            return false;
        }
        // Evict back-to-front so earlier indices stay valid.
        chosen.sort_unstable_by(|a, b| b.cmp(a));
        for v in chosen {
            self.evict(v);
        }
        true
    }

    /// Move live sequence `i` to the preempted set, retaining or
    /// dropping its KV cache under the retain cap.
    fn evict(&mut self, i: usize) {
        let mut s = self.live.remove(i);
        s.phase = SeqPhase::Preempted;
        s.preemptions += 1;
        self.preemptions += 1;
        let retain = self.cfg.kv_cache
            && self.retained_cache.saturating_add(s.cached_len)
                <= self.cfg.retain_cache_tokens;
        let cache_dropped = self.cfg.kv_cache && !retain;
        if retain {
            self.retained_cache += s.cached_len;
        } else {
            s.cached_len = 0;
        }
        self.events.push(SchedEvent::Preempted {
            id: s.req.id,
            cache_dropped,
        });
        self.preempted.push(s);
    }

    /// Admit pending candidate `p`: SLO shed, fit (evicting if allowed
    /// and necessary), validate, and enter the live batch.
    fn try_admit(&mut self, p: usize, now: f64) -> anyhow::Result<bool> {
        let class = self.pending[p].0.priority;
        if let Some(&slo) = self.cfg.ttft_slo.get(class) {
            let waited = now - self.pending[p].1;
            // Shed when the deadline is already blown or recent history
            // says it will be: predicted TTFT ≈ queue wait (the first
            // step after admission is fast relative to queueing).
            if waited.max(self.predicted_wait(class)) > slo {
                let (req, _, _) = self.pending.remove(p);
                self.events.push(SchedEvent::Rejected { id: req.id });
                self.rejected.push(req.id);
                return Ok(true);
            }
        }
        let cost = self.pending[p].0.prompt.len();
        if !self.fits(self.live.len(), self.live_tokens(), cost)
            && !self.preempt_to_fit(class, cost)
        {
            return Ok(false);
        }
        let (req, enqueue, _) = self.pending.remove(p);
        anyhow::ensure!(!req.prompt.is_empty(),
                        "request {}: empty prompt", req.id);
        anyhow::ensure!(req.prompt.len() <= self.cfg.ctx,
                        "request {}: prompt {} exceeds ctx {}",
                        req.id, req.prompt.len(), self.cfg.ctx);
        // A prompt that already fills the context has no room to append
        // even one generated token. Admitting it used to complete the
        // request silently with zero tokens — reject loudly instead so
        // callers learn their generation budget is unservable.
        anyhow::ensure!(
            req.max_new_tokens == 0 || req.prompt.len() < self.cfg.ctx,
            "request {}: prompt fills the whole context ({} == ctx), \
             leaving no room for any of the {} requested tokens — \
             shorten the prompt or raise ctx",
            req.id, req.prompt.len(), req.max_new_tokens
        );
        let waits = self.recent_waits.entry(class).or_default();
        if waits.len() >= SLO_WINDOW {
            waits.remove(0);
        }
        waits.push(now - enqueue);
        let ids = req.prompt.clone();
        let mut seq = SeqState {
            req,
            ids,
            phase: SeqPhase::Prefill,
            enqueue,
            admit: now,
            admit_step: self.steps,
            first_token: None,
            last_token: now,
            finish: now,
            cached_len: 0,
            preemptions: 0,
        };
        if !seq.wants_tokens(self.cfg.ctx) {
            // Zero-token request (max_new_tokens = 0): completes at
            // admission, generating nothing.
            seq.phase = SeqPhase::Done;
            seq.finish = now;
            self.done.push(seq);
            return Ok(true);
        }
        if self.live.is_empty() && self.cfg.mode == SchedMode::StaticDrain
        {
            self.drain_open = true;
        }
        self.live.push(seq);
        Ok(true)
    }

    /// The FIFO token-budgeted microbatch for this step: indices into
    /// [`Scheduler::live`]. Always non-empty when the batch is —
    /// an over-budget head sequence runs alone rather than stalling.
    pub fn microbatch(&self) -> Vec<usize> {
        let mut batch = Vec::with_capacity(self.live.len());
        let mut tokens = 0usize;
        for (i, s) in self.live.iter().enumerate() {
            let cost = self.seq_cost(s);
            if self.cfg.mode == SchedMode::Continuous
                && !batch.is_empty()
                && tokens + cost > self.cfg.max_batch_tokens
            {
                break;
            }
            batch.push(i);
            tokens += cost;
        }
        batch
    }

    /// Tokens the given microbatch computes (budget-priced per the
    /// `seq_cost` rule above).
    pub fn step_tokens(&self, batch: &[usize]) -> usize {
        batch.iter().map(|&i| self.seq_cost(&self.live[i])).sum()
    }

    /// Record one executed step: `next[j]` is the token generated for
    /// live sequence `batch[j]`. Finished sequences retire immediately
    /// (the remaining live batch keeps FIFO order); the retired request
    /// ids are returned so the driver can evict their KV caches.
    pub fn complete_step(&mut self, batch: &[usize], next: &[i32],
                         now: f64, dispatch_rounds: usize)
                         -> anyhow::Result<Vec<u64>> {
        anyhow::ensure!(batch.len() == next.len(),
                        "step produced {} tokens for {} sequences",
                        next.len(), batch.len());
        self.drain_open = false;
        self.steps += 1;
        self.dispatch_rounds += dispatch_rounds;
        for (&i, &tok) in batch.iter().zip(next) {
            let cost = self.seq_cost(&self.live[i]);
            let full = self.live[i].ids.len();
            self.computed_tokens += cost;
            self.cached_tokens += full - cost;
            let s = &mut self.live[i];
            if self.cfg.kv_cache {
                // The engine's cache now covers every token it was fed.
                s.cached_len = full;
            }
            s.ids.push(tok);
            if s.first_token.is_none() {
                s.first_token = Some((now, self.steps - 1));
                s.phase = SeqPhase::Decode;
            }
            s.last_token = now;
        }
        let ctx = self.cfg.ctx;
        let mut retired = Vec::new();
        let mut i = 0;
        while i < self.live.len() {
            if self.live[i].wants_tokens(ctx) {
                i += 1;
            } else {
                let mut s = self.live.remove(i);
                s.phase = SeqPhase::Done;
                s.finish = now;
                retired.push(s.req.id);
                self.done.push(s);
            }
        }
        Ok(retired)
    }

    /// Consume the scheduler into responses (sorted by request id) and
    /// serving metrics. `wall_time` is the driver clock at shutdown.
    /// SLO-shed requests produce no response; their ids are surfaced
    /// (sorted) in `ServeMetrics::rejected`.
    pub fn into_results(self, wall_time: f64)
                        -> (Vec<Response>, ServeMetrics) {
        debug_assert!(self.live.is_empty() && self.pending.is_empty()
                          && self.preempted.is_empty(),
                      "into_results with work still in flight");
        let mut done = self.done;
        done.sort_by_key(|s| s.req.id);
        let mut rejected = self.rejected;
        rejected.sort_unstable();
        let mut responses = Vec::with_capacity(done.len());
        let mut metrics = ServeMetrics {
            wall_time,
            steps: self.steps,
            dispatch_rounds: self.dispatch_rounds,
            computed_tokens: self.computed_tokens,
            cached_tokens: self.cached_tokens,
            preemptions: self.preemptions,
            resumes: self.resumes,
            rejected,
            ..ServeMetrics::default()
        };
        for s in done {
            let generated = s.generated();
            let latency = s.finish - s.enqueue;
            let queue_wait = s.admit - s.enqueue;
            let mut timing = RequestTiming {
                id: s.req.id,
                priority: s.req.priority,
                queue_wait,
                ttft: latency,
                latency,
                tpot: 0.0,
                admit_step: s.admit_step,
                first_token_step: s.admit_step,
                preemptions: s.preemptions,
                tokens: generated,
            };
            if let Some((t, step)) = s.first_token {
                timing.ttft = t - s.enqueue;
                timing.first_token_step = step;
                metrics.ttft.push(timing.ttft);
                if generated >= 2 {
                    timing.tpot =
                        (s.last_token - t) / (generated - 1) as f64;
                    metrics.tpot.push(timing.tpot);
                }
            }
            metrics.latencies.push(latency);
            metrics.queue_wait.push(queue_wait);
            metrics.generated_tokens += generated;
            metrics.per_request.push(timing);
            responses.push(Response {
                id: s.req.id,
                tokens: s.ids[s.req.prompt.len()..].to_vec(),
                latency,
            });
        }
        (responses, metrics)
    }
}

/// Virtual-clock serving driver for tests and benches: replays a
/// (time-sorted) arrival schedule through the scheduler with the engine
/// and the clock supplied by the caller. `step_fn` receives the
/// microbatch as `(request id, token prefix, cached prefix length)`
/// triples — the cached length is 0 under recompute pricing, and tells
/// a KV-aware fake engine how many leading tokens it may serve from its
/// cache — and returns the next token per sequence plus the dispatch
/// rounds the step issued; `step_cost` maps `(step tokens, dispatch
/// rounds)` to virtual seconds. The real server
/// ([`super::MoEServer::serve`]) is the same loop on the wall clock and
/// the PJRT engine.
pub fn simulate_serve<F, C>(cfg: SchedConfig,
                            arrivals: Vec<(Request, f64)>,
                            step_fn: F, step_cost: C)
                            -> anyhow::Result<(Vec<Response>, ServeMetrics)>
where
    F: FnMut(&[(u64, &[i32], usize)]) -> anyhow::Result<(Vec<i32>, usize)>,
    C: FnMut(usize, usize) -> f64,
{
    simulate_serve_with(cfg, arrivals, step_fn, step_cost, |_| {})
}

/// [`simulate_serve`] plus a retirement hook: `retire_fn` is called with
/// each request id the moment its sequence *finishes* and leaves the
/// live batch — exactly when the real server drops the sequence's KV
/// cache, so cache-eviction tests can mirror the lifecycle without
/// PJRT. Fires exactly once per admitted request, even across
/// preempt/resume cycles (preemption-time cache drops are surfaced
/// separately, by [`simulate_serve_events`]).
pub fn simulate_serve_with<F, C, R>(cfg: SchedConfig,
                                    arrivals: Vec<(Request, f64)>,
                                    step_fn: F, step_cost: C,
                                    mut retire_fn: R)
                                    -> anyhow::Result<(Vec<Response>,
                                                       ServeMetrics)>
where
    F: FnMut(&[(u64, &[i32], usize)]) -> anyhow::Result<(Vec<i32>, usize)>,
    C: FnMut(usize, usize) -> f64,
    R: FnMut(u64),
{
    simulate_serve_events(cfg, arrivals, step_fn, step_cost, |e| {
        if let SchedEvent::Retired { id } = e {
            retire_fn(*id);
        }
    })
}

/// [`simulate_serve`] plus the full [`SchedEvent`] stream: `event_fn`
/// sees every preemption (with its cache-drop verdict), resume,
/// SLO rejection, and retirement, in scheduler order — the same
/// notifications `server::drive` uses to keep engine-side KV caches in
/// lockstep with the scheduler.
pub fn simulate_serve_events<F, C, E>(cfg: SchedConfig,
                                      mut arrivals: Vec<(Request, f64)>,
                                      mut step_fn: F, mut step_cost: C,
                                      mut event_fn: E)
                                      -> anyhow::Result<(Vec<Response>,
                                                         ServeMetrics)>
where
    F: FnMut(&[(u64, &[i32], usize)]) -> anyhow::Result<(Vec<i32>, usize)>,
    C: FnMut(usize, usize) -> f64,
    E: FnMut(&SchedEvent),
{
    arrivals.sort_by(|a, b| {
        a.1.partial_cmp(&b.1).expect("NaN arrival time")
    });
    let mut sched = Scheduler::new(cfg)?;
    let mut next_arrival = 0usize;
    let mut now = 0.0f64;
    loop {
        // Admission: pull every arrived request the scheduler will take.
        loop {
            if sched.wants_offer()
                && next_arrival < arrivals.len()
                && arrivals[next_arrival].1 <= now
            {
                let (req, t) = arrivals[next_arrival].clone();
                next_arrival += 1;
                sched.offer(req, t);
                continue;
            }
            let progressed = sched.admit_pending(now)?;
            for e in sched.take_events() {
                event_fn(&e);
            }
            if !progressed {
                break;
            }
        }
        if sched.is_idle() {
            if next_arrival >= arrivals.len() {
                break;
            }
            // Open-loop idle gap: jump the clock to the next arrival.
            now = now.max(arrivals[next_arrival].1);
            continue;
        }
        if sched.live().is_empty() {
            anyhow::bail!("scheduler stalled with pending work");
        }
        let batch = sched.microbatch();
        let tokens = sched.step_tokens(&batch);
        let (next, rounds) = {
            let seqs: Vec<(u64, &[i32], usize)> = batch
                .iter()
                .map(|&i| {
                    let s = &sched.live()[i];
                    (s.req.id, s.ids.as_slice(), s.cached_len)
                })
                .collect();
            step_fn(&seqs)?
        };
        now += step_cost(tokens, rounds);
        for id in sched.complete_step(&batch, &next, now, rounds)? {
            event_fn(&SchedEvent::Retired { id });
        }
    }
    Ok(sched.into_results(now))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt: usize, new_tokens: usize) -> Request {
        Request {
            id,
            prompt: (0..prompt).map(|i| (id as i32) * 100 + i as i32)
                .collect(),
            max_new_tokens: new_tokens,
            priority: 0,
        }
    }

    fn preq(id: u64, prompt: usize, new_tokens: usize, priority: usize)
            -> Request {
        Request { priority, ..req(id, prompt, new_tokens) }
    }

    fn cfg(mode: SchedMode, max_batch: usize, budget: usize)
           -> SchedConfig {
        SchedConfig {
            mode,
            max_batch,
            max_batch_tokens: budget,
            ctx: 64,
            kv_cache: false,
            ..SchedConfig::default()
        }
    }

    use crate::testutil::fake_decode_token as fake_next;

    fn fake_step(seqs: &[(u64, &[i32], usize)])
                 -> anyhow::Result<(Vec<i32>, usize)> {
        let tokens: usize = seqs.iter().map(|(_, ids, _)| ids.len()).sum();
        let rounds = 2 * tokens.div_ceil(16); // 2 layers, tile 16
        Ok((seqs.iter().map(|(_, ids, _)| fake_next(ids)).collect(),
            rounds))
    }

    #[test]
    fn config_is_validated() {
        assert!(Scheduler::new(cfg(SchedMode::Continuous, 0, 8)).is_err());
        assert!(Scheduler::new(cfg(SchedMode::Continuous, 8, 0)).is_err());
        let bad = SchedConfig { ctx: 0, ..cfg(SchedMode::Continuous, 8, 8) };
        assert!(Scheduler::new(bad).is_err());
        let bad = SchedConfig {
            ttft_slo: vec![1.0, -0.5],
            ..cfg(SchedMode::Continuous, 8, 8)
        };
        assert!(Scheduler::new(bad).is_err(), "negative SLO deadline");
    }

    #[test]
    fn state_machine_walks_queued_prefill_decode_done() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        assert!(s.offer(req(0, 4, 2), 0.0));
        assert!(s.admit_pending(0.5).unwrap());
        assert_eq!(s.live()[0].phase, SeqPhase::Prefill);
        assert_eq!(s.live()[0].admit, 0.5);

        let batch = s.microbatch();
        assert_eq!(batch, vec![0]);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 1.0, 2).unwrap();
        assert_eq!(s.live()[0].phase, SeqPhase::Decode);
        assert_eq!(s.live()[0].first_token, Some((1.0, 0)));

        let batch = s.microbatch();
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 2.0, 2).unwrap();
        assert!(s.live().is_empty(), "finished sequences retire");
        assert_eq!(s.done().len(), 1);
        assert_eq!(s.done()[0].phase, SeqPhase::Done);
        assert_eq!(s.done()[0].generated(), 2);
        assert_eq!(s.steps(), 2);
        assert_eq!(s.dispatch_rounds(), 4);
    }

    #[test]
    fn admission_window_is_max_batch_deep() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 2, 64)).unwrap();
        assert!(s.offer(req(0, 4, 2), 0.0));
        assert!(s.offer(req(1, 4, 2), 0.0));
        assert!(!s.offer(req(2, 4, 2), 0.0),
                "window is max_batch deep");
        assert!(s.has_pending());
    }

    #[test]
    fn continuous_admission_respects_the_token_budget() {
        // Budget 10, prompts of 4: two fit, the third waits.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 10)).unwrap();
        for id in 0..3 {
            if s.wants_offer() {
                s.offer(req(id, 4, 4), 0.0);
            }
            let _ = s.admit_pending(0.0).unwrap();
        }
        assert_eq!(s.live().len(), 2);
        assert!(s.has_pending(), "third request buffered, not dropped");
        assert!(!s.admit_pending(0.0).unwrap(), "over budget");
        // An empty batch always admits, even over budget.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 2)).unwrap();
        s.offer(req(9, 8, 1), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert_eq!(s.live().len(), 1);
    }

    #[test]
    fn microbatch_is_a_fifo_budget_prefix() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 100)).unwrap();
        for id in 0..3 {
            s.offer(req(id, 6, 4), 0.0);
            assert!(s.admit_pending(0.0).unwrap());
        }
        // All three fit under 100.
        assert_eq!(s.microbatch(), vec![0, 1, 2]);
        assert_eq!(s.step_tokens(&s.microbatch()), 18);
        // Shrink the budget: only the FIFO prefix runs.
        let mut tight =
            Scheduler::new(cfg(SchedMode::Continuous, 8, 13)).unwrap();
        for id in 0..3 {
            tight.offer(req(id, 6, 4), 0.0);
            if !tight.admit_pending(0.0).unwrap() {
                break;
            }
        }
        assert_eq!(tight.live().len(), 2, "6 + 6 <= 13, third waits");
        assert_eq!(tight.microbatch(), vec![0, 1]);
    }

    #[test]
    fn static_drain_gates_admission_at_the_barrier() {
        let mut s =
            Scheduler::new(cfg(SchedMode::StaticDrain, 2, 1)).unwrap();
        // Drain opens on an empty batch and ignores the token budget.
        s.offer(req(0, 8, 2), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert!(s.wants_offer(), "drain window still open");
        s.offer(req(1, 8, 3), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert!(!s.wants_offer(), "max_batch reached");
        // First step closes the window: no mid-flight admission.
        let batch = s.microbatch();
        assert_eq!(batch.len(), 2, "static drain advances everyone");
        let next: Vec<i32> = batch
            .iter()
            .map(|&i| fake_next(&s.live()[i].ids))
            .collect();
        s.complete_step(&batch, &next, 1.0, 1).unwrap();
        assert!(!s.wants_offer(), "no admission mid-drain");
        s.offer(req(2, 4, 1), 1.0);
        assert!(!s.admit_pending(1.5).unwrap());
        // Drain the batch; the window reopens.
        while !s.live().is_empty() {
            let batch = s.microbatch();
            let next: Vec<i32> = batch
                .iter()
                .map(|&i| fake_next(&s.live()[i].ids))
                .collect();
            s.complete_step(&batch, &next, 2.0, 1).unwrap();
        }
        assert!(s.admit_pending(3.0).unwrap());
        assert_eq!(s.live()[0].req.id, 2);
    }

    #[test]
    fn zero_token_requests_complete_at_admission() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        s.offer(req(0, 4, 0), 0.0);
        assert!(s.admit_pending(0.25).unwrap());
        assert!(s.live().is_empty());
        assert_eq!(s.done().len(), 1);
        let (responses, metrics) = s.into_results(0.25);
        assert!(responses[0].tokens.is_empty());
        assert_eq!(metrics.generated_tokens, 0);
        assert!(metrics.ttft.is_empty(), "no token, no TTFT sample");
        assert_eq!(metrics.latencies.len(), 1);
    }

    #[test]
    fn malformed_requests_error_loudly() {
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        s.offer(req(0, 0, 4), 0.0);
        assert!(s.admit_pending(0.0).is_err(), "empty prompt");
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(1, 65, 4), 0.0); // ctx is 64
        assert!(s.admit_pending(0.0).is_err(), "prompt beyond ctx");
    }

    #[test]
    fn ctx_filling_prompt_with_generation_budget_is_rejected() {
        // Regression: a prompt at exactly ctx with max_new_tokens > 0
        // used to be admitted and silently completed with zero tokens;
        // it must now error loudly at admission.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(0, 64, 4), 0.0); // ctx is 64
        let err = s.admit_pending(0.0).unwrap_err().to_string();
        assert!(err.contains("no room"),
                "want the no-room-to-generate error, got: {err}");
        // The degenerate-but-honest case stays accepted: a ctx-long
        // prompt that asks for nothing completes at admission.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 999)).unwrap();
        s.offer(req(1, 64, 0), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        assert_eq!(s.done().len(), 1);
    }

    #[test]
    fn kv_pricing_charges_prefill_then_one_token_per_step() {
        let mut c = cfg(SchedMode::Continuous, 4, 64);
        c.kv_cache = true;
        let mut s = Scheduler::new(c).unwrap();
        s.offer(req(0, 6, 3), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        // Prefill step: the whole prompt is uncached.
        let batch = s.microbatch();
        assert_eq!(s.step_tokens(&batch), 6);
        assert_eq!(s.live_tokens(), 6);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 1.0, 1).unwrap();
        // Decode steps: exactly one uncached token per live sequence.
        assert_eq!(s.live()[0].cached_len, 6);
        let batch = s.microbatch();
        assert_eq!(s.step_tokens(&batch), 1,
                   "cached decode must cost 1 token");
        assert_eq!(s.live_tokens(), 1);
        let ids = s.live()[0].ids.clone();
        s.complete_step(&batch, &[fake_next(&ids)], 2.0, 1).unwrap();
        assert_eq!(s.live()[0].cached_len, 7);
    }

    #[test]
    fn kv_budget_admits_deeper_batches_than_recompute() {
        // Budget 10, prompts of 4 for 3 new tokens each. Recompute
        // pricing fits two live sequences; KV pricing fits the same two
        // at prefill but frees 3 tokens of budget the moment they decode
        // (cost 1 each), so the third request is admitted mid-flight.
        let run = |kv: bool| {
            let mut c = cfg(SchedMode::Continuous, 8, 10);
            c.kv_cache = kv;
            let arrivals: Vec<(Request, f64)> =
                (0..3).map(|id| (req(id, 4, 3), 0.0)).collect();
            simulate_serve(c, arrivals, fake_step, |_, _| 1.0)
                .unwrap()
                .1
        };
        let kv = run(true);
        let re = run(false);
        assert_eq!(kv.generated_tokens, re.generated_tokens);
        let wait = |m: &ServeMetrics| {
            m.per_request.iter().find(|t| t.id == 2).unwrap().queue_wait
        };
        assert!(wait(&kv) < wait(&re),
                "cached pricing must admit request 2 sooner: {} !< {}",
                wait(&kv), wait(&re));
    }

    #[test]
    fn kv_counters_split_computed_from_cached() {
        // One request, prompt P = 5, N = 4 new tokens, loose budget.
        // Computed = P + (N - 1) (prefill plus one per later step);
        // cached = sum of the prefix lengths served from cache.
        let mut c = cfg(SchedMode::Continuous, 4, 999);
        c.kv_cache = true;
        let (_, m) = simulate_serve(
            c,
            vec![(req(0, 5, 4), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(m.computed_tokens, 5 + 3);
        // Steps feed prefixes of length 5, 6, 7, 8; all but the last
        // token of each post-prefill step come from the cache.
        assert_eq!(m.cached_tokens, 5 + 6 + 7);
        assert!(m.cache_hit_rate() > 0.6);

        // Recompute pricing: everything is computed, nothing cached.
        let (_, m) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 999),
            vec![(req(0, 5, 4), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(m.computed_tokens, 5 + 6 + 7 + 8);
        assert_eq!(m.cached_tokens, 0);
        assert_eq!(m.cache_hit_rate(), 0.0);
    }

    #[test]
    fn retired_ids_are_reported_for_cache_eviction() {
        let mut evicted: Vec<u64> = Vec::new();
        let (responses, _) = simulate_serve_with(
            cfg(SchedMode::Continuous, 4, 64),
            (0..3).map(|id| (req(id, 4, 2), 0.0)).collect(),
            fake_step,
            |_, _| 1.0,
            |id| evicted.push(id),
        )
        .unwrap();
        assert_eq!(responses.len(), 3);
        evicted.sort_unstable();
        assert_eq!(evicted, vec![0, 1, 2],
                   "every retired request must be reported exactly once");
    }

    #[test]
    fn sequences_truncate_at_ctx() {
        let mut c = cfg(SchedMode::Continuous, 2, 64);
        c.ctx = 6;
        let (responses, _) = simulate_serve(
            c,
            vec![(req(0, 4, 100), 0.0)],
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(responses[0].tokens.len(), 2, "4 + 2 == ctx");
    }

    #[test]
    fn simulate_serve_completes_everything_and_times_the_clock() {
        let arrivals: Vec<(Request, f64)> =
            (0..5).map(|id| (req(id, 5, 3), 0.0)).collect();
        let (responses, metrics) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 40),
            arrivals,
            fake_step,
            |tokens, _| tokens as f64 * 1e-3,
        )
        .unwrap();
        assert_eq!(responses.len(), 5);
        assert!(responses.windows(2).all(|w| w[0].id < w[1].id));
        for r in &responses {
            assert_eq!(r.tokens.len(), 3);
        }
        assert_eq!(metrics.generated_tokens, 15);
        assert_eq!(metrics.per_request.len(), 5);
        assert_eq!(metrics.ttft.len(), 5);
        assert_eq!(metrics.tpot.len(), 5);
        assert!(metrics.wall_time > 0.0);
        assert!(metrics.steps > 0);
        assert!(metrics.dispatch_rounds > 0);
        assert!(metrics.queue_wait.iter().all(|&w| w >= 0.0));
    }

    #[test]
    fn step_budget_is_respected_throughout_the_run() {
        // Every step's token count stays under the budget (prompts are
        // all below it, so the at-least-one escape never triggers).
        let arrivals: Vec<(Request, f64)> =
            (0..8).map(|id| (req(id, 10, 6), 0.0)).collect();
        let mut step_sizes: Vec<usize> = Vec::new();
        let (responses, _) = simulate_serve(
            cfg(SchedMode::Continuous, 8, 25),
            arrivals,
            |seqs| {
                step_sizes
                    .push(seqs.iter().map(|(_, ids, _)| ids.len()).sum());
                fake_step(seqs)
            },
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(responses.len(), 8);
        assert!(!step_sizes.is_empty());
        assert!(step_sizes.iter().all(|&t| t <= 25),
                "budget violated: {step_sizes:?}");
    }

    #[test]
    fn priority_jumps_the_admission_queue() {
        // A later class-0 offer is admitted ahead of an earlier
        // class-1 offer; equal classes stay strictly FIFO.
        let mut s =
            Scheduler::new(cfg(SchedMode::Continuous, 4, 64)).unwrap();
        s.offer(preq(0, 4, 2, 1), 0.0);
        s.offer(preq(1, 4, 2, 0), 0.1);
        assert!(s.admit_pending(0.2).unwrap());
        assert_eq!(s.live()[0].req.id, 1, "class 0 jumps the queue");
        assert!(s.admit_pending(0.2).unwrap());
        assert_eq!(s.live()[1].req.id, 0);
    }

    #[test]
    fn preemption_evicts_deepest_lower_priority_decode() {
        let mut c = cfg(SchedMode::Continuous, 2, 20);
        c.preempt = true;
        let mut s = Scheduler::new(c).unwrap();
        // Two class-1 decodes of different depths.
        s.offer(preq(0, 8, 20, 1), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        s.offer(preq(1, 6, 20, 1), 0.0);
        assert!(s.admit_pending(0.0).unwrap());
        for t in 0..2 {
            let batch = s.microbatch();
            let next: Vec<i32> = batch
                .iter()
                .map(|&i| fake_next(&s.live()[i].ids))
                .collect();
            s.complete_step(&batch, &next, t as f64 + 1.0, 1).unwrap();
        }
        assert_eq!(s.live()[0].ids.len(), 10);
        assert_eq!(s.live()[1].ids.len(), 8);
        // A class-0 arrival needs both a slot and budget: the deepest
        // class-1 decode (request 0) is evicted, the shallower stays.
        s.offer(preq(2, 10, 2, 0), 2.0);
        assert!(s.admit_pending(2.0).unwrap());
        assert_eq!(s.preempted().len(), 1);
        assert_eq!(s.preempted()[0].req.id, 0, "deepest decode evicted");
        assert_eq!(s.preempted()[0].phase, SeqPhase::Preempted);
        assert_eq!(s.preempted()[0].preemptions, 1);
        assert_eq!(s.preemptions(), 1);
        let live_ids: Vec<u64> =
            s.live().iter().map(|q| q.req.id).collect();
        assert_eq!(live_ids, vec![1, 2]);
        let events = s.take_events();
        assert!(events.contains(&SchedEvent::Preempted {
            id: 0,
            cache_dropped: false, // recompute pricing holds no cache
        }), "events: {events:?}");
        // No resume yet: request 0 (cost 10) over budget next to the
        // live pair.
        assert!(!s.admit_pending(2.5).unwrap());
        // Drain the live batch, then the victim resumes and finishes.
        while !s.live().is_empty() {
            let batch = s.microbatch();
            let next: Vec<i32> = batch
                .iter()
                .map(|&i| fake_next(&s.live()[i].ids))
                .collect();
            s.complete_step(&batch, &next, 3.0, 1).unwrap();
            while s.admit_pending(3.0).unwrap() {}
        }
        assert!(s.preempted().is_empty(), "victim resumed");
        assert_eq!(s.resumes(), 1);
        let events = s.take_events();
        assert!(events.contains(&SchedEvent::Resumed { id: 0 }));
        assert_eq!(s.done().len(), 3);
    }

    #[test]
    fn preempted_cache_retained_under_cap_dropped_over_it() {
        let mut c = cfg(SchedMode::Continuous, 2, 30);
        c.kv_cache = true;
        c.preempt = true;
        c.retain_cache_tokens = 10;
        let mut s = Scheduler::new(c).unwrap();
        for (id, prompt) in [(0u64, 8usize), (1, 12)] {
            s.offer(preq(id, prompt, 20, 1), 0.0);
            assert!(s.admit_pending(0.0).unwrap());
            let batch = s.microbatch();
            let next: Vec<i32> = batch
                .iter()
                .map(|&i| fake_next(&s.live()[i].ids))
                .collect();
            s.complete_step(&batch, &next, 1.0, 1).unwrap();
        }
        // Caches: request 0 holds 9 rows, request 1 holds 12. The
        // first class-0 arrival evicts the deepest victim (request 1,
        // 12 rows > the 10-token retain cap → cache dropped); a second
        // class-0 arrival evicts request 0 (9 rows ≤ cap → retained).
        s.offer(preq(2, 28, 2, 0), 2.0);
        assert!(s.admit_pending(2.0).unwrap());
        let events = s.take_events();
        assert!(events.contains(&SchedEvent::Preempted {
            id: 1,
            cache_dropped: true,
        }), "over-cap cache dropped: {events:?}");
        s.offer(preq(3, 2, 1, 0), 2.0);
        assert!(s.admit_pending(2.0).unwrap());
        let events = s.take_events();
        assert!(events.contains(&SchedEvent::Preempted {
            id: 0,
            cache_dropped: false,
        }), "under-cap cache retained: {events:?}");
        let by_id = |id: u64| {
            s.preempted().iter().find(|q| q.req.id == id).unwrap()
        };
        assert_eq!(by_id(1).cached_len, 0, "dropped cache zeroed");
        assert_eq!(by_id(0).cached_len, 9, "retained cache kept");
    }

    #[test]
    fn slo_sheds_late_requests_loudly() {
        // Serial capacity (budget == prompt) with a 0.5 s deadline:
        // request 0 admits at t = 0; by the time it retires the rest
        // have blown the deadline and are shed, not served.
        let mut c = cfg(SchedMode::Continuous, 4, 4);
        c.ttft_slo = vec![0.5];
        let arrivals: Vec<(Request, f64)> =
            (0..3).map(|id| (req(id, 4, 2), 0.0)).collect();
        let mut shed: Vec<u64> = Vec::new();
        let (responses, metrics) = simulate_serve_events(
            c,
            arrivals,
            fake_step,
            |_, _| 1.0,
            |e| {
                if let SchedEvent::Rejected { id } = e {
                    shed.push(*id);
                }
            },
        )
        .unwrap();
        assert_eq!(responses.len(), 1, "only request 0 served");
        assert_eq!(responses[0].id, 0);
        assert_eq!(metrics.rejected, vec![1, 2]);
        shed.sort_unstable();
        assert_eq!(shed, vec![1, 2]);
        // The shed property: every *served* request met its deadline.
        assert!(metrics.per_request.iter().all(|t| t.queue_wait <= 0.5));
        // No SLO vector, no shedding: same trace serves everyone.
        let (responses, metrics) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 4),
            (0..3).map(|id| (req(id, 4, 2), 0.0)).collect(),
            fake_step,
            |_, _| 1.0,
        )
        .unwrap();
        assert_eq!(responses.len(), 3);
        assert!(metrics.rejected.is_empty());
    }

    #[test]
    fn uniform_priority_preempt_on_matches_off() {
        // With every request in the same class there is never a
        // strictly-lower-priority victim, so preemption must be a
        // no-op: token-for-token and metric-for-metric identical.
        let run = |preempt: bool| {
            let c = SchedConfig {
                preempt,
                ..cfg(SchedMode::Continuous, 4, 24)
            };
            let arrivals: Vec<(Request, f64)> = (0..6)
                .map(|id| (req(id, 5, 4), 0.3 * id as f64))
                .collect();
            simulate_serve(c, arrivals, fake_step, |_, _| 0.25).unwrap()
        };
        let (resp_on, m_on) = run(true);
        let (resp_off, m_off) = run(false);
        assert_eq!(m_on.preemptions, 0);
        for (a, b) in resp_on.iter().zip(&resp_off) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens);
        }
        assert_eq!(m_on.per_request.len(), m_off.per_request.len());
        for (a, b) in m_on.per_request.iter().zip(&m_off.per_request) {
            assert_eq!(a.queue_wait, b.queue_wait);
            assert_eq!(a.ttft, b.ttft);
        }
    }

    #[test]
    fn static_drain_never_preempts() {
        let c = SchedConfig {
            preempt: true,
            ..cfg(SchedMode::StaticDrain, 2, 8)
        };
        let arrivals: Vec<(Request, f64)> = vec![
            (preq(0, 8, 6, 1), 0.0),
            (preq(1, 4, 2, 0), 1.0),
        ];
        let (responses, metrics) =
            simulate_serve(c, arrivals, fake_step, |_, _| 1.0).unwrap();
        assert_eq!(responses.len(), 2);
        assert_eq!(metrics.preemptions, 0, "preempt inert under drain");
        assert_eq!(metrics.resumes, 0);
    }
}
