//! Fleet sharding: one admission front-end over N independent
//! [`MoEServer`] replicas.
//!
//! A single machine tops out at one scheduler and one placement copy;
//! GRACE-MoE's evaluation assumes the serving system scales *out*. This
//! module is the scale-out seam: [`FleetFrontend`] holds N fully
//! independent replicas — each with its own `Placement` copy,
//! dispatcher, KV caches, and executor thread pool — routes every
//! admitted request to exactly one of them through a pluggable
//! [`FleetRoutePolicy`], and runs the replicas on real threads
//! (`std::thread::scope`), so wall-clock throughput actually scales
//! with replica count in PJRT mode.
//!
//! The same split exists in simulation: `engine::fleet` builds the
//! virtual-clock analogue (deterministic min-clock interleave of N
//! shards, rolling epoch re-plans through
//! [`crate::replan::RollingReplan`]) from the same [`ShardConfig`] and
//! [`FleetRouter`], so routing policies and validation are pinned once
//! here and exercised identically in both worlds.
//!
//! Route policies:
//!
//! * **jsq** — join-shortest-queue by *outstanding tokens* (prompt +
//!   requested decode tokens still in flight), the classic latency
//!   workhorse.
//! * **wrr** — weighted round-robin; with a homogeneous fleet the
//!   weights are uniform, so this is plain round-robin (the baseline
//!   that ignores load).
//! * **affinity** — placement-affinity: score each replica by how much
//!   of the request's class-predicted hot-expert mass
//!   ([`ClassProfiles`], per-class [`LoadEstimator`] gate profiles) is
//!   locally replicated, and fall back to jsq until profiles warm up.

use crate::metrics::ServeMetrics;
use crate::placement::Placement;
use crate::routing::load::LoadEstimator;
use crate::routing::LoadAware;
use crate::server::{MoEServer, Request, Response};

/// How the fleet front-end picks a replica for each admitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetRoutePolicy {
    /// Join-shortest-queue by outstanding tokens (the default).
    Jsq,
    /// Weighted round-robin (uniform weights on a homogeneous fleet).
    Wrr,
    /// Placement-affinity: prefer the replica whose placement holds the
    /// most instances of the request class's predicted hot experts;
    /// falls back to [`FleetRoutePolicy::Jsq`] until the class profile
    /// has observed at least one dispatch round.
    Affinity,
}

impl FleetRoutePolicy {
    /// Parse a `--fleet-route` name. Unknown names are a loud error
    /// listing the valid spellings — a typo must not silently fall back
    /// to the default policy.
    pub fn from_name(name: &str) -> anyhow::Result<FleetRoutePolicy> {
        match name {
            "jsq" => Ok(FleetRoutePolicy::Jsq),
            "wrr" => Ok(FleetRoutePolicy::Wrr),
            "affinity" => Ok(FleetRoutePolicy::Affinity),
            other => anyhow::bail!(
                "unknown fleet route policy '{other}' \
                 (expected jsq|wrr|affinity)"
            ),
        }
    }

    /// The CLI spelling of this policy.
    pub fn name(&self) -> &'static str {
        match self {
            FleetRoutePolicy::Jsq => "jsq",
            FleetRoutePolicy::Wrr => "wrr",
            FleetRoutePolicy::Affinity => "affinity",
        }
    }
}

/// Fleet-level tunables shared by the threaded front-end and the
/// virtual-clock fleet replay.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Number of independent `MoEServer` replicas (≥ 1).
    pub replicas: usize,
    /// The route policy picking a replica per admitted request.
    pub route: FleetRoutePolicy,
    /// Fleet-wide admission queue capacity: requests beyond it are shed
    /// (rejected) instead of queued, the bounded-ingress discipline.
    pub queue_cap: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            replicas: 1,
            route: FleetRoutePolicy::Jsq,
            queue_cap: 64,
        }
    }
}

impl ShardConfig {
    /// Reject fleet shapes that would silently serve nothing or wedge:
    /// zero replicas is a fleet of nothing, and a queue smaller than the
    /// fleet cannot even hold one request per replica.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.replicas >= 1,
            "ShardConfig: --replicas 0 would shard the fleet into \
             nothing — every request would be shed"
        );
        anyhow::ensure!(
            self.queue_cap >= 1,
            "ShardConfig: queue_cap = 0 leaves no room to admit"
        );
        anyhow::ensure!(
            self.queue_cap >= self.replicas,
            "ShardConfig: queue capacity {} < {} replicas — the \
             admission queue cannot even hold one request per replica; \
             raise --queue-cap or lower --replicas",
            self.queue_cap,
            self.replicas
        );
        Ok(())
    }
}

/// The routing decision engine: stateless for jsq, a rotating cursor
/// for wrr, and affinity scores (when provided) for the affinity
/// policy. One instance is shared fleet-wide; decisions are
/// deterministic given the call sequence.
#[derive(Clone, Debug)]
pub struct FleetRouter {
    policy: FleetRoutePolicy,
    rr: usize,
}

impl FleetRouter {
    /// A fresh router for `policy` (wrr cursor at replica 0).
    pub fn new(policy: FleetRoutePolicy) -> FleetRouter {
        FleetRouter { policy, rr: 0 }
    }

    /// The policy this router runs.
    pub fn policy(&self) -> FleetRoutePolicy {
        self.policy
    }

    /// Pick the replica for one request. `outstanding[r]` is replica
    /// r's in-flight token load; `affinity`, when present, is the
    /// per-replica placement-affinity score for the request's class.
    /// Ties break to the lowest replica index so the decision — and
    /// with it the whole virtual-clock fleet replay — is deterministic.
    pub fn choose(&mut self, outstanding: &[f64],
                  affinity: Option<&[f64]>) -> usize {
        debug_assert!(!outstanding.is_empty());
        match self.policy {
            FleetRoutePolicy::Jsq => argmin(outstanding),
            FleetRoutePolicy::Wrr => {
                let pick = self.rr % outstanding.len();
                self.rr += 1;
                pick
            }
            FleetRoutePolicy::Affinity => {
                let scores = affinity.filter(|s| {
                    s.len() == outstanding.len()
                        && s.iter().any(|&v| v > 0.0)
                });
                match scores {
                    // Highest affinity wins; among tied-best replicas
                    // prefer the least-loaded, then the lowest index.
                    Some(s) => {
                        let best = s.iter().cloned().fold(f64::MIN, f64::max);
                        (0..s.len())
                            .filter(|&r| s[r] == best)
                            .min_by(|&a, &b| {
                                outstanding[a]
                                    .partial_cmp(&outstanding[b])
                                    .unwrap_or(std::cmp::Ordering::Equal)
                                    .then(a.cmp(&b))
                            })
                            .unwrap_or(0)
                    }
                    // Cold profiles: fall back to jsq.
                    None => argmin(outstanding),
                }
            }
        }
    }
}

/// Lowest index attaining the minimum (deterministic tie-break).
fn argmin(xs: &[f64]) -> usize {
    let mut best = 0usize;
    for (i, &x) in xs.iter().enumerate().skip(1) {
        if x < xs[best] {
            best = i;
        }
    }
    best
}

/// Per-priority-class gate profiles for affinity routing: one smoothed
/// [`LoadEstimator`] per class, fed from observed dispatch plans, read
/// back as a per-replica placement-affinity score — "how much of this
/// class's hot-expert mass does replica r hold locally-replicated?".
#[derive(Debug)]
pub struct ClassProfiles {
    ests: Vec<LoadEstimator>,
}

impl ClassProfiles {
    /// Profiles for `classes` priority classes (at least one).
    pub fn new(classes: usize) -> ClassProfiles {
        let n = classes.max(1);
        ClassProfiles {
            ests: (0..n)
                .map(|_| LoadEstimator::new(LoadAware::DEFAULT_ALPHA))
                .collect(),
        }
    }

    /// Number of classes tracked.
    pub fn classes(&self) -> usize {
        self.ests.len()
    }

    /// Record one routed token copy for `class` (out-of-range classes
    /// clamp to the last profile, mirroring request-priority clamping).
    pub fn observe(&mut self, class: usize, layer: usize,
                   lp: &crate::placement::LayerPlacement, expert: usize) {
        let c = class.min(self.ests.len() - 1);
        self.ests[c].record(layer, lp, expert);
    }

    /// Close one dispatch round on every class profile (classes that
    /// saw no tokens this round are unchanged).
    pub fn end_round(&mut self, layer: usize, n_gpus: usize,
                     experts: usize) {
        for est in &mut self.ests {
            est.end_round(layer, n_gpus, experts);
        }
    }

    /// Placement-affinity score of `placement` for `class`: the
    /// class-predicted per-expert load weighted by how many instances
    /// the placement hosts of each expert, summed over layers. More
    /// local replicas of the class's hot experts ⇒ higher score; a cold
    /// profile scores 0.0 (routers fall back to jsq).
    pub fn score(&self, placement: &Placement, class: usize) -> f64 {
        let c = class.min(self.ests.len() - 1);
        let mut s = 0.0;
        for (layer, lp) in placement.layers.iter().enumerate() {
            if let Some(loads) = self.ests[c].expert_loads(layer) {
                for (e, &w) in loads.iter().enumerate() {
                    if e < lp.instances.len() {
                        s += w * lp.instances[e].len() as f64;
                    }
                }
            }
        }
        s
    }
}

/// The threaded fleet front-end for PJRT (execute) mode: routes a
/// closed workload across its replicas, then serves every replica's
/// share on its own OS thread and merges the results.
///
/// Each replica is a full [`MoEServer`] — own placement copy, own
/// dispatcher/coordinator (and with it an independent re-planner when
/// configured: replicas re-plan on their own observations rather than
/// through a global barrier), own KV caches and executor pool. The
/// routing pre-pass uses outstanding-token jsq/wrr; the affinity policy
/// needs warm gate profiles, which a closed one-shot workload does not
/// have, so it routes through its documented jsq fallback here (the
/// virtual-clock fleet replay in `engine::fleet` exercises the warm
/// path).
pub struct FleetFrontend {
    replicas: Vec<MoEServer>,
    cfg: ShardConfig,
}

impl FleetFrontend {
    /// A front-end over `replicas` (one `MoEServer` each, already
    /// built). Validates the shard config and that the replica vector
    /// matches `cfg.replicas`.
    pub fn new(replicas: Vec<MoEServer>, cfg: ShardConfig)
               -> anyhow::Result<FleetFrontend> {
        cfg.validate()?;
        anyhow::ensure!(
            replicas.len() == cfg.replicas,
            "FleetFrontend: {} replica servers built but cfg.replicas \
             = {}",
            replicas.len(),
            cfg.replicas
        );
        Ok(FleetFrontend { replicas, cfg })
    }

    /// The replica servers (test/inspection handle).
    pub fn replicas(&self) -> &[MoEServer] {
        &self.replicas
    }

    /// Serve a closed workload across the fleet: requests beyond the
    /// fleet admission queue capacity are shed up front (their ids are
    /// returned in each metrics' `rejected` via the merged report),
    /// the rest are routed one-by-one through the [`FleetRouter`], and
    /// every replica serves its share on its own thread. Responses come
    /// back sorted by request id; metrics are the fleet-wide merge plus
    /// the per-replica breakdown.
    pub fn serve(&mut self, requests: Vec<Request>)
                 -> anyhow::Result<(Vec<Response>, ServeMetrics,
                                    Vec<ServeMetrics>)> {
        self.cfg.validate()?;
        let n = self.replicas.len();
        let mut router = FleetRouter::new(self.cfg.route);
        let mut outstanding = vec![0.0f64; n];
        let mut shares: Vec<Vec<Request>> = vec![Vec::new(); n];
        let mut shed: Vec<u64> = Vec::new();
        for (i, req) in requests.into_iter().enumerate() {
            if i >= self.cfg.queue_cap {
                shed.push(req.id);
                continue;
            }
            let r = router.choose(&outstanding, None);
            outstanding[r] +=
                (req.prompt.len() + req.max_new_tokens) as f64;
            shares[r].push(req);
        }

        // One OS thread per replica: scoped so the borrows of
        // `self.replicas` need no 'static, joined before returning so a
        // replica error surfaces after every thread has stopped.
        let results: Vec<anyhow::Result<(Vec<Response>, ServeMetrics)>> =
            std::thread::scope(|scope| {
                let handles: Vec<_> = self
                    .replicas
                    .iter_mut()
                    .zip(shares)
                    .map(|(srv, share)| {
                        scope.spawn(move || srv.serve(share))
                    })
                    .collect();
                handles.into_iter().map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err(anyhow::anyhow!("fleet replica panicked"))
                    })
                }).collect()
            });

        let mut responses = Vec::new();
        let mut per_replica = Vec::with_capacity(n);
        for res in results {
            let (rs, m) = res?;
            responses.extend(rs);
            per_replica.push(m);
        }
        responses.sort_by_key(|r| r.id);
        let mut merged = ServeMetrics::default();
        for m in &per_replica {
            merged.merge(m);
        }
        merged.rejected.extend(shed);
        merged.rejected.sort_unstable();
        merged.per_request.sort_by_key(|t| t.id);
        Ok((responses, merged, per_replica))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::profile::ModelProfile;
    use crate::trace::{Profile, TraceGen};

    #[test]
    fn route_policy_names_round_trip_and_typos_are_loud() {
        for p in [FleetRoutePolicy::Jsq, FleetRoutePolicy::Wrr,
                  FleetRoutePolicy::Affinity]
        {
            assert_eq!(FleetRoutePolicy::from_name(p.name()).unwrap(), p);
        }
        let err = FleetRoutePolicy::from_name("jqs").unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("jqs"), "{msg}");
        assert!(msg.contains("jsq|wrr|affinity"), "{msg}");
    }

    #[test]
    fn zero_replicas_and_tiny_queues_are_loud_errors() {
        // Regression: --replicas 0 must refuse at config time, not shed
        // the whole workload at runtime.
        let cfg = ShardConfig { replicas: 0, ..ShardConfig::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("--replicas 0"), "{err}");

        let cfg = ShardConfig {
            replicas: 4,
            queue_cap: 3,
            ..ShardConfig::default()
        };
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("queue capacity 3 < 4"),
                "{err}");

        let cfg = ShardConfig {
            replicas: 1,
            queue_cap: 0,
            ..ShardConfig::default()
        };
        assert!(cfg.validate().is_err());
        assert!(ShardConfig::default().validate().is_ok());
    }

    #[test]
    fn jsq_picks_least_outstanding_with_low_index_ties() {
        let mut r = FleetRouter::new(FleetRoutePolicy::Jsq);
        assert_eq!(r.choose(&[3.0, 1.0, 2.0], None), 1);
        assert_eq!(r.choose(&[5.0, 2.0, 2.0], None), 1);
        assert_eq!(r.choose(&[0.0, 0.0, 0.0], None), 0);
    }

    #[test]
    fn wrr_rotates_regardless_of_load() {
        let mut r = FleetRouter::new(FleetRoutePolicy::Wrr);
        let picks: Vec<usize> =
            (0..5).map(|_| r.choose(&[9.0, 0.0, 0.0], None)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1]);
    }

    #[test]
    fn affinity_prefers_high_scores_and_falls_back_cold() {
        let mut r = FleetRouter::new(FleetRoutePolicy::Affinity);
        // Warm scores: highest affinity wins even against lower load.
        assert_eq!(r.choose(&[0.0, 9.0], Some(&[1.0, 5.0])), 1);
        // Tied-best scores: least outstanding breaks the tie.
        assert_eq!(r.choose(&[9.0, 2.0, 5.0], Some(&[3.0, 3.0, 1.0])), 1);
        // Cold (all-zero) scores and missing scores: jsq fallback.
        assert_eq!(r.choose(&[4.0, 1.0], Some(&[0.0, 0.0])), 1);
        assert_eq!(r.choose(&[4.0, 1.0], None), 1);
    }

    fn two_gpu_placement(seed: u64) -> Placement {
        let t = TraceGen {
            experts: 8,
            top_k: 2,
            layers: 1,
            profile: Profile::Math,
            seed,
        }
        .generate(256);
        let mp = ModelProfile::from_trace(&t);
        let topo = Topology::two_by_two();
        let mut rng = crate::stats::Rng::new(1);
        Placement::build(
            &mp,
            crate::placement::ReplicationMode::None,
            |lp| crate::grouping::hierarchical(lp, &topo, 0.15, &mut rng),
        )
    }

    #[test]
    fn class_profiles_score_replicated_hot_experts_higher() {
        let base = two_gpu_placement(3);
        let mut profiles = ClassProfiles::new(2);
        // Cold profiles score zero everywhere (jsq-fallback regime).
        assert_eq!(profiles.score(&base, 0), 0.0);

        // Class 0 hammers expert 0; close the round so the estimator
        // publishes per-expert loads.
        for _ in 0..32 {
            profiles.observe(0, 0, &base.layers[0], 0);
        }
        profiles.observe(0, 0, &base.layers[0], 1);
        profiles.end_round(0, base.num_gpus, base.experts);

        // A replica that replicates expert 0 onto a second GPU holds
        // more of class 0's hot mass than the base placement.
        let mut replicated = base.clone();
        let other = 1 - replicated.layers[0].primary[0];
        replicated.layers[0].instances[0].push(other);
        let s_base = profiles.score(&base, 0);
        let s_rep = profiles.score(&replicated, 0);
        assert!(s_base > 0.0);
        assert!(s_rep > s_base,
                "replicating the hot expert must raise the score \
                 ({s_rep} vs {s_base})");
        // Class 1 never observed anything: still cold.
        assert_eq!(profiles.score(&base, 1), 0.0);
        // Out-of-range classes clamp instead of panicking.
        assert_eq!(profiles.score(&base, 7), profiles.score(&base, 1));
    }
}
