//! Serving front: request queue → dynamic batcher → prefill/decode
//! scheduler over the distributed MoE engine (execute mode).
//!
//! Shape follows the vLLM-router architecture: an admission queue with
//! backpressure ([`crate::exec::BoundedQueue`]), a batching loop that
//! drains up to `max_batch` requests per round, and a scheduler that runs
//! prefill then iterative greedy decode. Every token's MoE layers flow
//! through the same placement/routing machinery the paper describes;
//! python is never touched.
//!
//! With [`ServerConfig::replan`] set, the server closes the re-planning
//! loop online: every dispatched plan feeds the coordinator's
//! [`crate::replan::Replanner`], and *between* batch drains — never
//! mid-dispatch-round — an epoch tick may hot-swap the placement. The
//! executor stages the new replicas' weights before the swap
//! ([`DistributedMoE::apply_replan`]), so migration cost is paid where a
//! real deployment pays it.

use crate::cluster::{GpuId, Topology};
use crate::coordinator::OnlineCoordinator;
use crate::engine::real::{DistributedMoE, FfnMode, RealModel};
use crate::exec::BoundedQueue;
use crate::metrics::ServeMetrics;
use crate::placement::Placement;
use crate::replan::{self, CostParams, ReplanConfig, Replanner};
use crate::routing::{DispatchPlan, RoutingPolicy};
use crate::stats::Rng;
use std::sync::Arc;
use std::time::Instant;

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen request id (responses are sorted by it).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Tokens to generate (greedy decode).
    pub max_new_tokens: usize,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// Generated token ids (prompt excluded).
    pub tokens: Vec<i32>,
    /// End-to-end latency (enqueue → completion), seconds.
    pub latency: f64,
}

/// Server tunables.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Requests drained per batching round.
    pub max_batch: usize,
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Seed of the serving-side RNG (routing randomness).
    pub seed: u64,
    /// FFN executable for the serving hot path (§Perf): the dense
    /// per-expert XLA path is ~6× faster than the Pallas kernel under
    /// CPU interpret with identical numerics.
    pub ffn_mode: FfnMode,
    /// Epoch re-planning cadence/gates; `None` (the default) serves the
    /// offline placement statically.
    pub replan: Option<ReplanConfig>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            queue_cap: 64,
            seed: 7,
            ffn_mode: FfnMode::PerExpert,
            replan: None,
        }
    }
}

/// The serving engine: owns the model + placement and drains a queue.
/// All routing decisions flow through the online half of the L3
/// coordinator ([`OnlineCoordinator`]) — the serving surface has no
/// offline methods, so a server can never rebuild a placement that
/// disagrees with the one it was handed.
pub struct MoEServer {
    /// The loaded tiny model (shared with the executor).
    pub model: Arc<RealModel>,
    /// The placement being served; re-planning swaps it between batch
    /// drains, so readers see the currently-active plan.
    pub placement: Arc<Placement>,
    /// The online coordination surface (policy, topology, re-planner).
    pub coord: OnlineCoordinator,
    /// Server tunables.
    pub cfg: ServerConfig,
}

impl MoEServer {
    /// Serve a prebuilt placement under `policy` on `topo` (see
    /// [`MoEServer::with_coordinator`] when the caller already owns the
    /// coordinator that built the placement).
    pub fn new(model: Arc<RealModel>, placement: Arc<Placement>,
               topo: Topology, policy: RoutingPolicy,
               cfg: ServerConfig) -> MoEServer {
        Self::with_coordinator(model, placement,
                               OnlineCoordinator::new(topo, policy), cfg)
    }

    /// Serve with an explicit coordinator — normally (the online half of)
    /// the one whose offline phase produced `placement`. When the config
    /// enables re-planning and the coordinator does not already carry a
    /// re-planner, one is attached with the tiny-model cost parameters.
    pub fn with_coordinator(model: Arc<RealModel>,
                            placement: Arc<Placement>,
                            coord: impl Into<OnlineCoordinator>,
                            cfg: ServerConfig) -> MoEServer {
        let mut coord = coord.into();
        if let Some(rc) = cfg.replan {
            if coord.replanner().is_none() {
                let replanner = Replanner::new(
                    coord.topo().clone(),
                    rc,
                    CostParams::tiny(&model.cfg),
                );
                coord = coord.with_replanner(replanner);
            }
        }
        MoEServer { model, placement, coord, cfg }
    }

    /// Full greedy forward of one sequence: returns the next token id.
    /// Every dispatched layer plan is reported through `observe`
    /// (layer index + plan) so the serving loop can feed the re-planner
    /// without the executor knowing about it.
    fn next_token(model: &RealModel, n_gpus: usize,
                  dist: &mut DistributedMoE<'_>, ids: &[i32],
                  rng: &mut Rng,
                  observe: &mut dyn FnMut(usize, &DispatchPlan))
                  -> anyhow::Result<i32> {
        let c = &model.cfg;
        anyhow::ensure!(ids.len() <= c.ctx,
                        "sequence exceeds ctx {}", c.ctx);
        let mut padded = ids.to_vec();
        padded.resize(c.ctx, 0);
        let mut x = model.embed(&padded)?;
        for l in 0..c.layers {
            x = model.attention(&x, l, ids.len())?;
            // MoE over the valid prefix, tile by tile.
            let tiles = ids.len().div_ceil(c.tile_t);
            for tile in 0..tiles {
                let s = tile * c.tile_t * c.hidden;
                let e = s + c.tile_t * c.hidden;
                let run = dist.moe_layer(
                    &x[s..e],
                    l,
                    &|t| even_src(tile * c.tile_t + t, ids.len(), n_gpus),
                    rng,
                )?;
                x[s..e].copy_from_slice(&run.y);
                observe(l, &run.plan);
            }
        }
        let logits = model.lmhead(&x)?;
        let c_v = c.vocab;
        let last = ids.len() - 1;
        let row = &logits[last * c_v..(last + 1) * c_v];
        let mut best = 0usize;
        for (i, &v) in row.iter().enumerate() {
            if v > row[best] {
                best = i;
            }
        }
        Ok(best as i32)
    }

    /// Serve a closed set of requests through the batching loop; returns
    /// responses (request order) and aggregate metrics.
    ///
    /// One executor (and thus one dispatcher) spans the whole drain, so
    /// a stateful policy's online load estimates accumulate across every
    /// token of every request instead of resetting per forward. Epoch
    /// re-planning (when enabled) is evaluated between batch drains:
    /// deltas stage their replica weights through the executor and then
    /// hot-swap `self.placement` — never mid-dispatch-round.
    pub fn serve(&mut self, requests: Vec<Request>)
                 -> anyhow::Result<(Vec<Response>, ServeMetrics)> {
        let queue: BoundedQueue<(Request, Instant)> =
            BoundedQueue::new(self.cfg.queue_cap);
        for r in &requests {
            queue
                .send((r.clone(), Instant::now()))
                .map_err(|_| anyhow::anyhow!("queue closed"))?;
        }
        queue.close();

        let wall0 = Instant::now();
        let mut rng = Rng::new(self.cfg.seed);
        let model = self.model.clone();
        let n_gpus = self.coord.topo().num_gpus();
        let mut dist = DistributedMoE::new(
            &model,
            self.placement.clone(),
            &self.coord,
            self.cfg.ffn_mode,
        );
        let mut responses: Vec<Response> = Vec::with_capacity(requests.len());
        let mut generated = 0usize;

        loop {
            let batch = queue.recv_batch(self.cfg.max_batch);
            if batch.is_empty() {
                break;
            }
            // Iterative decode round-robin across the batch (continuous-
            // batching lite: every sequence advances one token per step).
            let mut states: Vec<(Request, Instant, Vec<i32>)> = batch
                .into_iter()
                .map(|(r, t0)| {
                    let ids = r.prompt.clone();
                    (r, t0, ids)
                })
                .collect();
            let max_steps = states
                .iter()
                .map(|(r, _, _)| r.max_new_tokens)
                .max()
                .unwrap_or(0);
            for step in 0..max_steps {
                for (r, _, ids) in states.iter_mut() {
                    if step >= r.max_new_tokens
                        || ids.len() >= self.model.cfg.ctx
                    {
                        continue;
                    }
                    let next = Self::next_token(
                        &model,
                        n_gpus,
                        &mut dist,
                        ids,
                        &mut rng,
                        &mut |layer, plan| {
                            self.coord.observe(
                                layer,
                                &self.placement.layers[layer],
                                plan,
                            );
                        },
                    )?;
                    ids.push(next);
                    generated += 1;
                }
            }
            for (r, t0, ids) in states {
                responses.push(Response {
                    id: r.id,
                    tokens: ids[r.prompt.len()..].to_vec(),
                    latency: t0.elapsed().as_secs_f64(),
                });
            }

            // Epoch boundary between batch drains: re-plan if due.
            let delta = self.coord.epoch_tick(&self.placement);
            if !delta.is_empty() {
                let next =
                    Arc::new(replan::apply_delta(&self.placement, &delta));
                dist.apply_replan(next.clone(), &delta)?;
                self.placement = next;
            }
        }

        responses.sort_by_key(|r| r.id);
        let metrics = ServeMetrics {
            latencies: responses.iter().map(|r| r.latency).collect(),
            generated_tokens: generated,
            wall_time: wall0.elapsed().as_secs_f64(),
        };
        Ok((responses, metrics))
    }
}

/// Even data-parallel assignment of a token index to a rank — the one
/// token→rank rule every engine shares (the sim engine's chunk split and
/// the serving forward's tile walk both route through it).
///
/// `total` is the *live* population being split (e.g. the current
/// sequence length, not the padded context). Indices at or past `total`
/// (padding rows of a partially-filled tile) clamp to the last rank
/// instead of producing an out-of-range GPU id; `total == 0` maps
/// everything to rank 0.
pub fn even_src(t: usize, total: usize, n_gpus: usize) -> GpuId {
    let total = total.max(1);
    t.min(total - 1) * n_gpus / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_src_covers_all_gpus() {
        let srcs: Vec<GpuId> =
            (0..16).map(|t| even_src(t, 16, 4)).collect();
        assert_eq!(srcs[0], 0);
        assert_eq!(srcs[15], 3);
        for g in 0..4 {
            assert_eq!(srcs.iter().filter(|&&s| s == g).count(), 4);
        }
    }

    #[test]
    fn even_src_is_monotone_and_balanced_for_uneven_totals() {
        for total in 1..40usize {
            for n_gpus in 1..6usize {
                let srcs: Vec<GpuId> =
                    (0..total).map(|t| even_src(t, total, n_gpus)).collect();
                assert!(srcs.windows(2).all(|w| w[0] <= w[1]),
                        "monotone (total {total}, gpus {n_gpus})");
                assert!(srcs.iter().all(|&s| s < n_gpus), "in range");
                let mut counts = vec![0usize; n_gpus];
                for &s in &srcs {
                    counts[s] += 1;
                }
                let max = counts.iter().max().unwrap();
                let min = counts.iter().min().unwrap();
                assert!(max - min <= 1,
                        "balanced (total {total}, gpus {n_gpus}): \
                         {counts:?}");
            }
        }
    }

    #[test]
    fn even_src_boundaries_clamp_instead_of_overflowing() {
        // Padding rows past the live length land on the last rank…
        assert_eq!(even_src(10, 10, 4), 3);
        assert_eq!(even_src(63, 10, 4), 3);
        // …instead of the out-of-range ids the old inline formula
        // (dividing by the padded ctx) silently avoided only because ctx
        // bounded the index. The degenerate empty split maps to rank 0.
        assert_eq!(even_src(0, 0, 4), 0);
        assert_eq!(even_src(5, 0, 4), 0);
        // Last live index is always the last rank when total ≥ n_gpus.
        for total in 4..32usize {
            assert_eq!(even_src(total - 1, total, 4), 3);
        }
    }

    // End-to-end serving over the real model is exercised in
    // tests/integration.rs and examples/serve_end_to_end.rs (it needs the
    // AOT artifacts and a PJRT client).
}
