//! Serving front: request queue → iteration-level scheduler → batched
//! decode over the distributed MoE engine (execute mode).
//!
//! Shape follows the vLLM architecture: an admission queue with
//! backpressure ([`crate::exec::BoundedQueue`]), the continuous-batching
//! scheduler of [`sched`] (per-request state machine, token-budgeted
//! microbatches, admission and retirement at every step), and one
//! batched multi-sequence forward per step whose MoE layers pack the
//! live batch into shared dispatch tiles. By default
//! ([`ServerConfig::kv_cache`]) that forward is the KV-cached
//! [`DistributedMoE::decode_step_cached`] — one *new* token per live
//! sequence, per-sequence caches owned here (allocated at admission,
//! dropped at retirement) — with the full-recompute
//! [`DistributedMoE::decode_step`] kept behind `--kv-cache off` as the
//! parity oracle. Every token's MoE layers flow through the same
//! placement/routing machinery the paper describes; python is never
//! touched.
//!
//! Two arrival modes: [`MoEServer::serve`] is closed-loop (every request
//! enqueued up front — the benchmark workloads), and
//! [`MoEServer::serve_open_loop`] replays a timed arrival schedule
//! (e.g. Poisson via [`crate::config::ServeLoad`]) from a producer
//! thread, so TTFT and queue-wait are measured under real arrival
//! pressure.
//!
//! With [`ServerConfig::replan`] set, the server closes the re-planning
//! loop online: every dispatched plan feeds the coordinator's
//! [`crate::replan::Replanner`], and *between* decode steps — never
//! mid-dispatch-round — an epoch tick may hot-swap the placement. The
//! executor stages the new replicas' weights before the swap
//! ([`DistributedMoE::apply_replan`]), so migration cost is paid where a
//! real deployment pays it. On stationary traffic every tick is a
//! structural no-op, so the re-planned server is a pure observer
//! (`tests/replan.rs`).

pub mod sched;
pub mod shard;

use crate::cluster::{GpuId, Topology};
use crate::coordinator::OnlineCoordinator;
use crate::engine::real::{CachedSeq, DistributedMoE, FfnMode, KvCache,
                          RealModel};
use crate::exec::BoundedQueue;
use crate::metrics::ServeMetrics;
use crate::placement::Placement;
use crate::replan::{self, CostParams, ReplanConfig, Replanner};
use crate::routing::RoutingPolicy;
use crate::stats::Rng;
use std::sync::Arc;
use std::time::{Duration, Instant};

pub use sched::{SchedConfig, SchedEvent, SchedMode, Scheduler, SeqPhase,
                SeqState};

/// One inference request.
#[derive(Clone, Debug)]
pub struct Request {
    /// Caller-chosen request id (responses are sorted by it).
    pub id: u64,
    /// Prompt token ids.
    pub prompt: Vec<i32>,
    /// Tokens to generate (greedy decode).
    pub max_new_tokens: usize,
    /// Priority class, 0 = most urgent (the default). Classes order
    /// admission; with [`ServerConfig::preempt`] a higher-priority
    /// arrival may evict lower-priority decodes, and
    /// [`ServerConfig::ttft_slo`] deadlines are looked up by class.
    pub priority: usize,
}

/// Completed response.
#[derive(Clone, Debug)]
pub struct Response {
    /// The request's id.
    pub id: u64,
    /// Generated token ids (prompt excluded).
    pub tokens: Vec<i32>,
    /// End-to-end latency (enqueue → completion), seconds.
    pub latency: f64,
}

/// Server tunables.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Maximum live sequences in the batch.
    pub max_batch: usize,
    /// Step token budget of the continuous scheduler: the tokens one
    /// batched forward may *compute*. With the KV cache on that is each
    /// sequence's uncached suffix (prompt at prefill, one per step
    /// after); with it off, the sum of full sequence lengths.
    pub max_batch_tokens: usize,
    /// Batching discipline ([`SchedMode::Continuous`] is the serving
    /// core; [`SchedMode::StaticDrain`] reproduces the old drain-barrier
    /// server for comparison).
    pub sched: SchedMode,
    /// Admission queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Seed of the serving-side RNG (routing randomness).
    pub seed: u64,
    /// FFN executable for the serving hot path (§Perf): the dense
    /// per-expert XLA path is ~6× faster than the Pallas kernel under
    /// CPU interpret with identical numerics.
    pub ffn_mode: FfnMode,
    /// Epoch re-planning cadence/gates; `None` (the default) serves the
    /// offline placement statically.
    pub replan: Option<ReplanConfig>,
    /// Decode through per-sequence KV caches (`true`, the default): one
    /// new token per live sequence per step. `false` runs the
    /// full-recompute forward — kept as the parity oracle behind
    /// `--kv-cache off`; greedy outputs are identical either way.
    pub kv_cache: bool,
    /// Evict lower-priority decodes when a higher-priority arrival
    /// cannot be admitted (continuous mode only; `--preempt on`).
    pub preempt: bool,
    /// Total KV-cache tokens preempted sequences may keep warm across
    /// evictions; over the cap a victim's cache is dropped and resume
    /// re-prefills. `usize::MAX` (the default) retains everything.
    pub retain_cache_tokens: usize,
    /// Per-class TTFT deadlines, seconds, indexed by priority class
    /// (`--ttft-slo`). Empty (the default) disables SLO admission.
    pub ttft_slo: Vec<f64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 8,
            max_batch_tokens: 256,
            sched: SchedMode::Continuous,
            queue_cap: 64,
            seed: 7,
            ffn_mode: FfnMode::PerExpert,
            replan: None,
            kv_cache: true,
            preempt: false,
            retain_cache_tokens: usize::MAX,
            ttft_slo: Vec::new(),
        }
    }
}

impl ServerConfig {
    /// Reject configurations that would silently serve nothing: the old
    /// server accepted `max_batch = 0` and exited dropping every queued
    /// request; now the foot-gun is a loud error before any request is
    /// consumed.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.max_batch > 0,
            "ServerConfig: max_batch = 0 would admit no request and \
             drop the whole workload"
        );
        anyhow::ensure!(
            self.queue_cap > 0,
            "ServerConfig: queue_cap = 0 leaves no room to enqueue"
        );
        anyhow::ensure!(
            self.max_batch_tokens > 0,
            "ServerConfig: max_batch_tokens = 0 would never step"
        );
        for (class, &slo) in self.ttft_slo.iter().enumerate() {
            anyhow::ensure!(
                slo.is_finite() && slo > 0.0,
                "ServerConfig: ttft_slo[{class}] = {slo} (want a \
                 positive finite deadline in seconds)"
            );
        }
        Ok(())
    }
}

/// The serving engine: owns the model + placement and drains a queue.
/// All routing decisions flow through the online half of the L3
/// coordinator ([`OnlineCoordinator`]) — the serving surface has no
/// offline methods, so a server can never rebuild a placement that
/// disagrees with the one it was handed.
pub struct MoEServer {
    /// The loaded tiny model (shared with the executor).
    pub model: Arc<RealModel>,
    /// The placement being served; re-planning swaps it between decode
    /// steps, so readers see the currently-active plan.
    pub placement: Arc<Placement>,
    /// The online coordination surface (policy, topology, re-planner).
    pub coord: OnlineCoordinator,
    /// Server tunables.
    pub cfg: ServerConfig,
}

impl MoEServer {
    /// Serve a prebuilt placement under `policy` on `topo` (see
    /// [`MoEServer::with_coordinator`] when the caller already owns the
    /// coordinator that built the placement).
    pub fn new(model: Arc<RealModel>, placement: Arc<Placement>,
               topo: Topology, policy: RoutingPolicy,
               cfg: ServerConfig) -> MoEServer {
        Self::with_coordinator(model, placement,
                               OnlineCoordinator::new(topo, policy), cfg)
    }

    /// Serve with an explicit coordinator — normally (the online half of)
    /// the one whose offline phase produced `placement`. When the config
    /// enables re-planning and the coordinator does not already carry a
    /// re-planner, one is attached with the tiny-model cost parameters.
    pub fn with_coordinator(model: Arc<RealModel>,
                            placement: Arc<Placement>,
                            coord: impl Into<OnlineCoordinator>,
                            cfg: ServerConfig) -> MoEServer {
        let mut coord = coord.into();
        if let Some(rc) = cfg.replan {
            if coord.replanner().is_none() {
                let replanner = Replanner::new(
                    coord.topo().clone(),
                    rc,
                    CostParams::tiny(&model.cfg),
                );
                coord = coord.with_replanner(replanner);
            }
        }
        MoEServer { model, placement, coord, cfg }
    }

    /// Serve a closed set of requests: every request is enqueued up
    /// front (moved in — nothing is double-buffered), then the serving
    /// loop runs until the queue drains and the last sequence retires.
    /// Returns responses (request order) and aggregate metrics.
    ///
    /// The queue is sized to hold the whole closed workload so the
    /// single-threaded enqueue can never deadlock against its own
    /// backpressure; open-loop serving keeps the configured bound.
    pub fn serve(&mut self, requests: Vec<Request>)
                 -> anyhow::Result<(Vec<Response>, ServeMetrics)> {
        self.cfg.validate()?;
        let cap = self.cfg.queue_cap.max(requests.len()).max(1);
        let queue: BoundedQueue<(Request, Instant)> = BoundedQueue::new(cap);
        for r in requests {
            queue
                .send((r, Instant::now()))
                .map_err(|_| anyhow::anyhow!("queue closed"))?;
        }
        queue.close();
        let wall0 = Instant::now();
        self.drive(&queue, wall0)
    }

    /// Serve an open-loop workload: a producer thread replays the
    /// `(request, arrival seconds)` schedule against the bounded queue
    /// (blocking on backpressure like a real ingress would) while the
    /// serving loop admits mid-flight at every step boundary.
    pub fn serve_open_loop(&mut self, mut arrivals: Vec<(Request, f64)>)
                           -> anyhow::Result<(Vec<Response>, ServeMetrics)>
    {
        self.cfg.validate()?;
        // Validate and sort the schedule on the caller thread: a NaN
        // inside the producer would panic after spawn without closing
        // the queue, hanging `drive` in `recv` forever.
        anyhow::ensure!(
            arrivals.iter().all(|(_, t)| t.is_finite()),
            "serve_open_loop: non-finite arrival time in schedule"
        );
        arrivals.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        let queue: BoundedQueue<(Request, Instant)> =
            BoundedQueue::new(self.cfg.queue_cap);
        let producer_q = queue.clone();
        let wall0 = Instant::now();
        let producer = std::thread::spawn(move || {
            for (req, t) in arrivals {
                let target = wall0 + Duration::from_secs_f64(t.max(0.0));
                if let Some(wait) =
                    target.checked_duration_since(Instant::now())
                {
                    std::thread::sleep(wait);
                }
                if producer_q.send((req, Instant::now())).is_err() {
                    break; // serving loop shut the queue down
                }
            }
            producer_q.close();
        });
        let out = self.drive(&queue, wall0);
        // On an engine error the producer may still be sleeping or
        // blocked on backpressure: closing the queue fails its sends.
        queue.close();
        let _ = producer.join();
        out
    }

    /// The serving loop shared by both arrival modes: iteration-level
    /// admission from the queue, one batched decode step per iteration,
    /// immediate retirement, and the re-plan epoch tick at the step
    /// boundary (never mid-dispatch-round).
    fn drive(&mut self, queue: &BoundedQueue<(Request, Instant)>,
             wall0: Instant)
             -> anyhow::Result<(Vec<Response>, ServeMetrics)> {
        let secs =
            |t: Instant| t.saturating_duration_since(wall0).as_secs_f64();
        let mut sched = Scheduler::new(SchedConfig {
            mode: self.cfg.sched,
            max_batch: self.cfg.max_batch,
            max_batch_tokens: self.cfg.max_batch_tokens,
            ctx: self.model.cfg.ctx,
            kv_cache: self.cfg.kv_cache,
            preempt: self.cfg.preempt,
            retain_cache_tokens: self.cfg.retain_cache_tokens,
            ttft_slo: self.cfg.ttft_slo.clone(),
        })?;
        let mut rng = Rng::new(self.cfg.seed);
        let mut dist = DistributedMoE::new(
            self.model.clone(),
            self.placement.clone(),
            &self.coord,
            self.cfg.ffn_mode,
        );
        // Per-live-sequence KV caches, keyed by request id: allocated at
        // admission, pulled out for each step the sequence runs in, and
        // dropped the moment the scheduler retires the request.
        let mut caches: std::collections::HashMap<u64, KvCache> =
            std::collections::HashMap::new();

        loop {
            // --- Admission at the step boundary (non-blocking). ---
            loop {
                if sched.wants_offer() {
                    if let Some((req, t)) = queue.try_recv() {
                        sched.offer(req, secs(t));
                        continue;
                    }
                }
                let progressed = sched.admit_pending(secs(Instant::now()))?;
                // Keep the engine-side caches in lockstep with the
                // scheduler: an eviction past the retain cap frees the
                // victim's cache now (resume re-prefills from scratch);
                // retained caches stay warm in the map. Rejected
                // requests never had a cache; resumed-with-cache
                // sequences find theirs still present, resumed-after-
                // drop ones get a fresh one below at allocation.
                for e in sched.take_events() {
                    if let SchedEvent::Preempted {
                        id,
                        cache_dropped: true,
                    } = e
                    {
                        caches.remove(&id);
                    }
                }
                if !progressed {
                    break;
                }
            }
            // Nothing in flight: block for work, or finish when the
            // queue is closed and drained.
            if sched.is_idle() {
                match queue.recv() {
                    Some((req, t)) => {
                        sched.offer(req, secs(t));
                        continue; // re-run admission
                    }
                    None => break,
                }
            }
            if sched.live().is_empty() {
                anyhow::bail!("scheduler stalled with a pending request");
            }

            // Allocate a cache for every newly admitted sequence.
            if self.cfg.kv_cache {
                for s in sched.live() {
                    caches
                        .entry(s.req.id)
                        .or_insert_with(|| KvCache::new(&self.model.cfg));
                }
            }

            // --- One batched decode step over the microbatch. ---
            let batch = sched.microbatch();
            let mut rounds = 0usize;
            let next = if self.cfg.kv_cache {
                // Pull the microbatch's caches out of the map so the
                // engine can borrow them mutably next to the scheduler
                // state; reinsert on success. On a step error the
                // pulled caches are dropped — they may be mid-update —
                // and the error propagates.
                let mut step_caches: Vec<KvCache> = batch
                    .iter()
                    .map(|&i| {
                        caches
                            .remove(&sched.live()[i].req.id)
                            .expect("cache allocated at admission")
                    })
                    .collect();
                let next = {
                    let mut seqs: Vec<CachedSeq> = batch
                        .iter()
                        .zip(step_caches.iter_mut())
                        .map(|(&i, cache)| CachedSeq {
                            ids: sched.live()[i].ids.as_slice(),
                            cache,
                        })
                        .collect();
                    dist.decode_step_cached(
                        &mut seqs,
                        &mut rng,
                        &mut |layer, plan| {
                            rounds += 1;
                            self.coord.observe(
                                layer,
                                &self.placement.layers[layer],
                                plan,
                            );
                        },
                    )?
                };
                for (&i, cache) in batch.iter().zip(step_caches) {
                    let s = &sched.live()[i];
                    // Engine-side cache and scheduler-side pricing must
                    // stay in lockstep: the cache now covers exactly
                    // the tokens the step was fed.
                    debug_assert_eq!(cache.len(), s.ids.len());
                    caches.insert(s.req.id, cache);
                }
                next
            } else {
                let seqs: Vec<&[i32]> = batch
                    .iter()
                    .map(|&i| sched.live()[i].ids.as_slice())
                    .collect();
                dist.decode_step(&seqs, &mut rng, &mut |layer, plan| {
                    rounds += 1;
                    self.coord.observe(
                        layer,
                        &self.placement.layers[layer],
                        plan,
                    );
                })?
            };
            for id in sched.complete_step(&batch, &next,
                                          secs(Instant::now()), rounds)?
            {
                // Retirement drops the sequence's cache immediately —
                // no cache outlives its request.
                caches.remove(&id);
            }

            // --- Step boundary: the only safe place to re-plan. ---
            let delta = self.coord.epoch_tick(&self.placement);
            if !delta.is_empty() {
                let next_p =
                    Arc::new(replan::apply_delta(&self.placement, &delta));
                dist.apply_replan(next_p.clone(), &delta)?;
                self.placement = next_p;
            }
        }

        debug_assert!(caches.is_empty(),
                      "KV caches must not outlive their requests");
        Ok(sched.into_results(wall0.elapsed().as_secs_f64()))
    }
}

/// Even data-parallel assignment of a token index to a rank — the one
/// token→rank rule every engine shares (the sim engine's chunk split and
/// the batched decode forward's shared-tile walk both route through it).
///
/// `total` is the *live* population being split (e.g. the live batch's
/// token count, not the padded context). Indices at or past `total`
/// (padding rows of a partially-filled tile) clamp to the last rank
/// instead of producing an out-of-range GPU id; `total == 0` maps
/// everything to rank 0.
pub fn even_src(t: usize, total: usize, n_gpus: usize) -> GpuId {
    let total = total.max(1);
    t.min(total - 1) * n_gpus / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_src_covers_all_gpus() {
        let srcs: Vec<GpuId> =
            (0..16).map(|t| even_src(t, 16, 4)).collect();
        assert_eq!(srcs[0], 0);
        assert_eq!(srcs[15], 3);
        for g in 0..4 {
            assert_eq!(srcs.iter().filter(|&&s| s == g).count(), 4);
        }
    }

    #[test]
    fn even_src_is_monotone_and_balanced_for_uneven_totals() {
        for total in 1..40usize {
            for n_gpus in 1..6usize {
                let srcs: Vec<GpuId> =
                    (0..total).map(|t| even_src(t, total, n_gpus)).collect();
                assert!(srcs.windows(2).all(|w| w[0] <= w[1]),
                        "monotone (total {total}, gpus {n_gpus})");
                assert!(srcs.iter().all(|&s| s < n_gpus), "in range");
                let mut counts = vec![0usize; n_gpus];
                for &s in &srcs {
                    counts[s] += 1;
                }
                let max = counts.iter().max().unwrap();
                let min = counts.iter().min().unwrap();
                assert!(max - min <= 1,
                        "balanced (total {total}, gpus {n_gpus}): \
                         {counts:?}");
            }
        }
    }

    #[test]
    fn even_src_boundaries_clamp_instead_of_overflowing() {
        // Padding rows past the live length land on the last rank…
        assert_eq!(even_src(10, 10, 4), 3);
        assert_eq!(even_src(63, 10, 4), 3);
        // …instead of the out-of-range ids the old inline formula
        // (dividing by the padded ctx) silently avoided only because ctx
        // bounded the index. The degenerate empty split maps to rank 0.
        assert_eq!(even_src(0, 0, 4), 0);
        assert_eq!(even_src(5, 0, 4), 0);
        // Last live index is always the last rank when total ≥ n_gpus.
        for total in 4..32usize {
            assert_eq!(even_src(total - 1, total, 4), 3);
        }
    }

    #[test]
    fn zero_batch_config_is_a_loud_error() {
        // Regression: `max_batch: 0` used to make `serve` exit silently,
        // dropping every request. It must refuse before consuming any.
        let cfg = ServerConfig { max_batch: 0, ..ServerConfig::default() };
        let err = cfg.validate().unwrap_err();
        assert!(err.to_string().contains("max_batch"), "{err}");
        let cfg = ServerConfig { queue_cap: 0, ..ServerConfig::default() };
        assert!(cfg.validate().is_err());
        let cfg = ServerConfig {
            max_batch_tokens: 0,
            ..ServerConfig::default()
        };
        assert!(cfg.validate().is_err());
        assert!(ServerConfig::default().validate().is_ok());
    }

    // End-to-end serving over the real model is exercised in
    // tests/end_to_end.rs and examples/serve_end_to_end.rs (it needs the
    // AOT artifacts and a PJRT client); scheduler semantics are pinned
    // engine-free in `sched::tests` and tests/serving.rs.
}
