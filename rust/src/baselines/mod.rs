//! System catalog: GRACE-MoE, the paper's baselines (§6.1), and the
//! component-ablation variants of Table 1.
//!
//! Every system is described by the same [`SystemSpec`] tuple —
//! (grouping strategy, replication mode, routing policy, collective,
//! backend efficiency factors) — and executed by the same engine, so
//! differences between systems are exactly the differences the paper
//! ascribes to them:
//!
//! | system | placement | replication | routing | collective | notes |
//! |---|---|---|---|---|---|
//! | Vanilla EP | sequential | — | primary | flat | reference EP |
//! | Tutel | sequential | — | primary | flat | tuned A2A kernels |
//! | MegaBlocks | sequential | — | primary | flat | block-sparse GEMM |
//! | vLLM | sequential | — | primary | flat | serving-optimized |
//! | C2R | uniform affinity | — | primary | flat | **lossy** route pruning |
//! | Occult (No-Prune) | uniform affinity | — | primary | flat | lossless baseline |
//! | GRACE-MoE | hierarchical non-uniform | dynamic | TAR | HSC | this paper |

use crate::cluster::Topology;
use crate::grouping::{self, Grouping};
use crate::placement::ReplicationMode;
use crate::profile::LayerProfile;
use crate::routing::RoutingPolicy;
use crate::comm::CommModel;
use crate::stats::Rng;

/// How a system groups experts onto GPUs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum GroupingStrategy {
    /// Contiguous expert-id chunks (vanilla expert parallelism).
    Sequential,
    /// Affinity-aware uniform groups (Occult / C2R placement).
    Uniform,
    /// GRACE hierarchical: fully non-uniform across nodes, controlled
    /// non-uniform (ratio `r`) across GPUs within a node.
    Hierarchical { r: f64 },
    /// Fully non-uniform at the GPU level (Appendix A.1 extreme).
    FullyNonUniform,
    /// Controlled non-uniform at the GPU level, non-hierarchical
    /// (Appendix A.1 middle point).
    ControlledFlat { r: f64 },
}

impl GroupingStrategy {
    /// Build one layer's grouping (one group per GPU).
    pub fn build(&self, profile: &LayerProfile, topo: &Topology,
                 rng: &mut Rng) -> Grouping {
        let g = topo.num_gpus();
        match *self {
            GroupingStrategy::Sequential => {
                let e = profile.experts();
                let per = e / g;
                let rem = e % g;
                let mut groups = Vec::with_capacity(g);
                let mut at = 0;
                for i in 0..g {
                    let take = per + usize::from(i < rem);
                    groups.push((at..at + take).collect());
                    at += take;
                }
                groups
            }
            GroupingStrategy::Uniform => grouping::uniform(profile, g, rng),
            GroupingStrategy::Hierarchical { r } => {
                grouping::hierarchical(profile, topo, r, rng)
            }
            GroupingStrategy::FullyNonUniform => {
                grouping::fully_nonuniform(profile, g, 1, rng)
            }
            GroupingStrategy::ControlledFlat { r } => {
                grouping::controlled_nonuniform(profile, g, r, rng)
            }
        }
    }
}

/// Full system description consumed by the engine.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemSpec {
    /// System name (report labels, CLI values).
    pub name: &'static str,
    /// Expert → GPU grouping strategy (§4.1).
    pub grouping: GroupingStrategy,
    /// Replica-selection mode (§4.2).
    pub replication: ReplicationMode,
    /// Online replica-routing policy (§4.3).
    pub routing: RoutingPolicy,
    /// All-to-All collective implementation (§5).
    pub comm: CommModel,
    /// Multiplier on the GPU's achieved MoE-GEMM efficiency (backend
    /// kernel quality: MegaBlocks' block-sparse reformulation ≈ 1.3×
    /// vanilla for skewed expert batches).
    pub compute_eff: f64,
    /// Multiplier on collective wall time (kernel maturity; Tutel's tuned
    /// A2A ≈ 0.85× vanilla NCCL usage).
    pub comm_eff: f64,
    /// Fraction of *remote* expert assignments C2R-style routing pruning
    /// drops (re-confined to local experts). Non-zero ⇒ lossy.
    pub prune_remote: f64,
    /// Whether the flat A2A dispatch aggregates duplicate (token → rank)
    /// sends. Vanilla EP duplicates one copy per expert assignment;
    /// collaboration-aware systems (C2R / Occult) merge them — their
    /// entire contribution is built around this aggregation.
    pub dedup_flat: bool,
    /// Whether the system re-plans replication online from measured
    /// loads (the epoch loop of [`crate::replan`]); the engine consults
    /// [`crate::engine::sim::SimConfig::replan`] for the cadence. Only
    /// [`SystemSpec::grace_dyn`] sets it.
    pub online_replan: bool,
}

impl SystemSpec {
    /// `true` when routing never drops assignments (C2R prunes).
    pub fn lossless(&self) -> bool {
        self.prune_remote == 0.0
    }

    /// Reference vanilla expert parallelism.
    pub fn vanilla() -> Self {
        SystemSpec {
            name: "vanilla",
            grouping: GroupingStrategy::Sequential,
            replication: ReplicationMode::None,
            routing: RoutingPolicy::Primary,
            comm: CommModel::Flat,
            compute_eff: 1.0,
            comm_eff: 1.0,
            prune_remote: 0.0,
            dedup_flat: false,
            online_replan: false,
        }
    }

    /// Tutel: vanilla EP with tuned A2A kernels.
    pub fn tutel() -> Self {
        SystemSpec {
            name: "tutel",
            compute_eff: 1.1,
            comm_eff: 0.85,
            ..Self::vanilla()
        }
    }

    /// MegaBlocks: vanilla EP with block-sparse expert GEMMs.
    pub fn megablocks() -> Self {
        SystemSpec {
            name: "megablocks",
            compute_eff: 1.3,
            ..Self::vanilla()
        }
    }

    /// vLLM: serving-optimized vanilla EP.
    pub fn vllm() -> Self {
        SystemSpec {
            name: "vllm",
            compute_eff: 1.2,
            comm_eff: 0.95,
            ..Self::vanilla()
        }
    }

    /// C2R: uniform affinity grouping + collaboration-constrained routing
    /// (lossy pruning of remote assignments).
    pub fn c2r() -> Self {
        SystemSpec {
            name: "c2r",
            grouping: GroupingStrategy::Uniform,
            compute_eff: 1.3,
            prune_remote: 0.30,
            dedup_flat: true,
            ..Self::vanilla()
        }
    }

    /// Occult No-Prune: the lossless uniform-grouping baseline Table 1
    /// normalizes against.
    pub fn occult() -> Self {
        SystemSpec {
            name: "occult",
            grouping: GroupingStrategy::Uniform,
            compute_eff: 1.3,
            dedup_flat: true,
            ..Self::vanilla()
        }
    }

    /// Full GRACE-MoE (HG + DR + TAR on HSC).
    pub fn grace(r: f64) -> Self {
        SystemSpec {
            name: "grace",
            grouping: GroupingStrategy::Hierarchical { r },
            replication: ReplicationMode::Dynamic,
            routing: RoutingPolicy::Tar,
            comm: CommModel::Hsc,
            compute_eff: 1.3,
            comm_eff: 1.0,
            prune_remote: 0.0,
            dedup_flat: true,
            online_replan: false,
        }
    }

    /// GRACE-MoE with the online load-predictive router: TAR's locality
    /// tiers, but the tier-(ii)/(iii) weights come from Eq. 4 recomputed
    /// every dispatch round over measured loads instead of the frozen
    /// placement-time prediction (beyond-Table-1 variant).
    pub fn grace_load_aware(r: f64) -> Self {
        SystemSpec {
            name: "grace+la",
            routing: RoutingPolicy::LoadAware,
            ..Self::grace(r)
        }
    }

    /// GRACE-MoE with epoch-based online re-planning: the full GRACE
    /// pipeline plus the measured-load → replication feedback loop of
    /// [`crate::replan`] — replica sets and polling weights are
    /// recomputed at epoch boundaries and hot-swapped when the migration
    /// pays for itself. The drifting-workload system (beyond-paper
    /// variant; stationary workloads reduce it to exactly `grace`).
    pub fn grace_dyn(r: f64) -> Self {
        SystemSpec {
            name: "grace-dyn",
            online_replan: true,
            ..Self::grace(r)
        }
    }

    /// Figure 4 baseline set (in the paper's order) + GRACE.
    pub fn fig4_systems(r: f64) -> Vec<SystemSpec> {
        vec![
            Self::vanilla(),
            Self::tutel(),
            Self::megablocks(),
            Self::vllm(),
            Self::c2r(),
            Self::occult(),
            Self::grace(r),
        ]
    }

    /// Table 1 / Fig 5 incremental component ladder:
    /// Occult → Occult+HSC → HG+HSC → +FR+WRR → +DR+WRR → +DR+TAR.
    pub fn table1_ladder(r: f64) -> Vec<SystemSpec> {
        let occult_hsc = SystemSpec {
            name: "occult+hsc",
            comm: CommModel::Hsc,
            ..Self::occult()
        };
        let hg_hsc = SystemSpec {
            name: "hg+hsc",
            grouping: GroupingStrategy::Hierarchical { r },
            ..occult_hsc.clone()
        };
        let hg_fr_wrr = SystemSpec {
            name: "+fr+wrr",
            replication: ReplicationMode::Fixed,
            routing: RoutingPolicy::Wrr,
            ..hg_hsc.clone()
        };
        let hg_dr_wrr = SystemSpec {
            name: "+dr+wrr",
            replication: ReplicationMode::Dynamic,
            routing: RoutingPolicy::Wrr,
            ..hg_hsc.clone()
        };
        let mut grace = Self::grace(r);
        grace.name = "+dr+tar";
        vec![
            Self::occult(),
            occult_hsc,
            hg_hsc,
            hg_fr_wrr,
            hg_dr_wrr,
            grace,
        ]
    }

    /// Appendix A.1 / Table 2 grouping-strategy comparison set.
    pub fn table2_groupings() -> Vec<SystemSpec> {
        let base = Self::occult();
        vec![
            SystemSpec { name: "uniform(occult)", ..base.clone() },
            SystemSpec {
                name: "controlled(r=0.15)",
                grouping: GroupingStrategy::Hierarchical { r: 0.15 },
                comm: CommModel::Hsc,
                ..base.clone()
            },
            // "fully non-uniform" = the same hierarchical pipeline with
            // the GPU-level size constraint effectively removed, isolating
            // the uniformity constraint (Appendix A.1's comparison).
            SystemSpec {
                name: "fully-non-uniform",
                grouping: GroupingStrategy::Hierarchical { r: 10.0 },
                comm: CommModel::Hsc,
                ..base
            },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grouping::is_partition;
    use crate::profile::ModelProfile;
    use crate::trace::{Profile, TraceGen};

    fn profile() -> LayerProfile {
        let t = TraceGen {
            experts: 64,
            top_k: 8,
            layers: 1,
            profile: Profile::Text,
            seed: 5,
        }
        .generate(256);
        ModelProfile::from_trace(&t).layers.remove(0)
    }

    #[test]
    fn sequential_chunks_cover_all_experts() {
        let p = profile();
        let topo = Topology::two_by_two();
        let g = GroupingStrategy::Sequential.build(&p, &topo,
                                                   &mut Rng::new(1));
        assert!(is_partition(&g, 64));
        assert_eq!(g[0], (0..16).collect::<Vec<_>>());
        assert!(g.iter().all(|gr| gr.len() == 16));
    }

    #[test]
    fn all_strategies_produce_partitions() {
        let p = profile();
        let topo = Topology::two_by_four();
        let mut rng = Rng::new(2);
        for s in [
            GroupingStrategy::Sequential,
            GroupingStrategy::Uniform,
            GroupingStrategy::Hierarchical { r: 0.15 },
            GroupingStrategy::FullyNonUniform,
            GroupingStrategy::ControlledFlat { r: 0.2 },
        ] {
            let g = s.build(&p, &topo, &mut rng);
            assert_eq!(g.len(), 8);
            assert!(is_partition(&g, 64), "{s:?}");
        }
    }

    #[test]
    fn catalog_shapes() {
        assert_eq!(SystemSpec::fig4_systems(0.15).len(), 7);
        let ladder = SystemSpec::table1_ladder(0.15);
        assert_eq!(ladder.len(), 6);
        assert_eq!(ladder[0].name, "occult");
        assert_eq!(ladder[5].name, "+dr+tar");
        assert_eq!(ladder[5].routing, RoutingPolicy::Tar);
        assert_eq!(SystemSpec::table2_groupings().len(), 3);
    }

    #[test]
    fn losslessness_flags() {
        assert!(SystemSpec::occult().lossless());
        assert!(SystemSpec::grace(0.15).lossless());
        assert!(SystemSpec::grace_load_aware(0.15).lossless());
        assert!(!SystemSpec::c2r().lossless(), "C2R prunes routes");
    }

    #[test]
    fn grace_load_aware_differs_only_in_routing() {
        let g = SystemSpec::grace(0.15);
        let la = SystemSpec::grace_load_aware(0.15);
        assert_eq!(la.routing, RoutingPolicy::LoadAware);
        assert_eq!(SystemSpec { name: g.name, routing: g.routing, ..la },
                   g);
    }

    #[test]
    fn grace_dyn_differs_only_in_replan_flag() {
        let g = SystemSpec::grace(0.15);
        let d = SystemSpec::grace_dyn(0.15);
        assert!(d.online_replan && !g.online_replan);
        assert!(d.lossless());
        assert_eq!(
            SystemSpec { name: g.name, online_replan: false, ..d },
            g
        );
    }

    #[test]
    fn grace_uses_all_three_components() {
        let g = SystemSpec::grace(0.15);
        assert!(matches!(g.grouping,
                         GroupingStrategy::Hierarchical { .. }));
        assert_eq!(g.replication, ReplicationMode::Dynamic);
        assert_eq!(g.routing, RoutingPolicy::Tar);
        assert_eq!(g.comm, CommModel::Hsc);
    }
}
