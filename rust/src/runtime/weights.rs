//! Weight blob access: the python compile path serialises all tiny-model
//! parameters as one f32 little-endian blob; the manifest records each
//! tensor's offset (in elements) and shape. This module memory-loads the
//! blob and slices per-layer / per-expert views for the engine.

use super::manifest::{Manifest, VariantMeta};

/// All weights of one tiny variant, resident in host memory.
#[derive(Clone, Debug)]
pub struct WeightStore {
    data: Vec<f32>,
    meta: VariantMeta,
}

impl WeightStore {
    /// Load one variant's weight blob into host memory.
    pub fn load(manifest: &Manifest, variant: &str)
                -> anyhow::Result<WeightStore> {
        let meta = manifest.variant(variant)?.clone();
        let path = manifest.path_of(&meta.weights.file);
        let bytes = std::fs::read(&path).map_err(|e| {
            anyhow::anyhow!("cannot read {}: {e}", path.display())
        })?;
        anyhow::ensure!(bytes.len() % 4 == 0, "blob not f32-aligned");
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        let need: usize = meta
            .weights
            .tensors
            .values()
            .map(|(off, shape)| off + shape.iter().product::<usize>())
            .max()
            .unwrap_or(0);
        anyhow::ensure!(
            data.len() >= need,
            "blob too small: {} < {need}",
            data.len()
        );
        Ok(WeightStore { data, meta })
    }

    /// The variant's architecture.
    pub fn config(&self) -> &super::manifest::TinyConfig {
        &self.meta.config
    }

    /// Whole tensor by name: (flat values, shape).
    pub fn tensor(&self, name: &str) -> anyhow::Result<(&[f32], &[usize])> {
        let (off, shape) = self
            .meta
            .weights
            .tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no tensor '{name}'"))?;
        let len: usize = shape.iter().product();
        Ok((&self.data[*off..*off + len], shape))
    }

    /// Slice one layer out of a `[layers, ...]` tensor: returns the flat
    /// values and the per-layer shape.
    pub fn layer_tensor(&self, name: &str, layer: usize)
                        -> anyhow::Result<(&[f32], Vec<usize>)> {
        let (vals, shape) = self.tensor(name)?;
        anyhow::ensure!(shape.len() >= 2, "'{name}' has no layer dim");
        let layers = shape[0];
        anyhow::ensure!(layer < layers, "layer {layer} >= {layers}");
        let per: usize = shape[1..].iter().product();
        Ok((&vals[layer * per..(layer + 1) * per], shape[1..].to_vec()))
    }

    /// Slice one expert's weights from a `[layers, experts, ...]` tensor.
    pub fn expert_tensor(&self, name: &str, layer: usize, expert: usize)
                         -> anyhow::Result<(&[f32], Vec<usize>)> {
        let (vals, shape) = self.layer_tensor(name, layer)?;
        anyhow::ensure!(shape.len() >= 2, "'{name}' has no expert dim");
        let experts = shape[0];
        anyhow::ensure!(expert < experts, "expert {expert} >= {experts}");
        let per: usize = shape[1..].iter().product();
        Ok((&vals[expert * per..(expert + 1) * per], shape[1..].to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn store() -> Option<WeightStore> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        let m = Manifest::load(&d).unwrap();
        Some(WeightStore::load(&m, "olmoe_tiny").unwrap())
    }

    #[test]
    fn tensor_shapes_match_config() {
        let Some(s) = store() else { return };
        let c = s.config().clone();
        let (emb, eshape) = s.tensor("emb").unwrap();
        assert_eq!(eshape, &[c.vocab, c.hidden]);
        assert_eq!(emb.len(), c.vocab * c.hidden);
        let (w1, w1shape) = s.tensor("w1").unwrap();
        assert_eq!(w1shape,
                   &[c.layers, c.experts, c.hidden, c.ffn]);
        assert_eq!(w1.len(), c.layers * c.experts * c.hidden * c.ffn);
    }

    #[test]
    fn layer_and_expert_slicing_consistent() {
        let Some(s) = store() else { return };
        let c = s.config().clone();
        let (l0, shape) = s.layer_tensor("w1", 0).unwrap();
        assert_eq!(shape, vec![c.experts, c.hidden, c.ffn]);
        let (e3, eshape) = s.expert_tensor("w1", 0, 3).unwrap();
        assert_eq!(eshape, vec![c.hidden, c.ffn]);
        let per = c.hidden * c.ffn;
        assert_eq!(e3, &l0[3 * per..4 * per]);
        // weights are not degenerate
        assert!(e3.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn bad_names_and_indices_error() {
        let Some(s) = store() else { return };
        assert!(s.tensor("nope").is_err());
        assert!(s.layer_tensor("w1", 999).is_err());
        assert!(s.expert_tensor("w1", 0, 999).is_err());
    }
}
