//! PJRT execution: compile HLO-text artifacts on the CPU client and run
//! them with literal marshalling. Executables are compiled once and
//! cached; the engine calls them from the request path.

use super::manifest::Manifest;
use std::collections::HashMap;
use std::sync::Mutex;

/// Whether a real PJRT runtime backs this build. `false` under the
/// vendored std-only `xla` stub (rust/shims/xla): execute-mode tests and
/// benches gate on this and skip loudly instead of failing, even when the
/// AOT artifacts are present on disk.
pub fn runtime_available() -> bool {
    // Cached: with real bindings the probe constructs a full CPU PJRT
    // runtime, which every gated test would otherwise pay again.
    static PROBE: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *PROBE.get_or_init(|| xla::PjRtClient::cpu().is_ok())
}

/// Cached PJRT client + compiled executables.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    /// Executions issued (perf accounting).
    pub exec_count: std::sync::atomic::AtomicU64,
}

impl PjrtEngine {
    /// Spin up the CPU PJRT client for `manifest`'s artifacts.
    pub fn new(manifest: Manifest) -> anyhow::Result<PjrtEngine> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
        Ok(PjrtEngine {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// The manifest this engine executes from.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact `variant`/`name`.
    pub fn executable(&self, variant: &str, name: &str)
                      -> anyhow::Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        let key = format!("{variant}/{name}");
        if let Some(exe) = self.cache.lock().unwrap().get(&key) {
            return Ok(exe.clone());
        }
        let meta = self.manifest.variant(variant)?;
        let art = meta.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!("variant {variant} has no artifact '{name}'")
        })?;
        let path = self.manifest.path_of(&art.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )
        .map_err(|e| {
            anyhow::anyhow!("parse {}: {e:?}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compile {key}: {e:?}"))?,
        );
        self.cache.lock().unwrap().insert(key, exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with the given inputs; returns the flattened
    /// tuple outputs (aot.py lowers everything with `return_tuple=True`).
    pub fn run(&self, variant: &str, name: &str, inputs: &[xla::Literal])
               -> anyhow::Result<Vec<xla::Literal>> {
        let exe = self.executable(variant, name)?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow::anyhow!("execute {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("to_literal {name}: {e:?}"))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn lit_f32(vals: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(vals.len() == n, "lit_f32: {} vs {shape:?}",
                    vals.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(vals)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal of the given shape.
pub fn lit_i32(vals: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(vals.len() == n, "lit_i32: {} vs {shape:?}",
                    vals.len());
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(vals)
        .reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape: {e:?}"))
}

/// Scalar i32 literal (e.g. attention valid_len).
pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Extract an f32 vector from a literal.
pub fn to_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec<f32>: {e:?}"))
}

/// Extract an i32 vector from a literal.
pub fn to_i32(lit: &xla::Literal) -> anyhow::Result<Vec<i32>> {
    lit.to_vec::<i32>()
        .map_err(|e| anyhow::anyhow!("to_vec<i32>: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::WeightStore;
    use std::path::PathBuf;

    fn engine() -> Option<PjrtEngine> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !d.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        if !runtime_available() {
            eprintln!("SKIP: PJRT runtime unavailable (std-only xla \
                       stub) — execute-mode tests need real bindings");
            return None;
        }
        Some(PjrtEngine::new(Manifest::load(&d).unwrap()).unwrap())
    }

    #[test]
    fn compiles_and_runs_gate() {
        let Some(eng) = engine() else { return };
        let c = eng.manifest().variant("olmoe_tiny").unwrap().config.clone();
        let ws =
            WeightStore::load(eng.manifest(), "olmoe_tiny").unwrap();
        let x: Vec<f32> = (0..c.tile_t * c.hidden)
            .map(|i| ((i % 13) as f32 - 6.0) * 0.1)
            .collect();
        let (wg, _) = ws.layer_tensor("wg", 0).unwrap();
        let out = eng
            .run(
                "olmoe_tiny",
                "gate",
                &[
                    lit_f32(&x, &[c.tile_t, c.hidden]).unwrap(),
                    lit_f32(wg, &[c.hidden, c.experts]).unwrap(),
                ],
            )
            .unwrap();
        assert_eq!(out.len(), 3, "gate returns (xn, topw, topi)");
        let topw = to_f32(&out[1]).unwrap();
        let topi = to_i32(&out[2]).unwrap();
        assert_eq!(topw.len(), c.tile_t * c.top_k);
        assert_eq!(topi.len(), c.tile_t * c.top_k);
        // per-token weights sum to 1 and indices are valid + distinct
        for t in 0..c.tile_t {
            let row = &topw[t * c.top_k..(t + 1) * c.top_k];
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-4, "token {t}: sum {s}");
            let mut ids: Vec<i32> =
                topi[t * c.top_k..(t + 1) * c.top_k].to_vec();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), c.top_k);
            assert!(ids.iter().all(|&e| (e as usize) < c.experts));
        }
    }

    #[test]
    fn executable_cache_hits() {
        let Some(eng) = engine() else { return };
        let a = eng.executable("olmoe_tiny", "lmhead").unwrap();
        let b = eng.executable("olmoe_tiny", "lmhead").unwrap();
        assert!(std::sync::Arc::ptr_eq(&a, &b));
    }

    #[test]
    fn unknown_artifact_is_error() {
        let Some(eng) = engine() else { return };
        assert!(eng.run("olmoe_tiny", "nope", &[]).is_err());
        assert!(eng.executable("missing_variant", "gate").is_err());
    }

    #[test]
    fn literal_roundtrip() {
        let l = lit_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        assert_eq!(to_f32(&l).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        let i = lit_i32(&[5, -1], &[2]).unwrap();
        assert_eq!(to_i32(&i).unwrap(), vec![5, -1]);
        assert!(lit_f32(&[1.0], &[2, 2]).is_err());
    }
}
