//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and execute them on the CPU PJRT client.
//!
//! This is the only boundary between rust and the JAX/Pallas compute
//! stack; after `make artifacts` the binary is self-contained (python is
//! never on the request path).
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (shapes, dims,
//!   weight-blob layout) with the in-repo JSON parser,
//! * [`weights`] — maps the deterministic f32-LE weight blob,
//! * [`pjrt`] — compiles + caches executables and marshals literals.

pub mod manifest;
pub mod pjrt;
pub mod weights;

pub use manifest::{ArtifactMeta, Manifest, TinyConfig, VariantMeta};
pub use pjrt::PjrtEngine;
pub use weights::WeightStore;
