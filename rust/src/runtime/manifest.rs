//! `artifacts/manifest.json` schema — the single source of truth shared
//! with the python compile path (see `python/compile/aot.py`).

use crate::configio::{self, Value};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tiny-variant architecture (mirrors `python/compile/model.py`'s
/// `ModelConfig`).
#[derive(Clone, Debug, PartialEq)]
pub struct TinyConfig {
    /// Experts per MoE layer.
    pub experts: usize,
    /// Experts each token activates.
    pub top_k: usize,
    /// Layers in the tiny variant.
    pub layers: usize,
    /// Layers of the paper-scale architecture it mirrors.
    pub paper_layers: usize,
    /// Hidden (model) dimension.
    pub hidden: usize,
    /// Per-expert FFN intermediate dimension.
    pub ffn: usize,
    /// Attention heads.
    pub heads: usize,
    /// Vocabulary size.
    pub vocab: usize,
    /// Tokens per gate/FFN tile.
    pub tile_t: usize,
    /// Rows per Pallas grouped-FFN tile.
    pub tile_m: usize,
    /// Tiles in the grouped-FFN dispatch capacity.
    pub cap_tiles: usize,
    /// Context length (sequences are ctx-padded).
    pub ctx: usize,
}

impl TinyConfig {
    /// Row capacity of one grouped-FFN call (`cap_tiles × tile_m`).
    pub fn cap_rows(&self) -> usize {
        self.cap_tiles * self.tile_m
    }
}

/// One compiled artifact (HLO file + input signature).
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactMeta {
    /// HLO-text file name (relative to the artifacts dir).
    pub file: String,
    /// Input shapes (row-major dims) and dtypes, in call order.
    pub inputs: Vec<(Vec<usize>, String)>,
}

/// Weight-blob layout: tensor name → (offset in f32 elements, shape).
#[derive(Clone, Debug, PartialEq)]
pub struct WeightsMeta {
    /// Weight-blob file name (relative to the artifacts dir).
    pub file: String,
    /// Tensor name → (offset in f32 elements, shape).
    pub tensors: BTreeMap<String, (usize, Vec<usize>)>,
}

/// One model variant's artifacts.
#[derive(Clone, Debug)]
pub struct VariantMeta {
    /// The variant's architecture.
    pub config: TinyConfig,
    /// Compiled artifacts by name (`gate`, `grouped_ffn`, …).
    pub artifacts: BTreeMap<String, ArtifactMeta>,
    /// Weight-blob layout.
    pub weights: WeightsMeta,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest (and artifacts) live in.
    pub dir: PathBuf,
    /// Fingerprint of the python sources that built the artifacts.
    pub fingerprint: String,
    /// Model variants by name.
    pub variants: BTreeMap<String, VariantMeta>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            )
        })?;
        let v = configio::parse(&text)
            .map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;
        let fingerprint = v.req_str("fingerprint")?.to_string();
        let mut variants = BTreeMap::new();
        let vobj = v
            .req("variants")?
            .as_object()
            .ok_or_else(|| anyhow::anyhow!("'variants' not an object"))?;
        for (name, vv) in vobj {
            variants.insert(name.clone(), parse_variant(vv)?);
        }
        Ok(Manifest { dir, fingerprint, variants })
    }

    /// Look a variant up by name (error lists what exists).
    pub fn variant(&self, name: &str) -> anyhow::Result<&VariantMeta> {
        self.variants.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "variant '{name}' not in manifest (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Absolute path of an artifact file.
    pub fn path_of(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }
}

fn parse_variant(v: &Value) -> anyhow::Result<VariantMeta> {
    let c = v.req("config")?;
    let config = TinyConfig {
        experts: c.req_usize("experts")?,
        top_k: c.req_usize("top_k")?,
        layers: c.req_usize("layers")?,
        paper_layers: c.req_usize("paper_layers")?,
        hidden: c.req_usize("hidden")?,
        ffn: c.req_usize("ffn")?,
        heads: c.req_usize("heads")?,
        vocab: c.req_usize("vocab")?,
        tile_t: c.req_usize("tile_t")?,
        tile_m: c.req_usize("tile_m")?,
        cap_tiles: c.req_usize("cap_tiles")?,
        ctx: c.req_usize("ctx")?,
    };
    let mut artifacts = BTreeMap::new();
    let aobj = v
        .req("artifacts")?
        .as_object()
        .ok_or_else(|| anyhow::anyhow!("'artifacts' not an object"))?;
    for (name, av) in aobj {
        let file = av.req_str("file")?.to_string();
        let mut inputs = Vec::new();
        for iv in av.req_array("inputs")? {
            let shape = iv
                .req_array("shape")?
                .iter()
                .map(|d| {
                    d.as_usize().ok_or_else(|| {
                        anyhow::anyhow!("bad dim in {name}")
                    })
                })
                .collect::<anyhow::Result<Vec<usize>>>()?;
            inputs.push((shape, iv.req_str("dtype")?.to_string()));
        }
        artifacts.insert(name.clone(), ArtifactMeta { file, inputs });
    }
    let w = v.req("weights")?;
    let mut tensors = BTreeMap::new();
    let tobj = w
        .req("tensors")?
        .as_object()
        .ok_or_else(|| anyhow::anyhow!("'tensors' not an object"))?;
    for (name, tv) in tobj {
        let offset = tv.req_usize("offset")?;
        let shape = tv
            .req_array("shape")?
            .iter()
            .map(|d| {
                d.as_usize()
                    .ok_or_else(|| anyhow::anyhow!("bad dim in {name}"))
            })
            .collect::<anyhow::Result<Vec<usize>>>()?;
        tensors.insert(name.clone(), (offset, shape));
    }
    Ok(VariantMeta {
        config,
        artifacts,
        weights: WeightsMeta {
            file: w.req_str("file")?.to_string(),
            tensors,
        },
    })
}

/// Default artifacts directory: `$GRACE_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var_os("GRACE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> Option<PathBuf> {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(!m.fingerprint.is_empty());
        let v = m.variant("olmoe_tiny").unwrap();
        assert_eq!(v.config.experts, 64);
        assert_eq!(v.config.top_k, 8);
        for want in ["gate", "grouped_ffn", "attention", "embed",
                     "lmhead", "moe_layer_full", "expert_ffn"] {
            let art = v.artifacts.get(want).expect(want);
            assert!(m.path_of(&art.file).exists(), "{want} file missing");
            assert!(!art.inputs.is_empty());
        }
        // gate inputs: x [tile_t, hidden], wg [hidden, experts]
        let gate = &v.artifacts["gate"];
        assert_eq!(gate.inputs[0].0,
                   vec![v.config.tile_t, v.config.hidden]);
        assert_eq!(gate.inputs[1].0,
                   vec![v.config.hidden, v.config.experts]);
        // weight tensors present
        for t in ["emb", "wqkv", "wo", "wg", "w1", "w3", "w2"] {
            assert!(v.weights.tensors.contains_key(t), "{t}");
        }
    }

    #[test]
    fn missing_variant_is_error() {
        let Some(dir) = artifacts_dir() else {
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn missing_dir_is_helpful_error() {
        let err = Manifest::load("/definitely/not/here").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
