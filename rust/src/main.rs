//! `grace-moe` — launcher CLI for the GRACE-MoE reproduction.
//!
//! Subcommands:
//!
//! * `simulate`  — run the paper-scale timing engine for one
//!   model × system × workload × cluster and print the metric table.
//! * `compare`   — run the full Fig.-4 system set on one configuration.
//! * `components`— the Table-1 incremental component ladder.
//! * `serve`     — execute-mode serving demo on the tiny AOT model
//!   (requires `make artifacts`).
//! * `placement` — show the offline phase's grouping/replication decisions.
//! * `replan`    — drifting-workload comparison: static GRACE vs the
//!   epoch re-planned `grace-dyn` on a trace whose hot-expert set rotates
//!   mid-run.
//! * `fleet`     — open-loop fleet replay: a Poisson request trace
//!   through scheduler + re-planner + the contended discrete-event
//!   network (`--comm des`) on a virtual clock.

use grace_moe::baselines::{GroupingStrategy, SystemSpec};
use grace_moe::cli::Args;
use grace_moe::cluster::Topology;
use grace_moe::comm::CommBackendKind;
use grace_moe::config::{ArrivalProcess, ModelSpec, PrefetchConfig,
                        ServeLoad, Workload};
use grace_moe::configio::Value;
use grace_moe::coordinator::Coordinator;
use grace_moe::engine::fleet::{replay_fleet, FleetConfig};
use grace_moe::engine::real::{profile_real, RealModel};
use grace_moe::engine::sim::{build_placement, drifting_rounds,
                             simulate_rounds, simulate_with_contention};
use grace_moe::engine::{simulate, SimConfig};
use grace_moe::metrics::{ContentionReport, PrefetchStats};
use grace_moe::placement::ReplicationMode;
use grace_moe::replan::ReplanConfig;
use grace_moe::report;
use grace_moe::routing::RoutingPolicy;
use grace_moe::server::shard::FleetRoutePolicy;
use grace_moe::server::{MoEServer, Request, ServerConfig};
use grace_moe::stats::Rng;
use grace_moe::trace::Profile;
use std::sync::Arc;

const USAGE: &str = "\
grace-moe — GRACE-MoE distributed MoE inference (paper reproduction)

USAGE:
  grace-moe <simulate|compare|components|serve|placement|replan|fleet>
            [options]

COMMON OPTIONS:
  --model <olmoe|dsv2_lite|qwen3>   model (default olmoe)
  --nodes <n>                       nodes (default 2)
  --gpus <n>                        GPUs per node (default 2)
  --batch / --prefill / --decode    workload (default 256/128/16)
  --dataset <text|math|code|mixed>  serving trace profile (default text)
  --placement-dataset <...>         profiling profile (default = dataset)
  --r <ratio>                       non-uniformity ratio (default 0.15)
  --seed <u64>                      run seed (default 42)
  --comm <analytic|des>             communication backend (default
                                    analytic; des = contended
                                    discrete-event network)
  --json                            machine-readable output

PREFETCH OPTIONS (simulate, fleet; default: no weight tier — every
expert weight stays resident and timing is bit-identical to PR 9):
  --prefetch <on|off>               predictive cross-layer expert
                                    pre-staging (default off)
  --weight-budget <n>               hot-tier capacity in experts per
                                    GPU (default 8; passing it without
                                    --prefetch on enables the tier
                                    with demand staging only)
  --prefetch-k <n>                  predicted experts staged per layer
                                    (default 4)
  --prefetch-alpha <f>              predictor EWMA decay in (0,1]
                                    (default 0.3)

PRIORITY OPTIONS (serve, fleet):
  --priority-classes <n>            round-robin request priority classes
                                    (default 1; class 0 most urgent)
  --preempt <on|off>                evict lower-priority decodes when a
                                    higher-priority arrival cannot be
                                    admitted (default off)
  --ttft-slo <s[,s...]>             per-class TTFT deadline in seconds;
                                    requests predicted to miss it are
                                    rejected loudly (default: no SLO)

FLEET OPTIONS (open-loop replay; also honours --comm and the
re-planning options with --system grace-dyn):
  --requests <n>  --prompt <len>  --new-tokens <n>
  --arrival-rate <req/s>            Poisson rate (default 256; must be
                                    finite and positive)
  --max-batch <n>  --max-batch-tokens <n>  scheduler admission limits
  --replicas <n>                    replica shards behind the admission
                                    front-end (default 1)
  --fleet-route <jsq|wrr|affinity>  replica route policy (default jsq)
  --queue-cap <n>                   fleet admission queue capacity;
                                    overflow arrivals are shed loudly
                                    (default: unbounded)
  --class-shift <on|off>            condition the gate trace on priority
                                    class (default off)
  --replica-profiles <on|off>       per-class replica placements
                                    (default off)

RE-PLANNING OPTIONS (simulate --system grace-dyn, serve, replan):
  --replan-epoch <rounds>           epoch length in dispatch rounds
  --replan-threshold <frac>         min predicted max-load improvement
  (replan only) --rounds <n>  --round-tokens <n>  --drift-at <round>

SERVE OPTIONS (tiny AOT model; run `make artifacts` first):
  --variant <olmoe_tiny|dsv2_tiny|qwen3_tiny>
  --requests <n>  --prompt <len>  --new-tokens <n>
  --policy <primary|wrr|tar|load-aware>
  --sched <continuous|static>       batching discipline (default
                                    continuous; static = drain barrier)
  --kv-cache <on|off>               per-sequence KV caches (default on;
                                    off = full-recompute parity oracle)
  --max-batch <n>                   live-sequence cap (default 8)
  --max-batch-tokens <n>            step token budget (default 256)
  --arrival-rate <req/s>            open-loop Poisson arrivals
                                    (default 0 = closed loop)
  --artifacts <dir>                 artifacts dir (default ./artifacts)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: Vec<String>) -> anyhow::Result<()> {
    let args = Args::parse(argv, &["json", "help"])?;
    if args.flag("help") || args.subcommand.is_none() {
        println!("{USAGE}");
        return Ok(());
    }
    match args.subcommand.as_deref().unwrap() {
        "simulate" => cmd_simulate(&args),
        "compare" => cmd_compare(&args),
        "components" => cmd_components(&args),
        "serve" => cmd_serve(&args),
        "placement" => cmd_placement(&args),
        "replan" => cmd_replan(&args),
        "fleet" => cmd_fleet(&args),
        other => anyhow::bail!("unknown subcommand '{other}'\n{USAGE}"),
    }
}

/// Parse the shared re-planning knobs (defaults per subcommand),
/// rejecting degenerate values (`--replan-epoch 0`, NaN thresholds) at
/// parse time instead of silently never ticking.
fn replan_config(args: &Args, default_epoch: u64)
                 -> anyhow::Result<ReplanConfig> {
    let rc = ReplanConfig {
        epoch_rounds: args.u64_or("replan-epoch", default_epoch)?,
        min_drift: args.f64_or("replan-threshold",
                               ReplanConfig::default().min_drift)?,
        ..ReplanConfig::default()
    };
    rc.validate()?;
    Ok(rc)
}

/// Parse the priority/preemption knobs shared by `serve` and `fleet`:
/// `--priority-classes`, `--preempt on|off`, `--ttft-slo s[,s...]`.
/// Degenerate values (zero classes, non-positive deadlines) are loud
/// parse errors, mirroring the library-side validation.
fn priority_opts(args: &Args) -> anyhow::Result<(usize, bool, Vec<f64>)> {
    let classes = args.usize_or("priority-classes", 1)?;
    anyhow::ensure!(classes >= 1,
                    "--priority-classes must be at least 1");
    let preempt = match args.str_or("preempt", "off") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("unknown --preempt '{other}' \
                                (expected on|off)"),
    };
    let mut slo = Vec::new();
    if let Some(spec) = args.get("ttft-slo") {
        for tok in spec.split(',') {
            let t = tok.trim();
            let s: f64 = t.parse().map_err(|_| anyhow::anyhow!(
                "--ttft-slo: '{t}' is not a number"))?;
            anyhow::ensure!(s.is_finite() && s > 0.0,
                            "--ttft-slo deadlines must be finite and \
                             positive, got {s}");
            slo.push(s);
        }
    }
    Ok((classes, preempt, slo))
}

fn sim_config(args: &Args) -> anyhow::Result<SimConfig> {
    let model = ModelSpec::by_name(args.str_or("model", "olmoe"))
        .ok_or_else(|| anyhow::anyhow!("unknown model"))?;
    let topo = Topology::paper_testbed(
        args.usize_or("nodes", 2)?,
        args.usize_or("gpus", 2)?,
    );
    topo.validate().map_err(|e| anyhow::anyhow!(e))?;
    let workload = Workload {
        batch: args.usize_or("batch", 256)?,
        prefill: args.usize_or("prefill", 128)?,
        decode: args.usize_or("decode", 16)?,
    };
    let mut cfg = SimConfig::new(model, topo, workload);
    let ds = args.str_or("dataset", "text");
    cfg.serve_profile = Profile::from_name(ds)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{ds}'"))?;
    let pds = args.str_or("placement-dataset", ds).to_string();
    cfg.placement_profile = Profile::from_name(&pds)
        .ok_or_else(|| anyhow::anyhow!("unknown dataset '{pds}'"))?;
    cfg.seed = args.u64_or("seed", 42)?;
    let comm = args.str_or("comm", "analytic");
    cfg.comm_backend = CommBackendKind::from_name(comm)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown --comm '{comm}' (expected analytic|des)"))?;
    cfg.prefetch = prefetch_config(args, cfg.model.experts)?;
    Ok(cfg)
}

/// Parse the weight-tier knobs shared by every `SimConfig` consumer.
/// `--prefetch on` enables the predictive pre-stager; `--weight-budget`
/// alone enables the capacity-bounded hot tier with demand staging
/// only; neither leaves the tier off entirely (the bit-compatible
/// default). Degenerate values (`--weight-budget 0`, `--prefetch-k`
/// above the expert count, NaN alpha) are loud parse errors.
fn prefetch_config(args: &Args, experts: usize)
                   -> anyhow::Result<Option<PrefetchConfig>> {
    let predictive = on_off(args, "prefetch")?;
    if !predictive && args.get("weight-budget").is_none() {
        return Ok(None);
    }
    let d = PrefetchConfig::default();
    let pc = PrefetchConfig {
        predictive,
        k: args.usize_or("prefetch-k", d.k)?,
        weight_budget: args.usize_or("weight-budget", d.weight_budget)?,
        alpha: args.f64_or("prefetch-alpha", d.alpha)?,
    };
    pc.validate(experts)?;
    Ok(Some(pc))
}

/// Parse an `on|off` option (default off), rejecting anything else
/// loudly instead of silently treating a typo as off.
fn on_off(args: &Args, key: &str) -> anyhow::Result<bool> {
    match args.str_or(key, "off") {
        "on" => Ok(true),
        "off" => Ok(false),
        other => anyhow::bail!("unknown --{key} '{other}' \
                                (expected on|off)"),
    }
}

/// Parse the `--system` selector shared by simulate and fleet.
fn system_spec(args: &Args) -> anyhow::Result<SystemSpec> {
    let r = args.f64_or("r", 0.15)?;
    Ok(match args.str_or("system", "grace") {
        "grace" => SystemSpec::grace(r),
        "grace-la" => SystemSpec::grace_load_aware(r),
        "grace-dyn" => SystemSpec::grace_dyn(r),
        "occult" => SystemSpec::occult(),
        "vanilla" => SystemSpec::vanilla(),
        "tutel" => SystemSpec::tutel(),
        "megablocks" => SystemSpec::megablocks(),
        "vllm" => SystemSpec::vllm(),
        "c2r" => SystemSpec::c2r(),
        other => anyhow::bail!("unknown system '{other}'"),
    })
}

/// Contention diagnostics as a JSON object (the DES backend's extra
/// output, schema shared with `fleet --json`).
fn contention_json(c: &ContentionReport) -> Value {
    Value::object(vec![
        ("max_utilization", Value::num(c.max_utilization)),
        ("queue_depth_p50", Value::num(c.queue_depth_p50)),
        ("queue_depth_p95", Value::num(c.queue_depth_p95)),
        ("queue_depth_p99", Value::num(c.queue_depth_p99)),
        ("queue_depth_max", Value::from(c.queue_depth_max)),
        ("queued_wait_s", Value::num(c.queued_wait_s)),
        ("straggler_stall_s", Value::num(c.straggler_stall_s)),
        ("transfers", Value::from(c.transfers as usize)),
        ("events", Value::from(c.events as usize)),
        ("event_digest", Value::str(format!("{:016x}", c.event_digest))),
    ])
}

/// One-line human rendering of the contention diagnostics.
fn contention_line(c: &ContentionReport) -> String {
    format!(
        "des: max link util {:.1}% | queue depth p50/p95/p99 \
         {:.1}/{:.1}/{:.1} (max {}) | queued {:.3} ms | stall {:.3} ms \
         | {} transfers, {} events, digest {:016x}",
        c.max_utilization * 100.0,
        c.queue_depth_p50,
        c.queue_depth_p95,
        c.queue_depth_p99,
        c.queue_depth_max,
        c.queued_wait_s * 1e3,
        c.straggler_stall_s * 1e3,
        c.transfers,
        c.events,
        c.event_digest
    )
}

/// Weight-staging diagnostics as a JSON object (schema shared with the
/// `prefetch` object in `fleet --json` output).
fn prefetch_json(p: &PrefetchStats) -> Value {
    Value::object(vec![
        ("prefetches", Value::from(p.prefetches)),
        ("hits", Value::from(p.hits)),
        ("stalls", Value::from(p.stalls)),
        ("stall_steps", Value::from(p.stall_steps)),
        ("evictions", Value::from(p.evictions)),
        ("hit_rate", Value::num(p.hit_rate())),
        ("prefetch_bytes", Value::num(p.prefetch_bytes)),
        ("demand_bytes", Value::num(p.demand_bytes)),
        ("wasted_bytes", Value::num(p.wasted_bytes)),
    ])
}

/// One-line human rendering of the weight-staging diagnostics.
fn prefetch_line(p: &PrefetchStats) -> String {
    format!(
        "tier: {} prefetches | {} hits / {} stalls ({} stalled rounds, \
         {:.0}% hit rate) | {:.1} MB pre-staged, {:.1} MB demand, \
         {:.1} MB wasted | {} evictions",
        p.prefetches, p.hits, p.stalls, p.stall_steps,
        p.hit_rate() * 100.0, p.prefetch_bytes / 1e6,
        p.demand_bytes / 1e6, p.wasted_bytes / 1e6, p.evictions
    )
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let mut cfg = sim_config(args)?;
    let sys = system_spec(args)?;
    if sys.online_replan {
        // Two phases per run ⇒ default to an epoch per dispatch round.
        cfg.replan = Some(replan_config(args, 1)?);
    }
    let placement = build_placement(&sys, &cfg);
    let (m, contention) =
        simulate_with_contention(&sys, &cfg, &placement);
    if args.flag("json") {
        let mut v = report::metrics_json(sys.name, &m);
        if let Value::Object(map) = &mut v {
            if let Some(c) = &contention {
                map.insert("contention".to_string(), contention_json(c));
            }
            if cfg.prefetch.is_some() {
                map.insert("prefetch".to_string(),
                           prefetch_json(&m.prefetch));
            }
        }
        println!("{}", grace_moe::configio::to_string_pretty(&v));
    } else {
        let pf = m.prefetch.clone();
        println!("{}", report::e2e_table(&[sys.name], &[m]).render());
        if let Some(c) = &contention {
            println!("{}", contention_line(c));
        }
        if cfg.prefetch.is_some() {
            println!("{}", prefetch_line(&pf));
        }
    }
    Ok(())
}

fn cmd_fleet(args: &Args) -> anyhow::Result<()> {
    let sim = sim_config(args)?;
    let sys = system_spec(args)?;
    let rate = args.f64_or("arrival-rate", 256.0)?;
    anyhow::ensure!(rate.is_finite() && rate > 0.0,
                    "--arrival-rate must be finite and positive, \
                     got {rate}");
    let load = ServeLoad {
        requests: args.usize_or("requests", 512)?,
        prompt: args.usize_or("prompt", 64)?,
        new_tokens: args.usize_or("new-tokens", 16)?,
        arrival: ArrivalProcess::Poisson { rate },
    };
    let mut fc = FleetConfig::new(sys, sim, load);
    fc.max_batch = args.usize_or("max-batch", 32)?;
    fc.max_batch_tokens = args.usize_or("max-batch-tokens", 1024)?;
    let (classes, preempt, slo) = priority_opts(args)?;
    fc.priority_classes = classes;
    fc.preempt = preempt;
    fc.ttft_slo = slo;
    fc.shard.replicas = args.usize_or("replicas", 1)?;
    fc.shard.route =
        FleetRoutePolicy::from_name(args.str_or("fleet-route", "jsq"))?;
    if args.get("queue-cap").is_some() {
        fc.shard.queue_cap = args.usize_or("queue-cap", 64)?;
    }
    fc.class_shift = on_off(args, "class-shift")?;
    fc.replica_profiles = on_off(args, "replica-profiles")?;
    // Shapes that would shed everything or serve nothing fail here,
    // before the replay consumes a single request.
    fc.shard.validate()?;
    if fc.sys.online_replan {
        fc.sim.replan = Some(replan_config(args, 64)?);
    }
    eprintln!("fleet: {} on {} ({} backend, {} replica(s), {} route)…",
              fc.load.label(), fc.sys.name, fc.sim.comm_backend.name(),
              fc.shard.replicas, fc.shard.route.name());
    let rep = replay_fleet(&fc)?;
    if args.flag("json") {
        println!("{}",
                 grace_moe::configio::to_string_pretty(&rep.to_value()));
        return Ok(());
    }
    let s = &rep.serve;
    if let Some(l) = s.latency_summary() {
        println!("latency   mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
                 l.mean() * 1e3, l.p50() * 1e3, l.p99() * 1e3);
    }
    if let Some(t) = s.ttft_summary() {
        println!("ttft      mean {:.2} ms  p99 {:.2} ms",
                 t.mean() * 1e3, t.p99() * 1e3);
    }
    if classes > 1 {
        for c in s.priority_classes() {
            if let Some(t) = s.ttft_summary_class(c) {
                println!("ttft[{c}]   mean {:.2} ms  p95 {:.2} ms  \
                          p99 {:.2} ms",
                         t.mean() * 1e3, t.p95() * 1e3, t.p99() * 1e3);
            }
        }
    }
    if s.preemptions > 0 || s.resumes > 0 || !s.rejected.is_empty() {
        println!("sched     {} preemptions | {} resumes | {} rejected",
                 s.preemptions, s.resumes, s.rejected.len());
    }
    if let Some(q) = s.queue_wait_summary() {
        println!("queue     mean {:.2} ms  p95 {:.2} ms",
                 q.mean() * 1e3, q.p95() * 1e3);
    }
    println!(
        "virtual   {:.3} s for {} requests | {:.1} tok/s | {} steps, \
         {} rounds",
        s.wall_time, s.latencies.len(), s.throughput_tps(), s.steps,
        s.dispatch_rounds
    );
    println!(
        "comm      {:.3} s a2a | {:.1} MB cross | {:.1} MB intra | \
         {} launches | {} replans ({:.1} MB migrated)",
        rep.comm.time, rep.comm.cross_bytes / 1e6,
        rep.comm.intra_bytes / 1e6, rep.comm.launches, rep.replans,
        rep.migration_bytes / 1e6
    );
    if rep.replicas > 1 {
        let per: Vec<String> = rep
            .per_replica
            .iter()
            .map(|m| format!("{}req/{}step", m.latencies.len(), m.steps))
            .collect();
        println!(
            "fleet     {} replicas [{}] | imbalance {:.2} | {} rolling \
             swaps",
            rep.replicas, per.join(" "), rep.fleet_imbalance(),
            rep.swaps
        );
    }
    if let Some(c) = &rep.contention {
        println!("{}", contention_line(c));
    }
    if let Some(p) = &rep.prefetch {
        println!("{}", prefetch_line(p));
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> anyhow::Result<()> {
    let cfg = sim_config(args)?;
    let r = args.f64_or("r", 0.15)?;
    let systems = SystemSpec::fig4_systems(r);
    let names: Vec<&str> = systems.iter().map(|s| s.name).collect();
    let runs: Vec<_> =
        systems.iter().map(|s| simulate(s, &cfg)).collect();
    if args.flag("json") {
        let named: Vec<(&str, &grace_moe::metrics::RunMetrics)> =
            names.iter().copied().zip(runs.iter()).collect();
        println!(
            "{}",
            grace_moe::configio::to_string_pretty(&report::runs_json(
                &named
            ))
        );
    } else {
        println!(
            "model={} cluster={}x{} workload={}",
            cfg.model.name,
            cfg.topo.nodes,
            cfg.topo.gpus_per_node,
            cfg.workload.label()
        );
        println!("{}", report::e2e_table(&names, &runs).render());
    }
    Ok(())
}

fn cmd_components(args: &Args) -> anyhow::Result<()> {
    let cfg = sim_config(args)?;
    let r = args.f64_or("r", 0.15)?;
    let ladder = SystemSpec::table1_ladder(r);
    let names: Vec<&str> = ladder.iter().map(|s| s.name).collect();
    let runs: Vec<_> =
        ladder.iter().map(|s| simulate(s, &cfg)).collect();
    println!("{}", report::table1(&names, &runs).render());
    println!("{}", report::e2e_table(&names, &runs).render());
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_or("artifacts", "artifacts");
    let variant = args.str_or("variant", "olmoe_tiny");
    let policy = match args.str_or("policy", "tar") {
        "primary" => RoutingPolicy::Primary,
        "wrr" => RoutingPolicy::Wrr,
        "tar" => RoutingPolicy::Tar,
        "load-aware" | "la" => RoutingPolicy::LoadAware,
        other => anyhow::bail!("unknown policy '{other}'"),
    };
    let topo = Topology::paper_testbed(
        args.usize_or("nodes", 2)?,
        args.usize_or("gpus", 2)?,
    );
    let n_requests = args.usize_or("requests", 4)?;
    let prompt_len = args.usize_or("prompt", 24)?;
    let new_tokens = args.usize_or("new-tokens", 8)?;
    let seed = args.u64_or("seed", 42)?;
    let arrival_rate = args.f64_or("arrival-rate", 0.0)?;
    if args.get("arrival-rate").is_some() {
        // Explicitly-passed rates must be usable; a silent fall-back to
        // the closed loop would misreport every latency metric.
        anyhow::ensure!(arrival_rate.is_finite() && arrival_rate > 0.0,
                        "--arrival-rate must be finite and positive, \
                         got {arrival_rate}; omit it for the closed \
                         loop");
    }
    let sched = match args.str_or("sched", "continuous") {
        "continuous" => grace_moe::server::SchedMode::Continuous,
        "static" => grace_moe::server::SchedMode::StaticDrain,
        other => anyhow::bail!("unknown scheduler '{other}'"),
    };
    let kv_cache = match args.str_or("kv-cache", "on") {
        "on" => true,
        "off" => false,
        other => anyhow::bail!("unknown --kv-cache '{other}' \
                                (expected on|off)"),
    };
    let (classes, preempt, ttft_slo) = priority_opts(args)?;
    let load = grace_moe::config::ServeLoad {
        requests: n_requests,
        prompt: prompt_len,
        new_tokens,
        arrival: if arrival_rate > 0.0 {
            grace_moe::config::ArrivalProcess::Poisson {
                rate: arrival_rate,
            }
        } else {
            grace_moe::config::ArrivalProcess::Closed
        },
    };
    load.validate()?;

    eprintln!("loading {variant} from {dir}…");
    let model = Arc::new(RealModel::load(dir, variant)?);
    eprintln!("profiling real gate…");
    let trace = profile_real(&model, 2, seed)?;
    // One L3 coordinator owns the whole pipeline: its offline phase turns
    // the real-gate trace into a placement, its online phase routes.
    let coord = Coordinator::new(
        GroupingStrategy::Hierarchical { r: args.f64_or("r", 0.15)? },
        ReplicationMode::Dynamic,
        policy,
        topo,
        seed,
    );
    let placement = Arc::new(coord.place(&trace));
    // Epoch re-planning rides along only when a cadence was asked for.
    let replan = if args.get("replan-epoch").is_some() {
        Some(replan_config(args, 64)?)
    } else {
        None
    };
    let mut server = MoEServer::with_coordinator(
        model,
        placement,
        coord,
        ServerConfig {
            max_batch: args.usize_or("max-batch", 8)?,
            max_batch_tokens: args.usize_or("max-batch-tokens", 256)?,
            sched,
            kv_cache,
            queue_cap: 64,
            seed,
            ffn_mode: if args.str_or("ffn", "per-expert") == "pallas" {
                grace_moe::engine::real::FfnMode::GroupedPallas
            } else {
                grace_moe::engine::real::FfnMode::PerExpert
            },
            replan,
            preempt,
            retain_cache_tokens: usize::MAX,
            ttft_slo,
        },
    );
    let mut rng = Rng::new(seed);
    let requests: Vec<Request> = (0..n_requests)
        .map(|i| Request {
            id: i as u64,
            prompt: (0..prompt_len)
                .map(|_| rng.index(server.model.cfg.vocab) as i32)
                .collect(),
            max_new_tokens: new_tokens,
            priority: i % classes,
        })
        .collect();
    eprintln!("serving {} (policy={}, sched={:?}, kv-cache={})…",
              load.label(), policy.name(), sched,
              if kv_cache { "on" } else { "off" });
    let (responses, metrics) = match load.arrival {
        grace_moe::config::ArrivalProcess::Closed => {
            server.serve(requests)?
        }
        grace_moe::config::ArrivalProcess::Poisson { .. } => {
            let times = load.arrival_times(&mut rng);
            server.serve_open_loop(
                requests.into_iter().zip(times).collect(),
            )?
        }
    };
    for r in &responses {
        println!(
            "request {}: {} tokens in {:.1} ms — {:?}",
            r.id,
            r.tokens.len(),
            r.latency * 1e3,
            r.tokens
        );
    }
    if let Some(s) = metrics.latency_summary() {
        println!(
            "latency   mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms  \
             p99 {:.1} ms",
            s.mean() * 1e3,
            s.p50() * 1e3,
            s.p95() * 1e3,
            s.p99() * 1e3
        );
    }
    if let Some(s) = metrics.ttft_summary() {
        println!(
            "ttft      mean {:.1} ms  p50 {:.1} ms  p95 {:.1} ms  \
             p99 {:.1} ms",
            s.mean() * 1e3,
            s.p50() * 1e3,
            s.p95() * 1e3,
            s.p99() * 1e3
        );
    }
    if let Some(s) = metrics.tpot_summary() {
        println!(
            "tpot      mean {:.2} ms  p50 {:.2} ms  p99 {:.2} ms",
            s.mean() * 1e3,
            s.p50() * 1e3,
            s.p99() * 1e3
        );
    }
    if classes > 1 {
        for c in metrics.priority_classes() {
            if let Some(s) = metrics.ttft_summary_class(c) {
                println!("ttft[{c}]   mean {:.1} ms  p95 {:.1} ms",
                         s.mean() * 1e3, s.p95() * 1e3);
            }
        }
    }
    if metrics.preemptions > 0 || metrics.resumes > 0
        || !metrics.rejected.is_empty()
    {
        println!(
            "sched     {} preemptions | {} resumes | {} rejected {:?}",
            metrics.preemptions, metrics.resumes,
            metrics.rejected.len(), metrics.rejected
        );
    }
    if let Some(s) = metrics.queue_wait_summary() {
        println!("queue     mean {:.1} ms  p95 {:.1} ms",
                 s.mean() * 1e3, s.p95() * 1e3);
    }
    println!(
        "throughput {:.1} tok/s | {} steps, {} dispatch rounds \
         ({:.2} rounds/token)",
        metrics.throughput_tps(),
        metrics.steps,
        metrics.dispatch_rounds,
        metrics.rounds_per_token()
    );
    println!(
        "kv cache  {} computed, {} cached ({:.0}% hit rate)",
        metrics.computed_tokens,
        metrics.cached_tokens,
        metrics.cache_hit_rate() * 100.0
    );
    Ok(())
}

fn cmd_placement(args: &Args) -> anyhow::Result<()> {
    let cfg = sim_config(args)?;
    let sys = SystemSpec::grace(args.f64_or("r", 0.15)?);
    let p = grace_moe::engine::sim::build_placement(&sys, &cfg);
    println!(
        "model={} experts={} gpus={} layers={}",
        cfg.model.name,
        p.experts,
        p.num_gpus,
        p.layers.len()
    );
    for (l, lp) in p.layers.iter().enumerate().take(4) {
        println!("layer {l}:");
        for (g, group) in lp.groups.iter().enumerate() {
            println!(
                "  gpu {g}: {} experts, load {:.0}, polling {:.3}",
                group.len(),
                lp.pre_loads[g],
                lp.polling[g]
            );
        }
        println!(
            "  replication: {} hot experts → gpus {:?} (ρ-driven n={})",
            lp.replication.hot_experts.len(),
            lp.replication.replica_gpus,
            lp.replication.n_replica
        );
    }
    println!(
        "replication overhead: {:.2}% extra instances",
        p.replication_overhead() * 100.0
    );
    Ok(())
}

fn cmd_replan(args: &Args) -> anyhow::Result<()> {
    let cfg = sim_config(args)?;
    let r = args.f64_or("r", 0.15)?;
    let rounds_n = args.usize_or("rounds", 12)?;
    anyhow::ensure!(rounds_n > 0,
                    "--rounds must be at least 1 (a zero-length trace \
                     replays nothing)");
    let drift_at = args.usize_or("drift-at", rounds_n / 3)?;
    let tokens = args
        .usize_or("round-tokens", 2048)?
        .min(cfg.max_chunk)
        .max(1);
    // simulate_rounds takes the replan cadence explicitly (SimConfig::replan
    // only drives the two-phase simulate path).
    let rc = replan_config(args, 2)?;

    let static_sys = SystemSpec::grace(r);
    let dyn_sys = SystemSpec::grace_dyn(r);
    let placement = build_placement(&static_sys, &cfg);
    let shift = cfg.model.experts / 2;
    let rounds = drifting_rounds(&cfg, rounds_n, drift_at, shift, tokens);
    eprintln!(
        "replaying {rounds_n} rounds × {tokens} tokens, hot-expert set \
         rotates by {shift} at round {drift_at} \
         (epoch {} rounds, threshold {})",
        rc.epoch_rounds, rc.min_drift
    );

    let (ms, rs) =
        simulate_rounds(&static_sys, &cfg, &placement, &rounds, None);
    let (md, rd) = simulate_rounds(&dyn_sys, &cfg, &placement, &rounds,
                                   Some(rc));

    let mut t = grace_moe::bench::Table::new(&[
        "SYSTEM",
        "E2E (ms)",
        "A2A (ms)",
        "MAX SHARE (post-drift)",
        "MIGRATION (MB)",
        "REPLANS",
    ]);
    for (name, m, rep) in
        [("grace (static)", &ms, &rs), ("grace-dyn", &md, &rd)]
    {
        t.row(vec![
            name.to_string(),
            format!("{:.2}", m.e2e_time * 1e3),
            format!("{:.2}", m.a2a_time * 1e3),
            format!("{:.3}", rep.max_load_share(drift_at)),
            format!("{:.1}", m.migration_bytes / 1e6),
            format!("{}", m.replans),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}
