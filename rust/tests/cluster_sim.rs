//! Integration tests for the contention-aware cluster simulator: the
//! PR-7 acceptance invariants pinned from outside the crate.
//!
//! * **agreement** — uncontended DES collectives reproduce the analytic
//!   closed forms, per collective and end-to-end through the serialized
//!   engine (`simulate_with_contention` on the `des` backend);
//! * **determinism** — same seed ⇒ bit-identical event log, digest, and
//!   contention report (the `des-smoke` CI gate in miniature);
//! * **conservation** — bytes entering each link equal bytes leaving it,
//!   including external request ingest;
//! * **fleet** — open-loop replay serves every request on both backends,
//!   the DES arm never beats the uncontended closed form, and a
//!   saturating burst strictly exceeds it;
//! * **validation** — degenerate loads and configs fail loudly at
//!   construction, not as NaNs mid-replay.

use grace_moe::baselines::SystemSpec;
use grace_moe::cluster::Topology;
use grace_moe::comm::model;
use grace_moe::comm::sim as des;
use grace_moe::comm::traffic::{per_copy, two_stage, Dispatch};
use grace_moe::comm::{CommBackend, CommBackendKind, NetworkSim};
use grace_moe::config::{ArrivalProcess, ModelSpec, ServeLoad, Workload};
use grace_moe::engine::sim::{build_placement, simulate_with_contention,
                             SimConfig};
use grace_moe::engine::{replay_fleet, FleetConfig};
use grace_moe::replan::ReplanConfig;
use grace_moe::stats::Rng;

fn close(a: f64, b: f64, rel: f64) -> bool {
    (a - b).abs() <= rel * a.abs().max(b.abs()).max(1.0)
}

/// Cross-node-heavy dispatch set: every token fans out to both GPUs of
/// the other node.
fn cross_heavy(n_tokens: usize, num_gpus: usize) -> Vec<Dispatch> {
    let half = num_gpus / 2;
    (0..n_tokens)
        .map(|i| Dispatch {
            src: i % half,
            dsts: (half..num_gpus).collect(),
        })
        .collect()
}

fn small_sim(backend: CommBackendKind) -> SimConfig {
    let model = ModelSpec { moe_layers: 2, ..ModelSpec::olmoe() };
    let mut sim = SimConfig::new(
        model,
        Topology::two_by_two(),
        Workload { batch: 8, prefill: 8, decode: 2 },
    );
    sim.profile_tokens = 256;
    sim.max_chunk = 256;
    sim.comm_backend = backend;
    sim
}

fn fleet_cfg(backend: CommBackendKind, rate: f64) -> FleetConfig {
    let load = ServeLoad {
        requests: 10,
        prompt: 6,
        new_tokens: 2,
        arrival: ArrivalProcess::Poisson { rate },
    };
    let mut cfg = FleetConfig::new(SystemSpec::grace(0.15),
                                   small_sim(backend), load);
    cfg.max_batch = 4;
    cfg.max_batch_tokens = 48;
    cfg
}

// --- agreement --------------------------------------------------------------

#[test]
fn uncontended_collectives_reproduce_analytic_times() {
    let t = Topology::paper_testbed(2, 4);
    let disp = cross_heavy(300, t.num_gpus());
    let flat_m = per_copy(&disp, t.num_gpus(), 2048.0);
    let ts = two_stage(&disp, &t, 2048.0);
    for seed in 0..4 {
        let a = model::flat_all_to_all(&flat_m, &t, &mut Rng::new(seed));
        let mut net = NetworkSim::new(&t);
        let d = des::flat_all_to_all(&mut net, &flat_m, &t, 0.0,
                                     &mut Rng::new(seed));
        assert!(close(a.time, d.time, 1e-9),
                "flat seed {seed}: analytic {} vs DES {}", a.time, d.time);

        let a = model::staged_hierarchical(&ts, &t, &mut Rng::new(seed));
        let mut net = NetworkSim::new(&t);
        let d = des::staged_hierarchical(&mut net, &ts, &t, 0.0,
                                         &mut Rng::new(seed));
        assert!(close(a.time, d.time, 1e-9),
                "staged seed {seed}: analytic {} vs DES {}",
                a.time, d.time);

        let a = model::hsc(&ts, &t, 1e-5, &mut Rng::new(seed));
        let mut net = NetworkSim::new(&t);
        let d = des::hsc(&mut net, &ts, &t, 1e-5, 0.0,
                         &mut Rng::new(seed));
        assert!(close(a.time, d.time, 1e-9),
                "hsc seed {seed}: analytic {} vs DES {}", a.time, d.time);
    }
}

#[test]
fn serialized_engine_on_des_backend_matches_analytic_end_to_end() {
    // The round-based engine submits every collective at the DES cursor
    // (back-to-back rounds), so the contended network never actually
    // queues and the whole run must reproduce the analytic metrics.
    for sys in [SystemSpec::vanilla(), SystemSpec::grace(0.15)] {
        let ana = small_sim(CommBackendKind::Analytic);
        let placement = build_placement(&sys, &ana);
        let (ma, ca) = simulate_with_contention(&sys, &ana, &placement);
        let des_cfg = small_sim(CommBackendKind::Des);
        let (md, cd) = simulate_with_contention(&sys, &des_cfg,
                                                &placement);
        assert!(ca.is_none(), "analytic backend reports no contention");
        assert!(close(ma.a2a_time, md.a2a_time, 1e-6),
                "{}: a2a {} vs {}", sys.name, ma.a2a_time, md.a2a_time);
        assert!(close(ma.e2e_time, md.e2e_time, 1e-6),
                "{}: e2e {} vs {}", sys.name, ma.e2e_time, md.e2e_time);
        assert_eq!(ma.launches, md.launches);
        let c = cd.expect("DES backend reports contention");
        assert!(c.transfers > 0);
        assert!(c.max_utilization > 0.0 && c.max_utilization <= 1.0 + 1e-9,
                "utilization {}", c.max_utilization);
    }
}

// --- determinism ------------------------------------------------------------

#[test]
fn same_seed_produces_identical_event_log_and_digest() {
    let t = Topology::two_by_two();
    let disp = cross_heavy(120, 4);
    let m = per_copy(&disp, 4, 1024.0);
    let run = || {
        let mut b = CommBackend::new(CommBackendKind::Des, &t);
        b.net_mut().unwrap().enable_log();
        let mut rng = Rng::new(11);
        // Overlapping submissions: two rounds at the same instant plus
        // an ingest DMA landing mid-flight, so contention is real.
        b.flat_round_at(&m, &t, 0.0, &mut rng);
        b.flat_round_at(&m, &t, 0.0, &mut rng);
        b.ingest(2, 8192.0, 1e-6);
        let rep = b.contention().unwrap();
        let log = b.net_mut().unwrap().log().unwrap().to_vec();
        (rep, log)
    };
    let (ra, la) = run();
    let (rb, lb) = run();
    assert_eq!(ra, rb, "contention reports diverge across reruns");
    assert_eq!(la, lb, "event logs diverge across reruns");
    assert!(!la.is_empty());
    assert!(ra.queued_wait_s > 0.0,
            "overlapping rounds must actually queue");
}

#[test]
fn replanning_fleet_on_the_contended_network_is_deterministic() {
    let mut cfg = fleet_cfg(CommBackendKind::Des, 5e4);
    cfg.sys = SystemSpec::grace_dyn(0.15);
    cfg.sim.replan = Some(ReplanConfig {
        epoch_rounds: 2,
        min_drift: 0.05,
        ..ReplanConfig::default()
    });
    let a = replay_fleet(&cfg).unwrap();
    let b = replay_fleet(&cfg).unwrap();
    assert_eq!(a.serve.latencies.len(), 10);
    assert_eq!(a.to_value(), b.to_value(),
               "fleet replay with replanning diverges across reruns");
}

// --- conservation -----------------------------------------------------------

#[test]
fn bytes_entering_each_link_equal_bytes_leaving() {
    let t = Topology::two_by_two();
    let disp = cross_heavy(250, 4);
    let m = per_copy(&disp, 4, 1024.0);
    let mut net = NetworkSim::new(&t);
    net.replay_stage(&m, 0.0);
    let ingest_bytes = 4096.0;
    net.ingest(3, ingest_bytes, 0.0);
    for g in 0..4 {
        assert_eq!(net.egress_bytes(g), m.egress(g),
                   "egress bytes of GPU {g}");
        let extra = if g == 3 { ingest_bytes } else { 0.0 };
        assert_eq!(net.ingress_bytes(g), m.ingress(g) + extra,
                   "ingress bytes of GPU {g}");
    }
    let out: f64 = (0..2).map(|n| net.nic_out_bytes(n)).sum();
    let inn: f64 = (0..2).map(|n| net.nic_in_bytes(n)).sum();
    assert_eq!(out, m.cross_node_bytes(&t));
    assert_eq!(inn, m.cross_node_bytes(&t) + ingest_bytes,
               "NIC-in must carry the cross traffic plus the ingest DMA");
}

// --- fleet ------------------------------------------------------------------

#[test]
fn des_fleet_never_beats_analytic_and_saturation_strictly_exceeds_it() {
    for (rate, must_exceed) in [(3.0, false), (5e4, true)] {
        let ana = replay_fleet(&fleet_cfg(CommBackendKind::Analytic,
                                          rate))
            .unwrap();
        let d = replay_fleet(&fleet_cfg(CommBackendKind::Des, rate))
            .unwrap();
        assert_eq!(ana.serve.latencies.len(), 10);
        assert_eq!(d.serve.latencies.len(), 10);
        let la = ana.serve.latency_summary().unwrap().mean();
        let ld = d.serve.latency_summary().unwrap().mean();
        assert!(ld >= la - 1e-12,
                "rate {rate}: DES mean {ld} beats analytic {la}");
        if must_exceed {
            assert!(ld > la,
                    "saturating burst shows no contention: DES {ld} vs \
                     analytic {la}");
            let c = d.contention.expect("DES contention report");
            assert!(c.queued_wait_s > 0.0,
                    "burst arm recorded no link queueing");
        }
    }
}

// --- validation -------------------------------------------------------------

#[test]
fn degenerate_configs_fail_loudly_before_replaying() {
    let ok = fleet_cfg(CommBackendKind::Des, 100.0);

    let mut bad = ok.clone();
    bad.load.requests = 0;
    assert!(replay_fleet(&bad).is_err(), "zero requests must error");

    let mut bad = ok.clone();
    bad.load.arrival = ArrivalProcess::Poisson { rate: 0.0 };
    assert!(replay_fleet(&bad).is_err(), "zero rate must error");

    let mut bad = ok.clone();
    bad.load.arrival = ArrivalProcess::Poisson { rate: f64::NAN };
    assert!(replay_fleet(&bad).is_err(), "NaN rate must error");

    let mut bad = ok.clone();
    bad.max_batch = 0;
    assert!(replay_fleet(&bad).is_err(), "zero max_batch must error");

    let bad_replan = ReplanConfig { epoch_rounds: 0,
                                    ..ReplanConfig::default() };
    assert!(bad_replan.validate().is_err(),
            "zero-round replan epoch must error");

    assert_eq!(CommBackendKind::from_name("bogus"), None);
    assert_eq!(CommBackendKind::from_name("des"),
               Some(CommBackendKind::Des));
}
