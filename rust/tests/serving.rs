//! Continuous-batching serving tests — engine-free.
//!
//! These pin the scheduler's acceptance bar without PJRT artifacts by
//! driving [`grace_moe::server::sched::simulate_serve`] with a
//! deterministic fake decode engine (next token = hash of the prefix,
//! so outputs depend only on the sequence — the same independence the
//! real greedy decoder has):
//!
//! * **determinism parity** — with a fixed seed the continuous scheduler
//!   produces token-for-token the same responses as the static-drain
//!   discipline on a closed-loop workload;
//! * **mid-flight admission** — a request arriving while a long request
//!   is in flight gets its first token strictly earlier (in time and in
//!   steps) than under the drain barrier;
//! * **open-loop Poisson serving** — the arrival generator drives the
//!   scheduler deterministically, queue-wait and TTFT populate, and the
//!   virtual clock respects the schedule.

use grace_moe::config::{ArrivalProcess, ServeLoad};
use grace_moe::server::sched::{simulate_serve, SchedConfig, SchedMode};
use grace_moe::server::Request;
use grace_moe::stats::Rng;
use grace_moe::testutil::fake_decode_token as fake_next;

const CTX: usize = 64;
const LAYERS: usize = 2;
const TILE_T: usize = 16;

fn cfg(mode: SchedMode, max_batch: usize, budget: usize) -> SchedConfig {
    SchedConfig { mode, max_batch, max_batch_tokens: budget, ctx: CTX }
}

/// Fake batched engine: per-step dispatch rounds follow the shared-tile
/// packing rule of the real batched forward
/// (`layers × ⌈step tokens / tile_t⌉`).
fn fake_step(seqs: &[(u64, &[i32])]) -> anyhow::Result<(Vec<i32>, usize)> {
    let tokens: usize = seqs.iter().map(|(_, ids)| ids.len()).sum();
    let rounds = LAYERS * tokens.div_ceil(TILE_T);
    Ok((seqs.iter().map(|(_, ids)| fake_next(ids)).collect(), rounds))
}

fn req(id: u64, prompt: usize, new_tokens: usize) -> Request {
    Request {
        id,
        prompt: (0..prompt)
            .map(|i| ((id as usize * 131 + i * 17) % 512) as i32)
            .collect(),
        max_new_tokens: new_tokens,
    }
}

#[test]
fn continuous_matches_static_drain_token_for_token() {
    // Closed loop: six requests of varying shape, both disciplines.
    let arrivals = |_: ()| -> Vec<(Request, f64)> {
        (0..6).map(|id| (req(id, 4 + id as usize, 5), 0.0)).collect()
    };
    let run = |mode| {
        simulate_serve(cfg(mode, 3, 64), arrivals(()), fake_step,
                       |_, _| 1.0)
            .unwrap()
    };
    let (r_static, m_static) = run(SchedMode::StaticDrain);
    let (r_cont, m_cont) = run(SchedMode::Continuous);
    assert_eq!(r_static.len(), 6);
    assert_eq!(r_cont.len(), 6);
    for (a, b) in r_static.iter().zip(&r_cont) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.tokens, b.tokens,
                   "request {}: scheduling changed decoded tokens", a.id);
        assert_eq!(a.tokens.len(), 5);
    }
    assert_eq!(m_static.generated_tokens, m_cont.generated_tokens);
    // Continuous refills the batch as requests retire, so it never runs
    // more steps than the drain barrier does.
    assert!(m_cont.steps <= m_static.steps,
            "continuous {} steps !<= static {}", m_cont.steps,
            m_static.steps);
}

#[test]
fn mid_flight_admission_beats_the_drain_barrier_on_ttft() {
    // One long request in flight; a short one arrives mid-generation.
    let arrivals = vec![(req(0, 8, 40), 0.0), (req(1, 8, 4), 0.5)];
    let run = |mode| {
        simulate_serve(cfg(mode, 4, 256), arrivals.clone(), fake_step,
                       |_, _| 1.0)
            .unwrap()
    };
    let (_, m_static) = run(SchedMode::StaticDrain);
    let (_, m_cont) = run(SchedMode::Continuous);
    let late = |m: &grace_moe::metrics::ServeMetrics| {
        m.per_request.iter().find(|t| t.id == 1).copied().unwrap()
    };
    let (s, c) = (late(&m_static), late(&m_cont));
    // Static drain: request 1 waits behind the whole 40-token drain.
    assert!(s.queue_wait > 30.0, "drain barrier wait: {}", s.queue_wait);
    // Continuous: admitted at the next step boundary.
    assert!(c.queue_wait < 2.0, "mid-flight wait: {}", c.queue_wait);
    assert!(
        c.ttft < s.ttft,
        "continuous TTFT {} !< drain-barrier TTFT {}", c.ttft, s.ttft
    );
    assert!(c.first_token_step < s.first_token_step);
    // The long request completes in both runs.
    assert!(late(&m_static).latency > 0.0);
    assert!(late(&m_cont).latency > 0.0);
}

#[test]
fn open_loop_poisson_is_deterministic_and_complete() {
    let load = ServeLoad {
        requests: 24,
        prompt: 6,
        new_tokens: 4,
        arrival: ArrivalProcess::Poisson { rate: 2.0 },
    };
    let run = || {
        let mut rng = Rng::new(11);
        let times = load.arrival_times(&mut rng);
        let arrivals: Vec<(Request, f64)> = (0..load.requests)
            .map(|i| (req(i as u64, load.prompt, load.new_tokens),
                      times[i]))
            .collect();
        let last_arrival = *times.last().unwrap();
        let (responses, metrics) = simulate_serve(
            cfg(SchedMode::Continuous, 4, 48),
            arrivals,
            fake_step,
            |tokens, _| tokens as f64 * 2e-3,
        )
        .unwrap();
        (responses, metrics, last_arrival)
    };
    let (responses, metrics, last_arrival) = run();
    assert_eq!(responses.len(), 24);
    for r in &responses {
        assert_eq!(r.tokens.len(), 4);
    }
    assert_eq!(metrics.generated_tokens, 24 * 4);
    assert_eq!(metrics.ttft.len(), 24);
    assert_eq!(metrics.queue_wait.len(), 24);
    assert!(metrics.queue_wait.iter().all(|&w| w >= 0.0));
    // The virtual clock cannot finish before the last arrival.
    assert!(metrics.wall_time >= last_arrival,
            "wall {} < last arrival {last_arrival}", metrics.wall_time);
    // Deterministic end to end.
    let (r2, m2, _) = run();
    let tok = |rs: &[grace_moe::server::Response]| {
        rs.iter().map(|r| r.tokens.clone()).collect::<Vec<_>>()
    };
    assert_eq!(tok(&responses), tok(&r2));
    assert_eq!(metrics.ttft, m2.ttft);
    assert_eq!(metrics.steps, m2.steps);
    assert_eq!(metrics.dispatch_rounds, m2.dispatch_rounds);
}

#[test]
fn batched_step_rounds_undercut_the_per_sequence_path() {
    // The dispatch-density claim at the scheduler level: a microbatch of
    // short sequences costs ⌈Σ len / tile_t⌉ rounds per layer batched,
    // vs Σ ⌈len / tile_t⌉ when each sequence runs its own forward (the
    // seed server). Count both on the same schedule.
    let arrivals: Vec<(Request, f64)> =
        (0..6).map(|id| (req(id, 5, 6), 0.0)).collect();
    let mut batched = 0usize;
    let mut per_seq = 0usize;
    let (_, metrics) = simulate_serve(
        cfg(SchedMode::Continuous, 6, 256),
        arrivals,
        |seqs| {
            let (next, rounds) = fake_step(seqs)?;
            batched += rounds;
            per_seq += seqs
                .iter()
                .map(|(_, ids)| LAYERS * ids.len().div_ceil(TILE_T))
                .sum::<usize>();
            Ok((next, rounds))
        },
        |_, _| 1.0,
    )
    .unwrap();
    assert_eq!(metrics.dispatch_rounds, batched);
    assert!(
        batched < per_seq,
        "shared tiles must cut dispatch rounds: {batched} !< {per_seq}"
    );
    assert!(metrics.rounds_per_token() > 0.0);
}

#[test]
fn queue_wait_reflects_budget_pressure() {
    // With a tight budget, later requests measurably queue; with a loose
    // one they do not.
    let arrivals = |_: ()| -> Vec<(Request, f64)> {
        (0..8).map(|id| (req(id, 8, 8), 0.0)).collect()
    };
    let run = |budget| {
        simulate_serve(cfg(SchedMode::Continuous, 8, budget),
                       arrivals(()), fake_step, |_, _| 1.0)
            .unwrap()
            .1
    };
    let tight = run(16);
    let loose = run(4096);
    let p95 = |m: &grace_moe::metrics::ServeMetrics| {
        m.queue_wait_summary().unwrap().p95()
    };
    assert!(p95(&tight) > p95(&loose),
            "tight {} !> loose {}", p95(&tight), p95(&loose));
    assert_eq!(loose.queue_wait.iter().filter(|&&w| w > 0.0).count(), 0,
               "loose budget admits everyone at t=0");
}
